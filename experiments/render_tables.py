"""Render the final §Roofline table into EXPERIMENTS.md."""
import os
import re
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.roofline import render, table  # noqa: E402

ART = os.path.join(os.path.dirname(__file__), "artifacts")
MD = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS.md")


def main():
    single = render(table(ART, "single"))
    multi_rows = table(ART, "multi")
    ok = sum(1 for r in multi_rows if not r.skipped)
    sk = sum(1 for r in multi_rows if r.skipped)
    block = (single + "\n\n"
             f"multi-pod (2x16x16): {ok} cells compiled + {sk} spec'd "
             "skips — per-cell artifacts in experiments/artifacts/"
             "*__multi.json\n")
    md = open(MD).read()
    md = re.sub(
        r"\(table inserted by experiments/render_tables\.py — see below\)",
        block, md, count=1)
    open(MD, "w").write(md)
    print(single)


if __name__ == "__main__":
    main()
