"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU, output shapes + no NaNs (spec deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, all_cells, cell_applicable
from repro.models import build_model

KEY = jax.random.PRNGKey(0)
ARCH_NAMES = sorted(ARCHS)


def make_batch(cfg, b=2, s=16):
    batch = {
        "tokens": jax.random.randint(KEY, (b, s), 0, cfg.vocab_size),
        "labels": jax.random.randint(jax.random.fold_in(KEY, 1), (b, s),
                                     0, cfg.vocab_size),
    }
    if cfg.frontend != "none" or cfg.is_encoder_decoder:
        batch["frontend_embeds"] = jax.random.normal(
            jax.random.fold_in(KEY, 2), (b, cfg.frontend_seq or s // 2,
                                         cfg.d_model))
    return batch


@pytest.fixture(scope="module", params=ARCH_NAMES)
def arch_setup(request):
    cfg = ARCHS[request.param].reduced()
    model = build_model(cfg)
    params = model.init(KEY)
    return request.param, cfg, model, params


class TestSmoke:
    def test_train_step_finite(self, arch_setup):
        name, cfg, model, params = arch_setup
        batch = make_batch(cfg)
        loss, grads = jax.jit(jax.value_and_grad(model.train_loss))(
            params, batch)
        assert jnp.isfinite(loss), name
        assert 1.0 < float(loss) < 20.0, (name, loss)
        gnorm = sum(jnp.sum(g.astype(jnp.float32) ** 2)
                    for g in jax.tree.leaves(grads))
        assert jnp.isfinite(gnorm), name

    def test_prefill_decode_shapes_and_finite(self, arch_setup):
        name, cfg, model, params = arch_setup
        b, s = 2, 16
        batch = make_batch(cfg, b, s)
        logits, caches = jax.jit(model.prefill)(
            params, batch["tokens"], batch.get("frontend_embeds"))
        assert logits.shape == (b, cfg.padded_vocab), name
        assert jnp.isfinite(logits).all(), name
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        pos = jnp.full((b,), s, jnp.int32)
        logits2, caches2 = jax.jit(model.decode_step)(params, caches, tok,
                                                      pos)
        assert logits2.shape == (b, cfg.padded_vocab), name
        assert jnp.isfinite(logits2).all(), name
        # cache structure preserved
        assert jax.tree.structure(caches) == jax.tree.structure(caches2)


DECODER_ONLY = [n for n in ARCH_NAMES
                if not ARCHS[n].is_encoder_decoder
                and ARCHS[n].frontend == "none"]


@pytest.mark.parametrize("name", DECODER_ONLY)
def test_decode_consistency_with_forward(name):
    """Teacher-forcing equivalence: prefill(t_0..t_{n-1}) then decode_step
    must reproduce the forward logits at the last position — catches any
    cache/positioning bug per architecture family."""
    cfg = ARCHS[name].reduced()
    model = build_model(cfg)
    params = model.init(KEY)
    b, s = 2, 12
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)

    hidden, _ = model.forward(params, toks)
    from repro.models.transformer import _compute, lm_head_weight
    w = _compute(lm_head_weight(params, cfg), cfg)
    full_logits = (hidden[:, -1] @ w).astype(jnp.float32)

    logits_prefill, caches = model.prefill(params, toks, max_len=s + 4)
    np.testing.assert_allclose(np.asarray(logits_prefill),
                               np.asarray(full_logits), rtol=2e-2,
                               atol=2e-2, err_msg=f"{name} prefill")

    # decode one step and compare with forward over s+1 tokens
    nxt = jax.random.randint(jax.random.fold_in(KEY, 3), (b, 1), 0,
                             cfg.vocab_size)
    logits_dec, _ = model.decode_step(params, caches, nxt,
                                      jnp.full((b,), s, jnp.int32))
    hidden2, _ = model.forward(params, jnp.concatenate([toks, nxt], axis=1))
    want = (hidden2[:, -1] @ w).astype(jnp.float32)
    # SSM/hybrid decode recomputes the recurrence in fp32 step form while
    # forward uses the bf16 chunked form: small rounding-order noise remains
    np.testing.assert_allclose(np.asarray(logits_dec), np.asarray(want),
                               rtol=3e-2, atol=3e-2,
                               err_msg=f"{name} decode")


def test_cell_applicability_matrix():
    """40 cells total; long_500k runs only for sub-quadratic archs."""
    cells = all_cells(include_skipped=True)
    assert len(cells) == 40
    runnable = [(a.name, s.name) for a, s, ok, _ in cells if ok]
    skipped = [(a.name, s.name) for a, s, ok, _ in cells if not ok]
    assert len(skipped) == 7
    assert all(s == "long_500k" for _, s in skipped)
    for name in ("zamba2-7b", "h2o-danube-1.8b", "falcon-mamba-7b"):
        assert (name, "long_500k") in runnable


def test_param_counts_match_public_sizes():
    """Analytic parameter counts land near the published model sizes."""
    expect = {
        "deepseek-7b": (6.5e9, 7.5e9),
        "yi-6b": (5.5e9, 6.5e9),
        "phi4-mini-3.8b": (3.5e9, 4.2e9),
        "h2o-danube-1.8b": (1.5e9, 2.2e9),
        "pixtral-12b": (11e9, 13.5e9),
        "falcon-mamba-7b": (6.5e9, 8e9),
        "zamba2-7b": (6.5e9, 8.5e9),
        "moonshot-v1-16b-a3b": (25e9, 32e9),   # assigned 48L spec (published Moonlight uses 27L)
        "llama4-scout-17b-a16e": (95e9, 115e9),   # total (active 17b)
        "whisper-tiny": (2e7, 6e7),
    }
    for name, (lo, hi) in expect.items():
        n = ARCHS[name].param_count()
        assert lo <= n <= hi, (name, f"{n:.3e}")
    a = ARCHS["llama4-scout-17b-a16e"].active_param_count()
    assert 12e9 <= a <= 20e9, a
    m = ARCHS["moonshot-v1-16b-a3b"].active_param_count()
    assert 2e9 <= m <= 5.5e9, m


def test_reduced_configs_stay_in_family():
    for name, cfg in ARCHS.items():
        r = cfg.reduced()
        assert r.family == cfg.family
        assert (r.n_experts > 0) == (cfg.n_experts > 0)
        assert (r.ssm_version) == (cfg.ssm_version)
        assert (r.window is not None) == (cfg.window is not None)
