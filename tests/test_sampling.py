"""Property tests for the serving sampling stack (Hypothesis).

Pinned properties (ISSUE 4 satellite):

* filtered distributions renormalize to 1;
* top-k / top-p sampling never emits an out-of-support token;
* temperature -> 0 converges to argmax;
* a fixed seed reproduces the same tokens across batch layouts.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.serving.sampling import (SamplingParams, filtered_probs,
                                    sample_batch, sample_token,
                                    speculative_accept)

VOCAB = 32


def logits_strategy(v=VOCAB):
    return st.lists(st.floats(-8.0, 8.0, allow_nan=False,
                              allow_infinity=False, width=32),
                    min_size=v, max_size=v).map(np.asarray)


params_strategy = st.builds(
    SamplingParams,
    temperature=st.floats(0.05, 3.0),
    top_k=st.integers(0, VOCAB),
    top_p=st.floats(0.05, 1.0),
    seed=st.integers(0, 2 ** 31 - 1),
)


class TestFilteredProbs:
    @settings(max_examples=60, deadline=None)
    @given(logits=logits_strategy(), sp=params_strategy)
    def test_renormalizes_to_one(self, logits, sp):
        p = filtered_probs(logits, sp)
        assert p.shape == (VOCAB,)
        assert np.all(p >= 0.0)
        assert p.sum() == pytest.approx(1.0, abs=1e-9)

    @settings(max_examples=60, deadline=None)
    @given(logits=logits_strategy(), sp=params_strategy)
    def test_greedy_limit_is_argmax_onehot(self, logits, sp):
        g = SamplingParams(temperature=0.0, top_k=sp.top_k, top_p=sp.top_p,
                           seed=sp.seed)
        p = filtered_probs(logits, g)
        assert p[int(np.argmax(logits))] == 1.0
        assert p.sum() == 1.0


class TestSupport:
    @settings(max_examples=60, deadline=None)
    @given(logits=logits_strategy(), k=st.integers(1, VOCAB),
           seed=st.integers(0, 2 ** 31 - 1),
           counter=st.integers(0, 64))
    def test_top_k_never_leaves_support(self, logits, k, seed, counter):
        sp = SamplingParams(temperature=1.0, top_k=k, seed=seed)
        tok = sample_token(logits, sp, counter)
        p1 = np.exp(logits - logits.max())
        support = set(np.argsort(-p1, kind="stable")[:k].tolist())
        assert tok in support

    @settings(max_examples=60, deadline=None)
    @given(logits=logits_strategy(), top_p=st.floats(0.05, 0.95),
           seed=st.integers(0, 2 ** 31 - 1),
           counter=st.integers(0, 64))
    def test_top_p_never_leaves_support(self, logits, top_p, seed, counter):
        sp = SamplingParams(temperature=1.0, top_p=top_p, seed=seed)
        tok = sample_token(logits, sp, counter)
        p = np.exp(logits - logits.max())
        p /= p.sum()
        order = np.argsort(-p, kind="stable")
        cut = int(np.searchsorted(np.cumsum(p[order]), top_p)) + 1
        assert tok in set(order[:cut].tolist())

    @settings(max_examples=40, deadline=None)
    @given(logits=logits_strategy(), sp=params_strategy,
           counter=st.integers(0, 64))
    def test_sampled_token_has_positive_filtered_prob(self, logits, sp,
                                                      counter):
        tok = sample_token(logits, sp, counter)
        assert filtered_probs(logits, sp)[tok] > 0.0


class TestTemperatureLimit:
    @settings(max_examples=60, deadline=None)
    @given(logits=logits_strategy(), seed=st.integers(0, 2 ** 31 - 1),
           counter=st.integers(0, 64))
    def test_temperature_to_zero_converges_to_argmax(self, logits, seed,
                                                     counter):
        # quantize to a 0.25 grid then de-tie, so every pairwise gap is
        # >= 1e-3 and the cold distribution is numerically a one-hot
        logits = np.round(logits * 4.0) / 4.0 + np.arange(VOCAB) * 1e-3
        want = int(np.argmax(logits))
        cold = SamplingParams(temperature=1e-5, seed=seed)
        assert sample_token(logits, cold, counter) == want
        greedy = SamplingParams(temperature=0.0, seed=seed)
        assert sample_token(logits, greedy, counter) == want


class TestSeedReproducibility:
    @settings(max_examples=30, deadline=None)
    @given(seeds=st.lists(st.integers(0, 2 ** 31 - 1), min_size=2,
                          max_size=6),
           counter=st.integers(0, 64),
           data=st.data())
    def test_fixed_seed_across_batch_layouts(self, seeds, counter, data):
        """The same request (seed, emission index) samples the same token
        whether it sits in lane 0 of a small batch or lane n of a large,
        permuted one."""
        n = len(seeds)
        rng = np.random.default_rng(0)
        logits = rng.normal(size=(n, VOCAB))
        params = [SamplingParams(temperature=0.9, top_k=12, top_p=0.9,
                                 seed=s) for s in seeds]
        counters = [counter + i for i in range(n)]
        toks = sample_batch(logits, params, counters)
        perm = data.draw(st.permutations(range(n)))
        toks_perm = sample_batch(logits[perm],
                                 [params[i] for i in perm],
                                 [counters[i] for i in perm])
        assert toks_perm == [toks[i] for i in perm]
        # singleton layout agrees too
        for i in range(n):
            assert sample_token(logits[i], params[i], counters[i]) == toks[i]


class TestSpeculativeAcceptProperties:
    @settings(max_examples=40, deadline=None)
    @given(data=st.data(), k=st.integers(1, 4),
           seed=st.integers(0, 2 ** 31 - 1), counter=st.integers(0, 32))
    def test_emits_accepted_prefix_plus_one(self, data, k, seed, counter):
        sp = SamplingParams(temperature=1.0, seed=seed)
        rng = np.random.default_rng(seed)
        target = rng.normal(size=(k + 1, VOCAB))
        drafts, qs = [], []
        for _ in range(k):
            q = filtered_probs(rng.normal(size=VOCAB), sp)
            drafts.append(data.draw(st.integers(0, VOCAB - 1)))
            qs.append(q)
        emitted, a = speculative_accept(drafts, qs, target, sp, counter)
        assert 0 <= a <= k
        assert len(emitted) == a + 1
        assert emitted[:a] == drafts[:a]
        assert all(0 <= t < VOCAB for t in emitted)

    @settings(max_examples=40, deadline=None)
    @given(k=st.integers(1, 4), seed=st.integers(0, 2 ** 31 - 1),
           counter=st.integers(0, 32))
    def test_greedy_accepts_exactly_matching_prefix(self, k, seed, counter):
        sp = SamplingParams(temperature=0.0)
        rng = np.random.default_rng(seed)
        target = rng.normal(size=(k + 1, VOCAB))
        argmaxes = [int(np.argmax(target[i])) for i in range(k + 1)]
        n_match = int(rng.integers(0, k + 1))
        drafts = argmaxes[:n_match] \
            + [(argmaxes[i] + 1) % VOCAB for i in range(n_match, k)]
        emitted, a = speculative_accept(drafts, [None] * k, target, sp,
                                        counter)
        assert a == n_match
        assert emitted == argmaxes[:n_match] + [argmaxes[n_match]]
