"""FIBER runtime semantics — paper §3.1–3.3, §4, §6.3."""
import pytest

from repro.core import (OAT_ALL, OAT_DYNAMIC, OAT_INSTALL, OAT_STATIC,
                        Fitting, OATHierarchyError,
                        OATMissingBasicParamError, OATPriorityError,
                        ParamStore, Varied)
from repro.core import paramfile
from repro.core.directives import (dynamic_select, install_define,
                                   install_unroll, static_select,
                                   static_unroll)


# --------------------------------------------------------------------------
# parameter store / Fig. 4 hierarchy
# --------------------------------------------------------------------------

class TestHierarchy:
    def test_install_visible_downstream(self):
        st = ParamStore()
        st.set_pp("CacheSize", 64, "install")
        assert st.get("CacheSize", "install") == 64
        assert st.get("CacheSize", "static") == 64
        assert st.get("CacheSize", "dynamic") == 64

    def test_static_not_visible_to_install(self):
        st = ParamStore()
        st.set_pp("X", 1, "static")
        with pytest.raises(OATHierarchyError):
            st.get("X", "install")

    def test_dynamic_only_visible_to_dynamic(self):
        st = ParamStore()
        st.set_pp("Y", 2, "dynamic")
        assert st.get("Y", "dynamic") == 2
        with pytest.raises(OATHierarchyError):
            st.get("Y", "static")

    def test_feedback_model_exception(self):
        """§3.1 footnote: with the FIBER feedback model, static may read
        dynamic-determined parameters."""
        st = ParamStore(feedback=True)
        st.set_pp("Y", 2, "dynamic")
        assert st.get("Y", "static") == 2

    def test_bps_visible_everywhere(self):
        st = ParamStore()
        st.set_bp("n", 1024)
        for phase in ("install", "static", "dynamic"):
            assert st.get("n", phase) == 1024


# --------------------------------------------------------------------------
# execution priority (§3.2) + BP guards (§4.2.2)
# --------------------------------------------------------------------------

def _add_regions(ctx):
    @install_define(ctx, name="SetCacheParam",
                    params=[("CacheSize", "out"), ("CacheLine", "out")])
    def set_cache():
        return {"CacheSize": 64, "CacheLine": 8}

    @static_unroll(ctx, name="MyMatMul", varied=Varied(("i", "j"), 1, 4),
                   params=["bp n"])
    def my_matmul(i=1, j=1, n=64):
        return lambda: (i - 2) ** 2 + (j - 3) ** 2 + 0.0

    return set_cache, my_matmul


def test_static_before_install_raises(ctx_with_bps):
    _add_regions(ctx_with_bps)
    with pytest.raises(OATPriorityError):
        ctx_with_bps.OAT_ATexec(OAT_STATIC, None)


def test_install_without_bps_raises(ctx):
    _add_regions(ctx)
    with pytest.raises(OATMissingBasicParamError):
        ctx.OAT_ATexec(OAT_INSTALL, None)


def test_dynamic_before_static_raises(ctx_with_bps):
    _add_regions(ctx_with_bps)
    ctx_with_bps.OAT_ATexec(OAT_INSTALL, None)
    with pytest.raises(OATPriorityError):
        ctx_with_bps.OAT_ATexec(OAT_DYNAMIC, None)


def test_full_priority_sequence_ok(ctx_with_bps):
    _add_regions(ctx_with_bps)
    ctx_with_bps.OAT_ATexec(OAT_INSTALL, None)
    ctx_with_bps.OAT_ATexec(OAT_STATIC, None)
    ctx_with_bps.OAT_ATexec(OAT_DYNAMIC, None)


# --------------------------------------------------------------------------
# install-time define (Sample 2) + parameter file output
# --------------------------------------------------------------------------

def test_install_define_writes_param_file(ctx_with_bps, tmp_path):
    _add_regions(ctx_with_bps)
    ctx_with_bps.OAT_ATexec(OAT_INSTALL, None)
    nodes = paramfile.load_file(
        paramfile.param_path(str(tmp_path), "install"))
    rec = next(n for n in nodes if n.name == "SetCacheParam")
    assert rec.child_value("CacheSize") == 64
    assert rec.child_value("CacheLine") == 8
    # visible downstream (FIBER)
    assert ctx_with_bps.store.get("CacheSize", "static") == 64


# --------------------------------------------------------------------------
# before-execute-time AT (Sample 4): BP sweep + nested records
# --------------------------------------------------------------------------

def _bp_dependent_factory(region, bp_env):
    def measure(asg):
        tgt = bp_env.get("OAT_PROBSIZE", 1024) // 1024
        return (asg.get("MyMatMul_I", 0) - tgt) ** 2 \
            + (asg.get("MyMatMul_J", 0) - 3) ** 2
    return measure


@pytest.fixture
def tuned_static(ctx_with_bps):
    _add_regions(ctx_with_bps)
    ctx_with_bps._executor_factory = _bp_dependent_factory
    ctx_with_bps.OAT_ATexec(OAT_INSTALL, None)
    ctx_with_bps.OAT_ATexec(OAT_STATIC, None)
    return ctx_with_bps


def test_static_records_per_probsize(tuned_static):
    nodes = paramfile.load_file(
        paramfile.param_path(tuned_static.workdir, "static"))
    mm = next(n for n in nodes if n.name == "MyMatMul")
    for size, want_i in ((1024, 1), (2048, 2), (3072, 3)):
        g = mm.keyed_child("OAT_PROBSIZE", size)
        assert g is not None
        assert g.child_value("MyMatMul_I") == want_i
        assert g.child_value("MyMatMul_J") == 3


def test_static_pp_interpolates_nonsample_points(tuned_static):
    """OAT_BPsetCDF semantics: non-sample problem sizes are inferred."""
    assert tuned_static.static_pp("MyMatMul", "MyMatMul_I", 2048) == 2
    tuned_static.OAT_BPsetCDF("n", "least-squares 1")
    v = tuned_static.static_pp("MyMatMul", "MyMatMul_I", 2560)
    assert v in (2, 3)


def test_search_counts_logged(tuned_static):
    # 4x4 joint exhaustive per BP point (default for unroll)
    assert tuned_static.search_log["MyMatMul"] == 16


# --------------------------------------------------------------------------
# parameter collision (§6.3)
# --------------------------------------------------------------------------

def test_collision_force_sets_user_value(ctx_with_bps, tmp_path):
    _add_regions(ctx_with_bps)
    pin = paramfile.Node("MyMatMul")
    pin.set("MyMatMul_I", 9)
    paramfile.save_file(
        paramfile.param_path(str(tmp_path), "static", user=True), [pin])
    ctx_with_bps._executor_factory = _bp_dependent_factory
    ctx_with_bps.OAT_ATexec(OAT_INSTALL, None)
    ctx_with_bps.OAT_ATexec(OAT_STATIC, None)
    assert ("MyMatMul", "MyMatMul_I", 9) in ctx_with_bps.collisions
    nodes = paramfile.load_file(
        paramfile.param_path(str(tmp_path), "static"))
    mm = next(n for n in nodes if n.name == "MyMatMul")
    assert mm.keyed_child("OAT_PROBSIZE", 1024) \
        .child_value("MyMatMul_I") == 9      # user value force-set


# --------------------------------------------------------------------------
# run-time AT: dynamic select (Sample 6), DynPerfThis (Sample 7), ATdel
# --------------------------------------------------------------------------

def _make_select(ctx):
    sel = dynamic_select(ctx, name="PrecondSelect",
                         params=["in eps", "in iter"],
                         according="min (eps) .and. condition (iter < 5)")

    @sel.alternative()
    def p1():
        return {"eps": 0.5, "iter": 3}

    @sel.alternative()
    def p2():
        return {"eps": 0.1, "iter": 9}     # best eps, violates iter < 5

    @sel.alternative()
    def p3():
        return {"eps": 0.3, "iter": 2}

    return sel.finalize()


def test_dynamic_select_sample6(ctx):
    _make_select(ctx)
    ctx.OAT_ATexec(OAT_DYNAMIC, None)
    for _ in range(3):
        ctx.execute("PrecondSelect")
    st = ctx.dynamic_state["PrecondSelect"]
    assert st.committed == 2     # p3: min eps among those with iter < 5
    # subsequent calls run the winner, no more tuning
    out = ctx.execute("PrecondSelect")
    assert out == {"eps": 0.3, "iter": 2}


def test_dyn_perf_this_runs_optimised_without_tuning(ctx):
    """Sample 7 semantics: OAT_DynPerfThis executes with optimised PPs and
    performs no parameter tuning."""
    _make_select(ctx)
    ctx.OAT_ATexec(OAT_DYNAMIC, None)
    for _ in range(3):
        ctx.execute("PrecondSelect")
    n_before = len(ctx.dynamic_state["PrecondSelect"].tried)
    out = ctx.OAT_DynPerfThis("PrecondSelect")
    assert out == {"eps": 0.3, "iter": 2}
    assert len(ctx.dynamic_state["PrecondSelect"].tried) == n_before


def test_atdel_removes_candidate(ctx_with_bps):
    _add_regions(ctx_with_bps)
    ctx_with_bps.OAT_ATdel("OAT_InstallRoutines", "SetCacheParam")
    ctx_with_bps.OAT_ATexec(OAT_INSTALL, None)
    nodes = paramfile.load_file(
        paramfile.param_path(ctx_with_bps.workdir, "install"))
    assert not any(n.name == "SetCacheParam" for n in nodes)


def test_install_init_allows_rerun(ctx_with_bps):
    _add_regions(ctx_with_bps)
    ctx_with_bps.OAT_ATexec(OAT_INSTALL, None)
    assert ctx_with_bps.store.entry("CacheSize") is not None
    ctx_with_bps.OAT_ATInstallInit("OAT_InstallRoutines")
    assert ctx_with_bps.store.entry("CacheSize") is None
    ctx_with_bps.OAT_ATexec(OAT_INSTALL, None)   # runs again cleanly
    assert ctx_with_bps.store.entry("CacheSize").value == 64


def test_oat_all_runs_phases_in_order(ctx_with_bps):
    _add_regions(ctx_with_bps)
    ctx_with_bps._executor_factory = _bp_dependent_factory
    ctx_with_bps.OAT_ATexec(OAT_ALL, None)
    assert ctx_with_bps.phase_ran["install"]
    assert ctx_with_bps.phase_ran["static"]
    assert ctx_with_bps.phase_ran["dynamic"]


# --------------------------------------------------------------------------
# estimated-cost select (Sample 5)
# --------------------------------------------------------------------------

def test_static_select_according_estimated(ctx_with_bps):
    """Sample 5: selection by user cost expressions over BPs + install
    parameters, Fortran syntax included."""

    @install_define(ctx_with_bps, name="SetCacheParam",
                    params=[("CacheSize", "out")])
    def set_cache():
        return {"CacheSize": 64}

    sel = static_select(
        ctx_with_bps, name="ATfromCacheSize",
        params=["in CacheSize", "bp OAT_PROBSIZE", "bp OAT_NUMPROCS"])
    sel.alternative(according=(
        "estimated 2.0d0*CacheSize*OAT_PROBSIZE*OAT_PROBSIZE"
        " / (3.0d0*OAT_NUMPROCS)"))(lambda: "process1")
    sel.alternative(according=(
        "estimated 4.0d0*CacheSize*OAT_PROBSIZE"
        "*dlog(OAT_PROBSIZE) / (2.0d0*OAT_NUMPROCS)"))(lambda: "process2")
    sel.finalize()

    ctx_with_bps.OAT_ATexec(OAT_INSTALL, None)
    ctx_with_bps.OAT_ATexec(OAT_STATIC, ["ATfromCacheSize"])
    # for OAT_PROBSIZE >= 1024: n^2/3 >> 2n log n, so process2 wins
    nodes = paramfile.load_file(
        paramfile.param_path(ctx_with_bps.workdir, "static"))
    rec = next(n for n in nodes if n.name == "ATfromCacheSize")
    g = rec.keyed_child("OAT_PROBSIZE", 1024)
    assert g.child_value("ATfromCacheSize_SELECT") == 1
