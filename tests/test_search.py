"""Paper §6.4.2 search semantics — Sample 10 counts reproduced exactly."""
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (ATRegion, CountingExecutor, Fitting, SearchPlan,
                        Varied, predicted_count)


def build_sample10(outer_search, inner_search):
    """Sample 10: ABlockRoutine(BL 1..16) nesting Kernel1(i,j 1..32) and
    Kernel2(l,m 1..32)."""
    root = ATRegion("static", "variable", "ABlockRoutine",
                    fn=lambda **kw: None, varied=Varied("BL", 1, 16),
                    search=outer_search)
    root.add_child(ATRegion("static", "unroll", "Kernel1",
                            fn=lambda **kw: None,
                            varied=Varied(("i", "j"), 1, 32),
                            search=inner_search))
    root.add_child(ATRegion("static", "unroll", "Kernel2",
                            fn=lambda **kw: None,
                            varied=Varied(("l", "m"), 1, 32),
                            search=inner_search))
    return root


class TestSample10Counts:
    """The paper's four worked cases.  Note: the paper prints '1,677,216'
    for case 1 — an arithmetic typo; 16 * 32**4 = 16,777,216 (asserted)."""

    def test_all_exhaustive(self):
        n = predicted_count(build_sample10("brute-force", "brute-force"))
        assert n == 16 * 32 ** 4 == 16_777_216

    def test_all_adhoc(self):
        assert predicted_count(build_sample10("ad-hoc", "ad-hoc")) == 144

    def test_exhaustive_outer_adhoc_inner(self):
        assert predicted_count(
            build_sample10("brute-force", "ad-hoc")) == 144

    def test_adhoc_outer_exhaustive_inner(self):
        assert predicted_count(
            build_sample10("ad-hoc", "brute-force")) == 2_064


SEP_OPT = {"ABlockRoutine_BL": 5, "Kernel1_I": 3, "Kernel1_J": 7,
           "Kernel2_L": 2, "Kernel2_M": 9}


def separable_cost(asg):
    return sum((asg[k] - v) ** 2 for k, v in SEP_OPT.items())


@pytest.mark.parametrize("outer,inner,count", [
    ("ad-hoc", "ad-hoc", 144),
    ("brute-force", "ad-hoc", 144),
    ("ad-hoc", "brute-force", 2064),
])
def test_sample10_actual_runs(outer, inner, count):
    """The executed trajectory has exactly the predicted length and finds
    the optimum of a separable cost."""
    ex = CountingExecutor(separable_cost)
    res = SearchPlan(build_sample10(outer, inner)).run(ex)
    assert ex.count == count == res.n_evaluations
    assert res.best == SEP_OPT


def test_small_exhaustive_actual_run():
    root = ATRegion("static", "unroll", "K",
                    fn=lambda **kw: None, varied=Varied(("i", "j"), 1, 4))
    ex = CountingExecutor(
        lambda a: (a["K_I"] - 2) ** 2 + (a["K_J"] - 3) ** 2)
    res = SearchPlan(root).run(ex)
    assert ex.count == 16 == res.n_evaluations    # joint 4x4
    assert res.best == {"K_I": 2, "K_J": 3}


def test_adhoc_nonseparable_is_coordinate_descent():
    """AD-HOC does one coordinate pass, not a joint search (paper: sum N)."""
    root = ATRegion("static", "unroll", "K", fn=lambda **kw: None,
                    varied=Varied(("i", "j"), 1, 8), search="ad-hoc")
    ex = CountingExecutor(lambda a: (a["K_I"] - a["K_J"]) ** 2
                          + 0.1 * (a["K_I"] - 5) ** 2)
    res = SearchPlan(root).run(ex)
    assert ex.count == 16      # 8 + 8


def test_fitting_search_sample1():
    """Sample 1: least-squares order 5, sampled (1-5, 8, 16) — only the 7
    sample points are measured; the optimum is inferred on the full grid."""
    r = ATRegion("install", "unroll", "MyMatMul", fn=lambda **kw: None,
                 varied=Varied(("i",), 1, 16),
                 fitting=Fitting.least_squares(
                     5, sampled=[1, 2, 3, 4, 5, 8, 16]))
    ex = CountingExecutor(lambda a: (a["MyMatMul_I"] - 6) ** 2 + 3.0)
    res = SearchPlan(r).run(ex)
    assert ex.count == 7
    assert res.best["MyMatMul_I"] == 6           # 6 was never measured
    assert res.fitted["MyMatMul_I"] is True


def test_default_search_methods():
    """§6.4.2 defaults: variable/unroll -> exhaustive, select -> AD-HOC."""
    mk = lambda f, **kw: ATRegion("static", f, f, fn=lambda **k: None, **kw)
    assert mk("variable", varied=Varied("x", 1, 4)).search_method \
        == "brute-force"
    assert mk("unroll", varied=Varied("x", 1, 4)).search_method \
        == "brute-force"
    assert mk("select").search_method == "ad-hoc"
    assert mk("define").search_method is None


@settings(max_examples=30, deadline=None)
@given(
    outer_n=st.integers(2, 6),
    inner_dims=st.lists(st.tuples(st.integers(1, 2), st.integers(2, 6)),
                        min_size=1, max_size=3),
    outer_search=st.sampled_from(["brute-force", "ad-hoc"]),
    inner_search=st.sampled_from(["brute-force", "ad-hoc"]))
def test_property_predicted_equals_actual(outer_n, inner_dims, outer_search,
                                          inner_search):
    """Property: predicted_count == executed evaluation count for random
    region trees and mixed search methods."""
    root = ATRegion("static", "variable", "Root", fn=lambda **kw: None,
                    varied=Varied("r", 1, outer_n), search=outer_search)
    for i, (nd, n) in enumerate(inner_dims):
        names = tuple(f"p{i}_{j}" for j in range(nd))
        root.add_child(ATRegion("static", "variable", f"Child{i}",
                                fn=lambda **kw: None,
                                varied=Varied(names, 1, n),
                                search=inner_search))
    ex = CountingExecutor(lambda a: sum((v - 1) ** 2 for v in a.values()))
    res = SearchPlan(root).run(ex)
    assert ex.count == predicted_count(root) == res.n_evaluations
    # the all-ones optimum is separable: every method must find it
    assert all(v == 1 for v in res.best.values())
