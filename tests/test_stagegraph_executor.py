"""Units: dependence analysis (stagegraph), measurement backends
(executor), sharding-rule sanitation, roofline record maths."""
import time

import pytest

from repro.core.executor import (CostModelExecutor, CountingExecutor,
                                 TableExecutor, WallClockExecutor)
from repro.core.stagegraph import (depends, interleave_orders, order_legal,
                                   stmt_rw, uncovered_flow_deps)


class TestStagegraph:
    def test_rw_extraction(self):
        rw = stmt_rw("A[i, j] = B[i, k] * C[k, j] + t")
        assert rw.writes == {"A"}
        assert {"B", "C", "t", "i", "j", "k", "A"} <= rw.reads

    def test_scalar_assign(self):
        rw = stmt_rw("t = x + 1")
        assert rw.writes == {"t"} and "x" in rw.reads

    def test_augassign_reads_target(self):
        rw = stmt_rw("acc += x")
        assert rw.writes == {"acc"} and {"acc", "x"} <= rw.reads

    def test_depends_raw_war_waw(self):
        a = stmt_rw("t = x * 2")
        b = stmt_rw("y = t + 1")       # RAW on t
        c = stmt_rw("t = z")           # WAW on t
        d = stmt_rw("x = 0")           # WAR vs a
        assert depends(a, b) and depends(a, c) and depends(a, d)
        e = stmt_rw("q = r")
        assert not depends(a, e)

    def test_order_legal(self):
        rws = [stmt_rw(s) for s in ("t = x", "y = t", "u = z")]
        assert order_legal(rws, [0, 1, 2])
        assert order_legal(rws, [2, 0, 1])      # independent stmt moves
        assert not order_legal(rws, [1, 0, 2])  # consumer before producer

    def test_interleave_orders(self):
        grouped, rr = interleave_orders([3, 3])
        assert grouped == [0, 1, 2, 3, 4, 5]
        assert rr == [0, 3, 1, 4, 2, 5]         # ROX,VX,ROY,VY,ROZ,VZ

    def test_uncovered_flow_deps(self):
        pre = [stmt_rw("qg = a * b"), stmt_rw("s = qg * 2")]
        post = [stmt_rw("t = qg + s")]
        # nothing recomputed: both qg and s leak
        leaks = uncovered_flow_deps(pre, post, set())
        assert leaks == {"qg", "s"}
        # recompute qg, treat s as loop-carried array
        leaks = uncovered_flow_deps(pre, post, {"qg"}, loop_carried={"s"})
        assert leaks == set()


class TestExecutors:
    def test_table_executor(self):
        t = TableExecutor({TableExecutor.key({"x": 1}): 5.0}, default=9.0)
        assert t({"x": 1}) == 5.0
        assert t({"x": 2}) == 9.0

    def test_cost_model_executor_expr(self):
        ex = CostModelExecutor("2.0d0 * n / p", env={"p": 4})
        assert ex({"n": 8}) == 4.0

    def test_cost_model_executor_callable(self):
        ex = CostModelExecutor(lambda env: env["x"] ** 2)
        assert ex({"x": 3}) == 9.0

    def test_wall_clock_orders_variants(self):
        def make_variant(asg):
            return lambda: time.sleep(0.005 * asg["k"])

        ex = WallClockExecutor(make_variant, repeats=2, warmup=0)
        assert ex({"k": 1}) < ex({"k": 20})

    def test_counting_trajectory(self):
        ex = CountingExecutor(lambda a: 0.0)
        ex({"x": 1})
        ex({"x": 2})
        assert ex.count == 2
        assert ex.trajectory == [{"x": 1}, {"x": 2}]


class TestShardingRules:
    def test_sanitize_drops_nondividing_axes(self):
        import jax
        from jax.sharding import PartitionSpec as P

        from repro.distributed.sharding import _sanitize
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        # 7 not divisible by any >1 axis — trivially ok on 1x1; shape
        # mismatch ranks get padded with None
        spec = _sanitize(P("data", "model"), (8, 6), mesh)
        assert len(spec) == 2

    def test_plan_names_cover_all_kinds(self):
        from repro.tuning import candidate_plans
        assert set(candidate_plans("train")) == {"tp", "fsdp"}
        assert set(candidate_plans("prefill")) == {"tp", "fsdp"}
        assert set(candidate_plans("decode")) == {
            "tp", "decode_seq", "decode_resident"}


class TestRooflineRecord:
    def test_from_artifact_maths(self):
        from repro.launch.roofline import (HBM_BW, ICI_BW, PEAK_FLOPS,
                                           from_artifact)
        rec = {
            "arch": "deepseek-7b", "shape": "train_4k", "mesh": "16x16",
            "chips": 256, "plan": "tp", "kind": "train", "remat": "full",
            "hlo_dot_flops": 2 * PEAK_FLOPS,       # 2 s compute per chip
            "hlo_collective_bytes": {"total": ICI_BW},   # 1 s collective
            "bytes_per_device": 1e9,
        }
        r = from_artifact(rec)
        assert r.compute_s == pytest.approx(2.0)
        assert r.collective_s == pytest.approx(1.0)
        assert r.dominant == "compute"
        assert r.bound_s == pytest.approx(2.0)
        assert 0 < r.useful_ratio < 1      # remat recompute + overheads

    def test_skipped_row(self):
        from repro.launch.roofline import from_artifact
        r = from_artifact({"arch": "a", "shape": "s", "skipped": True,
                           "reason": "why"})
        assert r.skipped and r.reason == "why"


class TestAnalyticModel:
    def test_moe_flops_scale_with_topk(self):
        from repro.configs import get_arch, get_shape
        from repro.launch.analytic import step_costs
        import dataclasses
        cfg = get_arch("moonshot-v1-16b-a3b")
        shape = get_shape("train_4k")
        base = step_costs(cfg, shape).flops
        doubled = step_costs(dataclasses.replace(cfg, top_k=12),
                             shape).flops
        assert doubled > base * 1.3

    def test_decode_cheaper_than_prefill(self):
        from repro.configs import get_arch, get_shape
        from repro.launch.analytic import step_costs
        cfg = get_arch("deepseek-7b")
        dec = step_costs(cfg, get_shape("decode_32k"))
        pre = step_costs(cfg, get_shape("prefill_32k"))
        assert dec.flops < pre.flops / 100

    def test_window_caps_attention(self):
        from repro.configs import get_arch, get_shape
        from repro.launch.analytic import step_costs
        import dataclasses
        cfg = get_arch("h2o-danube-1.8b")
        shape = get_shape("prefill_32k")
        windowed = step_costs(cfg, shape).flops
        full = step_costs(dataclasses.replace(cfg, window=None),
                          shape).flops
        assert windowed < full
