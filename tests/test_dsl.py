"""#OAT$ directive parsing + the full preprocessor->ATexec pipeline."""
import numpy as np
import pytest

from repro.core import OAT_INSTALL, CountingExecutor
from repro.core.dsl import (parse_fitting, parse_parameter, parse_search,
                            parse_varied, preprocess)


class TestSubtypeParsers:
    def test_varied(self):
        v = parse_varied("(i, j) from 1 to 16")
        assert v.names == ("i", "j")
        assert v.candidates() == tuple(range(1, 17))
        v2 = parse_varied("x from 2 to 10 step 2")
        assert v2.candidates() == (2, 4, 6, 8, 10)

    def test_fitting(self):
        f = parse_fitting("least-squares 5 sampled (1-5, 8, 16)")
        assert f.method == "least-squares"
        assert f.order == 5
        assert f.sampled == [1, 2, 3, 4, 5, 8, 16]
        assert parse_fitting("dspline").method == "dspline"
        assert parse_fitting("auto").method == "auto"
        fu = parse_fitting("user-defined c0 + c1*x sampled (1, 4, 9)")
        assert fu.method == "user-defined" and fu.expr == "c0 + c1*x"

    def test_parameter(self):
        ps = parse_parameter("(bp n, in CacheSize, out CacheLine)")
        assert [(p.name, p.attr) for p in ps] == [
            ("n", "bp"), ("CacheSize", "in"), ("CacheLine", "out")]

    def test_search(self):
        assert parse_search("Brute-force") == "brute-force"
        assert parse_search("AD-HOC") == "ad-hoc"


def annotated_matmul(N, A, B, C):
    #OAT$ install unroll region start
    #OAT$ name MyMatMul
    #OAT$ varied (i, j) from 1 to 4
    #OAT$ search AD-HOC
    for i in range(N):
        for j in range(N):
            for k in range(N):
                A[i, j] = A[i, j] + B[i, k] * C[k, j]
    #OAT$ install unroll region end
    return A


def test_preprocess_registers_region(ctx_with_bps, tmp_path):
    regions = preprocess(annotated_matmul, ctx_with_bps, str(tmp_path))
    assert "MyMatMul" in regions
    r = regions["MyMatMul"]
    assert r.at_type == "install" and r.feature == "unroll"
    assert r.varied.names == ("i", "j")
    assert r.search_method == "ad-hoc"
    assert (tmp_path / "OAT" / "OAT_annotated_matmul.py").exists()


def test_pipeline_tunes_unroll_through_atexec(ctx_with_bps, tmp_path):
    """The complete paper flow: annotate -> OATCodeGen -> OAT_ATexec ->
    tuned unrolled variant that computes the right answer."""
    regions = preprocess(annotated_matmul, ctx_with_bps, str(tmp_path))
    region = regions["MyMatMul"]

    rng = np.random.default_rng(0)
    n = 8
    b, c = rng.normal(size=(n, n)), rng.normal(size=(n, n))
    want = b @ c

    calls = CountingExecutor(lambda asg: abs(asg["MyMatMul_I"] - 2)
                             + abs(asg["MyMatMul_J"] - 2))
    ctx_with_bps._executor_factory = lambda r, env: calls
    ctx_with_bps.OAT_ATexec(OAT_INSTALL, ["MyMatMul"])
    assert calls.count == 8          # AD-HOC: 4 + 4
    assert ctx_with_bps.store.entry("MyMatMul_I").value == 2

    # run the tuned variant: generator called with tuned PPs
    variant = region.fn(i=2, j=2)
    a = np.zeros((n, n))
    variant(n, a, b, c)
    np.testing.assert_allclose(a, want, rtol=1e-10)
    # unrolled source really was generated with factor 2
    gen = region.metadata["codegen"]
    v = gen.unroll_variant(annotated_matmul, "MyMatMul", {"i": 2, "j": 2})
    assert "i + 1" in v.source and "j + 1" in v.source


def fused_split_annotated(N, A, B, C):
    #OAT$ install LoopFusionSplit region start
    #OAT$ name SmallSplit
    for i in range(N):
        for j in range(N):
            #OAT$ SplitPointCopyDef region start
            T = C[i, j] * 2.0
            #OAT$ SplitPointCopyDef region end
            A[i, j] = A[i, j] + T
            #OAT$ SplitPoint (i, j)
            B[i, j] = B[i, j] * T
    #OAT$ install LoopFusionSplit region end
    return A, B


def test_preprocess_fusionsplit_becomes_select(ctx_with_bps, tmp_path):
    regions = preprocess(fused_split_annotated, ctx_with_bps, str(tmp_path))
    r = regions["SmallSplit"]
    assert r.feature == "select"
    # 2-nest with split point: baseline + split@i + split@j + fuse +
    # split+fuse
    assert len(r.subregions) == 5
    rng = np.random.default_rng(1)
    n = 5
    a0, b0 = rng.normal(size=(n, n)), rng.normal(size=(n, n))
    c0 = rng.normal(size=(n, n))
    base = None
    for sub in r.subregions:
        a, b = a0.copy(), b0.copy()
        out = sub.fn(n, a, b, c0)
        if base is None:
            base = out
        else:
            for x, y in zip(base, out):
                np.testing.assert_allclose(x, y, rtol=1e-12,
                                           err_msg=sub.name)


def test_split_with_clobbered_recompute_raises(ctx_with_bps, tmp_path):
    """The legality check the paper leaves implicit: a CopyDef whose inputs
    are overwritten before the split point cannot be re-computed."""
    from repro.core.errors import OATCodegenError

    def clobbered(N, A, B):
        #OAT$ install LoopFusionSplit region start
        #OAT$ name Clobbered
        for i in range(N):
            #OAT$ SplitPointCopyDef region start
            T = A[i] * 2.0
            #OAT$ SplitPointCopyDef region end
            A[i] = A[i] + T
            #OAT$ SplitPoint (i)
            B[i] = B[i] * T
        #OAT$ install LoopFusionSplit region end
        return A, B

    with pytest.raises(OATCodegenError, match="overwritten"):
        preprocess(clobbered, ctx_with_bps, str(tmp_path))
