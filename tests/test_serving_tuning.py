"""Serving engine + the three FIBER tuning drivers end-to-end."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.core import ATContext
from repro.kernels import ops
from repro.models import build_model
from repro.serving import Request, ServingEngine, length_bucket
from repro.tuning import (analytic_plan_cost, candidate_plans,
                          register_kernel_regions, run_install_tuning,
                          tune_layout)


@pytest.fixture(scope="module")
def small_model():
    cfg = ARCHS["h2o-danube-1.8b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


class TestServingEngine:
    def test_completes_requests(self, small_model):
        cfg, model, params = small_model
        eng = ServingEngine(model, params, n_lanes=2, max_len=48)
        for rid in range(3):
            eng.submit(Request(rid=rid, prompt=[1 + rid, 2, 3],
                               max_new_tokens=4))
        done = eng.run(max_steps=40)
        assert len(done) == 3
        assert all(len(r.out_tokens) == 4 for r in done)

    def test_continuous_batching_recycles_lanes(self, small_model):
        cfg, model, params = small_model
        eng = ServingEngine(model, params, n_lanes=1, max_len=48)
        for rid in range(2):
            eng.submit(Request(rid=rid, prompt=[5, 6], max_new_tokens=3))
        done = eng.run(max_steps=40)
        assert len(done) == 2          # second request reused the lane

    def test_engine_matches_plain_decode(self, small_model):
        """Greedy engine output == direct prefill+decode loop (single
        request) — the batching/lane machinery changes nothing."""
        cfg, model, params = small_model
        prompt = [3, 1, 4, 1, 5]
        eng = ServingEngine(model, params, n_lanes=2, max_len=48)
        eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=5))
        out = eng.run(max_steps=30)[0].out_tokens

        logits, caches = model.prefill(params,
                                       jnp.asarray([prompt], jnp.int32),
                                       max_len=48)
        want = [int(jnp.argmax(logits[0]))]
        pos = len(prompt)
        for _ in range(4):
            logits, caches = model.decode_step(
                params, caches, jnp.asarray([[want[-1]]], jnp.int32),
                jnp.asarray([pos], jnp.int32))
            want.append(int(jnp.argmax(logits[0])))
            pos += 1
        assert out == want

    def test_length_bucket(self):
        assert length_bucket(100) == 128
        assert length_bucket(129) == 512
        assert length_bucket(10 ** 9) == 32768


class TestInstallTuning:
    def test_analytic_install_pass(self, tmp_path):
        ctx = ATContext(str(tmp_path))
        register_kernel_regions(ctx)
        tuned = run_install_tuning(ctx)
        assert set(tuned) == {"MatmulBlocks", "FlashBlocks", "SsmChunk"}
        assert ops.tuned("matmul")["block_m"] in (128, 256, 512)
        assert ops.tuned("flash_attention")["block_q"] in (128, 256, 512,
                                                           1024)
        # results persisted in the FIBER store at install level
        assert ctx.store.get("MatmulBlocks_BM", "static") is not None

    def test_wallclock_install_pass(self, tmp_path):
        ctx = ATContext(str(tmp_path))
        register_kernel_regions(ctx)
        tuned = run_install_tuning(ctx, wall_clock=True)
        assert "MatmulBlocks" in tuned


class TestStaticTuning:
    def test_decode_seq_wins_for_low_kv_decode(self, tmp_path):
        """yi-6b decode (kv=4 < model axis): the seq-sharded KV layout must
        beat tp-with-weight-gather on the roofline estimate."""
        c_tp = analytic_plan_cost("yi-6b", "decode_32k", "tp")
        c_seq = analytic_plan_cost("yi-6b", "decode_32k", "decode_seq")
        assert c_seq < c_tp

    def test_tp_wins_for_dense_train(self):
        c_tp = analytic_plan_cost("deepseek-7b", "train_4k", "tp")
        c_fsdp = analytic_plan_cost("deepseek-7b", "train_4k", "fsdp")
        assert c_tp != c_fsdp       # the select is meaningful

    def test_tune_layout_picks_min_cost(self, tmp_path):
        ctx = ATContext(str(tmp_path))
        costs = {"tp": 3.0, "decode_seq": 1.0,
                 "decode_resident": 2.0}
        best = tune_layout(ctx, "yi-6b", "decode_32k",
                           cost_fn=lambda p: costs[p])
        assert best == "decode_seq"
        # recorded in the static param file, keyed by BP (paper format)
        from repro.core import paramfile
        nodes = paramfile.load_file(
            paramfile.param_path(str(tmp_path), "static"))
        rec = next(n for n in nodes if n.name.startswith("Layout_yi_6b"))
        g = rec.keyed_child("OAT_PROBSIZE", 32768)
        assert g is not None

    def test_candidate_plans(self):
        assert "decode_seq" in candidate_plans("decode")
        assert "decode_resident" in candidate_plans("decode")
        assert "fsdp" in candidate_plans("train")

    def test_decode_resident_wins_overall(self):
        """The §Perf result: resident model-axis weights beat per-token
        FSDP re-gather for every dense decode cell."""
        for arch in ("deepseek-7b", "yi-6b"):
            c_tp = analytic_plan_cost(arch, "decode_32k", "tp")
            c_res = analytic_plan_cost(arch, "decode_32k",
                                       "decode_resident")
            assert c_res < c_tp, arch


class TestDynamicTuning:
    def test_bucket_tuner_commits(self, tmp_path):
        from repro.tuning import DecodeAutoTuner
        ctx = ATContext(str(tmp_path))
        ctx.phase_ran["install"] = ctx.phase_ran["static"] = True
        calls = []

        def make_decode(bk):
            def fn():
                calls.append(bk)
                return {"bk": bk}
            return fn

        tuner = DecodeAutoTuner(ctx, make_decode, buckets=(512, 2048),
                                block_ks=(256, 512))
        for _ in range(3):
            tuner.decode(300)
        committed = tuner.committed()
        assert committed[512] is not None
        assert committed[2048] is None     # untouched bucket still tuning
        out = tuner.decode(300)
        assert out["bk"] in (256, 512)

    def test_bucket_page_size_product_space(self, tmp_path):
        """With page_sizes the BP space is (bucket x block_k x page_size)."""
        from repro import at
        from repro.tuning import DecodeAutoTuner
        session = at.AutoTuner(str(tmp_path))

        def make_decode(bk, ps):
            return lambda: {"bk": bk, "ps": ps}

        tuner = DecodeAutoTuner(session, make_decode, buckets=(512,),
                                block_ks=(256, 512), page_sizes=(8, 16))
        assert len(tuner.regions[512].subregions) == 4
        for _ in range(4):                 # one call per candidate
            tuner.decode(100)
        pp = tuner.committed_params()[512]
        assert pp["block_k"] in (256, 512) and pp["page_size"] in (8, 16)

    def test_warm_restart_zero_tuning(self, tmp_path):
        """Satellite: a second session on the same workdir starts with
        every bucket committed and performs zero tuning-executor
        invocations — only the committed winner variant ever runs."""
        from repro import at
        from repro.tuning import DecodeAutoTuner

        def mk(calls):
            def make_decode(bk):
                def fn():
                    calls.append(bk)
                    return {"bk": bk}
                return fn
            return make_decode

        calls1: list[int] = []
        s1 = at.AutoTuner(str(tmp_path))
        t1 = DecodeAutoTuner(s1, mk(calls1), buckets=(512, 2048),
                             block_ks=(256, 512))
        for _ in range(2):                 # measure both candidates
            t1.decode(300)
            t1.decode(1500)
        assert all(v is not None for v in t1.committed().values())

        calls2: list[int] = []
        s2 = at.AutoTuner(str(tmp_path))   # fresh process, same workdir
        t2 = DecodeAutoTuner(s2, mk(calls2), buckets=(512, 2048),
                             block_ks=(256, 512))
        # committed *before* any decode call, loaded from the record store
        assert t2.committed() == t1.committed()
        assert s2.executor_calls == 0
        assert set(s2.warm_hits) >= {("dynamic", "DecodeBucket_512"),
                                     ("dynamic", "DecodeBucket_2048")}
        winners = {512: t1.committed()[512], 2048: t1.committed()[2048]}
        blocks = {0: 256, 1: 512}
        out = t2.decode(300)
        assert out["bk"] == blocks[winners[512]]
        assert calls2 == [blocks[winners[512]]]   # no re-measurement
