"""The ``repro.at`` session API: one frontend, pluggable backends, and the
persistent tuning database (warm path = zero executor invocations)."""
import json
import os

import pytest

import repro.at as at
from repro.at.records import bp_key
from repro.core import ATContext, ATRegion, SearchPlan, Varied


@pytest.fixture(autouse=True)
def _isolate_published():
    """The published-PP table is process-global (parity with the old
    ops._TUNED side-channel); clear it so tests are order-independent."""
    at.clear_published()
    yield
    at.clear_published()


def cost_fn(bm, bn):
    return abs(bm - 256) + abs(bn - 512) + 1.0


def build_session(workdir, *, booby_trap=False, **kw):
    """A session with one region per phase (install/static/dynamic)."""
    kw.setdefault("executor", "analytic-cost")
    t = at.AutoTuner(workdir, **kw)
    t.set_bps(numprocs=1, start=1024, end=2048, dist=1024)

    @t.autotune("install", "variable", name="Blocks",
                varied=Varied(("bm", "bn"), values=(128, 256, 512)),
                search="ad-hoc",
                publish=("matmul", {"bm": "block_m", "bn": "block_n"}))
    def blocks(bm=128, bn=128):
        if booby_trap:
            raise AssertionError("executed on the warm path")
        return cost_fn(bm, bn)

    @t.autotune("static", "variable", name="Chunk",
                varied=Varied(("c",), values=(32, 64, 128)))
    def chunk(c=32):
        if booby_trap:
            raise AssertionError("executed on the warm path")
        return abs(c - 64) + 1.0

    sel = t.autotune("dynamic", "select", name="Decode")
    sel.alternative(name="slow")(lambda: "slow")
    sel.alternative(name="fast")(lambda: "fast")
    return t, sel


class TestSessionRoundTrip:
    def test_full_phase_round_trip(self, tmp_path):
        t, sel = build_session(str(tmp_path))
        ran = t.run("all")
        assert ran == {"install": ["Blocks"], "static": ["Chunk"],
                       "dynamic": ["Decode"]}
        # install optimum found by the ad-hoc coordinate search
        assert t.best("Blocks") == {"Blocks_BM": 256, "Blocks_BN": 512}
        # static optimum recorded per BP point
        assert t.best("Chunk") == {"Chunk_C": 64}
        assert t.static_pp("Chunk", "Chunk_C", 1024) == 64
        # dynamic: candidates tried one per call, then committed
        outs = [sel() for _ in range(3)]
        assert set(outs[:2]) == {"slow", "fast"}
        assert t.ctx.dynamic_state["Decode"].committed is not None
        # published kernel PPs readable through the single lookup
        assert at.tuned("matmul") == {"block_m": 256, "block_n": 512}

    def test_phase_order_enforced(self, tmp_path):
        t, _ = build_session(str(tmp_path))
        from repro.core.errors import OATPriorityError
        with pytest.raises(OATPriorityError):
            t.run("static")

    def test_select_needs_no_finalize(self, tmp_path):
        t = at.AutoTuner(str(tmp_path))
        sel = t.autotune("dynamic", "select", name="S")
        sel.alternative()(lambda: 1)
        assert "S" in t.ctx.registry            # registered immediately
        assert sel.finalize() is sel.region      # compat no-op

    def test_dsl_preprocess_path(self, tmp_path):
        def k(N, A):
            #OAT$ install unroll region start
            #OAT$ name DslK
            #OAT$ varied (i) from 1 to 2
            for i in range(N):
                A[i] = A[i] * 2.0
            #OAT$ install unroll region end
            return A

        t = at.AutoTuner(str(tmp_path))
        regions = t.preprocess(k)
        assert "DslK" in regions and "DslK" in t.ctx.registry


class TestDeprecationShims:
    def test_shims_dispatch_to_same_registry(self, tmp_path):
        """Legacy decorators and the session decorator land in the same
        regions, tuned identically by the session."""
        from repro.core.directives import install_variable
        t = at.AutoTuner(str(tmp_path), executor="analytic-cost")
        t.set_bps(numprocs=1, start=1024, end=1024, dist=1024)
        with pytest.deprecated_call():
            @install_variable(t.ctx, name="Legacy",
                              varied=Varied(("x",), values=(1, 2, 3)))
            def legacy(x=1):
                return float(x)
        assert "Legacy" in t.ctx.registry
        t.run("install", ["Legacy"])
        assert t.best("Legacy") == {"Legacy_X": 1}
        # and the result persisted like any session-declared region
        assert t.records.lookup("install", "Legacy", {}) is not None

    def test_select_region_shim_warns(self, tmp_path):
        from repro.core.directives import dynamic_select
        ctx = ATContext(str(tmp_path))
        with pytest.deprecated_call():
            sel = dynamic_select(ctx, name="OldSel")
        sel.alternative()(lambda: 0)
        sel.finalize()
        assert "OldSel" in ctx.registry

    def test_ops_set_tuned_shim(self):
        from repro.kernels import ops
        ops.set_tuned("shim_kernel", block_m=64)
        assert at.tuned("shim_kernel") == {"block_m": 64}
        assert ops.tuned("shim_kernel") == {"block_m": 64}


class TestRecordStorePersistence:
    def test_warm_path_zero_executor_invocations(self, tmp_path):
        """The acceptance criterion: a fresh AutoTuner on the same workdir
        loads install/static optima without a single measurement."""
        wd = str(tmp_path)
        t1, _ = build_session(wd)
        t1.run("all")
        assert t1.executor_calls > 0
        cold_best = (t1.best("Blocks"), t1.best("Chunk"))

        t2, _ = build_session(wd, booby_trap=True)
        ran = t2.run("all")
        assert t2.executor_calls == 0
        assert ran["install"] == [] and ran["static"] == []
        assert ("install", "Blocks") in t2.warm_hits
        assert ("static", "Chunk") in t2.warm_hits
        assert (t2.best("Blocks"), t2.best("Chunk")) == cold_best
        # paper-format .dat files re-materialised for fidelity
        assert os.path.exists(os.path.join(wd, "OAT_InstallParam.dat"))
        assert os.path.exists(os.path.join(wd, "OAT_StaticParam.dat"))

    def test_dynamic_commit_persists_across_sessions(self, tmp_path):
        wd = str(tmp_path)
        t1, sel1 = build_session(wd)
        t1.run("all")
        for _ in range(3):
            sel1()
        committed = t1.ctx.dynamic_state["Decode"].committed

        t2, sel2 = build_session(wd, booby_trap=True)
        t2.run("all")
        # committed winner warm-loaded: the first call runs it directly
        assert t2.ctx.dynamic_state["Decode"].committed == committed

    def test_force_retunes(self, tmp_path):
        wd = str(tmp_path)
        t1, _ = build_session(wd)
        t1.run("install")
        t2, _ = build_session(wd)
        t2.run("install", force=True)
        assert t2.executor_calls > 0

    def test_records_scoped_by_machine(self, tmp_path):
        wd = str(tmp_path)
        t1, _ = build_session(wd)
        t1.run("install")
        # a different machine fingerprint must not see the records
        t2, _ = build_session(wd, machine="some-other-box")
        t2.run("install")
        assert t2.executor_calls > 0

    def test_jsonl_format_readable(self, tmp_path):
        wd = str(tmp_path)
        t, _ = build_session(wd)
        t.run("install")
        lines = open(os.path.join(wd, "OAT_Records.jsonl")).read() \
            .strip().splitlines()
        recs = [json.loads(ln) for ln in lines]
        assert any(r["region"] == "Blocks" and r["phase"] == "install"
                   and r["pp"] == {"Blocks_BM": 256, "Blocks_BN": 512}
                   for r in recs)

    def test_corrupt_lines_skipped(self, tmp_path):
        wd = str(tmp_path)
        t, _ = build_session(wd)
        t.run("install")
        with open(os.path.join(wd, "OAT_Records.jsonl"), "a") as f:
            f.write("not json\n")
        store = at.ATRecordStore(wd)
        assert store.lookup("install", "Blocks", {}) is not None

    def test_bp_key_canonical(self):
        assert bp_key({"b": 2, "a": 1}) == bp_key({"a": 1, "b": 2})
        assert bp_key(None) == bp_key({}) == ()


class TestBackendRegistries:
    def test_unknown_backend_raises(self):
        from repro.core.errors import OATSpecError
        with pytest.raises(OATSpecError, match="unknown executor"):
            at.executors.get("no-such-backend")

    def test_duplicate_registration_needs_overwrite(self):
        from repro.core.errors import OATSpecError
        at.searchers.register("test-dup", overwrite=True)(lambda *a, **k: None)
        with pytest.raises(OATSpecError, match="already registered"):
            at.searchers.register("test-dup")(lambda *a, **k: None)
        at.searchers.register("test-dup", overwrite=True)(lambda *a, **k: None)

    def test_builtin_backends_present(self):
        for name in ("composed", "brute-force", "ad-hoc", "dspline-guided"):
            assert name in at.searchers
        for name in ("wall-clock", "analytic-cost"):
            assert name in at.executors

    def test_custom_executor_by_name(self, tmp_path):
        calls = []

        @at.executors.register("table-test", overwrite=True)
        def table(region, bp_env):
            def measure(asg):
                calls.append(dict(asg))
                return float(asg["R_X"])
            return measure

        t = at.AutoTuner(str(tmp_path), executor="table-test")
        t.set_bps(numprocs=1, start=1, end=1, dist=1)

        @t.autotune("install", "variable", name="R",
                    varied=Varied(("x",), values=(3, 1, 2)))
        def r(x=3):
            raise AssertionError("custom executor should not call fn")

        t.run("install")
        assert t.best("R") == {"R_X": 1}
        assert len(calls) == 3

    def test_session_searcher_override(self, tmp_path):
        """brute-force searcher joins axes the composed search would split."""
        t = at.AutoTuner(str(tmp_path), executor="analytic-cost",
                         searcher="brute-force")
        t.set_bps(numprocs=1, start=1, end=1, dist=1)

        @t.autotune("install", "variable", name="BF",
                    varied=Varied(("a", "b"), values=(1, 2, 3)),
                    search="ad-hoc")
        def bf(a=1, b=1):
            return abs(a - 2) * 10 + abs(b - 3) + 1.0
        t.run("install")
        assert t.executor_calls == 9           # 3x3 joint product
        assert t.best("BF") == {"BF_A": 2, "BF_B": 3}

    def test_dspline_guided_searcher_samples_subset(self):
        region = ATRegion("install", "variable", "G",
                          fn=lambda **kw: None,
                          varied=Varied("u", 1, 16))
        plan = SearchPlan(region)
        seen = []

        def measure(asg):
            seen.append(asg["G_U"])
            u = asg["G_U"]
            return 10.0 / u + 0.15 * u

        res = at.searchers.get("dspline-guided")(plan, measure)
        assert len(seen) < 16                  # only sample points measured
        assert res.best["G_U"] in range(6, 13)  # near the true optimum ~8

    def test_module_level_autotune_uses_current_session(self, tmp_path):
        t = at.AutoTuner(str(tmp_path))
        assert at.current_session() is t

        @at.autotune("install", "variable", name="Mod",
                     varied=Varied(("x",), values=(1, 2)))
        def mod(x=1):
            return float(x)
        assert "Mod" in t.ctx.registry


class TestTunedLookup:
    def test_tuned_with_bp_point(self):
        at.publish("k1", block=128)
        at.publish_for_bp("k1", {"OAT_PROBSIZE": 2048}, block=256)
        assert at.tuned("k1") == {"block": 128}
        assert at.tuned("k1", OAT_PROBSIZE=2048) == {"block": 256}
        assert at.tuned("k1", OAT_PROBSIZE=4096) == {"block": 128}

    def test_unknown_kernel_empty(self):
        assert at.tuned("never-published-kernel") == {}
