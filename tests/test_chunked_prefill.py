"""Chunked prefill: kernel, cache growth, engine bit-identity, scheduling.

Correctness contract: chunked prefill is an *implementation detail* of
the paged engine — greedy outputs must be bit-identical to the monolithic
dense engine (which matches a plain prefill+decode loop,
test_serving_tuning.py), for every chunk size, including ragged last
chunks (prompt % chunk != 0), chunk > prompt, and swap-out mid-prefill
followed by resume.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import build_model
from repro.serving import PagedKVCache, Request, ServingEngine


@pytest.fixture(scope="module")
def paged_model():
    cfg = ARCHS["yi-6b"].reduced()      # plain GQA: paged-capable
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _requests(n=3, max_new=6, plen=11):
    return [Request(rid=i, prompt=[1 + i] + [(3 * i + j) % 90 + 2
                                             for j in range(plen - 1)],
                    max_new_tokens=max_new) for i in range(n)]


def _dense_want(model, params, reqs_fn, max_len=48, max_steps=200):
    eng = ServingEngine(model, params, n_lanes=2, max_len=max_len)
    for r in reqs_fn():
        eng.submit(r)
    return {r.rid: r.out_tokens for r in eng.run(max_steps=max_steps)}


# --------------------------------------------------------------------------
# kernel: paged prefill oracle + Pallas kernel
# --------------------------------------------------------------------------


class TestPagedPrefillKernel:
    def _pools(self, key, p, hkv, psz, d):
        kp = jax.random.normal(jax.random.PRNGKey(key), (p, hkv, psz, d))
        vp = jax.random.normal(jax.random.PRNGKey(key + 1),
                               (p, hkv, psz, d))
        return kp * 0.3, vp * 0.3

    def test_prefill_ref_matches_dense_causal(self):
        """Chunk queries at absolute offset over gathered pages == the
        dense causal oracle with end-aligned queries."""
        from repro.kernels import ref
        b, h, hkv, d, psz, nblk = 1, 4, 2, 16, 8, 3
        c, start = 6, 10
        kv_len = start + c
        q = jax.random.normal(jax.random.PRNGKey(0), (b, h, c, d)) * 0.3
        kp, vp = self._pools(1, 9, hkv, psz, d)
        table = jnp.asarray([[4, 2, 7]], jnp.int32)
        kd = kp[table].transpose(0, 2, 1, 3, 4).reshape(
            b, hkv, nblk * psz, d)
        vd = vp[table].transpose(0, 2, 1, 3, 4).reshape(
            b, hkv, nblk * psz, d)
        want = ref.attention_ref(q, kd[:, :, :kv_len], vd[:, :, :kv_len],
                                 causal=True)
        got = ref.paged_prefill_ref(q, kp, vp, table,
                                    jnp.asarray([start], jnp.int32),
                                    jnp.asarray([kv_len], jnp.int32))
        np.testing.assert_allclose(got, want, atol=1e-5)

    def test_flash_paged_prefill_interpret(self):
        from repro.kernels import ref
        from repro.kernels.flash_attention import flash_paged_prefill
        b, h, hkv, d, psz, nblk = 2, 4, 2, 16, 8, 4
        c = 8
        q = jax.random.normal(jax.random.PRNGKey(0), (b, h, c, d)) * 0.3
        kp, vp = self._pools(1, 11, hkv, psz, d)
        table = jnp.asarray([[3, 7, 1, 9], [5, 2, 6, 0]], jnp.int32)
        start = jnp.asarray([12, 0], jnp.int32)
        kv_len = jnp.asarray([20, 5], jnp.int32)   # seq 1: ragged chunk
        want = ref.paged_prefill_ref(q, kp, vp, table, start, kv_len)
        # tile space: whole-chunk, sub-chunk block_q, sub-page block_k
        for bq, bk in [(128, None), (4, 4), (8, 2), (4, 8)]:
            got = flash_paged_prefill(q, kp, vp, table, start, kv_len,
                                      block_q=bq, block_k=bk,
                                      interpret=True)
            # rows past kv_len are padding (their KV never committed)
            np.testing.assert_allclose(got[0], want[0], atol=1e-5,
                                       err_msg=f"bq={bq} bk={bk}")
            np.testing.assert_allclose(got[1, :, :5], want[1, :, :5],
                                       atol=1e-5, err_msg=f"bq={bq} bk={bk}")
        # a block_k that does not divide the page falls back to whole-page
        got_bad = flash_paged_prefill(q, kp, vp, table, start, kv_len,
                                      block_k=3, interpret=True)
        np.testing.assert_allclose(got_bad[0], want[0], atol=1e-5)

    def test_ops_dispatch_cpu(self):
        from repro.kernels import ops, ref
        b, h, hkv, d, psz, p = 1, 2, 1, 8, 4, 5
        q = jnp.ones((b, h, 3, d)) * 0.1
        kp = jnp.ones((p, hkv, psz, d)) * 0.2
        vp = jnp.ones((p, hkv, psz, d)) * 0.3
        table = jnp.asarray([[1, 2]], jnp.int32)
        start = jnp.asarray([4], jnp.int32)
        kv_len = jnp.asarray([7], jnp.int32)
        got = ops.paged_prefill_attention(q, kp, vp, table, start, kv_len)
        want = ref.paged_prefill_ref(q, kp, vp, table, start, kv_len)
        np.testing.assert_allclose(got, want, atol=1e-6)


# --------------------------------------------------------------------------
# PagedKVCache: chunk-granular page growth
# --------------------------------------------------------------------------


class TestEnsureTokens:
    def test_chunk_granular_growth(self, paged_model):
        cfg, model, params = paged_model
        kv = PagedKVCache(model, n_lanes=2, max_len=64, n_pages=9,
                          page_size=8)
        assert kv.ensure_tokens(0, 6)       # one page covers [0, 6)
        assert kv.used_pages == 1
        assert kv.ensure_tokens(0, 8)       # still within page 0
        assert kv.used_pages == 1
        assert kv.ensure_tokens(0, 20)      # grows to 3 pages
        assert kv.used_pages == 3
        assert not kv.ensure_tokens(0, 65)  # beyond max_len
        kv.release(0)
        assert kv.used_pages == 0

    def test_partial_alloc_survives_failure(self, paged_model):
        cfg, model, params = paged_model
        kv = PagedKVCache(model, n_lanes=1, max_len=64, n_pages=3,
                          page_size=8)       # 2 usable pages
        assert not kv.ensure_tokens(0, 24)   # needs 3, pool has 2
        assert kv.n_blocks[0] == 2           # acquired pages kept
        assert kv.ensure_tokens(0, 16)       # retry within holdings: ok

    def test_decode_extra_masks_prefill_lanes(self, paged_model):
        cfg, model, params = paged_model
        kv = PagedKVCache(model, n_lanes=2, max_len=32, n_pages=9,
                          page_size=8)
        kv.ensure_tokens(0, 8)
        kv.ensure_tokens(1, 8)
        (tbl,) = kv.decode_extra(mask_lanes=[0])
        assert int(tbl[0, 0]) == 0           # masked to the null page
        assert int(tbl[1, 0]) != 0
        assert kv.table[0, 0] != 0           # backing table untouched


# --------------------------------------------------------------------------
# engine: bit-identity across chunk sizes
# --------------------------------------------------------------------------


class TestChunkedEngine:
    def test_dense_rejects_chunked(self, paged_model):
        cfg, model, params = paged_model
        with pytest.raises(ValueError, match="paged"):
            ServingEngine(model, params, n_lanes=1, max_len=32,
                          prefill_chunk=8)

    @pytest.mark.parametrize("chunk", [4, 8, 64])
    def test_chunked_matches_dense(self, paged_model, chunk):
        """Chunk sizes cover prompt % chunk != 0 (11-token prompts,
        chunk=4) and chunk > prompt (chunk=64)."""
        cfg, model, params = paged_model
        want = _dense_want(model, params, _requests)
        eng = ServingEngine(model, params, n_lanes=2, max_len=48,
                            cache="paged", page_size=8,
                            prefill_chunk=chunk)
        for r in _requests():
            eng.submit(r)
        got = {r.rid: r.out_tokens for r in eng.run(max_steps=300)}
        assert got == want
        assert eng.prefill_chunks >= 3      # every prompt streamed in

    def test_swap_out_mid_prefill_then_resume(self, paged_model):
        """Tiny pool + two long prompts: one lane is evicted *during*
        prefill (partial pages swap to host), resumes, and still produces
        the exact dense-engine output."""
        cfg, model, params = paged_model

        def reqs():
            return [Request(rid=i,
                            prompt=[(7 * i + j) % 100 + 1
                                    for j in range(24)],
                            max_new_tokens=4) for i in range(2)]

        want = _dense_want(model, params, reqs, max_len=64)
        eng = ServingEngine(model, params, n_lanes=2, max_len=64,
                            cache="paged", page_size=8, n_pages=6,
                            prefill_chunk=8)
        for r in reqs():
            eng.submit(r)
        done = eng.run(max_steps=400)
        assert {r.rid: r.out_tokens for r in done} == want
        assert eng.scheduler.preemptions > 0        # evicted mid-prefill
        assert eng.kv.swap_outs > 0 and eng.kv.swap_ins > 0

    def test_short_request_decodes_before_long_prefill_finishes(
            self, paged_model):
        """The continuous-batching point: a short prompt behind a long one
        gets its first token while the long prompt is still streaming in
        (with monolithic prefill it would head-of-line-block)."""
        cfg, model, params = paged_model
        long_req = Request(rid=0, prompt=list(range(1, 41)),
                           max_new_tokens=6)
        short_req = Request(rid=1, prompt=[5, 6, 7], max_new_tokens=6)
        eng = ServingEngine(model, params, n_lanes=2, max_len=64,
                            cache="paged", page_size=8, prefill_chunk=4)
        eng.submit(long_req)
        eng.submit(short_req)
        done = {r.rid: r for r in eng.run(max_steps=300)}
        assert len(done) == 2
        # long prompt: 40 tokens / chunk 4 = 10 ticks of prefill; the
        # short request's first token must land before the long one's
        assert done[1].first_token_t < done[0].first_token_t
        # and the outputs still match solo (uninterleaved) runs
        for rid, prompt in ((0, list(range(1, 41))), (1, [5, 6, 7])):
            solo = ServingEngine(model, params, n_lanes=2, max_len=64)
            solo.submit(Request(rid=0, prompt=prompt, max_new_tokens=6))
            assert done[rid].out_tokens == \
                solo.run(max_steps=100)[0].out_tokens

    def test_single_token_prompt_and_eos(self, paged_model):
        """max_new_tokens=1 finishes at the end of prefill (no decode)."""
        cfg, model, params = paged_model
        eng = ServingEngine(model, params, n_lanes=1, max_len=32,
                            cache="paged", page_size=8, prefill_chunk=8)
        eng.submit(Request(rid=0, prompt=[3, 1, 4], max_new_tokens=1))
        done = eng.run(max_steps=20)
        assert len(done) == 1 and len(done[0].out_tokens) == 1


# --------------------------------------------------------------------------
# prefill tuning region (repro.at dynamic select)
# --------------------------------------------------------------------------


class TestPrefillTuningRegion:
    def _mk(self, calls):
        def make_prefill(bq, bk):
            def fn():
                calls.append((bq, bk))
                return {"bq": bq, "bk": bk}
            return fn
        return make_prefill

    def test_bucket_chunk_product_space_commits(self, tmp_path):
        from repro import at
        from repro.tuning import DecodeAutoTuner
        session = at.AutoTuner(str(tmp_path))
        tuner = DecodeAutoTuner(session, lambda bk: (lambda: bk),
                                buckets=(512,), block_ks=(256,))
        calls: list = []
        tuner.add_prefill(self._mk(calls), chunk_sizes=(8, 16),
                          buckets=(512, 2048), block_qs=(4, 8),
                          block_ks=(4, 8))
        assert len(tuner.prefill_regions) == 4      # bucket x chunk
        assert all(len(r.subregions) == 4           # block_q x block_k
                   for r in tuner.prefill_regions.values())
        for _ in range(4):                          # one call per candidate
            tuner.prefill(300, 8)
        pp = tuner.committed_prefill_params()[(512, 8)]
        assert pp["block_q"] in (4, 8) and pp["block_k"] in (4, 8)
        assert tuner.committed_prefill_params()[(2048, 8)] is None

    def test_warm_restart_zero_tuning(self, tmp_path):
        """A second session on the same workdir starts with the prefill
        bucket committed — zero tuning-executor invocations, alongside
        the decode winners."""
        from repro import at
        from repro.tuning import DecodeAutoTuner

        calls1: list = []
        s1 = at.AutoTuner(str(tmp_path))
        t1 = DecodeAutoTuner(s1, lambda bk: (lambda: bk),
                             buckets=(512,), block_ks=(256,))
        t1.add_prefill(self._mk(calls1), chunk_sizes=(8,), buckets=(512,),
                       block_qs=(4, 8), block_ks=(8,))
        for _ in range(2):
            t1.prefill(100, 8)
        winner = t1.committed_prefill()[(512, 8)]
        assert winner is not None

        calls2: list = []
        s2 = at.AutoTuner(str(tmp_path))
        t2 = DecodeAutoTuner(s2, lambda bk: (lambda: bk),
                             buckets=(512,), block_ks=(256,))
        t2.add_prefill(self._mk(calls2), chunk_sizes=(8,), buckets=(512,),
                       block_qs=(4, 8), block_ks=(8,))
        assert t2.committed_prefill()[(512, 8)] == winner
        assert s2.executor_calls == 0
        assert ("dynamic", "PrefillBucket_512_c8") in s2.warm_hits
        out = t2.prefill(100, 8)
        assert out["bq"] == (4, 8)[winner]
        assert calls2 == [((4, 8)[winner], 8)]      # no re-measurement

    def test_engine_routes_through_prefill_region(self, paged_model,
                                                  tmp_path):
        """End-to-end: the engine's prefill tick goes through the tuner's
        prefill region and outputs stay bit-identical."""
        cfg, model, params = paged_model
        from repro.launch.serve import _make_autotuner
        want = _dense_want(model, params, lambda: _requests(2))
        tuner = _make_autotuner(model, str(tmp_path), "paged", 8,
                                prefill_chunk=8)
        eng = ServingEngine(model, params, n_lanes=2, max_len=48,
                            cache="paged", page_size=8, prefill_chunk=8,
                            autotuner=tuner)
        for r in _requests(2):
            eng.submit(r)
        got = {r.rid: r.out_tokens for r in eng.run(max_steps=200)}
        assert got == want
        assert any(v is not None
                   for v in tuner.committed_prefill().values()) \
            or eng.prefill_chunks > 0
