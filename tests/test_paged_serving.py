"""Paged-KV serving stack: cache backends, scheduler, metrics, engine.

Correctness contract: the paged engine (with or without preemption) is an
*implementation detail* — greedy outputs must be bit-identical to the
dense engine, which in turn matches a plain prefill+decode loop
(test_serving_tuning.py).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import build_model
from repro.serving import (PagedKVCache, Request, Scheduler,
                           ServingEngine, ServingMetrics)
from repro.serving.kvcache import _lane_set


@pytest.fixture(scope="module")
def paged_model():
    cfg = ARCHS["yi-6b"].reduced()      # plain GQA: paged-capable
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _requests(n, max_new=6, plen=4):
    return [Request(rid=i, prompt=[1 + i] + list(range(2, 2 + plen - 1)),
                    max_new_tokens=max_new) for i in range(n)]


# --------------------------------------------------------------------------
# kernels: paged decode oracle + Pallas kernel
# --------------------------------------------------------------------------


class TestPagedDecodeKernel:
    def test_paged_ref_matches_dense_ref(self):
        """Gathering pages through a table == the dense decode oracle."""
        from repro.kernels import ref
        key = jax.random.PRNGKey(0)
        b, h, hkv, d, psz, nblk = 2, 4, 2, 16, 8, 3
        q = jax.random.normal(key, (b, h, 1, d)) * 0.3
        kd = jax.random.normal(jax.random.PRNGKey(1),
                               (b, hkv, nblk * psz, d)) * 0.3
        vd = jax.random.normal(jax.random.PRNGKey(2),
                               (b, hkv, nblk * psz, d)) * 0.3
        kv_len = jnp.asarray([20, 13], jnp.int32)
        # scatter the dense caches into a shuffled pool
        table = np.array([[3, 7, 1], [5, 2, 6]], np.int32)
        pool_shape = (9, hkv, psz, d)
        kp = jnp.zeros(pool_shape)
        vp = jnp.zeros(pool_shape)
        for bi in range(b):
            for blk in range(nblk):
                sl = slice(blk * psz, (blk + 1) * psz)
                kp = kp.at[table[bi, blk]].set(kd[bi, :, sl, :])
                vp = vp.at[table[bi, blk]].set(vd[bi, :, sl, :])
        # lanes may not share pages for this equivalence to hold
        want = ref.decode_ref(q, kd, vd, kv_len)
        got = ref.paged_decode_ref(q, kp, vp, jnp.asarray(table), kv_len)
        np.testing.assert_allclose(got, want, atol=1e-5)

    def test_flash_paged_decode_interpret(self):
        from repro.kernels import ref
        from repro.kernels.flash_attention import flash_paged_decode
        b, h, hkv, d, psz, p, nblk = 2, 4, 2, 16, 8, 10, 3
        q = jax.random.normal(jax.random.PRNGKey(0), (b, h, 1, d)) * 0.3
        kp = jax.random.normal(jax.random.PRNGKey(1), (p, hkv, psz, d)) * 0.3
        vp = jax.random.normal(jax.random.PRNGKey(2), (p, hkv, psz, d)) * 0.3
        table = jnp.asarray([[3, 7, 1], [5, 2, 0]], jnp.int32)
        kv_len = jnp.asarray([20, 13], jnp.int32)
        want = ref.paged_decode_ref(q, kp, vp, table, kv_len)
        got = flash_paged_decode(q, kp, vp, table, kv_len, interpret=True)
        np.testing.assert_allclose(got, want, atol=1e-5)
        # block_k sub-page split-K tiling (the kernel's run-time AT PP)
        got_sub = flash_paged_decode(q, kp, vp, table, kv_len,
                                     block_k=psz // 2, interpret=True)
        np.testing.assert_allclose(got_sub, want, atol=1e-5)
        # a block_k that does not divide the page falls back to whole-page
        got_bad = flash_paged_decode(q, kp, vp, table, kv_len,
                                     block_k=3, interpret=True)
        np.testing.assert_allclose(got_bad, want, atol=1e-5)

    def test_ops_dispatch_cpu(self):
        from repro.kernels import ops, ref
        b, h, hkv, d, psz, p = 1, 2, 1, 8, 4, 5
        q = jnp.ones((b, h, 1, d)) * 0.1
        kp = jnp.ones((p, hkv, psz, d)) * 0.2
        vp = jnp.ones((p, hkv, psz, d)) * 0.3
        table = jnp.asarray([[1, 2]], jnp.int32)
        kv_len = jnp.asarray([6], jnp.int32)
        got = ops.paged_decode_attention(q, kp, vp, table, kv_len)
        want = ref.paged_decode_ref(q, kp, vp, table, kv_len)
        np.testing.assert_allclose(got, want, atol=1e-6)


# --------------------------------------------------------------------------
# PagedKVCache backend
# --------------------------------------------------------------------------


class TestPagedKVCache:
    def test_alloc_accounting_and_null_page(self, paged_model):
        cfg, model, params = paged_model
        kv = PagedKVCache(model, n_lanes=2, max_len=64, n_pages=17,
                          page_size=8)
        assert kv.free_pages == 16          # page 0 reserved
        logits, c1 = model.prefill(params, jnp.asarray([[1, 2, 3]]),
                                   None, kv.prefill_len(3))
        assert kv.admit(0, c1, 3)
        assert kv.used_pages == 1           # 3 tokens -> one 8-token page
        assert kv.cache_tokens() == 8       # memory scales with live tokens
        assert 0 not in kv.table[0, :kv.n_blocks[0]]
        # page-boundary growth
        assert kv.ensure_capacity(0, 7)     # still page 0 of the lane
        assert kv.used_pages == 1
        assert kv.ensure_capacity(0, 8)     # crosses into block 1
        assert kv.used_pages == 2
        kv.release(0)
        assert kv.used_pages == 0 and kv.free_pages == 16

    def test_swap_out_in_roundtrip(self, paged_model):
        cfg, model, params = paged_model
        kv = PagedKVCache(model, n_lanes=2, max_len=32, n_pages=9,
                          page_size=8)
        logits, c1 = model.prefill(params, jnp.asarray([[5, 6, 7, 8]]),
                                   None, kv.prefill_len(4))
        kv.admit(0, c1, 4)
        before = jax.tree.map(
            lambda pool: np.asarray(pool[:, kv.table[0, :1]]), kv.caches)
        h = kv.swap_out(0)
        assert kv.used_pages == 0
        assert kv.swap_in(1, h)             # resume on a different lane
        after = jax.tree.map(
            lambda pool: np.asarray(pool[:, kv.table[1, :1]]), kv.caches)
        jax.tree.map(np.testing.assert_array_equal, before, after)

    def test_alloc_failure(self, paged_model):
        cfg, model, params = paged_model
        kv = PagedKVCache(model, n_lanes=1, max_len=64, n_pages=3,
                          page_size=8)   # 2 usable pages
        assert not kv.can_admit(24)      # would need 3 pages
        logits, c1 = model.prefill(params, jnp.asarray([[1] * 16]),
                                   None, kv.prefill_len(16))
        assert kv.admit(0, c1, 16)
        assert not kv.ensure_capacity(0, 16)   # pool exhausted

    def test_swa_arch_rejected(self):
        cfg = ARCHS["h2o-danube-1.8b"].reduced()   # sliding window
        model = build_model(cfg)
        with pytest.raises(ValueError, match="paged"):
            PagedKVCache(model, n_lanes=1, max_len=32, n_pages=5,
                         page_size=8)


# --------------------------------------------------------------------------
# _lane_set regression (satellite: full-width branch clobbered other lanes)
# --------------------------------------------------------------------------


class TestLaneSet:
    def test_full_width_source_writes_only_target_lane(self):
        full = jnp.arange(2 * 2 * 4 * 3, dtype=jnp.float32
                          ).reshape(2, 2, 4, 3)
        one = jnp.full((2, 2, 4, 3), -1.0)       # full-width source
        out = _lane_set(full, one, 1)
        np.testing.assert_array_equal(out[:, 0], full[:, 0])  # untouched
        np.testing.assert_array_equal(out[:, 1], one[:, 0])

    def test_two_concurrent_lanes_no_crosstalk(self, paged_model):
        """Second admission must not perturb the first lane's generation."""
        cfg, model, params = paged_model

        def solo(prompt):
            eng = ServingEngine(model, params, n_lanes=2, max_len=48)
            eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=5))
            return eng.run(max_steps=30)[0].out_tokens

        want = {0: solo([3, 1, 4, 1]), 1: solo([2, 7, 1, 8])}
        eng = ServingEngine(model, params, n_lanes=2, max_len=48)
        eng.submit(Request(rid=0, prompt=[3, 1, 4, 1], max_new_tokens=5))
        eng.submit(Request(rid=1, prompt=[2, 7, 1, 8], max_new_tokens=5))
        done = {r.rid: r.out_tokens for r in eng.run(max_steps=30)}
        assert done == want


# --------------------------------------------------------------------------
# scheduler + engine
# --------------------------------------------------------------------------


class TestScheduler:
    def test_fifo_deque(self):
        from collections import deque
        s = Scheduler(n_lanes=1)
        assert isinstance(s.waiting, deque)
        for i in range(5):
            s.submit(Request(rid=i, prompt=[1]))
        order = []
        while s.has_queued:
            kind, item = s.next_admission()
            order.append(item.rid)
        assert order == [0, 1, 2, 3, 4]

    def test_timeslice_victim_yields_to_queue(self):
        """A time-slice victim re-queues at the BACK: the waiting request
        gets the lane (rotation), not an immediate self-re-admission."""
        s = Scheduler(n_lanes=1)
        s.submit(Request(rid=9, prompt=[1]))
        req = Request(rid=1, prompt=[1])
        s.occupy(0, req, pos=4, remaining=2)
        s.preempt(0, req, handle="h")
        kind, item = s.next_admission()
        assert kind == "new" and item.rid == 9
        kind, item = s.next_admission()
        assert kind == "resume" and item.req.rid == 1
        assert item.pos == 4 and item.remaining == 2

    def test_page_pressure_victim_resumes_first(self):
        """A page-pressure victim re-queues at the FRONT so freeing memory
        never starves the evicted sequence."""
        s = Scheduler(n_lanes=1)
        s.submit(Request(rid=9, prompt=[1]))
        req = Request(rid=1, prompt=[1])
        s.occupy(0, req, pos=4, remaining=2)
        s.preempt(0, req, handle="h", priority=True)
        kind, item = s.next_admission()
        assert kind == "resume" and item.req.rid == 1

    def test_pick_victim_timeslice(self):
        s = Scheduler(n_lanes=2, timeslice=3)
        s.occupy(0, Request(rid=0, prompt=[1]), 4, 8)
        s.occupy(1, Request(rid=1, prompt=[1]), 4, 8)
        s.lanes[0].steps_served = 5
        s.lanes[1].steps_served = 2
        assert s.pick_victim() is None       # nothing queued
        s.submit(Request(rid=2, prompt=[1]))
        assert s.pick_victim() == 0          # longest-served past slice
        s.lanes[0].steps_served = 1
        assert s.pick_victim() is None       # nobody past the slice


class TestServingEngineFIFO:
    def test_fifo_fairness_under_pressure(self, paged_model):
        """8 requests through 1 lane: service order == submission order."""
        cfg, model, params = paged_model
        eng = ServingEngine(model, params, n_lanes=1, max_len=48)
        for r in _requests(8, max_new=3):
            eng.submit(r)
        done = eng.run(max_steps=200)
        assert [r.rid for r in done] == list(range(8))
        firsts = [r.first_token_t for r in done]
        assert firsts == sorted(firsts)


class TestPagedEngine:
    def test_paged_matches_dense(self, paged_model):
        cfg, model, params = paged_model
        reqs = _requests(3, max_new=6)
        dense = ServingEngine(model, params, n_lanes=2, max_len=48)
        for r in reqs:
            dense.submit(r)
        want = {r.rid: r.out_tokens for r in dense.run(max_steps=100)}
        paged = ServingEngine(model, params, n_lanes=2, max_len=48,
                              cache="paged", page_size=8)
        for r in _requests(3, max_new=6):
            paged.submit(r)
        got = {r.rid: r.out_tokens for r in paged.run(max_steps=100)}
        assert got == want

    def test_preemption_more_requests_than_lanes(self, paged_model):
        """2 lanes, 5 requests, tiny pool + timeslice: the scheduler must
        preempt (pages swap out/in) and every request still finishes with
        the exact dense-engine output."""
        cfg, model, params = paged_model
        dense = ServingEngine(model, params, n_lanes=2, max_len=48)
        for r in _requests(5, max_new=6):
            dense.submit(r)
        want = {r.rid: r.out_tokens for r in dense.run(max_steps=300)}

        eng = ServingEngine(model, params, n_lanes=2, max_len=48,
                            cache="paged", page_size=8, n_pages=9,
                            timeslice=3)
        for r in _requests(5, max_new=6):
            eng.submit(r)
        done = eng.run(max_steps=400)
        assert len(done) == 5                # served 5 > 2 lanes
        assert eng.scheduler.preemptions > 0
        assert eng.kv.swap_outs > 0 and eng.kv.swap_ins > 0
        assert {r.rid: r.out_tokens for r in done} == want
        assert eng.metrics.summary()["preemptions"] > 0
        # genuine rotation: a request beyond the lane count got its first
        # token before ANY request finished (preemption actually yielded
        # the lane to the queue, not an immediate self-re-admission)
        by_rid = {r.rid: r for r in done}
        first_finish = min(r.finish_t for r in done)
        assert by_rid[2].first_token_t <= first_finish

    def test_dense_timeslice_preemption(self, paged_model):
        """Preemption also works on the dense backend (lane-strip swap)."""
        cfg, model, params = paged_model
        eng = ServingEngine(model, params, n_lanes=1, max_len=48,
                            timeslice=2)
        for r in _requests(3, max_new=6):
            eng.submit(r)
        done = eng.run(max_steps=200)
        assert len(done) == 3
        assert eng.scheduler.preemptions > 0

    def test_pool_too_small_raises(self, paged_model):
        cfg, model, params = paged_model
        eng = ServingEngine(model, params, n_lanes=1, max_len=64,
                            cache="paged", page_size=8, n_pages=3)
        eng.submit(Request(rid=0, prompt=[1, 2, 3], max_new_tokens=40))
        with pytest.raises(RuntimeError, match="page pool too small"):
            eng.run(max_steps=100)


# --------------------------------------------------------------------------
# EOS guard (satellite: eos_id=0 is a valid stop token, None disables)
# --------------------------------------------------------------------------


class TestEOSGuard:
    def _zero_logit_engine(self, model, params, eos_id):
        """Engine whose decode always emits token 0."""
        def prefill_fn(p, tokens, fe, max_len):
            logits, caches = model.prefill(p, tokens, fe, max_len)
            return jnp.zeros_like(logits).at[:, 0].set(1.0), caches

        def decode_fn(p, caches, token, pos):
            logits, caches = model.decode_step(p, caches, token, pos)
            return jnp.zeros_like(logits).at[:, 0].set(1.0), caches

        return ServingEngine(model, params, n_lanes=1, max_len=48,
                             eos_id=eos_id, decode_fn=decode_fn,
                             prefill_fn=prefill_fn)

    def test_eos_zero_stops(self, paged_model):
        cfg, model, params = paged_model
        eng = self._zero_logit_engine(model, params, eos_id=0)
        eng.submit(Request(rid=0, prompt=[1, 2, 3], max_new_tokens=10))
        done = eng.run(max_steps=40)
        assert len(done) == 1
        assert len(done[0].out_tokens) < 10    # stopped on token 0

    def test_eos_none_never_stops_on_zero(self, paged_model):
        cfg, model, params = paged_model
        eng = self._zero_logit_engine(model, params, eos_id=None)
        eng.submit(Request(rid=0, prompt=[1, 2, 3], max_new_tokens=10))
        done = eng.run(max_steps=40)
        assert len(done[0].out_tokens) == 10   # token 0 is not EOS


# --------------------------------------------------------------------------
# metrics
# --------------------------------------------------------------------------


class TestMetrics:
    def test_percentiles(self):
        m = ServingMetrics()
        for i in range(4):
            r = Request(rid=i, prompt=[1], submit_t=float(i))
            r.first_token_t = float(i) + 0.5
            r.token_ts = [float(i) + 0.5, float(i) + 0.6, float(i) + 0.8]
            r.out_tokens = [7, 7, 7]
            r.finish_t = float(i) + 0.8
            m.observe(r)
        s = m.summary()
        assert s["requests"] == 4
        assert s["generated_tokens"] == 12
        assert s["ttft_s"]["p50"] == pytest.approx(0.5)
        # ITL samples alternate 0.1 / 0.2 -> p50 between them, p99 ~ 0.2
        assert 0.1 <= s["itl_s"]["p50"] <= 0.2
        assert s["itl_s"]["p99"] == pytest.approx(0.2, abs=0.01)
        assert s["wall_s"] == pytest.approx(3.8)
        assert s["tokens_per_s"] == pytest.approx(12 / 3.8)

    def test_empty_summary(self):
        s = ServingMetrics().summary()
        assert s["requests"] == 0 and s["ttft_s"]["p50"] is None

    def test_percentile_interpolates_on_tiny_samples(self):
        """Pinned 5-element series: linear interpolation between order
        statistics, not nearest-rank (which would report p99 == max and
        snap p50 to a sample)."""
        from repro.serving.metrics import percentile
        xs = [30.0, 10.0, 50.0, 20.0, 40.0]     # unsorted on purpose
        assert percentile(xs, 50) == pytest.approx(30.0)
        assert percentile(xs, 99) == pytest.approx(49.6)   # not 50.0
        assert percentile(xs, 0) == pytest.approx(10.0)
        assert percentile(xs, 100) == pytest.approx(50.0)
        assert percentile(xs, 25) == pytest.approx(20.0)
        assert percentile([7.0], 99) == pytest.approx(7.0)
        assert percentile([], 50) is None
        with pytest.raises(ValueError):
            percentile(xs, 101)
