"""Tensor-parallel paged serving over a device mesh.

Two layers of checks:

* in-process — a 1-device mesh (``make_serving_mesh("1x1")``) must be
  *bit-identical* to the unsharded engine across paged decode, chunked
  prefill, speculative decoding and int8 pools (the 1-device mesh takes
  the exact same code path: ``mesh_model_axis == 1`` skips shard_map),
  and region names must stay on the legacy spelling so existing tuning
  DBs warm-load unchanged;
* subprocess — a forced 4-host-device run (the main pytest process must
  keep seeing 1 device), asserting the 2x2-mesh engine's greedy outputs
  match the unsharded engine token for token, and that an indivisible
  head count fails with a clear ValueError instead of a shape crash.
"""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
sys.path.insert(0, SRC)

from repro.configs import get_arch                       # noqa: E402
from repro.distributed.sharding import make_serving_mesh  # noqa: E402
from repro.kernels import ops                            # noqa: E402
from repro.models import build_model                     # noqa: E402
from repro.serving import Request, ServingEngine         # noqa: E402


def run_with_devices(code: str, n: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=240)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def _serve_outputs(mesh=None, *, prefill_chunk=None, draft=False,
                   kv_dtype="fp", n_requests=3, max_new=4):
    cfg = get_arch("yi-6b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    draft_model = draft_params = None
    if draft:
        draft_model = model.draft_model()
        draft_params = model.slice_draft_params(params, draft_model)
    engine = ServingEngine(model, params, n_lanes=2, max_len=64,
                           cache="paged", page_size=8,
                           prefill_chunk=prefill_chunk,
                           draft_model=draft_model,
                           draft_params=draft_params,
                           spec_k=3 if draft else None,
                           kv_dtype=kv_dtype, mesh=mesh)
    rng = np.random.default_rng(0)
    for rid in range(n_requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              size=int(rng.integers(4, 12))).tolist()
        engine.submit(Request(rid=rid, prompt=prompt,
                              max_new_tokens=max_new))
    finished = engine.run(max_steps=n_requests * (max_new + 8))
    assert len(finished) == n_requests
    return {r.rid: list(r.out_tokens) for r in finished}


@pytest.mark.parametrize("variant", ["decode", "chunked", "spec", "int8"])
def test_one_device_mesh_bit_identical(variant):
    """mesh='1x1' must take the unsharded code path exactly: same greedy
    tokens across plain decode, chunked prefill, speculative decoding
    and int8 pools."""
    kw = {"decode": {},
          "chunked": {"prefill_chunk": 8},
          "spec": {"draft": True},
          "int8": {"kv_dtype": "int8"}}[variant]
    ref = _serve_outputs(None, **kw)
    got = _serve_outputs(make_serving_mesh("1x1"), **kw)
    assert got == ref


def test_one_device_mesh_keeps_legacy_region_names():
    """product-1 meshes reuse the legacy region spelling, so committed
    tuning DBs warm-load with zero re-tuning under --mesh 1x1."""
    from repro.tuning.dynamic import region_key
    assert region_key("decode", 128, mesh_shape="1x1") == "DecodeBucket_128"
    assert region_key("decode", 128, mesh_shape=None) == "DecodeBucket_128"
    assert region_key("decode", 128, mesh_shape="2x2") \
        == "DecodeBucket_128_mesh2x2"
    assert region_key("prefill", 128, chunk=8, mesh_shape="1x4") \
        == "PrefillBucket_128_c8_mesh1x4"


def test_mesh_spec_validation():
    assert make_serving_mesh(None) is None
    assert make_serving_mesh("") is None
    with pytest.raises(ValueError, match="expected 'RxC'"):
        make_serving_mesh("four")
    with pytest.raises(ValueError, match="XLA_FLAGS"):
        make_serving_mesh("8x8")   # more devices than the host has


def test_paged_pools_rejects_half_quantized():
    cfg = get_arch("yi-6b").reduced()
    model = build_model(cfg)
    caches = model.init_paged_caches(4, 8)
    k, v = caches["kv"]
    with pytest.raises(ValueError, match="k_scale without v_scale"):
        ops.paged_decode(
            jax.numpy.zeros((1, cfg.n_heads, 1, cfg.head_dim)),
            ops.PagedPools(k[0], v[0], k_scale=jax.numpy.ones((4, 2, 8))),
            jax.numpy.zeros((1, 2), jax.numpy.int32),
            jax.numpy.ones((1,), jax.numpy.int32))


@pytest.mark.slow
def test_indivisible_heads_clear_error():
    """kv_heads=2 cannot shard 4 ways: the engine must refuse with a
    message naming the head counts, not crash in a kernel reshape."""
    out = run_with_devices("""
import jax
from repro.configs import get_arch
from repro.distributed.sharding import make_serving_mesh
from repro.models import build_model
from repro.serving import ServingEngine
cfg = get_arch("yi-6b").reduced()
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
try:
    ServingEngine(model, params, n_lanes=2, max_len=64, cache="paged",
                  page_size=8, mesh=make_serving_mesh("1x4"))
except ValueError as e:
    assert "not divisible" in str(e), e
    assert "kv_heads=2" in str(e), e
    print("DIVIS_OK")
""")
    assert "DIVIS_OK" in out


@pytest.mark.slow
def test_four_device_mesh_greedy_agreement():
    """2x2 mesh on 4 forced host devices: the sharded engine's greedy
    outputs must match the unsharded engine token for token, for plain
    decode and for chunked prefill."""
    out = run_with_devices("""
import jax
import numpy as np
from repro.configs import get_arch
from repro.distributed.sharding import make_serving_mesh
from repro.models import build_model
from repro.serving import Request, ServingEngine

cfg = get_arch("yi-6b").reduced()
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))

def outputs(mesh, prefill_chunk):
    engine = ServingEngine(model, params, n_lanes=2, max_len=64,
                           cache="paged", page_size=8,
                           prefill_chunk=prefill_chunk, mesh=mesh)
    rng = np.random.default_rng(0)
    for rid in range(3):
        prompt = rng.integers(0, cfg.vocab_size,
                              size=int(rng.integers(4, 12))).tolist()
        engine.submit(Request(rid=rid, prompt=prompt, max_new_tokens=4))
    finished = engine.run(max_steps=40)
    assert len(finished) == 3
    return {r.rid: list(r.out_tokens) for r in finished}

assert len(jax.devices()) == 4
for chunk in (None, 8):
    ref = outputs(None, chunk)
    got = outputs(make_serving_mesh("2x2"), chunk)
    assert got == ref, (chunk, ref, got)
print("MESH_OK")
""")
    assert "MESH_OK" in out
