"""Flash-Decoding split-KV: combine math, kernels, tuner space, e2e.

The two-phase contract under test: phase 1 walks ``num_splits``
independent page-table segments in parallel, each emitting a partial
(m, l, acc) softmax state; phase 2 merges with the max-shift rescale and
normalizes.  ``num_splits=1`` IS the sequential kernel (bit-identical),
and every ``num_splits > 1`` must agree with the gather oracle within
fp32 rounding.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import at
from repro.distributed.compression import quantize_int8_rows
from repro.kernels import ops, ref
from repro.kernels.flash_attention import (flash_paged_decode,
                                           flash_paged_decode_quant,
                                           flash_paged_prefill,
                                           flash_paged_prefill_quant)


@pytest.fixture(autouse=True)
def _clean_published():
    at.clear_published()
    yield
    at.clear_published()


# --------------------------------------------------------------------------
# partial-softmax combine: property tests (hypothesis)
# --------------------------------------------------------------------------


def _segment_states(scores: np.ndarray, values: np.ndarray):
    """The (m, l, acc) triple one split emits for its score slice —
    an empty slice carries the kernel's skip convention (NEG_INF, 0, 0)."""
    if scores.shape[-1] == 0:
        d = values.shape[-1]
        return (np.full(scores.shape[:-1], -1e30, np.float32),
                np.zeros(scores.shape[:-1], np.float32),
                np.zeros((*scores.shape[:-1], d), np.float32))
    m = scores.max(axis=-1)
    p = np.exp(scores - m[..., None])
    return (m.astype(np.float32), p.sum(axis=-1).astype(np.float32),
            (p @ values).astype(np.float32))


def _stack_states(states):
    """[(m, l, acc), ...] -> the (ns, rows[, d]) arrays combine expects."""
    return (jnp.stack([s[0] for s in states]),
            jnp.stack([s[1] for s in states]),
            jnp.stack([s[2] for s in states]))


def _finalized(states):
    m, l, acc = _stack_states(states)
    _, l_star, acc_star = ref.combine_split_states(m, l, acc)
    return np.asarray(ref.finalize_split_states(l_star, acc_star))


def _combine_case(seed: int, n: int, rows: int = 2, d: int = 4):
    rng = np.random.default_rng(seed)
    scores = rng.normal(size=(rows, n)).astype(np.float32) * 3.0
    values = rng.normal(size=(n, d)).astype(np.float32)
    return scores, values


def _direct_softmax(scores: np.ndarray, values: np.ndarray) -> np.ndarray:
    p = np.exp(scores - scores.max(axis=-1, keepdims=True))
    return (p / p.sum(axis=-1, keepdims=True)) @ values


def _check_segmentation(scores, values, bounds):
    """ANY segmentation of the key axis (including empty segments)
    combines back to the plain softmax-weighted average."""
    states = [_segment_states(scores[:, a:b], values[a:b])
              for a, b in zip(bounds, bounds[1:])]
    np.testing.assert_allclose(_finalized(states),
                               _direct_softmax(scores, values),
                               rtol=1e-5, atol=1e-6)


def _check_order_invariant(scores, values, perm_seed):
    """Shuffling the split axis does not change the combined output (the
    merge is a max + weighted sums, all symmetric)."""
    half = scores.shape[-1] // 2
    states = [_segment_states(scores[:, :half], values[:half]),
              _segment_states(scores[:, half:], values[half:]),
              _segment_states(scores[:, :0], values[:0])]
    perm = np.random.default_rng(perm_seed).permutation(len(states))
    np.testing.assert_allclose(
        _finalized([states[i] for i in perm]), _finalized(states),
        rtol=1e-6, atol=1e-7)


def _check_associative(scores, values):
    """combine(combine(A, B), C) == combine(A, B, C) after the final
    normalize — merging is hierarchy-free, so a tree reduction and a
    flat reduction agree."""
    n = scores.shape[-1]
    a, b = n // 3, 2 * n // 3
    A = _segment_states(scores[:, :a], values[:a])
    B = _segment_states(scores[:, a:b], values[a:b])
    C = _segment_states(scores[:, b:], values[b:])
    ab = ref.combine_split_states(*_stack_states([A, B]))
    np.testing.assert_allclose(
        _finalized([tuple(np.asarray(x) for x in ab), C]),
        _finalized([A, B, C]), rtol=1e-6, atol=1e-7)


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                              # container without dev deps
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    class TestCombineProperties:
        """Hypothesis property tests over :func:`ref.combine_split_states`
        (the exact math both combine implementations share)."""

        common = settings(max_examples=30, deadline=None)

        @common
        @given(seed=st.integers(0, 10_000), n=st.integers(1, 24),
               ns=st.integers(1, 6), data=st.data())
        def test_segmentation_matches_unsegmented(self, seed, n, ns, data):
            scores, values = _combine_case(seed, n)
            cuts = sorted(data.draw(st.lists(
                st.integers(0, n), min_size=ns - 1, max_size=ns - 1)))
            _check_segmentation(scores, values, [0, *cuts, n])

        @common
        @given(seed=st.integers(0, 10_000), n=st.integers(2, 24),
               perm_seed=st.integers(0, 10_000))
        def test_order_invariant(self, seed, n, perm_seed):
            scores, values = _combine_case(seed, n)
            _check_order_invariant(scores, values, perm_seed)

        @common
        @given(seed=st.integers(0, 10_000), n=st.integers(3, 24))
        def test_associative(self, seed, n):
            scores, values = _combine_case(seed, n)
            _check_associative(scores, values)


class TestCombineDeterministic:
    """Pinned-seed coverage of the same combine properties, so the math
    stays tested on containers without hypothesis."""

    @pytest.mark.parametrize("seed,n,bounds", [
        (0, 16, [0, 4, 8, 16]),
        (1, 16, [0, 0, 16, 16]),      # leading + trailing empty segments
        (2, 7, [0, 2, 3, 5, 7]),      # ragged odd cuts
        (3, 1, [0, 1]),               # single key, single segment
    ])
    def test_segmentation_matches_unsegmented(self, seed, n, bounds):
        scores, values = _combine_case(seed, n)
        _check_segmentation(scores, values, bounds)

    @pytest.mark.parametrize("seed", [0, 7, 42])
    def test_order_invariant(self, seed):
        scores, values = _combine_case(seed, 12)
        _check_order_invariant(scores, values, seed + 1)

    @pytest.mark.parametrize("seed", [0, 7, 42])
    def test_associative(self, seed):
        scores, values = _combine_case(seed, 15)
        _check_associative(scores, values)

    def test_all_empty_is_zero(self):
        """Every split empty -> the l* == 0 guard yields exact zeros
        (the sequential kernel's all-masked convention)."""
        states = [_segment_states(np.zeros((2, 0), np.float32),
                                  np.zeros((0, 4), np.float32))] * 3
        np.testing.assert_array_equal(_finalized(states),
                                      np.zeros((2, 4), np.float32))


# --------------------------------------------------------------------------
# split-KV decode kernel vs oracles
# --------------------------------------------------------------------------


def _paged_case(b=3, h=4, hkv=2, d=16, psz=8, p=10, nblk=4, qscale=0.3):
    q = jax.random.normal(jax.random.PRNGKey(0), (b, h, 1, d)) * qscale
    kp = jax.random.normal(jax.random.PRNGKey(1), (p, hkv, psz, d)) * 0.3
    vp = jax.random.normal(jax.random.PRNGKey(2), (p, hkv, psz, d)) * 0.3
    # entries past kv_len route to the null page (page 0) — they must be
    # masked out, not attended
    table = jnp.asarray([[3, 7, 0, 0],
                         [5, 2, 6, 9],
                         [1, 4, 0, 0]], jnp.int32)[:b]
    # full-ish / ragged / shorter than one segment at every tested split
    kv_len = jnp.asarray([13, 26, 2], jnp.int32)[:b]
    return q, kp, vp, table, kv_len


class TestSplitDecodeKernel:
    @pytest.mark.parametrize("block_k", [None, 4])
    @pytest.mark.parametrize("num_splits", [1, 2, 4, 8])
    def test_matches_oracles(self, num_splits, block_k):
        q, kp, vp, table, kv_len = _paged_case()
        want = ref.paged_decode_ref(q, kp, vp, table, kv_len)
        got = flash_paged_decode(q, kp, vp, table, kv_len,
                                 block_k=block_k, num_splits=num_splits,
                                 interpret=True)
        np.testing.assert_allclose(got, want, atol=1e-5)
        # and the structural split-aware oracle (per-segment states)
        want_split = ref.paged_decode_split_ref(q, kp, vp, table, kv_len,
                                                num_splits)
        np.testing.assert_allclose(got, want_split, atol=1e-5)

    def test_ns1_bit_identical_to_sequential(self):
        """num_splits=1 is the legacy spelling: the exact same kernel,
        bitwise, as calling without the parameter."""
        q, kp, vp, table, kv_len = _paged_case()
        base = flash_paged_decode(q, kp, vp, table, kv_len, interpret=True)
        ns1 = flash_paged_decode(q, kp, vp, table, kv_len, num_splits=1,
                                 interpret=True)
        np.testing.assert_array_equal(np.asarray(base), np.asarray(ns1))

    def test_clamps_to_kv_walk(self):
        """num_splits beyond the number of KV steps clamps (never an
        empty grid) and still matches the oracle."""
        q, kp, vp, table, kv_len = _paged_case(b=2, nblk=4)
        want = ref.paged_decode_ref(q, kp, vp, table, kv_len)
        got = flash_paged_decode(q, kp, vp, table, kv_len, num_splits=64,
                                 interpret=True)
        np.testing.assert_allclose(got, want, atol=1e-5)

    @pytest.mark.parametrize("num_splits", [1, 4])
    def test_zero_length_lane_outputs_zero(self, num_splits):
        """An idle lane (kv_len 0): every split is empty, the combine's
        l* == 0 guard must reproduce the sequential kernel's exact-zero
        output, not NaN."""
        q, kp, vp, table, _ = _paged_case(b=2)
        kv_len = jnp.asarray([0, 0], jnp.int32)
        got = flash_paged_decode(q, kp, vp, table, kv_len,
                                 num_splits=num_splits, interpret=True)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.zeros_like(np.asarray(got)))

    @pytest.mark.parametrize("num_splits", [1, 2, 4])
    def test_quant_matches_oracles(self, num_splits):
        """int8 pools: dequant stays in-kernel next to the tile load, so
        the split path must agree with the quantized gather oracle."""
        q, kp, vp, table, kv_len = _paged_case()
        k8, ks = quantize_int8_rows(kp)
        v8, vs = quantize_int8_rows(vp)
        want = ref.paged_decode_ref(q, k8, v8, table, kv_len,
                                    k_scale=ks, v_scale=vs)
        got = flash_paged_decode_quant(q, k8, v8, ks, vs, table, kv_len,
                                       num_splits=num_splits,
                                       interpret=True)
        np.testing.assert_allclose(got, want, atol=1e-5)
        np.testing.assert_allclose(
            got,
            ref.paged_decode_split_ref(q, k8, v8, table, kv_len,
                                       num_splits, k_scale=ks, v_scale=vs),
            atol=1e-5)

    def test_quant_ns1_bit_identical(self):
        q, kp, vp, table, kv_len = _paged_case()
        k8, ks = quantize_int8_rows(kp)
        v8, vs = quantize_int8_rows(vp)
        base = flash_paged_decode_quant(q, k8, v8, ks, vs, table, kv_len,
                                        interpret=True)
        ns1 = flash_paged_decode_quant(q, k8, v8, ks, vs, table, kv_len,
                                       num_splits=1, interpret=True)
        np.testing.assert_array_equal(np.asarray(base), np.asarray(ns1))


# --------------------------------------------------------------------------
# split-KV prefill / verify (verify rides the prefill kernel)
# --------------------------------------------------------------------------


class TestSplitPrefillKernel:
    @staticmethod
    def _case():
        b, h, hkv, d, psz, p, c = 2, 4, 2, 16, 8, 10, 5
        q = jax.random.normal(jax.random.PRNGKey(0), (b, h, c, d)) * 0.3
        kp = jax.random.normal(jax.random.PRNGKey(1),
                               (p, hkv, psz, d)) * 0.3
        vp = jax.random.normal(jax.random.PRNGKey(2),
                               (p, hkv, psz, d)) * 0.3
        table = jnp.asarray([[3, 7, 1], [5, 0, 0]], jnp.int32)
        start = jnp.asarray([16, 3], jnp.int32)
        kv_len = jnp.asarray([21, 5], jnp.int32)
        return q, kp, vp, table, start, kv_len

    @pytest.mark.parametrize("num_splits", [2, 4, 8])
    def test_matches_oracle(self, num_splits):
        q, kp, vp, table, start, kv_len = self._case()
        want = ref.paged_prefill_ref(q, kp, vp, table, start, kv_len)
        got = flash_paged_prefill(q, kp, vp, table, start, kv_len,
                                  block_q=2, block_k=4,
                                  num_splits=num_splits, interpret=True)
        np.testing.assert_allclose(got, want, atol=1e-5)

    def test_ns1_bit_identical_to_sequential(self):
        q, kp, vp, table, start, kv_len = self._case()
        base = flash_paged_prefill(q, kp, vp, table, start, kv_len,
                                   block_q=2, interpret=True)
        ns1 = flash_paged_prefill(q, kp, vp, table, start, kv_len,
                                  block_q=2, num_splits=1, interpret=True)
        np.testing.assert_array_equal(np.asarray(base), np.asarray(ns1))

    @pytest.mark.parametrize("num_splits", [2, 4])
    def test_quant_matches_oracle(self, num_splits):
        q, kp, vp, table, start, kv_len = self._case()
        k8, ks = quantize_int8_rows(kp)
        v8, vs = quantize_int8_rows(vp)
        want = ref.paged_prefill_ref(q, k8, v8, table, start, kv_len,
                                     k_scale=ks, v_scale=vs)
        got = flash_paged_prefill_quant(q, k8, v8, ks, vs, table, start,
                                        kv_len, block_q=2, block_k=4,
                                        num_splits=num_splits,
                                        interpret=True)
        np.testing.assert_allclose(got, want, atol=1e-5)

    def test_verify_dispatch_reads_published_splits(self):
        """paged_verify rides the prefill kernel: a published num_splits
        under its tuned key flows through the ops dispatch."""
        q, kp, vp, table, start, kv_len = self._case()
        pools = ops.PagedPools(kp, vp)
        want = ref.paged_prefill_ref(q, kp, vp, table, start, kv_len)
        at.publish("flash_paged_verify", num_splits=4)
        got = ops.paged_verify(q, pools, table, start, kv_len,
                               use_kernel=True)
        np.testing.assert_allclose(got, want, atol=1e-5)

    def test_decode_dispatch_reads_published_splits(self):
        q, kp, vp, table, kv_len = _paged_case(b=2)
        pools = ops.PagedPools(kp, vp)
        want = ref.paged_decode_ref(q, kp, vp, table, kv_len)
        at.publish("flash_paged_decode", block_k=4, num_splits=4)
        got = ops.paged_decode(q, pools, table, kv_len, use_kernel=True)
        np.testing.assert_allclose(got, want, atol=1e-5)


# --------------------------------------------------------------------------
# block_k divisor hygiene (satellite 1)
# --------------------------------------------------------------------------


class TestBlockKResolution:
    def test_non_divisor_warns_and_falls_back(self):
        q, kp, vp, table, kv_len = _paged_case(b=2)
        want = flash_paged_decode(q, kp, vp, table, kv_len, interpret=True)
        with pytest.warns(RuntimeWarning,
                          match="flash_paged_decode.*block_k=3"):
            got = flash_paged_decode(q, kp, vp, table, kv_len, block_k=3,
                                     interpret=True)
        np.testing.assert_allclose(got, want, atol=1e-6)

    def test_divisor_block_k_does_not_warn(self):
        q, kp, vp, table, kv_len = _paged_case(b=2)
        with warnings.catch_warnings():
            warnings.simplefilter("error", RuntimeWarning)
            flash_paged_decode(q, kp, vp, table, kv_len, block_k=4,
                               interpret=True)

    def test_divisor_block_ks_filter(self):
        from repro.tuning import divisor_block_ks
        # non-divisors dropped, order preserved, clamp + dedup
        assert divisor_block_ks(16, (3, 8, 16, 5)) == (8, 16)
        assert divisor_block_ks(16, (32, 8)) == (16, 8)   # clamp to page
        assert divisor_block_ks(16, (8, 8, 16)) == (8, 16)
        # nothing survives -> whole page fallback
        assert divisor_block_ks(16, (3, 5, 7)) == (16,)
        assert divisor_block_ks(8, ()) == (8,)


# --------------------------------------------------------------------------
# tuner region growth: (block_k x page_size x num_splits)
# --------------------------------------------------------------------------


class TestTunerSplitAxis:
    def test_variant_order_keeps_legacy_prefix(self, tmp_path):
        """The ns=1 block leads and preserves the legacy variant order,
        so winner indices from a pre-split-KV DB name the same variants."""
        from repro.tuning import DecodeAutoTuner
        session = at.AutoTuner(str(tmp_path))
        built = []

        def make_decode(bk, ns):
            built.append((bk, ns))
            return lambda: {"bk": bk, "ns": ns}

        tuner = DecodeAutoTuner(session, make_decode, buckets=(512,),
                                block_ks=(256, 512), num_splits=(2, 4))
        assert tuner.param_names == ("block_k", "num_splits")
        assert tuner.variants == [(256, 1), (512, 1), (256, 2), (512, 2),
                                  (256, 4), (512, 4)]
        assert built == tuner.variants
        assert len(tuner.regions[512].subregions) == 6

    def test_forced_split_keeps_one_ladder_with_ns1_leading(self, tmp_path):
        from repro.tuning import DecodeAutoTuner
        session = at.AutoTuner(str(tmp_path))
        tuner = DecodeAutoTuner(session, lambda bk, ns: lambda: (bk, ns),
                                buckets=(512,), block_ks=(8, 16),
                                num_splits=(4,))
        assert tuner.variants == [(8, 1), (16, 1), (8, 4), (16, 4)]
        # forcing ns=1 dedupes to exactly the legacy variant count
        t1 = DecodeAutoTuner(at.AutoTuner(str(tmp_path)),
                             lambda bk, ns: lambda: (bk, ns),
                             buckets=(2048,), block_ks=(8, 16),
                             num_splits=(1,))
        assert t1.variants == [(8, 1), (16, 1)]

    def test_commits_over_grown_space(self, tmp_path):
        from repro.tuning import DecodeAutoTuner
        session = at.AutoTuner(str(tmp_path))
        tuner = DecodeAutoTuner(session, lambda bk, ns:
                                lambda: {"bk": bk, "ns": ns},
                                buckets=(512,), block_ks=(8,),
                                num_splits=(2,))
        for _ in range(len(tuner.variants)):
            tuner.decode(300)
        pp = tuner.committed_params()[512]
        assert pp["block_k"] == 8 and pp["num_splits"] in (1, 2)


class TestNumAltInvalidation:
    """OAT_NUMALT: a persisted winner index is only valid against the
    variant-space size that measured it."""

    @staticmethod
    def _mk(calls):
        def make(bk, *rest):
            def fn():
                calls.append((bk, *rest))
                return bk
            return fn
        return make

    def test_same_space_warm_loads(self, tmp_path):
        from repro.tuning import DecodeAutoTuner
        calls1: list = []
        s1 = at.AutoTuner(str(tmp_path))
        t1 = DecodeAutoTuner(s1, self._mk(calls1), buckets=(512,),
                             block_ks=(8,), num_splits=(2,))
        for _ in range(2):
            t1.decode(300)
        assert t1.committed()[512] is not None

        calls2: list = []
        s2 = at.AutoTuner(str(tmp_path))
        t2 = DecodeAutoTuner(s2, self._mk(calls2), buckets=(512,),
                             block_ks=(8,), num_splits=(2,))
        assert t2.committed()[512] == t1.committed()[512]
        assert s2.executor_calls == 0
        assert ("dynamic", "DecodeBucket_512") in s2.warm_hits

    def test_grown_space_re_measures(self, tmp_path):
        """A legacy (2-variant) winner must NOT warm-load into the grown
        (6-variant) region — the index would name a different variant."""
        from repro.tuning import DecodeAutoTuner
        s1 = at.AutoTuner(str(tmp_path))
        t1 = DecodeAutoTuner(s1, self._mk([]), buckets=(512,),
                             block_ks=(8, 16))
        for _ in range(2):
            t1.decode(300)
        assert t1.committed()[512] is not None
        rec = s1.records.lookup("dynamic", "DecodeBucket_512", {})
        assert rec.pp["OAT_NUMALT"] == 2

        s2 = at.AutoTuner(str(tmp_path))
        t2 = DecodeAutoTuner(s2, self._mk([]), buckets=(512,),
                             block_ks=(8, 16), num_splits=(2, 4))
        assert t2.committed()[512] is None          # cold: must re-measure
        assert ("dynamic", "DecodeBucket_512") not in s2.warm_hits
        for _ in range(len(t2.variants)):
            t2.decode(300)
        assert t2.committed()[512] is not None
        rec2 = s2.records.lookup("dynamic", "DecodeBucket_512", {})
        assert rec2.pp["OAT_NUMALT"] == 6

    def test_markerless_legacy_record_still_warm_loads(self, tmp_path):
        """Records written before the OAT_NUMALT stamp carry no marker;
        they keep warm-loading unchanged (same-sized spaces only ever
        existed when they were written)."""
        from repro.tuning import DecodeAutoTuner
        s0 = at.AutoTuner(str(tmp_path))
        s0.records.put("dynamic", "DecodeBucket_512", {},
                       {"DecodeBucket_512_SELECT": 1})
        s1 = at.AutoTuner(str(tmp_path))
        t1 = DecodeAutoTuner(s1, self._mk([]), buckets=(512,),
                             block_ks=(8, 16))
        assert t1.committed()[512] == 1
        assert ("dynamic", "DecodeBucket_512") in s1.warm_hits


# --------------------------------------------------------------------------
# e2e: greedy bit-identity, splits on vs off, through the engine
# --------------------------------------------------------------------------


class TestEndToEndSplits:
    def _serve(self, tmp_path, tag, **kw):
        from repro.launch.serve import serve
        (tmp_path / tag).mkdir(exist_ok=True)
        return serve(arch="yi-6b", cache="paged", page_size=8,
                     n_requests=2, n_lanes=1, max_len=48, prompt_len=8,
                     max_new=5, workdir=str(tmp_path / tag), **kw)

    def test_greedy_outputs_identical_across_split_degrees(self, tmp_path):
        """Forced num_splits=4, forced num_splits=1 and the default (no
        splits configured) must produce bit-identical greedy tokens —
        split-KV is a pure execution-schedule change."""
        base = self._serve(tmp_path, "base")
        at.clear_published()
        forced1 = self._serve(tmp_path, "ns1", num_splits=1)
        at.clear_published()
        forced4 = self._serve(tmp_path, "ns4", num_splits=4)
        assert base["outputs"] == forced1["outputs"] == forced4["outputs"]
        assert base["finished"] == 2
        assert forced4["config"]["num_splits"] == 4

    def test_autotuned_splits_match_forced_sequential(self, tmp_path):
        """The tuned ladder (1, 2, 4) measures every candidate yet emits
        the same greedy tokens as the forced-sequential run — candidate
        measurement must never leak into outputs."""
        tuned = self._serve(tmp_path, "auto", autotune=True)
        committed = tuned["committed_buckets"]
        assert any(pp is not None and "num_splits" in pp
                   for pp in committed.values())
        at.clear_published()
        forced1 = self._serve(tmp_path, "seq", num_splits=1)
        assert tuned["outputs"] == forced1["outputs"]
