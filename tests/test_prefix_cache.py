"""Prefix caching: hash index, refcounts, COW, eviction, bit-identity,
the PrefixPolicy tuning region, and the monotonic-clock metrics guard.

Correctness contract: the prefix cache is an *implementation detail* of
the paged engine — greedy outputs with caching on must be bit-identical
to caching off (which in turn matches the dense engine), across chunked
prefill, speculative decoding, and swap-out/resume under page pressure.
The pool must never leak: after every request finishes,
``used + cached + free == n_pages - 1`` and every refcount is zero.
"""
import time

import jax
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import build_model
from repro.serving import (LENGTH_BUCKETS, REDUCED_BUCKETS, PagedKVCache,
                           Request, ServingEngine, length_bucket)
from repro.serving.kvcache import chain_hash


@pytest.fixture(scope="module")
def paged_model():
    cfg = ARCHS["yi-6b"].reduced()      # plain GQA: paged-capable
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


PSZ = 8
SHARED = [50 + i for i in range(2 * PSZ)]      # 2 exact pages


def _shared_requests(n=3, max_new=5, aligned_tail=False):
    """Requests sharing a 16-token (2-page) system prompt; the last one
    repeats the prefix exactly (page-aligned full hit -> the COW path)."""
    reqs = [Request(rid=i, prompt=SHARED + [70 + 3 * i, 71 + 3 * i,
                                            72 + 3 * i],
                    max_new_tokens=max_new) for i in range(n)]
    reqs.append(Request(rid=n, prompt=list(SHARED),
                        max_new_tokens=max_new))
    return reqs


def _outputs(model, params, reqs_fn, max_steps=400, **kw):
    eng = ServingEngine(model, params, **kw)
    for r in reqs_fn():
        eng.submit(r)
    done = eng.run(max_steps=max_steps)
    return {r.rid: r.out_tokens for r in done}, eng


def _zero_leak(kv):
    assert kv.used_pages == 0
    assert kv.used_pages + kv.cached_pages + kv.free_pages \
        == kv.n_pages - 1
    assert int(np.asarray(kv.refcount).sum()) == 0
    assert np.all(np.asarray(kv.table) == 0)
    # the maintained cached-page counter agrees with a full scan
    assert kv.cached_pages == sum(1 for p in kv._page_key
                                  if kv.refcount[p] == 0)


# --------------------------------------------------------------------------
# hash index: publish / match / chain
# --------------------------------------------------------------------------


class TestHashIndex:
    def _kv(self, model, **kw):
        kw.setdefault("n_pages", 17)
        kw.setdefault("page_size", PSZ)
        return PagedKVCache(model, n_lanes=2, max_len=64,
                            prefix_cache=True, **kw)

    def test_publish_then_match_roundtrip(self, paged_model):
        cfg, model, params = paged_model
        kv = self._kv(model)
        prompt = list(range(1, 21))             # 2 full pages + 4 ragged
        assert kv.ensure_tokens(0, 20)
        kv.publish_prefix(0, prompt, 20)
        pages, chain = kv.match_prefix(prompt)
        assert len(pages) == 2                  # the ragged page never
        #                                         publishes
        assert pages == [int(p) for p in kv.table[0, :2]]
        assert chain                            # chain key of the last hit

    def test_chain_binds_page_to_its_prefix(self, paged_model):
        """Page 1's key chains page 0's: identical second-page tokens
        behind a different first page must NOT match."""
        cfg, model, params = paged_model
        kv = self._kv(model)
        a = list(range(1, 17))
        b = [99] * PSZ + a[PSZ:]                # same page 1, different 0
        kv.ensure_tokens(0, 16)
        kv.publish_prefix(0, a, 16)
        assert len(kv.match_prefix(a)[0]) == 2
        assert kv.match_prefix(b)[0] == []
        k0 = chain_hash("", a[:PSZ])
        assert chain_hash(k0, a[PSZ:]) != chain_hash("", a[PSZ:])

    def test_min_match_granularity(self, paged_model):
        cfg, model, params = paged_model
        kv = self._kv(model)
        prompt = list(range(1, 17))
        kv.ensure_tokens(0, 16)
        kv.publish_prefix(0, prompt, 16)
        kv.set_prefix_policy(min_match=3)
        assert kv.match_prefix(prompt)[0] == []     # 2 hits < 3 required
        assert kv.seed_prefix(1, prompt) == 0
        kv.set_prefix_policy(min_match=1)
        assert len(kv.match_prefix(prompt)[0]) == 2

    def test_short_prompt_never_indexes(self, paged_model):
        cfg, model, params = paged_model
        kv = self._kv(model)
        prompt = [1, 2, 3]                      # < one page
        kv.ensure_tokens(0, 3)
        kv.publish_prefix(0, prompt, 3)
        assert kv.match_prefix(prompt)[0] == []
        assert kv._index == {}

    def test_bad_eviction_policy_rejected(self, paged_model):
        cfg, model, params = paged_model
        kv = self._kv(model)
        with pytest.raises(ValueError, match="eviction"):
            kv.set_prefix_policy(eviction="random")


# --------------------------------------------------------------------------
# refcounts, sharing, accounting
# --------------------------------------------------------------------------


class TestRefcounts:
    def test_seed_shares_and_release_keeps_cached(self, paged_model):
        cfg, model, params = paged_model
        kv = PagedKVCache(model, n_lanes=2, max_len=64, n_pages=17,
                          page_size=PSZ, prefix_cache=True)
        prompt = list(range(1, 21))
        kv.ensure_tokens(0, 20)                 # 3 pages (2 full + ragged)
        kv.publish_prefix(0, prompt, 20)
        start = kv.seed_prefix(1, prompt)
        assert start == 16
        shared = [int(p) for p in kv.table[1, :2]]
        assert shared == [int(p) for p in kv.table[0, :2]]
        assert all(kv.refcount[p] == 2 for p in shared)
        assert kv.used_pages == 3               # shared pages count once
        kv.release(0)
        assert kv.used_pages == 2               # lane1 still holds them
        assert all(kv.refcount[p] == 1 for p in shared)
        kv.release(1)
        assert kv.cached_pages == 2             # indexed, not freed
        _zero_leak(kv)

    def test_truncate_never_frees_shared_pages(self, paged_model):
        """Speculative rollback on a lane holding shared prefix pages
        only drops the lane's private tail; the shared pages survive for
        the other lane and the index."""
        cfg, model, params = paged_model
        kv = PagedKVCache(model, n_lanes=2, max_len=64, n_pages=17,
                          page_size=PSZ, prefix_cache=True)
        prompt = list(range(1, 17))
        kv.ensure_tokens(0, 16)
        kv.publish_prefix(0, prompt, 16)
        kv.seed_prefix(1, prompt)
        shared = [int(p) for p in kv.table[1, :2]]
        kv.ensure_tokens(1, 32)                 # 2 private tail pages
        free_before = kv.free_pages
        assert kv.truncate_to(1, 17) == 1       # drops one private page
        assert kv.free_pages == free_before + 1
        assert kv.truncate_to(1, 16) == 1       # page 2 (private) goes too
        # rolling all the way down to the shared pages must not free them
        assert kv.truncate_to(1, 8) == 1
        assert all(kv.refcount[p] >= 1 for p in shared)
        assert all(p not in kv._free for p in shared)
        assert len(kv.match_prefix(prompt)[0]) == 2    # index intact

    def test_lru_vs_fifo_eviction(self, paged_model):
        """Only refcount-zero index entries are reclaimed, in policy
        order: LRU spares the recently-hit prefix, FIFO evicts the
        oldest-published page regardless."""
        cfg, model, params = paged_model

        def build(eviction):
            kv = PagedKVCache(model, n_lanes=2, max_len=32, n_pages=6,
                              page_size=4, prefix_cache=True,
                              prefix_eviction=eviction)
            a = list(range(1, 9))               # 2 pages
            b = list(range(11, 19))             # 2 pages
            kv.ensure_tokens(0, 8)
            kv.publish_prefix(0, a, 8)
            kv.release(0)
            kv.ensure_tokens(0, 8)
            kv.publish_prefix(0, b, 8)
            kv.release(0)
            assert kv.seed_prefix(1, a) == 7    # refresh a's last-hit
            kv.release(1)
            assert kv.cached_pages == 4 and kv.free_pages == 1
            # force one eviction: 2 pages needed, 1 free
            assert kv.ensure_tokens(0, 8)
            assert kv.index_evictions == 1
            return kv, a, b

        kv, a, b = build("lru")
        assert len(kv.match_prefix(a)[0]) == 2      # recently hit: spared
        assert kv.match_prefix(b)[0] == []          # oldest hit: evicted
        kv, a, b = build("fifo")
        assert kv.match_prefix(a)[0] == []          # oldest publish goes
        assert len(kv.match_prefix(b)[0]) == 2

    def test_referenced_pages_never_evicted(self, paged_model):
        cfg, model, params = paged_model
        kv = PagedKVCache(model, n_lanes=2, max_len=32, n_pages=4,
                          page_size=4, prefix_cache=True)
        a = list(range(1, 9))
        kv.ensure_tokens(0, 8)
        kv.publish_prefix(0, a, 8)              # lane0 holds both pages
        assert kv.free_pages == 1 and kv.cached_pages == 0
        assert kv._alloc(2) is None             # referenced: not evictable
        assert len(kv.match_prefix(a)[0]) == 2


# --------------------------------------------------------------------------
# copy-on-write
# --------------------------------------------------------------------------


class TestCopyOnWrite:
    def test_cow_copies_shared_page(self, paged_model):
        cfg, model, params = paged_model
        kv = PagedKVCache(model, n_lanes=2, max_len=64, n_pages=17,
                          page_size=PSZ, prefix_cache=True)
        prompt = list(range(1, 17))
        kv.ensure_tokens(0, 16)
        kv.publish_prefix(0, prompt, 16)
        assert kv.seed_prefix(1, prompt) == 15  # capped at plen - 1
        old = int(kv.table[1, 1])
        assert kv.cow_writable(1, 15)           # write lands in block 1
        new = int(kv.table[1, 1])
        assert new != old
        assert kv.refcount[old] == 1            # lane0's ref only
        assert kv.refcount[new] == 1
        assert kv.cow_copies == 1
        assert int(kv.table[0, 1]) == old       # lane0 untouched
        # the copy carries the page's pool content verbatim
        for pool in jax.tree.leaves(kv.caches):
            np.testing.assert_array_equal(np.asarray(pool[:, new]),
                                          np.asarray(pool[:, old]))
        # private now: a second write needs no copy
        assert kv.cow_writable(1, 15) and kv.cow_copies == 1

    def test_cow_protects_sole_owner_indexed_page(self, paged_model):
        """Writing into your OWN published page would silently diverge
        its content from its hash — it must copy too, leaving the index
        entry's page pristine (cached once the writer releases)."""
        cfg, model, params = paged_model
        kv = PagedKVCache(model, n_lanes=1, max_len=64, n_pages=17,
                          page_size=PSZ, prefix_cache=True)
        prompt = list(range(1, 17))
        kv.ensure_tokens(0, 16)
        kv.publish_prefix(0, prompt, 16)
        old = int(kv.table[0, 1])
        assert kv.cow_writable(0, 15)
        assert int(kv.table[0, 1]) != old
        assert kv.refcount[old] == 0 and old in kv._page_key
        assert kv.cached_pages == 1             # pristine page now cached
        assert len(kv.match_prefix(prompt)[0]) == 2

    def test_private_pages_skip_cow(self, paged_model):
        cfg, model, params = paged_model
        kv = PagedKVCache(model, n_lanes=1, max_len=64, n_pages=5,
                          page_size=PSZ, prefix_cache=True)
        kv.ensure_tokens(0, 16)                 # unpublished: private
        tbl = [int(p) for p in kv.table[0, :2]]
        assert kv.cow_writable(0, 15)
        assert [int(p) for p in kv.table[0, :2]] == tbl
        assert kv.cow_copies == 0


# --------------------------------------------------------------------------
# engine bit-identity + TTFT win
# --------------------------------------------------------------------------


class TestEngineBitIdentity:
    @pytest.mark.parametrize("chunk", [4, 8])
    def test_cache_on_matches_cache_off(self, paged_model, chunk):
        """Chunked engine, shared-prefix workload incl. a page-aligned
        full-prompt repeat (the COW admission): caching changes nothing
        but the work done."""
        cfg, model, params = paged_model
        kw = dict(n_lanes=2, max_len=64, cache="paged", page_size=PSZ,
                  prefill_chunk=chunk)
        want, _ = _outputs(model, params, _shared_requests, **kw)
        got, eng = _outputs(model, params, _shared_requests,
                            prefix_cache=True, **kw)
        assert got == want
        st = eng.kv.stats()["prefix"]
        assert st["hits"] >= 2 and st["hit_tokens"] > 0
        assert st["cow_copies"] >= 1            # the full-hit repeat
        _zero_leak(eng.kv)

    def test_cache_matches_dense_engine(self, paged_model):
        cfg, model, params = paged_model
        want, _ = _outputs(model, params, _shared_requests,
                           n_lanes=2, max_len=64)
        got, _ = _outputs(model, params, _shared_requests,
                          n_lanes=2, max_len=64, cache="paged",
                          page_size=PSZ, prefill_chunk=8,
                          prefix_cache=True)
        assert got == want

    def test_speculative_with_prefix_cache(self, paged_model):
        """Speculation + prefix caching in one engine: verify writes and
        truncate_to rollbacks never touch the shared prefix pages."""
        cfg, model, params = paged_model
        dmodel = model.draft_model()
        dparams = model.slice_draft_params(params, dmodel)
        want, _ = _outputs(model, params, _shared_requests,
                           n_lanes=2, max_len=64)
        got, eng = _outputs(model, params, _shared_requests,
                            n_lanes=2, max_len=64, cache="paged",
                            page_size=PSZ, prefill_chunk=8,
                            prefix_cache=True, draft_model=dmodel,
                            draft_params=dparams, spec_k=2)
        assert got == want
        assert eng.spec_ticks > 0
        assert eng.kv.stats()["prefix"]["hits"] > 0
        _zero_leak(eng.kv)

    def test_repeat_prompt_skips_prefill_chunks(self, paged_model):
        """The headline mechanism: a repeated prompt admits with its
        prefix seeded, runs strictly fewer prefill chunks, and stamps
        ``cached_tokens`` for the metrics layer."""
        cfg, model, params = paged_model

        def reqs():
            return [Request(rid=i, prompt=SHARED + [80, 81, 82],
                            max_new_tokens=4) for i in range(2)]

        kw = dict(n_lanes=1, max_len=64, cache="paged", page_size=PSZ,
                  prefill_chunk=4)
        _, cold = _outputs(model, params, reqs, **kw)
        got, warm = _outputs(model, params, reqs, prefix_cache=True, **kw)
        want, _ = _outputs(model, params, reqs, **kw)
        assert got == want
        assert warm.prefill_chunks < cold.prefill_chunks
        by_rid = {r.rid: r for r in warm.finished}
        assert by_rid[0].cached_tokens == 0     # cold admission
        assert by_rid[1].cached_tokens == 16    # both shared pages
        m = warm.metrics.summary()["prefix_cache"]
        assert m["hit_tokens"] == 16 and m["hit_rate"] == 0.5

    def test_pressure_swap_resume_with_shared_pages(self, paged_model):
        """Satellite: tiny pool + timeslice forces full evict/resume
        cycles with refcounted shared pages in the mix — outputs stay
        bit-identical, stats stay exact, zero pages leak."""
        cfg, model, params = paged_model

        def reqs():
            return _shared_requests(4, max_new=5)

        want, _ = _outputs(model, params, reqs, n_lanes=2, max_len=64)
        got, eng = _outputs(model, params, reqs, n_lanes=2, max_len=64,
                            cache="paged", page_size=PSZ, n_pages=13,
                            prefill_chunk=8, timeslice=3,
                            prefix_cache=True, max_steps=600)
        assert got == want
        assert eng.scheduler.preemptions > 0
        assert eng.kv.swap_outs > 0 and eng.kv.swap_ins > 0
        _zero_leak(eng.kv)
        st = eng.kv.stats()
        assert st["used_pages"] == 0
        assert st["free_pages"] + st["cached_pages"] == eng.kv.n_pages - 1

    def test_prefix_cache_requires_paged_and_chunked(self, paged_model):
        cfg, model, params = paged_model
        with pytest.raises(ValueError, match="paged"):
            ServingEngine(model, params, n_lanes=1, max_len=32,
                          prefix_cache=True)
        with pytest.raises(ValueError, match="prefill_chunk"):
            ServingEngine(model, params, n_lanes=1, max_len=32,
                          cache="paged", prefix_cache=True)


# --------------------------------------------------------------------------
# swap round-trip accounting (satellite)
# --------------------------------------------------------------------------


class TestSwapAccounting:
    def test_swap_roundtrip_exact_stats_with_shared_pages(self,
                                                          paged_model):
        cfg, model, params = paged_model
        kv = PagedKVCache(model, n_lanes=2, max_len=32, n_pages=9,
                          page_size=PSZ, prefix_cache=True)
        prompt = list(range(1, 17))
        kv.ensure_tokens(0, 16)
        kv.publish_prefix(0, prompt, 16)
        kv.seed_prefix(1, prompt)
        shared = [int(p) for p in kv.table[1, :2]]
        before = jax.tree.map(
            lambda pool: np.asarray(pool[:, shared]), kv.caches)
        assert kv.used_pages == 2 and kv.free_pages == 6
        h = kv.swap_out(1)                      # drops the shared refs
        assert kv.used_pages == 2               # lane0 still holds them
        assert all(kv.refcount[p] == 1 for p in shared)
        assert kv.swap_in(1, h)                 # fresh private pages
        assert kv.used_pages == 4 and kv.free_pages == 4
        fresh = [int(p) for p in kv.table[1, :2]]
        assert set(fresh).isdisjoint(shared)
        after = jax.tree.map(
            lambda pool: np.asarray(pool[:, fresh]), kv.caches)
        jax.tree.map(np.testing.assert_array_equal, before, after)
        kv.release(0)
        kv.release(1)
        assert kv.cached_pages == 2             # published pages resident
        assert kv.free_pages == 6
        _zero_leak(kv)


# --------------------------------------------------------------------------
# monotonic clock (satellite): metrics survive wall-clock adjustments
# --------------------------------------------------------------------------


class TestMonotonicClock:
    def test_metrics_nonnegative_under_backwards_wall_clock(
            self, paged_model, monkeypatch):
        """Engine + Request timestamps use time.monotonic: a wall clock
        stepping BACKWARDS mid-run (NTP, DST) must not produce negative
        TTFT/ITL samples or a negative serving window."""
        cfg, model, params = paged_model
        wall = iter(float(t) for t in range(10 ** 6, 0, -60))
        monkeypatch.setattr(time, "time", lambda: next(wall))
        eng = ServingEngine(model, params, n_lanes=2, max_len=48,
                            cache="paged", page_size=PSZ, prefill_chunk=4)
        for r in _shared_requests(2, max_new=4):
            eng.submit(r)
        done = eng.run(max_steps=200)
        assert len(done) == 3
        s = eng.metrics.summary()
        assert s["wall_s"] >= 0
        assert all(t >= 0 for t in eng.metrics.ttfts())
        assert all(t >= 0 for t in eng.metrics.inter_token_latencies())
        for r in done:
            assert r.first_token_t >= r.submit_t
            assert r.finish_t >= r.first_token_t


# --------------------------------------------------------------------------
# PrefixPolicy tuning region (repro.at dynamic select)
# --------------------------------------------------------------------------


class TestPrefixPolicyRegion:
    def _mk(self, calls):
        def make_policy(g, ev):
            def fn(miss=0.2):
                calls.append((g, ev))
                # finer granularity "matches more" in this mock
                return {"g": g, "ev": ev, "cached": 16,
                        "miss_fraction": miss * g}
            return fn
        return make_policy

    def test_policy_product_space_commits(self, tmp_path):
        from repro import at
        from repro.tuning import DecodeAutoTuner
        session = at.AutoTuner(str(tmp_path))
        tuner = DecodeAutoTuner(session, lambda bk: (lambda: bk),
                                buckets=(512,), block_ks=(256,))
        calls: list = []
        tuner.add_prefix_policy(self._mk(calls), min_matches=(1, 2),
                                evictions=("lru", "fifo"))
        assert len(tuner.prefix_region.subregions) == 4
        assert tuner.committed_prefix_params() is None
        for _ in range(4):                      # one call per candidate
            tuner.prefix_policy()
        pp = tuner.committed_prefix_params()
        # commits on smallest miss fraction -> min_match=1 wins the mock
        assert pp == {"min_match": 1, "eviction": "lru"}

    def test_warm_restart_zero_tuning(self, tmp_path):
        from repro import at
        from repro.tuning import DecodeAutoTuner

        s1 = at.AutoTuner(str(tmp_path))
        t1 = DecodeAutoTuner(s1, lambda bk: (lambda: bk),
                             buckets=(512,), block_ks=(256,))
        t1.add_prefix_policy(self._mk([]), min_matches=(1, 2),
                             evictions=("lru", "fifo"))
        for _ in range(4):
            t1.prefix_policy()
        winner = t1.committed_prefix()
        assert winner is not None

        calls2: list = []
        s2 = at.AutoTuner(str(tmp_path))
        t2 = DecodeAutoTuner(s2, lambda bk: (lambda: bk),
                             buckets=(512,), block_ks=(256,))
        t2.add_prefix_policy(self._mk(calls2), min_matches=(1, 2),
                             evictions=("lru", "fifo"))
        assert t2.committed_prefix() == winner
        assert s2.executor_calls == 0
        assert ("dynamic", "PrefixPolicy") in s2.warm_hits
        out = t2.prefix_policy()
        assert (out["g"], out["ev"]) == t2.prefix_variants[winner]
        assert calls2 == [t2.prefix_variants[winner]]   # no re-measure

    def test_engine_routes_through_policy_region(self, paged_model,
                                                 tmp_path):
        """End-to-end: admissions route through PrefixPolicy (each call
        measures one (min_match x eviction) candidate on a live match)
        and greedy outputs stay bit-identical."""
        cfg, model, params = paged_model
        from repro.launch.serve import _make_autotuner
        want, _ = _outputs(model, params, _shared_requests,
                           n_lanes=2, max_len=64)
        tuner = _make_autotuner(model, str(tmp_path), "paged", PSZ,
                                prefill_chunk=8, prefix_cache=True)
        assert tuner.prefix_region is not None
        got, eng = _outputs(model, params,
                            lambda: _shared_requests(5, max_new=4),
                            n_lanes=2, max_len=64, cache="paged",
                            page_size=PSZ, prefill_chunk=8,
                            prefix_cache=True, autotuner=tuner,
                            max_steps=600)
        full_want, _ = _outputs(model, params,
                                lambda: _shared_requests(5, max_new=4),
                                n_lanes=2, max_len=64)
        assert got == full_want
        # 6 admissions > 4 candidates: the region has committed and the
        # winner persisted to the record store
        assert tuner.committed_prefix() is not None
        assert eng.kv.stats()["prefix"]["hits"] > 0


# --------------------------------------------------------------------------
# bucket ladders (satellite): one table, no drift
# --------------------------------------------------------------------------


class TestBucketLadders:
    def test_single_source_of_truth(self):
        import inspect

        from repro.serving import buckets as B
        from repro.tuning.dynamic import DecodeAutoTuner
        assert length_bucket.__defaults__[0] is B.LENGTH_BUCKETS
        sig = inspect.signature(DecodeAutoTuner.__init__)
        assert sig.parameters["buckets"].default is B.LENGTH_BUCKETS
        for meth in (DecodeAutoTuner.add_prefill, DecodeAutoTuner.add_spec):
            assert inspect.signature(meth).parameters["buckets"].default \
                is B.LENGTH_BUCKETS
        # the CPU-proxy ladder is a strict prefix of the full one: a
        # winner tuned on the reduced ladder routes identically on both
        assert B.REDUCED_BUCKETS == B.LENGTH_BUCKETS[:len(
            B.REDUCED_BUCKETS)]
        assert LENGTH_BUCKETS is B.LENGTH_BUCKETS
        assert REDUCED_BUCKETS is B.REDUCED_BUCKETS

    def test_reduced_ladder_routes_consistently(self):
        for n in (4, 100, 128, 300, 2048):
            assert length_bucket(n, REDUCED_BUCKETS) \
                == length_bucket(n, LENGTH_BUCKETS)
