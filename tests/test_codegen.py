"""OATCodeGen — paper §5 loop transforms (Samples 8, 9) + unrolling."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

# Sample 8 source (the FDM stress kernel + inputs) lives in fdm_sample.py,
# shared with examples/autotune_fdm.py
from fdm_sample import _fdm_inputs, fdm_stress

from repro.core.codegen import (OATCodeGen, enumerate_unroll_variants,
                                parse_loop_nest, render, transform_fuse_all,
                                transform_split, transform_unroll)
from repro.core.errors import OATCodegenError


@pytest.fixture(scope="module")
def fdm_variants(tmp_path_factory):
    gen = OATCodeGen(str(tmp_path_factory.mktemp("oat")))
    return gen.generate(fdm_stress)["FDMStress"]


class TestSample8:
    def test_exactly_8_variants(self, fdm_variants):
        assert len(fdm_variants) == 8
        descs = [v.description for v in fdm_variants]
        assert descs[0] == "baseline"
        assert sum("split@" in d and "fuse" not in d and "collapse" not in d
                   for d in descs) == 3          # splits at k, j, i
        assert any("fuse" in d and "split" not in d for d in descs)
        assert any("collapse" in d and "split" not in d for d in descs)
        assert any("split" in d and "fuse" in d for d in descs)
        assert any("split" in d and "collapse" in d for d in descs)

    def test_variants_numerically_identical(self, fdm_variants):
        """Flow-dependent QG is recomputed (SplitPointCopyDef semantics =
        rematerialisation) so every variant matches bit-for-bit-ish."""
        arrs, state0 = _fdm_inputs()
        base = fdm_variants[0].fn(
            4, 4, 4, **arrs, **{k: v.copy() for k, v in state0.items()},
            DT=0.1)
        for v in fdm_variants[1:]:
            out = v.fn(4, 4, 4, **arrs,
                       **{k: vv.copy() for k, vv in state0.items()}, DT=0.1)
            for b, o in zip(base, out):
                np.testing.assert_allclose(b, o, rtol=1e-12,
                                           err_msg=v.description)

    def test_generated_file_written(self, tmp_path):
        gen = OATCodeGen(str(tmp_path))
        gen.generate(fdm_stress)
        out = tmp_path / "OAT" / "OAT_fdm_stress.py"
        assert out.exists()
        src = out.read_text()
        assert "QG" in src and src.count("def fdm_stress__FDMStress__v") == 8


def test_split_without_copydef_raises():
    """§5.2: a flow-dependent scalar crossing the split point without a
    re-computation copy is illegal."""

    def bad(N, A, B):
        #OAT$ install LoopFusionSplit region start
        #OAT$ name Bad
        for i in range(N):
            t = A[i] * 2.0
            A[i] = t
            #OAT$ SplitPoint (i)
            B[i] = t + 1.0
        #OAT$ install LoopFusionSplit region end
        return A, B

    gen = OATCodeGen("/tmp")
    with pytest.raises(OATCodegenError, match="SplitPointCopyDef"):
        gen.generate(bad)


# --------------------------------------------------------------------------
# Sample 9: statement re-ordering (RotationOrder) x fusion
# --------------------------------------------------------------------------

def fvm_vel(NX, NY, NZ, DEN, DXSXX, DYSXY, DZSXZ, DXSXY, DYSYY, DZSYZ,
            DXSXZ, DYSYZ, DZSZZ, VX, VY, VZ, DT):
    #OAT$ install LoopFusion region start
    #OAT$ name FVMVel
    for k in range(NZ):
        for j in range(NY):
            for i in range(NX):
                #OAT$ RotationOrder sub region start
                ROX = 2.0 / (DEN[i, j, k] + DEN[i + 1, j, k])
                ROY = 2.0 / (DEN[i, j, k] + DEN[i, j + 1, k])
                ROZ = 2.0 / (DEN[i, j, k] + DEN[i, j, k + 1])
                #OAT$ RotationOrder sub region end
                #OAT$ RotationOrder sub region start
                VX[i, j, k] = VX[i, j, k] + (DXSXX[i, j, k] + DYSXY[i, j, k] + DZSXZ[i, j, k]) * ROX * DT
                VY[i, j, k] = VY[i, j, k] + (DXSXY[i, j, k] + DYSYY[i, j, k] + DZSYZ[i, j, k]) * ROY * DT
                VZ[i, j, k] = VZ[i, j, k] + (DXSXZ[i, j, k] + DYSYZ[i, j, k] + DZSZZ[i, j, k]) * ROZ * DT
                #OAT$ RotationOrder sub region end
    #OAT$ install LoopFusion region end
    return VX, VY, VZ


class TestSample9:
    @pytest.fixture(scope="class")
    def variants(self, tmp_path_factory):
        gen = OATCodeGen(str(tmp_path_factory.mktemp("oat9")))
        return gen.generate(fvm_vel)["FVMVel"]

    def test_six_variants(self, variants):
        assert len(variants) == 6        # {nofuse, fuse2, collapse3} x
        #                                  {grouped, interleave}

    def test_numerically_identical(self, variants):
        rng = np.random.default_rng(1)
        n = 3
        shp = (n + 1, n + 1, n + 1)
        arrs = {k: rng.normal(size=shp) for k in
                ["DXSXX", "DYSXY", "DZSXZ", "DXSXY", "DYSYY", "DZSYZ",
                 "DXSXZ", "DYSYZ", "DZSZZ"]}
        arrs["DEN"] = rng.uniform(0.5, 2.0, size=shp)
        v0 = {k: rng.normal(size=shp) for k in ["VX", "VY", "VZ"]}
        base = variants[0].fn(n, n, n, **arrs,
                              **{k: v.copy() for k, v in v0.items()}, DT=0.1)
        for v in variants[1:]:
            out = v.fn(n, n, n, **arrs,
                       **{k: vv.copy() for k, vv in v0.items()}, DT=0.1)
            for b, o in zip(base, out):
                np.testing.assert_allclose(b, o, rtol=1e-12,
                                           err_msg=v.description)

    def test_interleaving_actually_happened(self, variants):
        """The generated interleaved code matches the paper's printed
        output: ROX; VX; ROY; VY; ROZ; VZ."""
        inter = next(v for v in variants
                     if "interleave" in v.description
                     and "nofuse" in v.description)
        order = [l.split("=")[0].strip().split("[")[0]
                 for l in inter.source.splitlines()
                 if l.strip().startswith(("RO", "VX", "VY", "VZ"))]
        assert order == ["ROX", "VX", "ROY", "VY", "ROZ", "VZ"]


# --------------------------------------------------------------------------
# unroll transform
# --------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 17), factor=st.integers(1, 6))
def test_unroll_identity(n, factor):
    """Unrolled loop (with remainder) computes the same result for every
    (size, factor) combination — including non-dividing remainders."""
    src = ["for i in range(N):",
           "    ACC[i] = A[i] * 2.0 + i"]
    nodes = parse_loop_nest(src)
    unrolled = transform_unroll(nodes, "i", factor)
    code = "\n".join(render(unrolled))
    a = np.arange(n, dtype=np.float64)
    acc1 = np.zeros(n)
    acc2 = np.zeros(n)
    exec(compile("\n".join(src), "<base>", "exec"),
         {"N": n, "A": a, "ACC": acc1})
    exec(compile(code, "<unrolled>", "exec"), {"N": n, "A": a, "ACC": acc2})
    np.testing.assert_allclose(acc1, acc2)


def test_unroll_region_variants_run():
    def matmul_kernel(N, A, B, C):
        #OAT$ install unroll region start
        #OAT$ name MyMatMul
        #OAT$ varied (i, j) from 1 to 4
        for i in range(N):
            for j in range(N):
                for k in range(N):
                    A[i, j] = A[i, j] + B[i, k] * C[k, j]
        #OAT$ install unroll region end
        return A

    gen = OATCodeGen("/tmp")
    rng = np.random.default_rng(0)
    n = 6
    b, c = rng.normal(size=(n, n)), rng.normal(size=(n, n))
    base = np.zeros((n, n))
    matmul_kernel(n, base, b, c)
    for fi in (1, 2, 3):
        for fj in (1, 4):
            v = gen.unroll_variant(matmul_kernel, "MyMatMul",
                                   {"i": fi, "j": fj})
            a = np.zeros((n, n))
            v.fn(n, a, b, c)
            np.testing.assert_allclose(a, base, rtol=1e-12,
                                       err_msg=f"unroll i={fi} j={fj}")


def test_fuse_preserves_iteration_space():
    src = ["for i in range(2, N):",
           "    for j in range(M):",
           "        OUT[i, j] = A[i] + 10.0 * j"]
    nodes = parse_loop_nest(src)
    fused = transform_fuse_all(nodes, ("i", "j"))
    code = "\n".join(render(fused))
    n, m = 7, 5
    a = np.arange(n, dtype=np.float64)
    o1 = np.zeros((n, m))
    o2 = np.zeros((n, m))
    exec(compile("\n".join(src), "<b>", "exec"),
         {"N": n, "M": m, "A": a, "OUT": o1})
    exec(compile(code, "<f>", "exec"), {"N": n, "M": m, "A": a, "OUT": o2})
    np.testing.assert_allclose(o1, o2)
    assert code.count("for ") == 1      # genuinely collapsed
