"""End-to-end training loop: convergence, microbatching equivalence,
checkpoint/restart exactness (the fault-tolerance contract)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.train import train


def test_loss_decreases():
    out = train(arch="h2o-danube-1.8b", steps=8, seq_len=32, batch=4,
                log_every=100)
    first = np.mean(out["losses"][:2])
    last = np.mean(out["losses"][-2:])
    assert last < first, out["losses"]


def test_microbatching_matches_full_batch():
    """Gradient accumulation is numerically equivalent to the full batch
    (same data, same steps) to fp32 tolerance."""
    a = train(arch="yi-6b", steps=4, seq_len=16, batch=8,
              num_microbatches=1, log_every=100)
    b = train(arch="yi-6b", steps=4, seq_len=16, batch=8,
              num_microbatches=4, log_every=100)
    np.testing.assert_allclose(a["losses"], b["losses"], rtol=2e-4,
                               atol=2e-4)


def test_remat_matches_no_remat():
    a = train(arch="yi-6b", steps=3, seq_len=16, batch=4, remat="none",
              log_every=100)
    b = train(arch="yi-6b", steps=3, seq_len=16, batch=4, remat="full",
              log_every=100)
    np.testing.assert_allclose(a["losses"], b["losses"], rtol=2e-4,
                               atol=2e-4)


def test_checkpoint_restart_exact(tmp_path):
    """Kill-and-resume reproduces the uninterrupted run exactly: the
    deterministic (seed, step, shard) data pipeline + checkpointed
    (params, opt) leave no hidden state."""
    straight = train(arch="h2o-danube-1.8b", steps=10, seq_len=24, batch=4,
                     log_every=100)

    d = str(tmp_path / "ck")
    part1 = train(arch="h2o-danube-1.8b", steps=10, seq_len=24, batch=4,
                  ckpt_dir=d, ckpt_every=5, log_every=100, run_steps=5)
    # "failure" here; restart resumes from step_000000004
    part2 = train(arch="h2o-danube-1.8b", steps=10, seq_len=24, batch=4,
                  ckpt_dir=d, ckpt_every=100, log_every=100)
    resumed = part1["losses"] + part2["losses"]
    np.testing.assert_allclose(resumed, straight["losses"], rtol=1e-5,
                               atol=1e-5)
