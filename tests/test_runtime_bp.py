"""Paper Samples 3/4c: user basic parameters with custom sample ranges
(OAT_BPset / OAT_BPsetName / OAT_BPsetCDF) driving a 2-D BP grid."""
import pytest

from repro.core import OAT_INSTALL, OAT_STATIC, Varied
from repro.core import paramfile
from repro.core.directives import static_unroll


def test_sample4c_two_basic_parameters(ctx_with_bps):
    """Sample 4c: both n and nprocs are BPs; nprocs gets its own
    STARTTUNESIZE/ENDTUNESIZE/SAMPDIST names; the static sweep covers the
    (OAT_PROBSIZE x nprocs) product and records optima per point."""
    ctx = ctx_with_bps

    @static_unroll(ctx, name="MyMatMul", varied=Varied(("i", "j"), 1, 4),
                   params=["bp n", "bp nprocs"])
    def my_matmul(i=1, j=1, **bps):
        return lambda: 0.0

    # the paper's registration sequence (Sample 4c)
    ctx.OAT_BPset("nprocs")
    ctx.OAT_BPsetName("STARTTUNESIZE", "nprocs", "OAT_NprocsStartSize")
    ctx.OAT_BPsetName("ENDTUNESIZE", "nprocs", "OAT_NprocsEndSize")
    ctx.OAT_BPsetName("SAMPDIST", "nprocs", "OAT_NprocsSampDist")
    ctx.store.set_bp("OAT_NprocsStartSize", 1)
    ctx.store.set_bp("OAT_NprocsEndSize", 4)
    ctx.store.set_bp("OAT_NprocsSampDist", 1)

    # cost depends on BOTH BPs: optimum i tracks probsize, j tracks nprocs
    def factory(region, bp_env):
        def measure(asg):
            ti = bp_env["OAT_PROBSIZE"] // 1024
            tj = bp_env["nprocs"]
            return (asg["MyMatMul_I"] - ti) ** 2 \
                + (asg["MyMatMul_J"] - tj) ** 2
        return measure

    ctx._executor_factory = factory
    ctx.phase_ran["install"] = True
    ctx.OAT_ATexec(OAT_STATIC, ["MyMatMul"])

    nodes = paramfile.load_file(paramfile.param_path(ctx.workdir, "static"))
    mm = next(n for n in nodes if n.name == "MyMatMul")
    # 3 probsize points x 4 nprocs points = 12 records
    groups = [g for g in mm.children if g.name == "OAT_PROBSIZE"]
    assert len(groups) == 12
    for g in groups:
        assert g.child_value("MyMatMul_I") == int(g.value) // 1024
        assert g.child_value("MyMatMul_J") == g.child_value("nprocs")


def test_bpset_cdf_controls_interpolation(ctx_with_bps):
    """OAT_BPsetCDF: the non-sample-point inference method is selectable."""
    ctx = ctx_with_bps

    @static_unroll(ctx, name="K", varied=Varied(("u",), 1, 8),
                   params=["bp n"])
    def k(u=1, **bps):
        return lambda: 0.0

    def factory(region, bp_env):
        return lambda asg: (asg["K_U"] - bp_env["OAT_PROBSIZE"] // 1024) ** 2

    ctx._executor_factory = factory
    ctx.phase_ran["install"] = True
    ctx.OAT_ATexec(OAT_STATIC, ["K"])

    ctx.OAT_BPsetCDF("n", "least-squares 1")
    v_ls = ctx.static_pp("K", "K_U", 2560)
    assert v_ls in (2, 3)                    # linear interpolation
    ctx.bp_specs["n"].cdf = "dspline"
    v_ds = ctx.static_pp("K", "K_U", 2560)
    assert v_ds in (2, 3)


def test_bpsetname_unknown_kind_rejected(ctx):
    from repro.core import OATSpecError
    with pytest.raises(OATSpecError):
        ctx.OAT_BPsetName("BOGUS", "n", "X")


def test_nested_region_extraction():
    """dsl.extract_regions: nested region start/end pairs are balanced."""
    from repro.core.codegen import extract_regions
    src = """#OAT$ static variable region start
#OAT$ name Outer
for i in range(N):
    #OAT$ static unroll region start
    #OAT$ name Inner
    for j in range(M):
        A[i, j] = 0.0
    #OAT$ static unroll region end
    B[i] = 1.0
#OAT$ static variable region end
"""
    lines, regions = extract_regions(src)
    assert [r.name for r in regions] == ["Outer"]
    body = "\n".join(regions[0].body_lines)
    assert "Inner" in body          # inner region stays inside the outer
