"""Paper Sample 8 source: the ppOpen-APPL/FDM stress kernel loop nest with
its ``#OAT$`` annotations, plus an input generator.

Shared by tests/test_codegen.py and examples/autotune_fdm.py (kept free of
test-only dependencies so the example can import it directly).
"""
import numpy as np


def fdm_stress(NX, NY, NZ, LAM, RIG, Q, ABSX, ABSY, ABSZ,
               DXVX, DYVY, DZVZ, DXVY, DYVX, DXVZ, DZVX, DYVZ, DZVY,
               SXX, SYY, SZZ, SXY, SXZ, SYZ, DT):
    #OAT$ install LoopFusionSplit region start
    #OAT$ name FDMStress
    for k in range(NZ):
        for j in range(NY):
            for i in range(NX):
                RL = LAM[i, j, k]
                RM = RIG[i, j, k]
                RM2 = RM + RM
                RLTHETA = (DXVX[i, j, k] + DYVY[i, j, k] + DZVZ[i, j, k]) * RL
                #OAT$ SplitPointCopyDef region start
                QG = ABSX[i] * ABSY[j] * ABSZ[k] * Q[i, j, k]
                #OAT$ SplitPointCopyDef region end
                SXX[i, j, k] = (SXX[i, j, k] + (RLTHETA + RM2 * DXVX[i, j, k]) * DT) * QG
                SYY[i, j, k] = (SYY[i, j, k] + (RLTHETA + RM2 * DYVY[i, j, k]) * DT) * QG
                SZZ[i, j, k] = (SZZ[i, j, k] + (RLTHETA + RM2 * DZVZ[i, j, k]) * DT) * QG
                #OAT$ SplitPoint (k, j, i)
                STMP1 = 1.0 / RIG[i, j, k]
                STMP2 = 1.0 / RIG[i + 1, j, k]
                STMP4 = 1.0 / RIG[i, j, k + 1]
                STMP3 = STMP1 + STMP2
                RMAXY = 4.0 / (STMP3 + 1.0 / RIG[i, j + 1, k] + 1.0 / RIG[i + 1, j + 1, k])
                RMAXZ = 4.0 / (STMP3 + STMP4 + 1.0 / RIG[i + 1, j, k + 1])
                RMAYZ = 4.0 / (STMP3 + STMP4 + 1.0 / RIG[i, j + 1, k + 1])
                #OAT$ SplitPointCopyInsert
                SXY[i, j, k] = (SXY[i, j, k] + (RMAXY * (DXVY[i, j, k] + DYVX[i, j, k])) * DT) * QG
                SXZ[i, j, k] = (SXZ[i, j, k] + (RMAXZ * (DXVZ[i, j, k] + DZVX[i, j, k])) * DT) * QG
                SYZ[i, j, k] = (SYZ[i, j, k] + (RMAYZ * (DYVZ[i, j, k] + DZVY[i, j, k])) * DT) * QG
    #OAT$ install LoopFusionSplit region end
    return SXX, SYY, SZZ, SXY, SXZ, SYZ


def _fdm_inputs(n=4, seed=0):
    rng = np.random.default_rng(seed)
    shp = (n + 1, n + 1, n + 1)
    arrs = dict(LAM=rng.normal(size=shp),
                RIG=rng.uniform(0.5, 2.0, size=shp),
                Q=rng.normal(size=shp), ABSX=rng.normal(size=n + 1),
                ABSY=rng.normal(size=n + 1), ABSZ=rng.normal(size=n + 1))
    for k in ("DXVX", "DYVY", "DZVZ", "DXVY", "DYVX", "DXVZ", "DZVX",
              "DYVZ", "DZVY"):
        arrs[k] = rng.normal(size=shp)
    state = {k: rng.normal(size=shp) for k in
             ("SXX", "SYY", "SZZ", "SXY", "SXZ", "SYZ")}
    return arrs, state
