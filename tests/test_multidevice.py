"""Multi-device semantics (ring attention, compressed pod psum).

The main pytest process must keep seeing 1 device (the dry-run rule), so
these specs run in subprocesses with ``xla_force_host_platform_device_count``.
"""
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_with_devices(code: str, n: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=240)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.slow
def test_ring_attention_matches_reference():
    """ring_sp plan: seq-sharded shard_map attention == dense oracle, and
    the compiled module moves KV only via collective-permute."""
    out = run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.ring_attention import make_ring_attention
from repro.kernels import ref
if hasattr(jax.sharding, "AxisType"):       # jax >= 0.6
    mesh = jax.make_mesh((4,), ("model",),
                         axis_types=(jax.sharding.AxisType.Auto,))
else:
    mesh = jax.make_mesh((4,), ("model",))
B, H, S, D = 2, 4, 64, 16
q = jax.random.normal(jax.random.PRNGKey(0), (B, H, S, D)) * 0.4
k = jax.random.normal(jax.random.PRNGKey(1), (B, H, S, D)) * 0.4
v = jax.random.normal(jax.random.PRNGKey(2), (B, H, S, D))
with mesh:
    for causal in (True, False):
        ring = make_ring_attention(mesh, causal=causal)
        out = jax.jit(ring)(q, k, v)
        want = ref.attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)
        txt = jax.jit(ring).lower(q, k, v).compile().as_text()
        assert txt.count("collective-permute(") > 0
        assert txt.count("all-gather(") == 0
print("RING_OK")
""")
    assert "RING_OK" in out


@pytest.mark.slow
def test_compressed_pod_psum():
    """int8 pod-axis gradient reduction == exact psum within int8 error."""
    out = run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.distributed.compression import compressed_psum
if hasattr(jax.sharding, "AxisType"):       # jax >= 0.6
    mesh = jax.make_mesh((2,), ("pod",),
                         axis_types=(jax.sharding.AxisType.Auto,))
else:
    mesh = jax.make_mesh((2,), ("pod",))
g = jax.random.normal(jax.random.PRNGKey(0), (2, 512))

def reduce_fn(x):
    return compressed_psum(x, "pod")

def exact_fn(x):
    return jax.lax.psum(x, "pod")

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map

with mesh:
    sm_c = jax.jit(shard_map(reduce_fn, mesh=mesh,
                             in_specs=P("pod", None),
                             out_specs=P("pod", None)))
    sm_e = jax.jit(shard_map(exact_fn, mesh=mesh,
                             in_specs=P("pod", None),
                             out_specs=P("pod", None)))
    approx = np.asarray(sm_c(g))
    exact = np.asarray(sm_e(g))
amax = np.abs(g).max()
assert np.abs(approx - exact).max() <= 2 * amax / 127.0 + 1e-6, \\
    np.abs(approx - exact).max()
print("PSUM_OK")
""", n=2)
    assert "PSUM_OK" in out
