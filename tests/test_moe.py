"""MoE routing semantics: capacity, dropping, balance, shared experts."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.moe import MoEConfig, apply_moe, capacity, init_moe

KEY = jax.random.PRNGKey(0)


def mk(e=4, k=2, cf=1.25, shared=0, group=16, d=32, ff=64):
    cfg = MoEConfig(d_model=d, d_ff=ff, n_experts=e, top_k=k,
                    n_shared_experts=shared, capacity_factor=cf,
                    group_size=group)
    return cfg, init_moe(KEY, cfg)


def test_output_shape_and_finite():
    cfg, p = mk()
    x = jax.random.normal(KEY, (2, 24, 32))
    out, aux = apply_moe(p, x, cfg)
    assert out.shape == x.shape
    assert jnp.isfinite(out).all() and jnp.isfinite(aux)


def test_ragged_tail_not_dropped():
    """Tokens beyond the last full group must still get expert outputs
    (regression: the tail used to be zero-padded away)."""
    cfg, p = mk(cf=4.0)
    x = jax.random.normal(KEY, (1, 26, 32)) * 0.5    # 26 % 16 != 0
    out, _ = apply_moe(p, x, cfg)
    tail = out[0, 16:]
    assert float(jnp.abs(tail).max()) > 1e-4   # non-zero expert output
    # and equals the same tokens processed alone (drop-free capacity)
    out2, _ = apply_moe(p, x[:, 16:], cfg)
    np.testing.assert_allclose(np.asarray(out[:, 16:]), np.asarray(out2),
                               rtol=1e-4, atol=1e-5)


def test_capacity_dropping_happens_when_tight():
    """With capacity_factor << 1 some tokens must lose expert capacity
    (their output becomes exactly zero) — the GShard dropped-token
    behaviour."""
    cfg, p = mk(cf=0.25, k=1)
    x = jax.random.normal(KEY, (1, 64, 32))
    out, _ = apply_moe(p, x, cfg)
    norms = jnp.linalg.norm(out[0], axis=-1)
    assert int((norms < 1e-7).sum()) > 0


def test_drop_free_at_high_capacity():
    cfg, p = mk(cf=4.0)
    x = jax.random.normal(KEY, (1, 32, 32))
    out, _ = apply_moe(p, x, cfg)
    norms = jnp.linalg.norm(out[0], axis=-1)
    assert int((norms < 1e-7).sum()) == 0


def test_shared_expert_always_active():
    cfg0, p0 = mk(cf=0.25, k=1, shared=0)
    cfg1, p1 = mk(cf=0.25, k=1, shared=1)
    x = jax.random.normal(KEY, (1, 64, 32))
    out0, _ = apply_moe(p0, x, cfg0)
    out1, _ = apply_moe(p1, x, cfg1)
    n0 = int((jnp.linalg.norm(out0[0], axis=-1) < 1e-7).sum())
    n1 = int((jnp.linalg.norm(out1[0], axis=-1) < 1e-7).sum())
    assert n0 > 0 and n1 == 0      # shared expert rescues dropped tokens


def test_aux_loss_penalises_imbalance():
    cfg, p = mk(e=4, k=1, cf=4.0)
    # force the router toward expert 0
    p_bad = dict(p)
    router = np.zeros((32, 4), np.float32)
    router[:, 0] = 5.0
    p_bad["router"] = jnp.asarray(router)
    x = jax.random.normal(KEY, (1, 64, 32))
    _, aux_bal = apply_moe(p, x, cfg)
    _, aux_bad = apply_moe(p_bad, x, cfg)
    assert float(aux_bad) > float(aux_bal)


def test_capacity_formula():
    cfg = MoEConfig(32, 64, n_experts=8, top_k=2, capacity_factor=1.0,
                    group_size=128)
    assert capacity(cfg, 128) == 32           # 128*2/8
    assert capacity(cfg, 4) == 4              # floor at 4
