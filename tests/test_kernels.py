"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import ref
from repro.kernels.fdm_stress import fdm_stress
from repro.kernels.flash_attention import flash_attention, flash_decode
from repro.kernels.matmul import matmul
from repro.kernels.ssm_scan import selective_scan

KEY = jax.random.PRNGKey(0)


def rand(shape, dtype=jnp.float32, k=0, scale=1.0):
    return (jax.random.normal(jax.random.fold_in(KEY, k), shape)
            * scale).astype(dtype)


TOL = {jnp.float32: dict(rtol=2e-4, atol=2e-4),
       jnp.bfloat16: dict(rtol=3e-2, atol=3e-2)}


class TestMatmul:
    @pytest.mark.parametrize("m,k,n", [(64, 96, 128), (50, 70, 30),
                                       (128, 128, 128), (13, 257, 65)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_shapes_dtypes(self, m, k, n, dtype):
        x, y = rand((m, k), dtype, 1), rand((k, n), dtype, 2)
        out = matmul(x, y, block_m=32, block_n=32, block_k=32,
                     interpret=True)
        want = ref.matmul_ref(x, y)
        np.testing.assert_allclose(
            out.astype(np.float32), want.astype(np.float32), **TOL[dtype])

    @pytest.mark.parametrize("epilogue", ["none", "gelu", "silu", "relu"])
    def test_fused_epilogue_with_bias(self, epilogue):
        x, y, b = rand((64, 64), k=1), rand((64, 96), k=2), rand((96,), k=3)
        out = matmul(x, y, b, epilogue=epilogue, block_m=32, block_n=32,
                     block_k=32, interpret=True)
        want = ref.matmul_ref(x, y, b, epilogue)
        np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)

    @settings(max_examples=10, deadline=None)
    @given(bm=st.sampled_from([16, 32, 64]), bn=st.sampled_from([16, 32]),
           bk=st.sampled_from([16, 32, 64]))
    def test_property_block_shape_invariance(self, bm, bn, bk):
        """Block shape is a pure performance parameter — results match the
        oracle for every tile configuration."""
        x, y = rand((96, 80), k=4), rand((80, 48), k=5)
        out = matmul(x, y, block_m=bm, block_n=bn, block_k=bk,
                     interpret=True)
        np.testing.assert_allclose(out, ref.matmul_ref(x, y), rtol=2e-5,
                                   atol=2e-5)


class TestFlashAttention:
    @pytest.mark.parametrize("h,hkv", [(8, 8), (8, 2), (4, 1)])
    @pytest.mark.parametrize("causal", [True, False])
    def test_gqa_causal(self, h, hkv, causal):
        q = rand((2, h, 128, 32), k=1, scale=0.3)
        kk = rand((2, hkv, 128, 32), k=2, scale=0.3)
        v = rand((2, hkv, 128, 32), k=3)
        out = flash_attention(q, kk, v, causal=causal, block_q=64,
                              block_k=64, interpret=True)
        want = ref.attention_ref(q, kk, v, causal=causal)
        np.testing.assert_allclose(out, want, rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("window", [16, 64])
    def test_sliding_window(self, window):
        q = rand((1, 2, 192, 32), k=4, scale=0.3)
        out = flash_attention(q, q, q, causal=True, window=window,
                              block_q=64, block_k=64, interpret=True)
        want = ref.attention_ref(q, q, q, causal=True, window=window)
        np.testing.assert_allclose(out, want, rtol=2e-4, atol=2e-4)

    def test_nondivisible_seq(self):
        q = rand((1, 2, 100, 32), k=5, scale=0.3)
        out = flash_attention(q, q, q, block_q=64, block_k=64,
                              interpret=True)
        want = ref.attention_ref(q, q, q)
        np.testing.assert_allclose(out, want, rtol=2e-4, atol=2e-4)

    def test_matches_chunked_jnp_path(self):
        """The two long-sequence paths (Pallas kernel, chunked jnp) agree."""
        q = rand((1, 4, 256, 32), k=6, scale=0.3)
        a = flash_attention(q, q, q, block_q=64, block_k=64, interpret=True)
        b = ref.chunked_attention(q, q, q, block_q=64, block_k=64)
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)

    def test_decode_ragged_kv_len(self):
        q = rand((2, 8, 1, 32), k=7, scale=0.4)
        kk = rand((2, 2, 256, 32), k=8, scale=0.4)
        v = rand((2, 2, 256, 32), k=9)
        kv_len = jnp.array([100, 256], jnp.int32)
        out = flash_decode(q, kk, v, kv_len, block_k=64, interpret=True)
        want = ref.decode_ref(q, kk, v, kv_len)
        np.testing.assert_allclose(out, want, rtol=2e-4, atol=2e-4)


class TestSelectiveScan:
    @pytest.mark.parametrize("l,chunk", [(96, 32), (90, 32), (64, 64),
                                         (33, 16)])
    def test_chunking_invariance(self, l, chunk):
        bsz, di, n = 2, 16, 8
        x = rand((bsz, l, di), k=1)
        dt = jax.nn.softplus(rand((bsz, l, di), k=2))
        a = -jnp.exp(rand((di, n), k=3))
        b = rand((bsz, l, n), k=4)
        c = rand((bsz, l, n), k=5)
        d = rand((di,), k=6)
        out = selective_scan(x, dt, a, b, c, d, chunk=chunk, interpret=True)
        want = ref.selective_scan_ref(x, dt, a, b, c, d)
        np.testing.assert_allclose(out, want, rtol=2e-4, atol=2e-4)


class TestFdmStress:
    @pytest.mark.parametrize("variant", ["fused", "split"])
    @pytest.mark.parametrize("blocks", [(8, 8, 8), (4, 16, 8)])
    def test_vs_ref(self, variant, blocks):
        nx, ny, nz = 12, 10, 16
        rng = np.random.default_rng(0)
        arrays = dict(
            lam=jnp.asarray(rng.normal(size=(nx, ny, nz)), jnp.float32),
            rig=jnp.asarray(rng.uniform(0.5, 2.0, size=(nx, ny, nz)),
                            jnp.float32),
            q=jnp.asarray(rng.normal(size=(nx, ny, nz)), jnp.float32),
            absx=jnp.asarray(rng.normal(size=nx), jnp.float32),
            absy=jnp.asarray(rng.normal(size=ny), jnp.float32),
            absz=jnp.asarray(rng.normal(size=nz), jnp.float32),
            **{k: jnp.asarray(rng.normal(size=(nx, ny, nz)), jnp.float32)
               for k in ("dxvx", "dyvy", "dzvz", "dxvy", "dyvx", "dxvz",
                         "dzvx", "dyvz", "dzvy")})
        state = {k: jnp.asarray(rng.normal(size=(nx, ny, nz)), jnp.float32)
                 for k in ("sxx", "syy", "szz", "sxy", "sxz", "syz")}
        want = ref.fdm_stress_ref(arrays, state, 0.1)
        bx, by, bz = blocks
        out = fdm_stress(arrays, state, 0.1, variant=variant, bx=bx, by=by,
                         bz=bz, interpret=True)
        for kk in want:
            np.testing.assert_allclose(out[kk], want[kk], rtol=2e-5,
                                       atol=2e-5,
                                       err_msg=f"{variant}:{kk}")
