"""Quantized paged KV: int8 pages, per-row scales, in-kernel dequant.

Correctness contract: an int8 pool is a *lossy but bounded* stand-in for
the fp pool — per-row round-trip error is bounded by half a quantization
step of that row, the quantized kernels match the quantized gather
oracle exactly (same dequant, different schedule), and end-to-end greedy
serving tracks the fp engine's outputs above the KVPrecision quality
floor.  Swaps of int8 pools are bit-exact (the payload is already the
canonical representation); fp-pool swap compression is opt-in and lossy.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.distributed.compression import (compress_roundtrip_error,
                                           compress_roundtrip_error_rows,
                                           dequantize_int8,
                                           dequantize_int8_rows,
                                           quantize_int8,
                                           quantize_int8_rows)
from repro.models import build_model
from repro.serving import PagedKVCache, Request, ServingEngine

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:               # pragma: no cover - dev deps include it
    HAVE_HYPOTHESIS = False


@pytest.fixture(scope="module")
def paged_model():
    cfg = ARCHS["yi-6b"].reduced()      # plain GQA: paged-capable
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


# --------------------------------------------------------------------------
# per-row quantization: round-trip error bounds
# --------------------------------------------------------------------------


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
class TestRoundTripBounds:
    if HAVE_HYPOTHESIS:
        @given(seed=st.integers(0, 2**31 - 1), rows=st.integers(1, 6),
               cols=st.integers(1, 32), scale_exp=st.integers(-6, 6))
        @settings(max_examples=40, deadline=None)
        def test_rowwise_error_half_step(self, seed, rows, cols, scale_exp):
            """Round-to-nearest over 127 steps: the worst element errs by
            at most half a step of its own row's scale, so the global max
            error is bounded by the largest row amax / 254 (with fp
            slack) — independent of the data's absolute magnitude."""
            rng = np.random.default_rng(seed)
            x = jnp.asarray(
                rng.standard_normal((rows, cols)) * 10.0 ** scale_exp,
                jnp.float32)
            amax = float(np.max(np.abs(np.asarray(x))))
            err = float(compress_roundtrip_error_rows(x))
            assert err <= max(amax / 250.0, 1e-9)

        @given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 64))
        @settings(max_examples=40, deadline=None)
        def test_tensorwise_error_half_step(self, seed, n):
            rng = np.random.default_rng(seed)
            x = jnp.asarray(rng.standard_normal(n), jnp.float32)
            amax = float(np.max(np.abs(np.asarray(x))))
            err = float(compress_roundtrip_error(x))
            assert err <= max(amax / 250.0, 1e-9)


class TestRowQuantization:
    def test_rowwise_beats_tensorwise_on_skewed_rows(self):
        """The reason pages carry per-row scales: one hot row must not
        flatten every other row's resolution to zero."""
        rng = np.random.default_rng(0)
        x = jnp.asarray(np.stack([rng.standard_normal(32) * 1e3,
                                  rng.standard_normal(32) * 1e-3]),
                        jnp.float32)
        qr, sr = quantize_int8_rows(x)
        row_err = float(jnp.max(jnp.abs(
            dequantize_int8_rows(qr, sr)[1] - x[1])))
        qt, st_ = quantize_int8(x)
        tensor_err = float(jnp.max(jnp.abs(
            dequantize_int8(qt, st_)[1] - x[1])))
        assert row_err < tensor_err / 100

    def test_zero_rows_roundtrip_to_zero(self):
        """Untouched pool rows (all-zero, scale 0) must dequantize to
        exactly 0.0 — the null page stays null under quantization."""
        q, s = quantize_int8_rows(jnp.zeros((3, 8)))
        assert float(jnp.max(jnp.abs(dequantize_int8_rows(q, s)))) == 0.0


# --------------------------------------------------------------------------
# quantized kernels vs the quantized gather oracle
# --------------------------------------------------------------------------


def _quantized_pools(p, hkv, psz, d, scale=0.3):
    kp = jax.random.normal(jax.random.PRNGKey(1), (p, hkv, psz, d)) * scale
    vp = jax.random.normal(jax.random.PRNGKey(2), (p, hkv, psz, d)) * scale
    k8, ks = quantize_int8_rows(kp)
    v8, vs = quantize_int8_rows(vp)
    return kp, vp, k8, ks, v8, vs


class TestQuantKernels:
    def test_decode_quant_matches_ref(self):
        from repro.kernels import ref
        from repro.kernels.flash_attention import flash_paged_decode_quant
        b, h, hkv, d, psz, p = 2, 4, 2, 16, 8, 10      # GQA group of 2
        q = jax.random.normal(jax.random.PRNGKey(0), (b, h, 1, d)) * 0.3
        kp, vp, k8, ks, v8, vs = _quantized_pools(p, hkv, psz, d)
        table = jnp.asarray([[3, 7, 1], [5, 2, 0]], jnp.int32)
        kv_len = jnp.asarray([20, 13], jnp.int32)      # ragged
        want = ref.paged_decode_ref(q, k8, v8, table, kv_len,
                                    k_scale=ks, v_scale=vs)
        got = flash_paged_decode_quant(q, k8, v8, ks, vs, table, kv_len,
                                       interpret=True)
        np.testing.assert_allclose(got, want, atol=1e-5)
        # sub-page split-K tile (the tuned PP) must not change the result
        got_sub = flash_paged_decode_quant(q, k8, v8, ks, vs, table,
                                           kv_len, block_k=psz // 2,
                                           interpret=True)
        np.testing.assert_allclose(got_sub, want, atol=1e-5)
        # the quantized answer tracks the fp pools it was built from
        fp = ref.paged_decode_ref(q, kp, vp, table, kv_len)
        np.testing.assert_allclose(got, fp, atol=0.05)

    def test_prefill_quant_matches_ref(self):
        from repro.kernels import ref
        from repro.kernels.flash_attention import flash_paged_prefill_quant
        b, h, hkv, d, psz, p, c = 2, 4, 2, 16, 8, 10, 5
        q = jax.random.normal(jax.random.PRNGKey(0), (b, h, c, d)) * 0.3
        kp, vp, k8, ks, v8, vs = _quantized_pools(p, hkv, psz, d)
        # lane 1's tail pages route to the null page (ragged chunk)
        table = jnp.asarray([[3, 7, 1], [5, 0, 0]], jnp.int32)
        start = jnp.asarray([16, 3], jnp.int32)
        kv_len = jnp.asarray([21, 5], jnp.int32)
        want = ref.paged_prefill_ref(q, k8, v8, table, start, kv_len,
                                     k_scale=ks, v_scale=vs)
        got = flash_paged_prefill_quant(q, k8, v8, ks, vs, table, start,
                                        kv_len, interpret=True)
        np.testing.assert_allclose(got, want, atol=1e-5)
        got_sub = flash_paged_prefill_quant(q, k8, v8, ks, vs, table,
                                            start, kv_len, block_q=2,
                                            block_k=psz // 2,
                                            interpret=True)
        np.testing.assert_allclose(got_sub, want, atol=1e-5)
        fp = ref.paged_prefill_ref(q, kp, vp, table, start, kv_len)
        np.testing.assert_allclose(got, fp, atol=0.05)

    def test_ops_dispatch_quant_cpu(self):
        """Passing scales through the ops layer routes every paged entry
        point (decode / prefill / verify) to the quantized backend."""
        from repro.kernels import ops, ref
        b, h, hkv, d, psz, p, c = 1, 2, 1, 8, 4, 6, 3
        _, _, k8, ks, v8, vs = _quantized_pools(p, hkv, psz, d)
        table = jnp.asarray([[1, 2]], jnp.int32)
        kv_len = jnp.asarray([6], jnp.int32)
        qd = jnp.ones((b, h, 1, d)) * 0.1
        got = ops.paged_decode_attention(qd, k8, v8, table, kv_len,
                                         k_scale=ks, v_scale=vs)
        want = ref.paged_decode_ref(qd, k8, v8, table, kv_len,
                                    k_scale=ks, v_scale=vs)
        np.testing.assert_allclose(got, want, atol=1e-6)
        qc = jnp.ones((b, h, c, d)) * 0.1
        start = jnp.asarray([3], jnp.int32)
        klen = jnp.asarray([6], jnp.int32)
        want_c = ref.paged_prefill_ref(qc, k8, v8, table, start, klen,
                                       k_scale=ks, v_scale=vs)
        for entry in (ops.paged_prefill_attention,
                      ops.paged_verify_attention):
            got_c = entry(qc, k8, v8, table, start, klen,
                          k_scale=ks, v_scale=vs)
            np.testing.assert_allclose(got_c, want_c, atol=1e-6)


# --------------------------------------------------------------------------
# PagedKVCache: int8 pools, stats, swap round trips
# --------------------------------------------------------------------------


class TestQuantizedPagedCache:
    def test_pool_bytes_follow_dtype(self, paged_model):
        cfg, model, params = paged_model
        fp = PagedKVCache(model, n_lanes=2, max_len=64, n_pages=9,
                          page_size=8)
        q8 = PagedKVCache(model, n_lanes=2, max_len=64, n_pages=9,
                          page_size=8, kv_dtype="int8")
        sf, s8 = fp.stats(), q8.stats()
        assert sf["kv_dtype"] == "fp" and s8["kv_dtype"] == "int8"
        # int8 payload + fp32 per-row scales must be well under half the
        # fp pool at the same page count; capacity (pages) is unchanged
        assert s8["pool_bytes"] < sf["pool_bytes"] / 2
        assert s8["kv_bytes_per_token"] < sf["kv_bytes_per_token"] / 2
        assert s8["capacity_tokens"] == sf["capacity_tokens"]
        # stats derive from the actual pool leaves, not an assumed dtype
        assert sf["pool_bytes"] == sum(
            leaf.size * leaf.dtype.itemsize
            for leaf in jax.tree.leaves(fp.caches))
        assert s8["pool_bytes"] == sum(
            leaf.size * leaf.dtype.itemsize
            for leaf in jax.tree.leaves(q8.caches))

    def test_dense_int8_rejected(self, paged_model):
        cfg, model, params = paged_model
        from repro.serving.kvcache import make_kv_cache
        with pytest.raises(ValueError, match="paged"):
            make_kv_cache(model, "dense", n_lanes=1, max_len=32,
                          kv_dtype="int8")
        with pytest.raises(ValueError, match="kv_dtype"):
            PagedKVCache(model, n_lanes=1, max_len=32, n_pages=5,
                         page_size=8, kv_dtype="int4")

    def test_int8_swap_roundtrip_bit_exact(self, paged_model):
        """int8 pools swap their native payload: the handle is int8
        pages + fp32 scales (compact) and the round trip is bit-exact."""
        cfg, model, params = paged_model
        kv = PagedKVCache(model, n_lanes=2, max_len=32, n_pages=9,
                          page_size=8, kv_dtype="int8")
        _, pre = model.prefill(params, jnp.asarray([[1, 2, 3, 4, 5]]),
                               max_len=8)
        assert kv.admit(0, pre, 5)
        pages = np.asarray(kv.table[0, :kv.n_blocks[0]])
        before = jax.tree.map(lambda pool: np.asarray(pool[:, pages]),
                              kv.caches)
        h = kv.swap_out(0)
        assert h.packed is None                 # native, not repacked
        leaves = jax.tree.leaves(h.chunks)
        assert {leaf.dtype for leaf in leaves} \
            == {np.dtype(np.int8), np.dtype(np.float32)}
        assert kv.swap_in(0, h)
        fresh = np.asarray(kv.table[0, :kv.n_blocks[0]])
        after = jax.tree.map(lambda pool: np.asarray(pool[:, fresh]),
                             kv.caches)
        jax.tree.map(np.testing.assert_array_equal, before, after)
        assert kv.stats()["swap_outs"] == kv.stats()["swap_ins"] == 1

    def test_int8_swap_handle_is_smaller(self, paged_model):
        """Same admitted tokens: the int8 handle's host bytes undercut
        the fp handle's (the dense-lane byte halving, paged form)."""
        cfg, model, params = paged_model
        _, pre = model.prefill(params, jnp.asarray([[1, 2, 3, 4, 5]]),
                               max_len=8)
        sizes = {}
        for kd in ("fp", "int8"):
            kv = PagedKVCache(model, n_lanes=1, max_len=32, n_pages=5,
                              page_size=8, kv_dtype=kd)
            assert kv.admit(0, pre, 5)
            sizes[kd] = kv.swap_out(0).host_bytes()
        assert sizes["int8"] < sizes["fp"] / 2

    def test_fp_swap_compress_packs_and_roundtrips(self, paged_model):
        """Opt-in fp swap compression: the handle is a PackedTree at
        ~1/4 the raw f32 bytes, and the round trip is int8-accurate
        (bounded error, not bit-exact — which is why it's opt-in)."""
        cfg, model, params = paged_model
        kv = PagedKVCache(model, n_lanes=1, max_len=32, n_pages=5,
                          page_size=8, swap_compress=True)
        _, pre = model.prefill(params, jnp.asarray([[1, 2, 3, 4, 5]]),
                               max_len=8)
        assert kv.admit(0, pre, 5)
        pages = np.asarray(kv.table[0, :kv.n_blocks[0]])
        before = jax.tree.map(lambda pool: np.asarray(pool[:, pages]),
                              kv.caches)
        raw = sum(leaf.nbytes for leaf in jax.tree.leaves(before))
        h = kv.swap_out(0)
        assert h.packed is not None and h.chunks is None
        assert h.host_bytes() < raw / 3
        assert kv.swap_in(0, h)
        fresh = np.asarray(kv.table[0, :kv.n_blocks[0]])
        after = jax.tree.map(lambda pool: np.asarray(pool[:, fresh]),
                             kv.caches)
        for b, a in zip(jax.tree.leaves(before), jax.tree.leaves(after)):
            bound = max(float(np.max(np.abs(b))) / 120.0, 1e-6)
            assert float(np.max(np.abs(a - b))) <= bound

    def test_int8_pool_ignores_swap_compress(self, paged_model):
        """swap_compress is an fp-pool knob: int8 payloads are already
        compact and must keep their lossless native swap."""
        cfg, model, params = paged_model
        kv = PagedKVCache(model, n_lanes=1, max_len=32, n_pages=5,
                          page_size=8, kv_dtype="int8",
                          swap_compress=True)
        assert kv.swap_compress is False


# --------------------------------------------------------------------------
# end-to-end: int8 engine tracks the fp engine's greedy outputs
# --------------------------------------------------------------------------


class TestQuantizedServing:
    def test_greedy_agreement_above_floor(self, paged_model):
        cfg, model, params = paged_model
        outs = {}
        for kd in ("fp", "int8"):
            eng = ServingEngine(model, params, n_lanes=2, max_len=48,
                                cache="paged", page_size=8, kv_dtype=kd)
            for rid in range(3):
                eng.submit(Request(rid=rid,
                                   prompt=[1 + rid, 2, 3, 4],
                                   max_new_tokens=6))
            done = eng.run(max_steps=60)
            assert len(done) == 3
            assert eng.kv.stats()["kv_dtype"] == kd
            outs[kd] = {r.rid: r.out_tokens for r in done}
        total = match = 0
        for rid, ref_toks in outs["fp"].items():
            got = outs["int8"][rid]
            total += max(len(ref_toks), len(got))
            match += sum(a == b for a, b in zip(ref_toks, got))
        # the KVPrecision quality floor, enforced end to end
        assert match / total >= 0.95

    def test_int8_with_chunked_prefill_and_timeslice(self, paged_model):
        """Quantized pages compose with the rest of the serving stack:
        chunked prefill scatter + preemption swaps, all int8."""
        cfg, model, params = paged_model
        eng = ServingEngine(model, params, n_lanes=1, max_len=48,
                            cache="paged", page_size=8, n_pages=13,
                            timeslice=2, prefill_chunk=4,
                            kv_dtype="int8")
        for rid in range(2):
            eng.submit(Request(rid=rid, prompt=list(range(1, 10)),
                               max_new_tokens=4))
        done = eng.run(max_steps=80)
        assert len(done) == 2
        assert all(len(r.out_tokens) == 4 for r in done)


# --------------------------------------------------------------------------
# KVPrecision dynamic-select regions
# --------------------------------------------------------------------------


class TestKVPrecisionRegion:
    def _tuner(self, workdir, make_variant, buckets=(512,)):
        from repro import at
        from repro.tuning import DecodeAutoTuner
        session = at.AutoTuner(str(workdir))
        tuner = DecodeAutoTuner(session, lambda bk: (lambda: {"bk": bk}),
                                buckets=(512,), block_ks=(256,))
        tuner.add_kv_precision(make_variant, buckets=buckets,
                               block_ks=(16,))
        return session, tuner

    def test_agreement_guard_blocks_fast_int8(self, tmp_path):
        """A 10x-faster int8 candidate below the agreement floor must
        lose to the slower fp candidate — latency never outvotes the
        quality guard."""
        def make_variant(bucket, kv_dtype, block_k):
            def fn():
                fast = kv_dtype == "int8"
                return {"kv_dtype": kv_dtype, "block_k": block_k,
                        "time_per_token": 0.001 if fast else 0.01,
                        "agreement": 0.5 if fast else 1.0}
            return fn

        _, tuner = self._tuner(tmp_path, make_variant)
        while not tuner.kv_precision_committed(512):
            tuner.kv_precision(512)
        assert tuner.committed_kv_precision_params()[512] \
            == {"kv_dtype": "fp", "block_k": 16}
        assert tuner.resolve_kv_dtype() == "fp"

    def test_fast_agreeing_int8_wins(self, tmp_path):
        def make_variant(bucket, kv_dtype, block_k):
            def fn():
                fast = kv_dtype == "int8"
                return {"kv_dtype": kv_dtype, "block_k": block_k,
                        "time_per_token": 0.001 if fast else 0.01,
                        "agreement": 1.0 if fast else 1.0}
            return fn

        _, tuner = self._tuner(tmp_path, make_variant)
        while not tuner.kv_precision_committed(512):
            tuner.kv_precision(512)
        assert tuner.committed_kv_precision_params()[512]["kv_dtype"] \
            == "int8"
        assert tuner.resolve_kv_dtype() == "int8"

    def test_resolve_majority_and_tie_break(self, tmp_path):
        """Per-bucket winners collapse by majority vote; a tie breaks
        toward int8 (capacity is the point); no commits -> default."""
        def make_variant(bucket, kv_dtype, block_k):
            def fn():
                # fp wins bucket 128, int8 wins bucket 512
                wins = (kv_dtype == "fp") == (bucket == 128)
                return {"kv_dtype": kv_dtype, "block_k": block_k,
                        "time_per_token": 0.001 if wins else 0.01,
                        "agreement": 1.0}
            return fn

        _, tuner = self._tuner(tmp_path, make_variant, buckets=(128, 512))
        assert tuner.resolve_kv_dtype() == "fp"       # nothing committed
        assert tuner.resolve_kv_dtype(default="int8") == "int8"
        for b in (128, 512):
            while not tuner.kv_precision_committed(b):
                tuner.kv_precision(b)
        params = tuner.committed_kv_precision_params()
        assert params[128]["kv_dtype"] == "fp"
        assert params[512]["kv_dtype"] == "int8"
        assert tuner.resolve_kv_dtype() == "int8"     # 1-1 tie -> int8

    def test_warm_restart_zero_tuning(self, tmp_path):
        """Satellite acceptance: a second session on the same workdir
        starts with every KVPrecision region committed and performs zero
        tuning-executor invocations."""
        from repro import at
        from repro.tuning import DecodeAutoTuner

        def make_variant(bucket, kv_dtype, block_k):
            def fn():
                return {"kv_dtype": kv_dtype, "block_k": block_k,
                        "time_per_token": 0.01 if kv_dtype == "fp"
                        else 0.002,
                        "agreement": 1.0}
            return fn

        def build(workdir):
            session = at.AutoTuner(str(workdir))
            tuner = DecodeAutoTuner(session,
                                    lambda bk: (lambda: {"bk": bk}),
                                    buckets=(512,), block_ks=(256,))
            tuner.add_kv_precision(make_variant, buckets=(128, 512),
                                   block_ks=(16,))
            return session, tuner

        _, t1 = build(tmp_path)
        for b in (128, 512):
            while not t1.kv_precision_committed(b):
                t1.kv_precision(b)
        assert all(v is not None
                   for v in t1.committed_kv_precision().values())

        s2, t2 = build(tmp_path)            # fresh process, same workdir
        assert t2.committed_kv_precision() == t1.committed_kv_precision()
        assert t2.resolve_kv_dtype() == "int8"
        assert s2.executor_calls == 0
