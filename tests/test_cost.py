"""`according` clauses + Fortran expression translation + roofline model."""
import math

import pytest

from repro.core.cost import (According, RooflineTerms, eval_expr,
                             fortran_to_python, roofline_terms)


class TestFortranTranslation:
    def test_d_exponent(self):
        assert eval_expr("2.0d0 * 3", {}) == 6.0
        assert eval_expr("1.5D2", {}) == 150.0

    def test_dlog(self):
        assert eval_expr("dlog(OAT_PROBSIZE)", {"OAT_PROBSIZE": math.e}) \
            == pytest.approx(1.0)

    def test_sample5_expression(self):
        env = {"CacheSize": 64, "OAT_PROBSIZE": 2048, "OAT_NUMPROC": 4}
        v = eval_expr(
            "2.0d0*CacheSize*OAT_PROBSIZE*OAT_PROBSIZE / (3.0d0*OAT_NUMPROC)",
            env)
        assert v == pytest.approx(2.0 * 64 * 2048 * 2048 / 12.0)

    def test_logical_ops(self):
        assert eval_expr("(1 .lt. 2) .and. .true.", {}) is True
        assert eval_expr("(3 .le. 2) .or. (1 .eq. 1)", {}) is True


class TestAccording:
    def test_parse_estimated(self):
        a = According.parse("estimated 2.0d0*n / p")
        assert a.estimated_cost({"n": 6, "p": 3}) == 4.0

    def test_parse_min_and_condition_sample6(self):
        a = According.parse("min (eps) .and. condition (iter < 5)")
        assert a.minimize == "eps"
        assert a.conditions == ["iter < 5"]
        assert a.conditions_hold({"iter": 3})
        assert not a.conditions_hold({"iter": 9})

    def test_callable_estimated(self):
        a = According(estimated=lambda env: env["x"] * 2)
        assert a.estimated_cost({"x": 21}) == 42


class TestRoofline:
    def test_terms_and_dominant(self):
        t = roofline_terms(total_flops=197e12 * 256,       # 1s compute
                           total_bytes=819e9 * 256 * 0.5,  # 0.5s memory
                           collective_bytes=50e9 * 256 * 2,  # 2s collective
                           chips=256)
        assert t.compute_s == pytest.approx(1.0)
        assert t.memory_s == pytest.approx(0.5)
        assert t.collective_s == pytest.approx(2.0)
        assert t.dominant == "collective"
        assert t.bound_s == pytest.approx(2.0)
