"""Substrate tests: optimizer, data pipeline, checkpointing, fault
tolerance, gradient compression, HLO analysis."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import checkpoint
from repro.data import DataConfig, DataIterator, batch_for_step
from repro.distributed.compression import (compress_roundtrip_error,
                                           dequantize_int8, quantize_int8)
from repro.distributed.fault_tolerance import (HeartbeatMonitor,
                                               StragglerWatchdog,
                                               plan_remesh)
from repro.optim import AdamWConfig, adamw


# --------------------------------------------------------------------------
# optimizer
# --------------------------------------------------------------------------

class TestAdamW:
    def test_quadratic_convergence(self):
        params = {"w": jnp.array([5.0, -3.0])}
        state = adamw.init(params)
        cfg = AdamWConfig(lr=0.3, weight_decay=0.0, warmup_steps=0,
                          total_steps=100, clip_norm=10.0)
        loss = lambda p: jnp.sum((p["w"] - 1.0) ** 2)
        for _ in range(60):
            g = jax.grad(loss)(params)
            params, state, _ = adamw.update(g, state, params, cfg)
        np.testing.assert_allclose(params["w"], [1.0, 1.0], atol=0.05)

    def test_clipping(self):
        g = {"w": jnp.array([3.0, 4.0])}       # norm 5
        clipped, gn = adamw.clip_by_global_norm(g, 1.0)
        assert gn == pytest.approx(5.0)
        np.testing.assert_allclose(clipped["w"], [0.6, 0.8], rtol=1e-6)

    def test_schedule_shape(self):
        cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_ratio=0.1)
        assert float(adamw.schedule(jnp.int32(0), cfg)) == pytest.approx(0.0)
        assert float(adamw.schedule(jnp.int32(10), cfg)) \
            == pytest.approx(1.0, abs=0.02)
        assert float(adamw.schedule(jnp.int32(100), cfg)) \
            == pytest.approx(0.1, abs=0.02)


# --------------------------------------------------------------------------
# data pipeline
# --------------------------------------------------------------------------

class TestData:
    CFG = DataConfig(vocab_size=1000, seq_len=32, global_batch=8, seed=7)

    def test_deterministic_per_step(self):
        a = batch_for_step(self.CFG, 3)
        b = batch_for_step(self.CFG, 3)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_steps_differ(self):
        a = batch_for_step(self.CFG, 3)
        b = batch_for_step(self.CFG, 4)
        assert not np.array_equal(a["tokens"], b["tokens"])

    def test_shards_differ_and_partition(self):
        a = batch_for_step(self.CFG, 0, shard=0, n_shards=2)
        b = batch_for_step(self.CFG, 0, shard=1, n_shards=2)
        assert a["tokens"].shape[0] == 4
        assert not np.array_equal(a["tokens"], b["tokens"])

    def test_iterator_resume_exact(self):
        it = DataIterator(self.CFG)
        seq = [next(it)["tokens"] for _ in range(6)]
        it2 = DataIterator(self.CFG, start_step=3)
        for i in range(3):
            np.testing.assert_array_equal(next(it2)["tokens"], seq[3 + i])

    def test_labels_shifted(self):
        a = batch_for_step(self.CFG, 0)
        assert a["tokens"].shape == a["labels"].shape
        assert (np.asarray(a["tokens"]) < 1000).all()


# --------------------------------------------------------------------------
# checkpointing
# --------------------------------------------------------------------------

def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"layer": {"w": jax.random.normal(k, (4, 4)),
                      "b": jnp.zeros((4,))},
            "step_arrays": [jnp.ones((2,)), jnp.arange(3.0)]}


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        t = _tree()
        checkpoint.save(str(tmp_path), 5, t, extra={"arch": "x"})
        restored, meta = checkpoint.restore(str(tmp_path), t)
        assert meta["step"] == 5 and meta["arch"] == "x"
        jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b), t,
                     restored)

    def test_latest_and_retention(self, tmp_path):
        for s in (1, 2, 3, 4, 10):
            checkpoint.save(str(tmp_path), s, _tree(s))
        assert checkpoint.latest_step(str(tmp_path)) == 10
        removed = checkpoint.apply_retention(str(tmp_path), keep=2,
                                             keep_period=2)
        left = checkpoint.available_steps(str(tmp_path))
        assert 10 in left and 4 in left and 2 in left
        assert 1 in removed and 3 in removed

    def test_tmp_dirs_invisible(self, tmp_path):
        """A killed writer (stale .tmp) must never be restored from."""
        os.makedirs(tmp_path / "step_000000007.tmp")
        assert checkpoint.latest_step(str(tmp_path)) is None
        checkpoint.save(str(tmp_path), 3, _tree())
        assert checkpoint.latest_step(str(tmp_path)) == 3

    def test_async_checkpointer(self, tmp_path):
        ck = checkpoint.AsyncCheckpointer(str(tmp_path), keep=2)
        for s in range(4):
            ck.save(s, _tree(s))
        ck.wait()
        steps = checkpoint.available_steps(str(tmp_path))
        assert steps == [2, 3]

    def test_restore_into_abstract(self, tmp_path):
        t = _tree()
        checkpoint.save(str(tmp_path), 1, t)
        abstract = jax.eval_shape(lambda: t)
        restored, _ = checkpoint.restore(str(tmp_path), abstract)
        jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b), t,
                     restored)


# --------------------------------------------------------------------------
# fault tolerance state machines
# --------------------------------------------------------------------------

class TestFaultTolerance:
    def test_heartbeat_detects_silent_host(self):
        hb = HeartbeatMonitor(n_hosts=4, timeout_s=10.0)
        for h in range(4):
            hb.beat(h, now=0.0)
        hb.beat(0, now=11.0)
        hb.beat(1, now=11.0)
        hb.beat(2, now=11.0)
        failed = hb.tick(now=12.0)
        assert failed == {3}
        assert hb.alive == [0, 1, 2]

    def test_remesh_keeps_model_axis(self):
        plan = plan_remesh((2, 16, 16), ("pod", "data", "model"),
                           surviving_chips=480, resume_step=100)
        assert plan.new_shape[-1] == 16
        assert plan.axis_names[-1] == "model"
        total = np.prod(plan.new_shape)
        assert total <= 480 and total % 16 == 0
        assert plan.resume_step == 100
        assert plan.batch_scale < 1.0

    def test_remesh_folds_lost_pod(self):
        plan = plan_remesh((2, 16, 16), ("pod", "data", "model"),
                           surviving_chips=256, resume_step=5)
        assert np.prod(plan.new_shape) == 256
        assert plan.new_shape[-1] == 16

    def test_remesh_impossible_raises(self):
        with pytest.raises(RuntimeError):
            plan_remesh((16, 16), ("data", "model"), surviving_chips=8,
                        resume_step=0)

    def test_straggler_ejection(self):
        wd = StragglerWatchdog(n_hosts=4, z_threshold=2.0,
                               strikes_to_eject=3)
        eject = False
        for step in range(10):
            for h in range(3):
                wd.observe(h, 1.0 + 0.01 * h)
            eject = wd.observe(3, 10.0 if step >= 4 else 1.0)
            if eject:
                break
        assert eject

    def test_steady_fleet_not_ejected(self):
        wd = StragglerWatchdog(n_hosts=4)
        for _ in range(50):
            for h in range(4):
                assert not wd.observe(h, 1.0 + 0.02 * h)


# --------------------------------------------------------------------------
# gradient compression
# --------------------------------------------------------------------------

class TestCompression:
    def test_roundtrip_error_bound(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (1024,)) * 3.0
        err = compress_roundtrip_error(x)
        amax = float(jnp.max(jnp.abs(x)))
        assert float(err) <= amax / 127.0 * 0.51 + 1e-6

    @settings(max_examples=20, deadline=None)
    @given(scale=st.floats(1e-3, 1e3), seed=st.integers(0, 100))
    def test_property_quantize_bounded(self, scale, seed):
        x = jax.random.normal(jax.random.PRNGKey(seed), (256,)) * scale
        q, s = quantize_int8(x)
        back = dequantize_int8(q, s)
        assert float(jnp.max(jnp.abs(back - x))) <= float(s) * 0.5 + 1e-9

    def test_stochastic_rounding_unbiased(self):
        x = jnp.full((20000,), 0.3)
        q, s = quantize_int8(x, key=jax.random.PRNGKey(1))
        mean = float(dequantize_int8(q, s).mean())
        assert abs(mean - 0.3) < 0.003


# --------------------------------------------------------------------------
# HLO analysis
# --------------------------------------------------------------------------

class TestHloAnalysis:
    def test_loop_aware_flops_exact(self):
        from repro.launch.hlo_analysis import analyze_module

        def body(x, w):
            return jnp.tanh(x @ w), None

        def scanned(x, ws):
            out, _ = jax.lax.scan(body, x, ws)
            return out.sum()

        x = jnp.zeros((64, 32))
        ws = jnp.zeros((5, 32, 32))
        comp = jax.jit(scanned).lower(x, ws).compile()
        res = analyze_module(comp.as_text())
        want = 2 * 64 * 32 * 32 * 5
        assert res["dot_flops"] == pytest.approx(want, rel=0.01)
        assert 5 in res["while_trips"]

    def test_collective_parse_fixture(self):
        from repro.launch.hlo_analysis import collective_bytes_by_kind
        hlo = """
ENTRY %main (p0: f32[128,256]) -> f32[128,256] {
  %p0 = f32[128,256]{1,0} parameter(0)
  %ag = f32[128,256]{1,0} all-gather(%p0), dimensions={0}
  ROOT %ar = f32[128,256]{1,0} all-reduce(%ag), to_apply=%sum
}
"""
        out = collective_bytes_by_kind(hlo)
        assert out["all-gather"] == 128 * 256 * 4
        assert out["all-reduce"] == 128 * 256 * 4
        assert out["total"] == 2 * 128 * 256 * 4
