"""Tuning-DB hardening: torn-line recovery, atomic concurrent appends,
pluggable jsonl/sqlite backends, golden-winner export/merge/overlay, and
the ``python -m repro.at`` fleet CLI."""
import json
import multiprocessing
import os
import sys
import warnings

import pytest

import repro.at as at
from repro.at import cli
from repro.at.records import (TuningRecord, bp_key, prefer_incoming,
                              read_records_file, write_records_file)
from repro.core import Varied
from repro.core.errors import OATSpecError

BACKENDS = ("jsonl", "sqlite")


@pytest.fixture(autouse=True)
def _isolate_published():
    at.clear_published()
    yield
    at.clear_published()


def open_store(workdir, backend="jsonl", machine="test-box", **kw):
    return at.open_record_store(str(workdir), backend=backend,
                                machine=machine, **kw)


# --------------------------------------------------------------------------
# satellite: torn-line recovery + atomic appends
# --------------------------------------------------------------------------

class TestTornLineRecovery:
    def test_corrupt_line_warns_with_line_number(self, tmp_path):
        store = open_store(tmp_path)
        store.put("install", "A", None, {"bm": 256}, cost=1.0)
        store.put("install", "B", None, {"bm": 512}, cost=2.0)
        path = store.path
        with open(path, "a") as f:
            f.write('{"machine": "test-box", "phase": "inst')  # torn write
        with pytest.warns(at.ATRecordWarning,
                          match=r"OAT_Records\.jsonl:3"):
            reloaded = open_store(tmp_path)
        # the intact winners survive; only the torn line degrades
        assert len(reloaded) == 2
        assert reloaded.lookup("install", "A").pp == {"bm": 256}
        assert reloaded.lookup("install", "B").pp == {"bm": 512}

    def test_unknown_fields_warn_not_crash(self, tmp_path):
        store = open_store(tmp_path)
        store.put("install", "A", None, {"bm": 256})
        with open(store.path, "a") as f:
            f.write(json.dumps({"machine": "m", "mystery": 1}) + "\n")
        with pytest.warns(at.ATRecordWarning, match=":2:"):
            reloaded = open_store(tmp_path)
        assert len(reloaded) == 1

    def test_blank_lines_are_not_corruption(self, tmp_path):
        store = open_store(tmp_path)
        store.put("install", "A", None, {"bm": 256})
        with open(store.path, "a") as f:
            f.write("\n\n")
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            reloaded = open_store(tmp_path)
        assert len(reloaded) == 1

    def test_put_appends_one_whole_line(self, tmp_path):
        store = open_store(tmp_path)
        store.put("install", "A", None, {"payload": "x" * 4096})
        store.put("install", "B", None, {"bm": 1})
        with open(store.path) as f:
            lines = f.read().splitlines()
        assert len(lines) == 2
        for line in lines:
            json.loads(line)  # every line individually well-formed


def _append_worker(workdir, worker, count):
    store = at.open_record_store(workdir, machine="test-box")
    for i in range(count):
        # long payloads make torn interleaved writes overwhelmingly
        # likely if appends were not a single O_APPEND write
        store.put("install", f"W{worker}_R{i}", None,
                  {"payload": f"w{worker}" * 1500, "i": i}, cost=float(i))


class TestConcurrentPut:
    def test_two_process_append_safety(self, tmp_path):
        n = 40
        ctx = multiprocessing.get_context("spawn")
        procs = [ctx.Process(target=_append_worker,
                             args=(str(tmp_path), w, n)) for w in (1, 2)]
        for p in procs:
            p.start()
        for p in procs:
            p.join()
            assert p.exitcode == 0
        with warnings.catch_warnings():
            warnings.simplefilter("error")  # any torn line would warn
            store = open_store(tmp_path)
        assert len(store) == 2 * n
        assert store.lookup("install", "W2_R7").pp["i"] == 7


# --------------------------------------------------------------------------
# satellite: fingerprint failure path is not cached
# --------------------------------------------------------------------------

class TestMachineFingerprint:
    @pytest.fixture(autouse=True)
    def _fresh_cache(self):
        at.reset_fingerprint_cache()
        yield
        at.reset_fingerprint_cache()

    def test_failure_path_not_cached(self, monkeypatch):
        from repro.at import records
        with monkeypatch.context() as m:
            m.setitem(sys.modules, "jax", None)
            degraded = at.machine_fingerprint()
            assert degraded.endswith("-nojax")
            assert records._fingerprint_cache is None  # not poisoned
        # jax back: the very next call heals and caches the real id
        healed = at.machine_fingerprint()
        assert not healed.endswith("-nojax")
        assert records._fingerprint_cache == healed

    def test_reset_forgets_cached_fingerprint(self):
        from repro.at import records
        fp = at.machine_fingerprint()
        assert records._fingerprint_cache == fp
        at.reset_fingerprint_cache()
        assert records._fingerprint_cache is None
        assert at.machine_fingerprint() == fp


# --------------------------------------------------------------------------
# satellite: non-finite floats sanitized on write, tolerated on load
# --------------------------------------------------------------------------

class TestNonFiniteSanitization:
    def test_nan_inf_become_null_on_disk(self, tmp_path):
        store = open_store(tmp_path)
        store.put("install", "A", {"x": float("inf")}, {"bm": 256},
                  cost=float("nan"))
        with open(store.path) as f:
            line = f.read().strip()

        def no_constants(tok):
            raise AssertionError(f"non-finite token {tok} on disk")

        parsed = json.loads(line, parse_constant=no_constants)
        assert parsed["cost"] is None
        assert parsed["bp"]["x"] is None

    def test_legacy_nan_tokens_tolerated_on_load(self, tmp_path):
        path = tmp_path / "OAT_Records.jsonl"
        rec = {"machine": "test-box", "phase": "install", "region": "A",
               "bp": {}, "pp": {"bm": 256, "bad": float("inf")},
               "cost": float("nan"), "n_evaluations": 3}
        path.write_text(json.dumps(rec) + "\n")  # emits bare NaN/Infinity
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            store = open_store(tmp_path)
        got = store.lookup("install", "A")
        assert got.cost is None
        assert got.pp == {"bm": 256, "bad": None}

    def test_merge_never_prefers_unmeasured_cost(self):
        cur = TuningRecord("m", "install", "A", {}, {"bm": 1}, cost=2.0)
        inc = TuningRecord("m", "install", "A", {}, {"bm": 2}, cost=None)
        assert not prefer_incoming(cur, inc)           # None never wins
        assert prefer_incoming(inc, cur)               # measured beats None
        assert prefer_incoming(cur, inc, "incoming")
        assert not prefer_incoming(cur, inc, "existing")
        with pytest.raises(ValueError):
            prefer_incoming(cur, inc, "bogus")


# --------------------------------------------------------------------------
# satellite: (machine, phase, region) secondary index
# --------------------------------------------------------------------------

class TestSecondaryIndex:
    def test_lookup_all_scoped_to_machine_and_region(self, tmp_path):
        store = open_store(tmp_path)
        store.put("static", "Chunk", {"n": 1024}, {"c": 32}, cost=1.0)
        store.put("static", "Chunk", {"n": 2048}, {"c": 64}, cost=2.0)
        store.put("static", "Other", {"n": 1024}, {"c": 16})
        store.put_record(TuningRecord("other-box", "static", "Chunk",
                                      {"n": 1024}, {"c": 99}))
        got = store.lookup_all("static", "Chunk")
        assert sorted(r.pp["c"] for r in got) == [32, 64]
        assert store.regions("static") == ["Chunk", "Other"]
        assert store.regions("install") == []

    def test_overwrite_replaces_in_both_indexes(self, tmp_path):
        store = open_store(tmp_path)
        store.put("install", "A", {"n": 1}, {"bm": 128}, cost=5.0)
        store.put("install", "A", {"n": 1}, {"bm": 256}, cost=1.0)
        assert len(store.lookup_all("install", "A")) == 1
        assert store.lookup("install", "A", {"n": 1}).pp["bm"] == 256
        # last-wins survives a reload of the append-only file too
        reloaded = open_store(tmp_path)
        assert reloaded.lookup("install", "A", {"n": 1}).pp["bm"] == 256

    def test_index_rebuilt_by_load_on_both_backends(self, tmp_path):
        for backend in BACKENDS:
            wd = tmp_path / backend
            wd.mkdir()
            store = open_store(wd, backend)
            store.put("dynamic", "DecodeBucket_128", None, {"variant": 1})
            reloaded = open_store(wd, backend)
            assert reloaded.regions("dynamic") == ["DecodeBucket_128"]
            assert len(reloaded.lookup_all("dynamic",
                                           "DecodeBucket_128")) == 1


# --------------------------------------------------------------------------
# tentpole: the backend registry + sqlite backend
# --------------------------------------------------------------------------

class TestBackendRegistry:
    def test_registered_backends(self):
        assert set(at.record_backends.names()) >= {"jsonl", "sqlite",
                                                   "memory"}
        assert at.record_backends.get("jsonl") is at.ATRecordStore
        assert at.record_backends.get("sqlite") is at.SqliteRecordStore

    def test_unknown_backend_is_a_spec_error(self, tmp_path):
        with pytest.raises(OATSpecError):
            at.open_record_store(str(tmp_path), backend="csv")


class TestSqliteBackend:
    def test_put_survives_reopen(self, tmp_path):
        store = open_store(tmp_path, "sqlite")
        store.put("install", "A", {"n": 1}, {"bm": 256}, cost=1.5,
                  n_evaluations=9)
        assert os.path.exists(os.path.join(str(tmp_path),
                                           "OAT_Records.sqlite"))
        got = open_store(tmp_path, "sqlite").lookup("install", "A",
                                                    {"n": 1})
        assert got.pp == {"bm": 256}
        assert got.cost == 1.5 and got.n_evaluations == 9

    def test_upsert_keeps_one_row_per_key(self, tmp_path):
        store = open_store(tmp_path, "sqlite")
        for bm in (128, 256, 512):
            store.put("install", "A", {"n": 1}, {"bm": bm})
        reloaded = open_store(tmp_path, "sqlite")
        assert len(reloaded) == 1
        assert reloaded.lookup("install", "A", {"n": 1}).pp["bm"] == 512

    def test_two_process_put_safety(self, tmp_path):
        n = 15
        ctx = multiprocessing.get_context("spawn")
        procs = [ctx.Process(target=_sqlite_worker,
                             args=(str(tmp_path), w, n)) for w in (1, 2)]
        for p in procs:
            p.start()
        for p in procs:
            p.join()
            assert p.exitcode == 0
        assert len(open_store(tmp_path, "sqlite")) == 2 * n


def _sqlite_worker(workdir, worker, count):
    store = at.open_record_store(workdir, backend="sqlite",
                                 machine="test-box")
    for i in range(count):
        store.put("install", f"W{worker}_R{i}", None, {"i": i})


# --------------------------------------------------------------------------
# satellite: JSONL <-> sqlite equivalence (same winners, warm path intact)
# --------------------------------------------------------------------------

def build_session(workdir, *, backend="jsonl", booby_trap=False, **kw):
    """One region per phase, mirroring test_at_session.build_session."""
    kw.setdefault("executor", "analytic-cost")
    t = at.AutoTuner(str(workdir), record_backend=backend, **kw)
    t.set_bps(numprocs=1, start=1024, end=2048, dist=1024)

    @t.autotune("install", "variable", name="Blocks",
                varied=Varied(("bm", "bn"), values=(128, 256, 512)),
                search="ad-hoc")
    def blocks(bm=128, bn=128):
        if booby_trap:
            raise AssertionError("executed on the warm path")
        return abs(bm - 256) + abs(bn - 512) + 1.0

    @t.autotune("static", "variable", name="Chunk",
                varied=Varied(("c",), values=(32, 64, 128)))
    def chunk(c=32):
        if booby_trap:
            raise AssertionError("executed on the warm path")
        return abs(c - 64) + 1.0

    sel = t.autotune("dynamic", "select", name="DecodeBucket_128")
    sel.alternative(name="slow")(lambda: "slow")
    sel.alternative(name="fast")(lambda: "fast")
    return t, sel


class TestBackendEquivalence:
    @pytest.mark.parametrize("backend", BACKENDS)
    def test_warm_restart_zero_tuning(self, tmp_path, backend):
        t1, sel1 = build_session(tmp_path, backend=backend)
        t1.run("all")
        for _ in range(3):                 # measure + commit the select
            sel1()
        assert t1.ctx.dynamic_state["DecodeBucket_128"].committed

        t2, sel2 = build_session(tmp_path, backend=backend,
                                 booby_trap=True)
        assert t2.records.backend_name == backend
        t2.run("all")                      # booby trap proves zero timing
        assert t2.best("Blocks") == {"Blocks_BM": 256, "Blocks_BN": 512}
        assert t2.best("Chunk") == {"Chunk_C": 64}
        st = t2.ctx.dynamic_state["DecodeBucket_128"]
        assert st.committed is not None and not st.tried

    def test_both_backends_find_identical_winners(self, tmp_path):
        winners = {}
        for backend in BACKENDS:
            wd = tmp_path / backend
            wd.mkdir()
            t, sel = build_session(wd, backend=backend)
            t.run("all")
            winners[backend] = (t.best("Blocks"), t.best("Chunk"))
        assert winners["jsonl"] == winners["sqlite"]


# --------------------------------------------------------------------------
# satellite: export -> merge round trip, zero re-tuning after merge
# --------------------------------------------------------------------------

class TestExportMerge:
    @pytest.mark.parametrize("src", BACKENDS)
    @pytest.mark.parametrize("dst", BACKENDS)
    @pytest.mark.parametrize("ext", ("jsonl", "sqlite"))
    def test_round_trip_zero_retuning(self, tmp_path, src, dst, ext):
        t1, sel1 = build_session(tmp_path / "tuned", backend=src)
        t1.run("all")
        for _ in range(3):
            sel1()
        golden = str(tmp_path / f"golden.{ext}")
        n = t1.records.export(golden)
        assert n == len(t1.records)

        fresh = tmp_path / "fresh"
        fresh.mkdir()
        store = at.open_record_store(str(fresh), backend=dst)
        stats = store.merge_records(read_records_file(golden))
        assert stats["added"] == n and stats["updated"] == 0

        t2, _ = build_session(fresh, backend=dst, booby_trap=True)
        t2.run("all")                      # warm from merged winners only
        assert t2.best("Blocks") == t1.best("Blocks")
        assert t2.best("Chunk") == t1.best("Chunk")
        assert t2.ctx.dynamic_state["DecodeBucket_128"].committed \
            == t1.ctx.dynamic_state["DecodeBucket_128"].committed

    def test_merge_better_cost_wins(self, tmp_path):
        store = open_store(tmp_path)
        store.put("install", "A", None, {"bm": 128}, cost=5.0)
        incoming = [
            TuningRecord("test-box", "install", "A", {}, {"bm": 256},
                         cost=1.0),                      # better: replaces
            TuningRecord("test-box", "install", "B", {}, {"bm": 512},
                         cost=9.0),                      # new: added
        ]
        stats = store.merge_records(incoming)
        assert stats == {"added": 1, "updated": 1, "kept": 0}
        assert store.lookup("install", "A").pp["bm"] == 256
        worse = [TuningRecord("test-box", "install", "A", {}, {"bm": 64},
                              cost=3.0)]
        assert store.merge_records(worse)["kept"] == 1
        assert store.lookup("install", "A").pp["bm"] == 256

    def test_merge_preserves_foreign_machine_keys(self, tmp_path):
        store = open_store(tmp_path)
        store.merge_records([TuningRecord("other-box", "install", "A",
                                          {}, {"bm": 256}, cost=1.0)])
        assert store.lookup("install", "A") is None   # not ours
        recs = list(store.records())
        assert len(recs) == 1 and recs[0].machine == "other-box"
        # and it survives the reload (persisted, not just indexed)
        assert list(open_store(tmp_path).records())[0].machine \
            == "other-box"

    def test_export_filters_machine_and_phase(self, tmp_path):
        store = open_store(tmp_path)
        store.put("install", "A", None, {"bm": 1})
        store.put("static", "B", None, {"c": 2})
        store.put_record(TuningRecord("other-box", "install", "A", {},
                                      {"bm": 9}))
        out = str(tmp_path / "g.jsonl")
        assert store.export(out) == 3                       # all machines
        assert store.export(out, machine="test-box") == 2
        assert store.export(out, machine="test-box",
                            phase="install") == 1


# --------------------------------------------------------------------------
# satellite: golden overlay precedence
# --------------------------------------------------------------------------

class TestGoldenOverlay:
    def make_golden(self, path, pp, cost=1.0):
        write_records_file(str(path), [
            TuningRecord("test-box", "install", "A", {}, dict(pp),
                         cost=cost)])

    def test_golden_beats_cold(self, tmp_path):
        golden = tmp_path / "golden.jsonl"
        self.make_golden(golden, {"bm": 256})
        store = open_store(tmp_path / "wd", golden_db=str(golden))
        assert store.backend_name == "jsonl+golden"
        assert store.lookup("install", "A").pp == {"bm": 256}

    def test_local_beats_golden(self, tmp_path):
        golden = tmp_path / "golden.jsonl"
        self.make_golden(golden, {"bm": 256})
        store = open_store(tmp_path / "wd", golden_db=str(golden))
        store.put("install", "A", None, {"bm": 512}, cost=0.5)
        assert store.lookup("install", "A").pp == {"bm": 512}
        assert len(store.lookup_all("install", "A")) == 1  # shadowed

    def test_writes_never_touch_golden_file(self, tmp_path):
        golden = tmp_path / "golden.jsonl"
        self.make_golden(golden, {"bm": 256})
        before = golden.read_bytes()
        store = open_store(tmp_path / "wd", golden_db=str(golden))
        store.put("install", "A", None, {"bm": 512})
        store.put("static", "New", None, {"c": 64})
        assert golden.read_bytes() == before

    def test_golden_store_is_read_only(self, tmp_path):
        golden = tmp_path / "golden.jsonl"
        self.make_golden(golden, {"bm": 256})
        gs = at.GoldenStore(str(golden))
        with pytest.raises(RuntimeError, match="read-only"):
            gs.put("install", "X", None, {"bm": 1})

    def test_missing_golden_warns_and_degrades(self, tmp_path):
        with pytest.warns(at.ATRecordWarning, match="not found"):
            store = open_store(tmp_path, golden_db=str(tmp_path /
                                                       "missing.jsonl"))
        assert store.lookup("install", "A") is None

    def test_sqlite_golden_db(self, tmp_path):
        golden = tmp_path / "golden.sqlite"
        self.make_golden(golden, {"bm": 256})
        store = open_store(tmp_path / "wd", "sqlite",
                           golden_db=str(golden))
        assert store.backend_name == "sqlite+golden"
        assert store.lookup("install", "A").pp == {"bm": 256}

    def test_session_warm_loads_from_golden_only(self, tmp_path):
        t1, sel1 = build_session(tmp_path / "tuned")
        t1.run("all")
        for _ in range(3):
            sel1()
        golden = str(tmp_path / "golden.jsonl")
        t1.records.export(golden)

        fresh = tmp_path / "fresh"
        fresh.mkdir()
        t2, _ = build_session(fresh, booby_trap=True, golden_db=golden)
        assert t2.records.backend_name == "jsonl+golden"
        t2.run("all")                      # zero measurements, all golden
        assert t2.best("Blocks") == t1.best("Blocks")
        assert not os.path.exists(os.path.join(str(fresh),
                                               "OAT_Records.jsonl"))

    def test_describe_reports_overlay(self, tmp_path):
        golden = tmp_path / "golden.jsonl"
        self.make_golden(golden, {"bm": 256})
        store = open_store(tmp_path / "wd", golden_db=str(golden))
        d = store.describe()
        assert d["backend"] == "jsonl+golden"
        assert d["golden"] == str(golden)
        assert d["records"] == 1


# --------------------------------------------------------------------------
# acceptance: every committed region family round-trips, legacy + mesh
# --------------------------------------------------------------------------

REGION_NAMES = (
    "DecodeBucket_128", "PrefillBucket_512_c128", "SpecBucket_128",
    "KVPrecision_128", "PrefixPolicy", "GatewayPolicy",
    "DecodeBucket_128_mesh2x2", "PrefillBucket_512_c128_mesh2x2",
    "SpecBucket_128_mesh2x2", "KVPrecision_128_mesh2x2",
    "PrefixPolicy_mesh2x2", "GatewayPolicy_mesh2x2",
)


class TestRegionFamilies:
    @pytest.mark.parametrize("backend", BACKENDS)
    def test_all_families_round_trip(self, tmp_path, backend):
        src = open_store(tmp_path / "src", "jsonl")
        for name in REGION_NAMES:
            src.put("dynamic", name, None, {"winner": name}, cost=1.0)
        golden = str(tmp_path / "golden.jsonl")
        src.export(golden)

        wd = tmp_path / backend
        wd.mkdir()
        dst = open_store(wd, backend)
        dst.merge_records(read_records_file(golden))
        for name in REGION_NAMES:
            got = open_store(wd, backend).lookup("dynamic", name)
            assert got is not None and got.pp == {"winner": name}

    def test_describe_region_parses_all_families(self):
        from repro.tuning.dynamic import describe_region
        for name in REGION_NAMES:
            d = describe_region(name)
            assert d is not None, name
            assert d["mesh"] == ("2x2" if name.endswith("_mesh2x2")
                                 else "")
        assert describe_region("Blocks") is None  # kernel region: literal


# --------------------------------------------------------------------------
# tentpole: the repro.at CLI
# --------------------------------------------------------------------------

class TestCLI:
    def seed(self, workdir, backend="jsonl"):
        store = open_store(workdir, backend)
        store.put("dynamic", "DecodeBucket_128", None, {"variant": "f"},
                  cost=1.0)
        store.put("install", "Blocks", {"n": 1024}, {"bm": 256}, cost=2.0)
        return store

    def test_list(self, tmp_path, capsys):
        self.seed(tmp_path)
        assert cli.main(["list", "--workdir", str(tmp_path)]) == 0
        out = capsys.readouterr().out
        assert "DecodeBucket_128" in out and "kind=decode" in out
        assert "2 record(s) total" in out

    def test_list_empty(self, tmp_path, capsys):
        assert cli.main(["list", "--workdir", str(tmp_path)]) == 0
        assert "no records" in capsys.readouterr().out

    def test_export_then_list_db(self, tmp_path, capsys):
        self.seed(tmp_path)
        golden = str(tmp_path / "golden.sqlite")
        assert cli.main(["export", "--workdir", str(tmp_path),
                         "--out", golden]) == 0
        assert "exported 2 record(s)" in capsys.readouterr().out
        assert cli.main(["list", "--db", golden, "--workdir",
                         str(tmp_path / "nowhere")]) == 0
        assert "DecodeBucket_128" in capsys.readouterr().out

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_merge_into_fresh_workdir(self, tmp_path, capsys, backend):
        self.seed(tmp_path / "tuned")
        golden = str(tmp_path / "golden.jsonl")
        cli.main(["export", "--workdir", str(tmp_path / "tuned"),
                  "--out", golden])
        fresh = tmp_path / "fresh"
        fresh.mkdir()
        assert cli.main(["merge", "--workdir", str(fresh),
                         "--backend", backend, "--db", golden]) == 0
        assert "2 added" in capsys.readouterr().out
        got = open_store(fresh, backend).lookup("dynamic",
                                                "DecodeBucket_128")
        assert got.pp == {"variant": "f"}

    def test_stale_and_fail_on_stale(self, tmp_path, capsys):
        self.seed(tmp_path)
        argv = ["stale", "--workdir", str(tmp_path),
                "--machine", "other-box"]
        assert cli.main(argv) == 0
        out = capsys.readouterr().out
        assert "2 stale region(s) for other-box" in out
        assert cli.main(argv + ["--fail-on-stale"]) == 1
        # the tuned machine itself has nothing stale
        assert cli.main(["stale", "--workdir", str(tmp_path),
                         "--machine", "test-box",
                         "--fail-on-stale"]) == 0

    def test_promote_accumulates_golden(self, tmp_path, capsys):
        golden = str(tmp_path / "golden.jsonl")
        self.seed(tmp_path / "a")
        assert cli.main(["promote", "--workdir", str(tmp_path / "a"),
                         "--db", golden]) == 0
        assert "2 added" in capsys.readouterr().out
        # a second workdir with a better decode cost wins on promote
        b = open_store(tmp_path / "b")
        b.put("dynamic", "DecodeBucket_128", None, {"variant": "g"},
              cost=0.5)
        assert cli.main(["promote", "--workdir", str(tmp_path / "b"),
                         "--db", golden]) == 0
        assert "1 updated" in capsys.readouterr().out
        by_region = {r.region: r for r in read_records_file(golden)}
        assert by_region["DecodeBucket_128"].pp == {"variant": "g"}
        assert len(by_region) == 2


# --------------------------------------------------------------------------
# threading: AutoTuner / engine expose the backend choice
# --------------------------------------------------------------------------

class TestSessionThreading:
    def test_autotuner_backend_kwargs(self, tmp_path):
        t = at.AutoTuner(str(tmp_path), record_backend="sqlite")
        d = t.records.describe()
        assert d["backend"] == "sqlite"
        assert d["path"].endswith("OAT_Records.sqlite")

    def test_autotuner_golden_overlay(self, tmp_path):
        golden = tmp_path / "golden.jsonl"
        write_records_file(str(golden), [
            TuningRecord(at.machine_fingerprint(), "install", "A", {},
                         {"bm": 256}, cost=1.0)])
        t = at.AutoTuner(str(tmp_path), golden_db=str(golden))
        assert t.records.describe()["golden"] == str(golden)
        assert t.records.lookup("install", "A").pp == {"bm": 256}

    def test_bp_key_canonicalizes_numpy(self):
        import numpy as np
        assert bp_key({"n": np.int64(3), "m": 1}) \
            == bp_key({"m": 1, "n": 3})
