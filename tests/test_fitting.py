"""Fitting (§3.4.3): least-squares / dspline / user-defined / auto."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.fitting import (auto_sample_points, fit_auto, fit_dspline,
                                fit_polynomial, fit_user_defined,
                                fitted_minimum)
from repro.core.params import parse_sampled
from repro.core.region import Fitting


def test_parse_sampled_paper_notation():
    assert parse_sampled("(1-5, 8, 16)") == [1, 2, 3, 4, 5, 8, 16]
    assert parse_sampled("1, 2, 3") == [1, 2, 3]
    assert parse_sampled([4, 5]) == [4, 5]


def test_polynomial_exact_recovery():
    xs = [1, 2, 3, 4, 5, 8, 16]
    f = lambda x: 2.0 * (x - 6.0) ** 2 + 1.0
    pred = fit_polynomial(xs, [f(x) for x in xs], 2)
    grid = np.arange(1, 17)
    np.testing.assert_allclose(pred(grid), [f(x) for x in grid], rtol=1e-8)


def test_sample1_least_squares_order5():
    """Sample 1: order-5 LS over samples (1-5, 8, 16) finds an unmeasured
    optimum on a realistic unroll cost curve."""
    xs = parse_sampled("(1-5, 8, 16)")
    cost = lambda u: 10.0 / u + 0.15 * u       # sweet spot ~ 8.2
    ys = [cost(x) for x in xs]
    best = fitted_minimum(Fitting.least_squares(5, xs), xs, ys,
                          range(1, 17))
    true_best = min(range(1, 17), key=cost)
    assert abs(best - true_best) <= 1


def test_dspline_interpolates_samples_exactly():
    xs = [1, 3, 5, 9, 16]
    ys = [5.0, 2.0, 4.0, 1.0, 8.0]
    pred = fit_dspline(xs, ys)
    np.testing.assert_allclose(pred(np.array(xs, float)), ys, atol=1e-9)


def test_dspline_minimum_between_samples():
    xs = [1, 4, 8, 12, 16]
    f = lambda x: (x - 6.0) ** 2
    best = fitted_minimum(Fitting.dspline(xs), xs, [f(x) for x in xs],
                          range(1, 17))
    assert abs(best - 6) <= 1


def test_user_defined_expression():
    """'infer using least squares with the user's expression' — an
    n*log(n)-shaped cost."""
    xs = [2, 4, 8, 16, 32]
    ys = [3.0 * x * np.log(x) + 7.0 for x in xs]
    pred = fit_user_defined(xs, ys, "c0 + c1*x*log(x)")
    np.testing.assert_allclose(pred(np.array([24.0])),
                               3.0 * 24 * np.log(24) + 7.0, rtol=1e-6)


def test_auto_picks_reasonable_model():
    xs = list(range(1, 17, 2))
    f = lambda x: 0.5 * x ** 2 - 6 * x + 20
    pred = fit_auto(xs, [f(x) for x in xs])
    grid = np.arange(1, 17)
    best = grid[int(np.argmin(pred(grid)))]
    assert abs(best - 6) <= 1


def test_auto_sample_points():
    pts = auto_sample_points(1, 256, budget=8)
    assert pts[0] == 1 and pts[-1] == 256
    assert len(pts) <= 10
    pts_small = auto_sample_points(1, 5)
    assert pts_small == [1, 2, 3, 4, 5]


@settings(max_examples=30, deadline=None)
@given(opt=st.integers(2, 15), scale=st.floats(0.5, 5.0))
def test_property_quadratic_recovery(opt, scale):
    """Property: order-2 LS over the paper's sample set recovers the
    optimum of any quadratic within 1 grid point."""
    xs = [1, 2, 3, 4, 5, 8, 16]
    ys = [scale * (x - opt) ** 2 for x in xs]
    best = fitted_minimum(Fitting.least_squares(2, xs), xs, ys,
                          range(1, 17))
    assert abs(best - opt) <= 1
