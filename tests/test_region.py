"""Region model: nesting legality (Tables 1-2), depth, registry."""
import pytest

from repro.core import (ATRegion, OATNestingError, OATSpecError,
                        RegionRegistry, Varied)


def mk(at_type="static", feature="variable", name="R", **kw):
    if feature in ("variable", "unroll") and "varied" not in kw:
        kw["varied"] = Varied("x", 1, 4)
    return ATRegion(at_type, feature, name, fn=lambda **k: None, **kw)


class TestTable1TypeNesting:
    """install may nest only install; static nests install/static;
    dynamic nests everything."""

    @pytest.mark.parametrize("outer,inner,ok", [
        ("install", "install", True), ("install", "static", False),
        ("install", "dynamic", False), ("static", "install", True),
        ("static", "static", True), ("static", "dynamic", False),
        ("dynamic", "install", True), ("dynamic", "static", True),
        ("dynamic", "dynamic", True),
    ])
    def test_pairs(self, outer, inner, ok):
        o = mk(outer, "variable", "O")
        i = mk(inner, "variable", "I")
        if ok:
            o.add_child(i)
            assert i.parent is o
        else:
            with pytest.raises(OATNestingError):
                o.add_child(i)


class TestTable2FeatureNesting:
    """unroll may nest nothing; define/variable/select nest everything."""

    @pytest.mark.parametrize("outer", ["define", "variable", "select"])
    @pytest.mark.parametrize("inner", ["define", "variable", "select",
                                       "unroll"])
    def test_permissive(self, outer, inner):
        mk("static", outer, "O").add_child(mk("static", inner, "I"))

    @pytest.mark.parametrize("inner", ["define", "variable", "select",
                                       "unroll"])
    def test_unroll_nests_nothing(self, inner):
        with pytest.raises(OATNestingError):
            mk("static", "unroll", "O").add_child(mk("static", inner, "I"))


def test_max_depth_three():
    a = mk(name="A")
    b = mk(name="B")
    c = mk(name="C")
    d = mk(name="D")
    a.add_child(b)
    b.add_child(c)
    with pytest.raises(OATNestingError):
        c.add_child(d)


def test_varied_required_for_unroll():
    with pytest.raises(OATSpecError):
        ATRegion("static", "unroll", "X", fn=lambda: None)


def test_qualified_pp_names():
    r = mk(feature="unroll", name="MyMatMul", varied=Varied(("i", "j"), 1, 4))
    assert r.pp_names == ("MyMatMul_I", "MyMatMul_J")


def test_registry_number_ordering():
    reg = RegionRegistry()
    reg.register(mk(name="first"))
    reg.register(mk(name="second", number=1))
    reg.register(mk(name="third", number=0))
    names = [r.name for r in reg.by_phase("static")]
    assert names == ["third", "second", "first"]


def test_registry_duplicate_rejected():
    reg = RegionRegistry()
    reg.register(mk(name="X"))
    with pytest.raises(OATSpecError):
        reg.register(mk(name="X"))
