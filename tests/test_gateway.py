"""Gateway tests: SSE bit-identity, backpressure, disconnect leak
accounting, queue-wait metrics, GatewayPolicy commit + warm restart, and
the compare.py cell-key usage errors.

The HTTP tests run a real ``GatewayServer`` on an ephemeral localhost
port and talk to it with the stdlib SSE client — actual TCP, actual
HTTP/1.1 framing, no mocked transport.
"""
from __future__ import annotations

import asyncio
import importlib.util
import json
import os

import jax
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import build_model
from repro.serving import Request, ServingEngine
from repro.serving.gateway import (GatewayServer, PipelinedEngine,
                                   get_json, sse_generate)
from repro.serving.gateway.pipeline import QueueFull

ARCH = "yi-6b"


@pytest.fixture(scope="module")
def model_and_params():
    cfg = get_arch(ARCH).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def make_engine(model_and_params, **kw):
    cfg, model, params = model_and_params
    kw.setdefault("n_lanes", 2)
    kw.setdefault("max_len", 96)
    kw.setdefault("cache", "paged")
    kw.setdefault("page_size", 8)
    kw.setdefault("prefill_chunk", 8)
    return ServingEngine(model, params, **kw)


def make_prompts(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size,
                         size=int(rng.integers(4, 12))).tolist()
            for _ in range(n)]


# -- streaming bit-identity -------------------------------------------------

def test_sse_stream_bit_identical_to_sync_engine(model_and_params):
    """Tokens streamed over SSE match ``Engine.run()`` exactly — same
    content, same order — under preemption, chunked prefill and the
    prefix cache, with pipelined (overlapped) ticks."""
    cfg = model_and_params[0]
    prompts = make_prompts(cfg, 6)
    eng = make_engine(model_and_params, timeslice=4, prefix_cache=True)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=7))
    ref = {tuple(r.prompt): list(r.out_tokens) for r in eng.run()}

    async def go():
        eng2 = make_engine(model_and_params, timeslice=4,
                           prefix_cache=True)
        pipe = PipelinedEngine(eng2, queue_limit=16)
        srv = GatewayServer(pipe)
        await srv.start()
        outs, finals = {}, {}

        async def one(p):
            toks = []
            async for kind, payload in sse_generate(
                    "127.0.0.1", srv.port, p, max_new_tokens=7):
                if kind == "tokens":
                    toks.extend(payload)
                else:
                    finals[tuple(p)] = (kind, payload)
            outs[tuple(p)] = toks

        await asyncio.gather(*[one(p) for p in prompts])
        await srv.drain()
        return outs, finals, pipe

    outs, finals, pipe = asyncio.run(go())
    assert outs == ref            # content AND per-request order
    assert pipe.overlapped_ticks > 0
    for p in prompts:             # exactly one terminal frame, with stats
        kind, info = finals[tuple(p)]
        assert kind == "done"
        assert info["n_tokens"] == len(ref[tuple(p)])
        assert info["queue_wait_s"] is not None
        assert info["ttft_s"] is not None and info["ttft_s"] >= 0


def test_pipelined_step_split_matches_step(model_and_params):
    """schedule/dispatch/emit driven manually is the same machine as
    ``step()`` — the overlap window moves host work, never device math."""
    cfg = model_and_params[0]
    prompts = make_prompts(cfg, 4, seed=3)
    eng_a = make_engine(model_and_params)
    eng_b = make_engine(model_and_params)
    for i, p in enumerate(prompts):
        eng_a.submit(Request(rid=i, prompt=p, max_new_tokens=6))
        eng_b.submit(Request(rid=i, prompt=p, max_new_tokens=6))
    ref = {r.rid: list(r.out_tokens) for r in eng_a.run()}
    for _ in range(200):
        if not (eng_b.active or eng_b.scheduler.has_queued):
            break
        eng_b.schedule()
        work = eng_b.dispatch()
        if work is not None:
            work.block()
        eng_b.emit(work)
    assert {r.rid: list(r.out_tokens) for r in eng_b.finished} == ref


# -- backpressure -----------------------------------------------------------

def test_admission_queue_backpressure(model_and_params):
    async def go():
        eng = make_engine(model_and_params)
        pipe = PipelinedEngine(eng, queue_limit=2)
        # direct-path bound: third submit bounces before the loop runs
        pipe.submit([1, 2, 3, 4], max_new_tokens=2)
        pipe.submit([1, 2, 3, 5], max_new_tokens=2)
        with pytest.raises(QueueFull):
            pipe.submit([1, 2, 3, 6], max_new_tokens=2)
        assert pipe.rejected == 1

        # HTTP path: a zero-capacity gateway answers 429 + Retry-After
        eng2 = make_engine(model_and_params)
        srv = GatewayServer(PipelinedEngine(eng2, queue_limit=0),
                            retry_after_s=7)
        await srv.start()
        events = [e async for e in sse_generate(
            "127.0.0.1", srv.port, [1, 2, 3, 4], max_new_tokens=2)]
        await srv.drain()
        assert len(events) == 1
        kind, info = events[0]
        assert kind == "http_error" and info["status"] == 429
        assert info["retry_after"] == "7"

    asyncio.run(go())


# -- disconnect / leak accounting ------------------------------------------

def test_mid_stream_disconnect_releases_pages(model_and_params):
    """A client that vanishes mid-stream must leave the page pool's
    three-state accounting exact: no page stays referenced by the dead
    lane, and used + free + cached still covers the whole pool (minus
    the null page)."""
    cfg = model_and_params[0]
    prompts = make_prompts(cfg, 3, seed=5)

    async def go():
        eng = make_engine(model_and_params, prefix_cache=True)
        pipe = PipelinedEngine(eng, queue_limit=8)
        srv = GatewayServer(pipe)
        await srv.start()

        async def abandoner():
            # read two token frames, then close the socket without
            # consuming the rest of the stream
            async for _ in sse_generate("127.0.0.1", srv.port, prompts[0],
                                        max_new_tokens=40,
                                        disconnect_after=2):
                pass

        async def full(p):
            return [e async for e in sse_generate(
                "127.0.0.1", srv.port, p, max_new_tokens=6)]

        await abandoner()
        # later traffic still serves normally after the cancellation
        done = await asyncio.gather(full(prompts[1]), full(prompts[2]))
        await srv.drain()
        return eng, pipe, done

    eng, pipe, done = asyncio.run(go())
    assert pipe.cancels == 1
    assert len(eng.cancelled) == 1
    cancelled = eng.cancelled[0]
    assert cancelled.cancelled and len(cancelled.out_tokens) < 40
    for events in done:
        assert events[-1][0] == "done"
    # three-state pool accounting: nothing leaked by the dead lane
    stats = eng.kv.stats()
    assert stats["used_pages"] == 0
    assert (stats["used_pages"] + stats["free_pages"]
            + stats["cached_pages"]) == stats["n_pages"] - 1
    # cancelled requests are not serving metrics
    assert all(not r.cancelled for r in eng.metrics.requests)


# -- queue-wait metrics -----------------------------------------------------

def test_queue_wait_metrics(model_and_params):
    cfg = model_and_params[0]
    eng = make_engine(model_and_params, n_lanes=1)
    prompts = make_prompts(cfg, 4, seed=7)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=4))
    eng.run()
    waits = eng.metrics.queue_waits()
    assert len(waits) == len(prompts)
    assert all(w >= 0 for w in waits)
    s = eng.metrics.summary()
    assert s["queue_wait_s"]["p50"] is not None
    assert s["queue_wait_s"]["p99"] >= s["queue_wait_s"]["p50"]
    # single lane: later requests wait strictly longer than the first
    reqs = sorted(eng.metrics.requests, key=lambda r: r.rid)
    assert reqs[-1].admit_t - reqs[-1].submit_t \
        >= reqs[0].admit_t - reqs[0].submit_t


# -- GatewayPolicy region ---------------------------------------------------

def _make_tuner(workdir):
    from repro import at
    from repro.tuning import DecodeAutoTuner
    session = at.AutoTuner(str(workdir))

    def make_decode(block_k):
        return lambda *a, **k: None     # region never routed in this test

    tuner = DecodeAutoTuner(session, make_decode, buckets=(128,),
                            block_ks=(256,))
    tuner.add_gateway(max_inflights=(1, 2), admit_batches=(1,))
    return tuner


def _drive(model_and_params, tuner, n_requests, seed=11):
    cfg = model_and_params[0]
    prompts = make_prompts(cfg, n_requests, seed=seed)

    async def go():
        eng = make_engine(model_and_params)
        pipe = PipelinedEngine(eng, queue_limit=32, tuner=tuner,
                               policy_window=1)
        for p in prompts:
            pipe.submit(p, max_new_tokens=4)
        pipe.start()
        await pipe.drain()
        return pipe

    return asyncio.run(go())


def test_gateway_policy_commits_and_warm_loads(model_and_params, tmp_path):
    tuner = _make_tuner(tmp_path)
    assert tuner.committed_gateway() is None
    pipe = _drive(model_and_params, tuner, n_requests=8)
    # both candidates measured over windows, winner committed + persisted
    assert pipe.policy_windows >= 2
    idx = tuner.committed_gateway()
    assert idx is not None
    committed = tuner.committed_gateway_params()
    assert set(committed) == {"max_inflight", "admit_batch"}
    assert os.path.exists(tmp_path / "OAT_DynamicParamGatewayPolicy.dat")
    # committed knobs are live on the pipeline
    assert pipe.knobs.max_inflight == committed["max_inflight"]

    # warm restart: a fresh session over the same workdir starts
    # committed and runs ZERO measurement windows
    tuner2 = _make_tuner(tmp_path)
    assert tuner2.committed_gateway() == idx
    pipe2 = _drive(model_and_params, tuner2, n_requests=4, seed=12)
    assert pipe2.policy_windows == 0
    assert pipe2.knobs.max_inflight == committed["max_inflight"]
    assert pipe2.knobs.admit_batch == committed["admit_batch"]


# -- stats route ------------------------------------------------------------

def test_stats_and_healthz_routes(model_and_params):
    async def go():
        eng = make_engine(model_and_params)
        pipe = PipelinedEngine(eng, queue_limit=4)
        srv = GatewayServer(pipe)
        await srv.start()
        s_health = await get_json("127.0.0.1", srv.port, "/healthz")
        s_stats = await get_json("127.0.0.1", srv.port, "/v1/stats")
        s_404 = await get_json("127.0.0.1", srv.port, "/nope")
        await srv.drain()
        return s_health, s_stats, s_404

    (hs, health), (ss, stats), (ns, _) = asyncio.run(go())
    assert hs == 200 and health["ok"] and not health["draining"]
    assert ss == 200
    assert {"ticks", "backlog", "policy"} <= set(stats)
    assert ns == 404


# -- compare.py cell-key usage errors ---------------------------------------

def _load_compare():
    path = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "compare.py")
    spec = importlib.util.spec_from_file_location("bench_compare", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _payload(rows, config=None):
    return {"benchmark": "serving", "config": config or {"requests": 4},
            "results": rows}


def _row(arch="yi-6b", workload="uniform", **kw):
    return {"arch": arch, "cache": "paged", "workload": workload,
            "tokens_per_s": 10.0, **kw}


def _run_main(cmp_mod, tmp_path, base, cur, argv_extra=()):
    import sys
    bp, cp = tmp_path / "base.json", tmp_path / "cur.json"
    bp.write_text(json.dumps(base))
    cp.write_text(json.dumps(cur))
    argv = ["compare", str(bp), str(cp), *argv_extra]
    old = sys.argv
    sys.argv = argv
    try:
        cmp_mod.main()
        return 0
    except SystemExit as e:
        return e.code or 0
    finally:
        sys.argv = old


def test_compare_disjoint_keysets_exit2(tmp_path, capsys):
    cmp_mod = _load_compare()
    base = _payload([_row(workload="uniform")])
    cur = _payload([_row(workload="gateway")])
    assert _run_main(cmp_mod, tmp_path, base, cur) == 2
    out = capsys.readouterr().out
    assert "share no cell keys" in out
    assert "uniform" in out and "gateway" in out   # names missing + extra


def test_compare_duplicate_keys_exit2(tmp_path, capsys):
    cmp_mod = _load_compare()
    base = _payload([_row(), _row()])      # same key twice
    cur = _payload([_row()])
    assert _run_main(cmp_mod, tmp_path, base, cur) == 2
    assert "duplicate cell keys" in capsys.readouterr().out


def test_compare_partial_overlap_still_gates(tmp_path, capsys):
    """A genuinely dropped cell is a regression (exit 1), not a usage
    error — the disjoint check must not swallow it."""
    cmp_mod = _load_compare()
    base = _payload([_row(), _row(arch="deepseek-7b")])
    cur = _payload([_row()])
    assert _run_main(cmp_mod, tmp_path, base, cur) == 1
    assert "missing from current run" in capsys.readouterr().out


def test_compare_gates_goodput_and_slo(tmp_path, capsys):
    cmp_mod = _load_compare()
    g = dict(workload="gateway", goodput_tok_s=100.0, slo_attainment=0.9)
    base = _payload([_row(**g)])
    ok = _payload([_row(**{**g, "goodput_tok_s": 95.0})])
    assert _run_main(cmp_mod, tmp_path, base, ok) == 0
    bad = _payload([_row(**{**g, "goodput_tok_s": 50.0})])
    assert _run_main(cmp_mod, tmp_path, base, bad) == 1
    assert "goodput dropped" in capsys.readouterr().out
    dead = _payload([_row(**{**g, "slo_attainment": 0.0})])
    assert _run_main(cmp_mod, tmp_path, base, dead) == 1
    assert "SLO attainment fell to zero" in capsys.readouterr().out
