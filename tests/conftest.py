import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import pytest


@pytest.fixture
def ctx(tmp_path):
    from repro.core import ATContext
    return ATContext(workdir=str(tmp_path))


@pytest.fixture
def ctx_with_bps(ctx):
    ctx.store.set_bp("OAT_NUMPROCS", 4)
    ctx.store.set_bp("OAT_STARTTUNESIZE", 1024)
    ctx.store.set_bp("OAT_ENDTUNESIZE", 3072)
    ctx.store.set_bp("OAT_SAMPDIST", 1024)
    return ctx
