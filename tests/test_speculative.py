"""Speculative decoding: verify dispatch, KV rollback, bit-identity,
sampler determinism, and the SpecBucket tuning region.

Correctness contract: speculative decoding is an *implementation detail*
of the paged engine — greedy outputs must be bit-identical to the dense
engine token-for-token for every draft length k, through mid-stream
rejections, EOS inside an accepted run, and mid-spec swap-out/resume.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import build_model
from repro.serving import (PagedKVCache, Request, SamplingParams,
                           ServingEngine)
from repro.serving import sampling


@pytest.fixture(scope="module")
def spec_model():
    cfg = ARCHS["yi-6b"].reduced()      # plain GQA: paged-capable
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    draft_model = model.draft_model()
    draft_params = model.slice_draft_params(params, draft_model)
    return cfg, model, params, draft_model, draft_params


def _requests(n=3, max_new=6, plen=11):
    return [Request(rid=i, prompt=[1 + i] + [(3 * i + j) % 90 + 2
                                             for j in range(plen - 1)],
                    max_new_tokens=max_new) for i in range(n)]


def _dense_want(model, params, reqs_fn, max_len=48, max_steps=200,
                eos_id=None):
    eng = ServingEngine(model, params, n_lanes=2, max_len=max_len,
                        eos_id=eos_id)
    for r in reqs_fn():
        eng.submit(r)
    return {r.rid: r.out_tokens for r in eng.run(max_steps=max_steps)}


def _spec_engine(model, params, dmodel, dparams, k, **kw):
    kw.setdefault("n_lanes", 2)
    kw.setdefault("max_len", 48)
    kw.setdefault("page_size", 8)
    return ServingEngine(model, params, cache="paged", draft_model=dmodel,
                         draft_params=dparams, spec_k=k, **kw)


# --------------------------------------------------------------------------
# draft config + params
# --------------------------------------------------------------------------


class TestDraftConfig:
    def test_reduced_depth_same_vocab(self):
        cfg = ARCHS["yi-6b"].reduced()
        d = cfg.draft_config()
        assert d.n_layers == max(1, cfg.n_layers // 2)
        assert d.vocab_size == cfg.vocab_size
        assert d.d_model == cfg.d_model          # self-slicing width

    def test_every_registry_config_has_a_draft(self):
        for cfg in ARCHS.values():
            d = cfg.draft_config()
            assert 1 <= d.n_layers < max(2, cfg.n_layers)
            assert d.family == cfg.family

    def test_width_reduced_draft(self):
        cfg = ARCHS["yi-6b"].reduced()
        d = cfg.draft_config(width_frac=0.5)
        assert d.d_model == cfg.d_model // 2

    def test_slice_draft_params(self, spec_model):
        cfg, model, params, dmodel, dparams = spec_model
        stacked = jax.tree.leaves(params["layers"])[0]
        sliced = jax.tree.leaves(dparams["layers"])[0]
        assert sliced.shape[0] == dmodel.cfg.n_layers
        np.testing.assert_array_equal(
            np.asarray(sliced), np.asarray(stacked[:dmodel.cfg.n_layers]))
        assert dparams["embed"] is params["embed"]   # shared head/embed

    def test_slice_rejects_width_mismatch(self, spec_model):
        cfg, model, params, *_ = spec_model
        narrow = build_model(cfg.draft_config(width_frac=0.5))
        with pytest.raises(ValueError, match="width"):
            model.slice_draft_params(params, narrow)


# --------------------------------------------------------------------------
# verify dispatch: ops + speculative_step
# --------------------------------------------------------------------------


class TestVerifyDispatch:
    def test_ops_verify_matches_prefill_oracle(self):
        from repro.kernels import ops, ref
        b, h, hkv, d, psz, p = 2, 4, 2, 16, 8, 9
        q = jax.random.normal(jax.random.PRNGKey(0), (b, h, 5, d)) * 0.3
        kp = jax.random.normal(jax.random.PRNGKey(1), (p, hkv, psz, d)) * 0.3
        vp = jax.random.normal(jax.random.PRNGKey(2), (p, hkv, psz, d)) * 0.3
        table = jnp.asarray([[3, 7, 1], [5, 2, 6]], jnp.int32)
        start = jnp.asarray([10, 0], jnp.int32)
        kv_len = jnp.asarray([15, 3], jnp.int32)
        got = ops.paged_verify_attention(q, kp, vp, table, start, kv_len)
        want = ref.paged_prefill_ref(q, kp, vp, table, start, kv_len)
        np.testing.assert_allclose(got, want, atol=1e-6)

    def test_speculative_step_c1_matches_decode_step(self, spec_model):
        """A 1-wide verify chunk is a decode step: same logits row."""
        cfg, model, params, *_ = spec_model
        kv = PagedKVCache(model, n_lanes=1, max_len=32, n_pages=9,
                          page_size=8)
        prompt = [5, 6, 7, 8]
        logits, c1 = model.prefill(params, jnp.asarray([prompt]), None,
                                   kv.prefill_len(len(prompt)))
        assert kv.admit(0, c1, len(prompt))
        kv.ensure_capacity(0, len(prompt))
        tok = jnp.asarray([[int(jnp.argmax(logits[0]))]], jnp.int32)
        pos = jnp.asarray([len(prompt)], jnp.int32)
        table = kv.decode_extra()[0]
        want, _ = model.paged_decode_step(params, kv.caches, table, tok, pos)
        got, _ = model.speculative_step(params, kv.caches, table, tok,
                                        pos, pos + 1)
        np.testing.assert_allclose(np.asarray(got[0, 0]),
                                   np.asarray(want[0]), atol=1e-4)


# --------------------------------------------------------------------------
# KV rollback (truncate_to)
# --------------------------------------------------------------------------


class TestTruncateTo:
    def test_frees_exactly_overallocated_pages(self, spec_model):
        cfg, model, params, *_ = spec_model
        kv = PagedKVCache(model, n_lanes=2, max_len=64, n_pages=17,
                          page_size=8)
        assert kv.ensure_tokens(0, 20)          # 3 pages for [0, 20)
        assert kv.used_pages == 3
        free_before = kv.free_pages
        held = [int(p) for p in kv.table[0, :3]]
        # commit only 10 tokens: pages 2 (covering [0, 16)) stay, page 3 goes
        assert kv.truncate_to(0, 10) == 1
        assert kv.used_pages == 2
        assert kv.free_pages == free_before + 1
        assert kv.n_blocks[0] == 2
        assert [int(p) for p in kv.table[0, :2]] == held[:2]
        assert int(kv.table[0, 2]) == 0          # vacated row -> null page
        assert held[2] in kv._free               # back in the pool
        # idempotent: already tight
        assert kv.truncate_to(0, 10) == 0
        assert kv.truncate_to(0, 16) == 0        # same page count

    def test_truncate_other_lane_untouched(self, spec_model):
        cfg, model, params, *_ = spec_model
        kv = PagedKVCache(model, n_lanes=2, max_len=64, n_pages=17,
                          page_size=8)
        kv.ensure_tokens(0, 24)
        kv.ensure_tokens(1, 24)
        lane1 = [int(p) for p in kv.table[1, :3]]
        kv.truncate_to(0, 1)
        assert [int(p) for p in kv.table[1, :3]] == lane1
        assert kv.n_blocks[1] == 3

    def test_dense_truncate_is_noop(self, spec_model):
        from repro.serving import DenseKVCache
        cfg, model, params, *_ = spec_model
        kv = DenseKVCache(model, n_lanes=1, max_len=32)
        assert kv.truncate_to(0, 4) == 0

    def test_full_spec_cycle_leaks_zero_pages(self, spec_model):
        """admit -> speculate -> reject -> decode -> finish returns every
        page to the pool."""
        cfg, model, params, dmodel, dparams = spec_model
        eng = _spec_engine(model, params, dmodel, dparams, k=4, n_pages=17)
        for r in _requests(3, max_new=6):
            eng.submit(r)
        done = eng.run(max_steps=200)
        assert len(done) == 3
        assert eng.drafted_tokens > eng.accepted_tokens   # rejections hit
        assert eng.kv.used_pages == 0
        assert eng.kv.free_pages == eng.kv.n_pages - 1    # null page apart
        assert np.all(np.asarray(eng.kv.table) == 0)


# --------------------------------------------------------------------------
# engine bit-identity
# --------------------------------------------------------------------------


class TestSpecBitIdentity:
    def test_spec_requires_paged_and_draft(self, spec_model):
        cfg, model, params, dmodel, dparams = spec_model
        with pytest.raises(ValueError, match="paged"):
            ServingEngine(model, params, n_lanes=1, max_len=32,
                          draft_model=dmodel, draft_params=dparams,
                          spec_k=2)
        with pytest.raises(ValueError, match="draft"):
            ServingEngine(model, params, n_lanes=1, max_len=32,
                          cache="paged", spec_k=2)

    @pytest.mark.parametrize("k", [1, 2, 4])
    def test_spec_greedy_matches_dense(self, spec_model, k):
        """Speculative greedy == plain greedy token-for-token, with
        mid-stream rejections exercised (random-init draft disagrees)."""
        cfg, model, params, dmodel, dparams = spec_model
        want = _dense_want(model, params, _requests)
        eng = _spec_engine(model, params, dmodel, dparams, k)
        for r in _requests():
            eng.submit(r)
        got = {r.rid: r.out_tokens for r in eng.run(max_steps=200)}
        assert got == want
        assert eng.spec_ticks > 0
        assert eng.drafted_tokens > 0
        assert eng.drafted_tokens >= eng.accepted_tokens

    def test_spec_greedy_matches_dense_moe_arch(self):
        """Second bench config (deepseek-7b, MoE stack): same contract."""
        cfg = ARCHS["deepseek-7b"].reduced()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        dmodel = model.draft_model()
        dparams = model.slice_draft_params(params, dmodel)
        want = _dense_want(model, params, lambda: _requests(2))
        eng = _spec_engine(model, params, dmodel, dparams, k=4)
        for r in _requests(2):
            eng.submit(r)
        got = {r.rid: r.out_tokens for r in eng.run(max_steps=200)}
        assert got == want
        assert eng.spec_ticks > 0

    def test_spec_with_eos_matches_dense(self, spec_model):
        """EOS inside an accepted run truncates the emission exactly
        where the dense engine stops."""
        cfg, model, params, dmodel, dparams = spec_model
        plain = _dense_want(model, params, _requests)
        # pick a token the dense run emits mid-stream as the EOS id
        eos = plain[0][2]
        want = _dense_want(model, params, _requests, eos_id=eos)
        assert want != plain                    # EOS actually fired
        eng = _spec_engine(model, params, dmodel, dparams, k=4, eos_id=eos)
        for r in _requests():
            eng.submit(r)
        got = {r.rid: r.out_tokens for r in eng.run(max_steps=200)}
        assert got == want

    def test_mid_spec_swap_out_resume(self, spec_model):
        """Tiny pool + timeslice: lanes are preempted between speculative
        ticks (pages swap out/in, the draft cache rebuilds) and outputs
        stay bit-identical."""
        cfg, model, params, dmodel, dparams = spec_model
        want = _dense_want(model, params,
                           lambda: _requests(5, max_new=6), max_steps=300)
        eng = _spec_engine(model, params, dmodel, dparams, k=2,
                           n_pages=9, timeslice=3)
        for r in _requests(5, max_new=6):
            eng.submit(r)
        done = eng.run(max_steps=400)
        assert len(done) == 5
        assert eng.scheduler.preemptions > 0
        assert eng.kv.swap_outs > 0 and eng.kv.swap_ins > 0
        assert {r.rid: r.out_tokens for r in done} == want

    def test_spec_with_chunked_prefill(self, spec_model):
        """Chunked prefill + speculation in the same engine: prefill lanes
        ride the verify step masked to the null page."""
        cfg, model, params, dmodel, dparams = spec_model
        def reqs():
            return [Request(rid=0, prompt=list(range(1, 25)),
                            max_new_tokens=6),
                    Request(rid=1, prompt=[5, 6, 7], max_new_tokens=6)]
        want = _dense_want(model, params, reqs, max_len=64)
        eng = _spec_engine(model, params, dmodel, dparams, k=2,
                           max_len=64, prefill_chunk=4)
        for r in reqs():
            eng.submit(r)
        got = {r.rid: r.out_tokens for r in eng.run(max_steps=300)}
        assert got == want
        assert eng.prefill_chunks > 0 and eng.spec_ticks > 0

    def test_sampled_spec_runs_to_completion(self, spec_model):
        """Sampled speculation: right token counts, reproducible reruns."""
        cfg, model, params, dmodel, dparams = spec_model
        def reqs():
            return [Request(rid=i, prompt=[3 + i, 1, 4, 1],
                            max_new_tokens=6,
                            sampling=SamplingParams(temperature=0.8,
                                                    top_k=50, seed=7 + i))
                    for i in range(3)]
        outs = []
        for _ in range(2):
            eng = _spec_engine(model, params, dmodel, dparams, k=2)
            for r in reqs():
                eng.submit(r)
            done = eng.run(max_steps=200)
            assert all(len(r.out_tokens) == 6 for r in done)
            outs.append({r.rid: r.out_tokens for r in done})
        assert outs[0] == outs[1]               # seeded determinism


# --------------------------------------------------------------------------
# sampler unit behavior (deterministic; property tests in test_sampling.py)
# --------------------------------------------------------------------------


class TestSamplerUnits:
    def test_greedy_is_exact_argmax(self):
        logits = np.asarray([0.1, 2.0, -1.0, 2.0])
        sp = SamplingParams()
        assert sp.greedy
        assert sampling.sample_token(logits, sp, 0) == int(np.argmax(logits))

    def test_greedy_speculative_accept_rule(self):
        V = 8
        tl = np.zeros((3, V))
        tl[0, 2] = 1.0          # target argmax: 2
        tl[1, 5] = 1.0          # target argmax: 5
        tl[2, 6] = 1.0          # bonus row
        sp = SamplingParams()
        # both drafts agree -> all accepted + bonus
        emitted, a = sampling.speculative_accept(
            [2, 5], [None, None], tl, sp, 0)
        assert (emitted, a) == ([2, 5, 6], 2)
        # second draft disagrees -> correction from its target row
        emitted, a = sampling.speculative_accept(
            [2, 4], [None, None], tl, sp, 0)
        assert (emitted, a) == ([2, 5], 1)
        # immediate rejection -> single corrected token
        emitted, a = sampling.speculative_accept(
            [0, 4], [None, None], tl, sp, 0)
        assert (emitted, a) == ([2], 0)

    def test_sampled_accept_emits_in_support(self):
        rng = np.random.default_rng(0)
        sp = SamplingParams(temperature=1.0, seed=3)
        tl = rng.normal(size=(3, 16))
        q0 = sampling.filtered_probs(rng.normal(size=16), sp)
        q1 = sampling.filtered_probs(rng.normal(size=16), sp)
        emitted, a = sampling.speculative_accept([4, 9], [q0, q1], tl, sp, 0)
        assert 1 <= len(emitted) == a + 1 <= 3
        assert all(0 <= t < 16 for t in emitted)

    def test_spec_stats_off_by_default(self, spec_model):
        cfg, model, params, *_ = spec_model
        eng = ServingEngine(model, params, n_lanes=1, max_len=32)
        st = eng.spec_stats()
        assert st["spec_k"] is None and st["accept_rate"] == 0.0


# --------------------------------------------------------------------------
# SpecBucket tuning region (repro.at dynamic select)
# --------------------------------------------------------------------------


class TestSpecTuningRegion:
    def _mk(self, calls):
        def make_verify(k, bq, bk):
            def fn():
                calls.append((k, bq, bk))
                return {"k": k, "bq": bq, "bk": bk}
            return fn
        return make_verify

    def test_k_by_tile_product_space_commits(self, tmp_path):
        from repro import at
        from repro.tuning import DecodeAutoTuner
        session = at.AutoTuner(str(tmp_path))
        tuner = DecodeAutoTuner(session, lambda bk: (lambda: bk),
                                buckets=(512,), block_ks=(256,))
        calls: list = []
        tuner.add_spec(self._mk(calls), ks=(1, 4), buckets=(512, 2048),
                       block_qs=(5,), block_ks=(4, 8))
        assert len(tuner.spec_regions) == 2
        assert all(len(r.subregions) == 4           # k x block_k
                   for r in tuner.spec_regions.values())
        for _ in range(4):                          # one call per candidate
            tuner.spec(300)
        pp = tuner.committed_spec_params()[512]
        assert pp["k"] in (1, 4) and pp["block_k"] in (4, 8)
        assert tuner.committed_spec_params()[2048] is None

    def test_commits_on_time_per_token_not_call_latency(self, tmp_path):
        """A narrower verify is always cheaper per call, so the region
        must commit on reported time_per_token (throughput), not raw
        latency — and the engine-facing spec_draft_k then caps drafting
        at the winner's window."""
        import time as _time

        from repro import at
        from repro.tuning import DecodeAutoTuner
        session = at.AutoTuner(str(tmp_path))
        tuner = DecodeAutoTuner(session, lambda bk: (lambda: bk),
                                buckets=(512,), block_ks=(256,))

        def make_verify(k, bq, bk):
            def fn():
                # k=1 is the fastest CALL but the worst per emitted token
                _time.sleep(0.001 * k)
                return {"k": k, "time_per_token": 1.0 / k}
            return fn

        tuner.add_spec(make_verify, ks=(1, 4), buckets=(512,),
                       block_qs=(5,), block_ks=(8,))
        assert tuner.spec_draft_k(100, 4) == 4     # uncommitted: full width
        for _ in range(2):
            tuner.spec(100)
        assert tuner.committed_spec_params()[512]["k"] == 4
        assert tuner.spec_draft_k(100, 4) == 4
        assert tuner.spec_draft_k(100, 2) == 2     # engine cap still wins

    def test_warm_restart_zero_tuning(self, tmp_path):
        """A second session on the same workdir starts with the spec
        bucket committed — zero tuning-executor invocations."""
        from repro import at
        from repro.tuning import DecodeAutoTuner

        s1 = at.AutoTuner(str(tmp_path))
        t1 = DecodeAutoTuner(s1, lambda bk: (lambda: bk),
                             buckets=(512,), block_ks=(256,))
        t1.add_spec(self._mk([]), ks=(1, 4), buckets=(512,),
                    block_qs=(5,), block_ks=(8,))
        for _ in range(2):
            t1.spec(100)
        winner = t1.committed_spec()[512]
        assert winner is not None

        calls2: list = []
        s2 = at.AutoTuner(str(tmp_path))
        t2 = DecodeAutoTuner(s2, lambda bk: (lambda: bk),
                             buckets=(512,), block_ks=(256,))
        t2.add_spec(self._mk(calls2), ks=(1, 4), buckets=(512,),
                    block_qs=(5,), block_ks=(8,))
        assert t2.committed_spec()[512] == winner
        assert s2.executor_calls == 0
        assert ("dynamic", "SpecBucket_512") in s2.warm_hits
        out = t2.spec(100)
        assert out["k"] == (1, 4)[winner]
        assert calls2 == [((1, 4)[winner], 5, 8)]   # no re-measurement

    def test_engine_routes_through_spec_region(self, spec_model, tmp_path):
        """End-to-end: the engine's speculative tick goes through the
        tuner's SpecBucket region and greedy outputs stay bit-identical
        (even while candidates with different k are being measured)."""
        cfg, model, params, dmodel, dparams = spec_model
        from repro.launch.serve import _make_autotuner
        want = _dense_want(model, params, _requests)
        tuner = _make_autotuner(model, str(tmp_path), "paged", 8, spec_k=4)
        assert tuner.spec_regions
        eng = _spec_engine(model, params, dmodel, dparams, k=4,
                           autotuner=tuner)
        for r in _requests():
            eng.submit(r)
        got = {r.rid: r.out_tokens for r in eng.run(max_steps=200)}
        assert got == want
        assert eng.spec_ticks > 0
