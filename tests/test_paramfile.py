"""Parameter information files — the paper's S-expression format (§6.2)."""
import os

import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import paramfile
from repro.core.paramfile import Node, dumps, loads, param_path


def test_paper_install_example_roundtrip():
    """The printed OAT_InstallParam.dat example (Sample 2)."""
    text = "(SetCacheParam\n(CacheSize 64)\n(CacheLine 8)\n)\n"
    nodes = loads(text)
    assert len(nodes) == 1
    n = nodes[0]
    assert n.name == "SetCacheParam"
    assert n.child_value("CacheSize") == 64
    assert n.child_value("CacheLine") == 8
    assert loads(dumps(nodes)) == nodes


def test_paper_static_example_nested():
    """The printed OAT_StaticParam.dat example with nested OAT_PROBSIZE
    groups (Sample 4a)."""
    text = """(MyMatMul
(OAT_NUMPROCS 4)
(OAT_SAMPDIST 1024)
(OAT_PROBSIZE 1024
(MyMatMul_I 4)
(MyMatMul_J 8))
(OAT_PROBSIZE 2048
(MyMatMul_I 4)
(MyMatMul_J 9) )
(OAT_PROBSIZE 3072
(MyMatMul_I 5)
(MyMatMul_J 10) )
)
"""
    nodes = loads(text)
    mm = nodes[0]
    assert mm.child_value("OAT_NUMPROCS") == 4
    g = mm.keyed_child("OAT_PROBSIZE", 2048)
    assert g.child_value("MyMatMul_I") == 4
    assert g.child_value("MyMatMul_J") == 9
    assert loads(dumps(nodes)) == nodes


def test_scalar_kinds():
    nodes = loads('(X (a 1) (b 2.5) (c .true.) (d .false.) (e "hi"))')
    x = nodes[0]
    assert x.child_value("a") == 1
    assert x.child_value("b") == 2.5
    assert x.child_value("c") is True
    assert x.child_value("d") is False
    assert x.child_value("e") == "hi"


def test_file_naming_conventions(tmp_path):
    """§6.2: OAT_<Phase>Param[Def]<X>.dat."""
    d = str(tmp_path)
    assert param_path(d, "install").endswith("OAT_InstallParam.dat")
    assert param_path(d, "static", "MyMatMul").endswith(
        "OAT_StaticParamMyMatMul.dat")
    assert param_path(d, "dynamic", user=True).endswith(
        "OAT_DynamicParamDef.dat")


def test_atomic_save(tmp_path):
    path = str(tmp_path / "OAT_InstallParam.dat")
    paramfile.save_file(path, [Node("A", children=[Node("x", 1)])])
    assert not os.path.exists(path + ".tmp")
    assert paramfile.load_file(path)[0].child_value("x") == 1


_names = st.text(
    alphabet=st.sampled_from("abcXYZ_123"), min_size=1, max_size=8)
_scalars = st.one_of(st.integers(-1000, 1000), st.booleans(),
                     st.text(alphabet=st.sampled_from("abc DEF"),
                             min_size=1, max_size=6).map(lambda s: s))


@st.composite
def _node(draw, depth=0):
    name = draw(_names)
    value = draw(st.none() | _scalars)
    children = []
    if depth < 2:
        children = draw(st.lists(_node(depth=depth + 1), max_size=3))
    if isinstance(value, str):
        value = value.strip() or None
    return Node(name, value, children)


@settings(max_examples=50, deadline=None)
@given(st.lists(_node(), min_size=1, max_size=4))
def test_property_roundtrip(nodes):
    """Property: dumps -> loads is the identity on arbitrary trees."""
    def norm(n):
        v = n.value
        return Node(n.name, v, [norm(c) for c in n.children])

    nodes = [norm(n) for n in nodes]
    assert loads(dumps(nodes)) == nodes
