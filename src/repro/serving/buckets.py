"""The length-bucket ladders — ONE definition, imported everywhere.

Run-time AT regions are keyed by sequence-length bucket
(``DecodeBucket_{b}`` / ``PrefillBucket_{b}_c{c}`` / ``SpecBucket_{b}``),
and the bucket a call routes to is whatever ladder the caller holds.
These tables used to be hardcoded independently in ``serving/engine.py``,
``tuning/dynamic.py`` and ``launch/serve.py`` — any drift between them
silently mis-routes committed winners (a region tuned under one ladder is
looked up under another and the wrong bucket answers).  Both ladders now
live here and every layer imports them.

* :data:`LENGTH_BUCKETS` — the full production ladder (kv lengths up to
  32k); the default for :func:`repro.serving.length_bucket` and the
  ``DecodeAutoTuner`` region families.
* :data:`REDUCED_BUCKETS` — the CPU-proxy ladder the serving driver and
  benchmarks tune over (reduced configs never exceed 2k).
"""
from __future__ import annotations

LENGTH_BUCKETS: tuple[int, ...] = (128, 512, 2048, 8192, 32768)
REDUCED_BUCKETS: tuple[int, ...] = (128, 512, 2048)
