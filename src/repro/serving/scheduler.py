"""Request scheduling for the serving engine: FIFO admission + preemption.

Pure policy, no jax: the scheduler decides *which* request gets a lane and
*when* a running one is preempted; the engine performs the actual model
and cache operations.  One unified ``ready`` queue (``collections.deque``,
O(1) at both ends) holds new requests and preempted sequences in FIFO
order:

* new requests join at the back;
* a **time-slice** victim also joins at the back — it yields its lane to
  whatever is at the head of the queue, which is what makes preemption an
  actual rotation (the engine serves more concurrent requests than it has
  decode lanes) rather than an immediate self-re-admission;
* a **page-pressure** victim (evicted because the pool could not grow its
  sequence) re-joins at the *front*: it resumes as soon as pages free up,
  so memory eviction never turns into queue starvation.

Preempted sequences carry a KV swap handle and resume by swap-in — no
prefill re-run, bit-identical continuation.

With chunked prefill a lane passes through a **prefill phase** first
(``LaneState.phase``): ``pos`` counts committed prompt tokens until the
prompt is fully streamed in, then the lane flips to ``decode``.  A lane
preempted mid-prefill re-queues with its phase and progress in the
:class:`ResumeEntry`, so it resumes exactly where it stopped.  Time-slice
victim selection only considers decoding lanes (a prefill chunk is one
bounded unit of work per tick already); page-pressure eviction of a
prefill lane is handled by the engine's prefill tick.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any

from .sampling import SamplingParams


@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int = 16
    sampling: SamplingParams = field(default_factory=SamplingParams)
    out_tokens: list[int] = field(default_factory=list)
    done: bool = False
    # timestamps use the monotonic clock (as does the engine): TTFT/ITL
    # are durations, and wall-clock adjustments (NTP slew, DST) must not
    # produce negative or inflated latency percentiles
    submit_t: float = field(default_factory=time.monotonic)
    admit_t: float | None = None   # first lane occupancy (queue wait ends)
    first_token_t: float | None = None
    finish_t: float | None = None
    token_ts: list[float] = field(default_factory=list)
    preemptions: int = 0
    cached_tokens: int = 0     # prompt tokens served from the prefix cache
    cancelled: bool = False    # aborted (client disconnect), not finished


@dataclass
class LaneState:
    rid: int | None = None
    pos: int = 0               # decode: next KV write position;
    #                            prefill: prompt tokens committed so far
    remaining: int = 0         # decode-token budget left
    steps_served: int = 0      # decode steps since (re-)admission
    tokens_served: int = 0     # tokens emitted since (re-)admission (a
    #                            speculative tick emits several per step)
    phase: str = "decode"      # "prefill" while the prompt streams in


@dataclass
class ResumeEntry:
    """A preempted request plus everything needed to resume it."""

    req: Request
    handle: Any                # kv backend swap handle
    pos: int
    remaining: int
    phase: str = "decode"      # preempted mid-prefill resumes mid-prefill


class Scheduler:
    """FIFO + preemptive continuous batching over ``n_lanes`` slots."""

    def __init__(self, n_lanes: int, timeslice: int | None = None):
        self.lanes = [LaneState() for _ in range(n_lanes)]
        self.ready: deque[Request | ResumeEntry] = deque()
        self.timeslice = timeslice
        self.preemptions = 0

    # -- queue state --------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.ready.append(req)

    @property
    def has_queued(self) -> bool:
        return bool(self.ready)

    @property
    def pending(self) -> int:
        return len(self.ready)

    @property
    def waiting(self) -> deque:
        """New (never-run) requests still queued, in FIFO order."""
        return deque(r for r in self.ready if isinstance(r, Request))

    def free_lanes(self) -> list[int]:
        return [i for i, l in enumerate(self.lanes) if l.rid is None]

    def active_lanes(self) -> list[int]:
        return [i for i, l in enumerate(self.lanes) if l.rid is not None]

    def prefill_lanes(self) -> list[int]:
        return [i for i, l in enumerate(self.lanes)
                if l.rid is not None and l.phase == "prefill"]

    def decode_lanes(self) -> list[int]:
        return [i for i, l in enumerate(self.lanes)
                if l.rid is not None and l.phase == "decode"]

    # -- admission ----------------------------------------------------------
    def next_admission(self) -> tuple[str, Any] | None:
        """Head of the ready queue as ('resume' | 'new', item)."""
        if not self.ready:
            return None
        item = self.ready.popleft()
        return ("resume" if isinstance(item, ResumeEntry) else "new", item)

    def push_back(self, kind: str, item: Any) -> None:
        """Return an un-admittable item to the head of the queue."""
        self.ready.appendleft(item)

    def remove_queued(self, rid: int) -> Request | None:
        """Pull a queued request (new or preempted) out of the ready deque
        — the cancellation path for work that never reached, or was bumped
        from, a lane.  Returns the request, or None if ``rid`` is not
        queued."""
        for item in self.ready:
            req = item.req if isinstance(item, ResumeEntry) else item
            if req.rid == rid:
                self.ready.remove(item)
                return req
        return None

    def occupy(self, lane_id: int, req: Request, pos: int,
               remaining: int, phase: str = "decode") -> None:
        self.lanes[lane_id] = LaneState(rid=req.rid, pos=pos,
                                        remaining=remaining, steps_served=0,
                                        phase=phase)

    def vacate(self, lane_id: int) -> None:
        self.lanes[lane_id] = LaneState()

    # -- preemption ---------------------------------------------------------
    def pick_victim(self) -> int | None:
        """Time-slice policy: with work queued, preempt the longest-served
        lane once it has used up its slice.  Service is counted in both
        decode steps and emitted tokens — a speculative tick emits several
        tokens per step, and the larger of the two counts is what burns
        the slice (variable tokens-per-tick can't stretch a lane's turn).
        Returns a lane id or None."""
        if self.timeslice is None or not self.has_queued:
            return None
        served = [(max(l.steps_served, l.tokens_served), i)
                  for i, l in enumerate(self.lanes)
                  if l.rid is not None and l.phase == "decode"
                  and max(l.steps_served, l.tokens_served) >= self.timeslice]
        if not served:
            return None
        return max(served)[1]

    def preempt(self, lane_id: int, req: Request, handle: Any,
                priority: bool = False) -> None:
        """Vacate ``lane_id``; the sequence re-queues at the back (time
        slice expired: yield to the queue head) or the front
        (``priority=True``, page pressure: resume as soon as possible)."""
        lane = self.lanes[lane_id]
        req.preemptions += 1
        self.preemptions += 1
        entry = ResumeEntry(req=req, handle=handle, pos=lane.pos,
                            remaining=lane.remaining, phase=lane.phase)
        if priority:
            self.ready.appendleft(entry)
        else:
            self.ready.append(entry)
        self.vacate(lane_id)
