"""Dependency-free asyncio HTTP/1.1 + SSE front-end for the gateway.

The container's serving deps are jax + numpy, so the server is built on
``asyncio.start_server`` directly: a small HTTP/1.1 request parser, an
SSE response writer, and three routes.

* ``POST /v1/generate`` — body ``{"prompt": [ids], "max_new_tokens": n,
  "sampling": {temperature, top_k, top_p, seed}}``.  The handler stamps
  ``submit_t`` the moment the request is parsed — *before* any queueing
  — so queue-wait percentiles measure the full gateway-side delay.  The
  response streams Server-Sent Events: ``data: {"tokens": [...]}`` per
  emission, then one ``event: done`` frame carrying ``n_tokens``,
  ``queue_wait_s``, ``ttft_s`` and ``cached_tokens``.  A full admission
  queue answers ``429`` with a ``Retry-After`` hint instead of queueing
  unboundedly (backpressure is the contract: the load generator counts
  these); a draining gateway answers ``503``.
* ``GET /healthz`` — liveness + drain state.
* ``GET /v1/stats`` — the pipeline's counters and current
  ``GatewayPolicy`` knobs.

Client disconnects are detected two ways — the socket reaches EOF (a
watcher task polls the reader), or an SSE write fails — and both funnel
into ``PipelinedEngine.cancel``, which applies the cancellation at the
next tick boundary and releases the lane's pages and prefix refcounts.
A disconnect therefore never leaks pool pages (asserted by test).

Shutdown is a graceful drain: stop accepting connections, let admitted
work finish streaming, then close.
"""
from __future__ import annotations

import asyncio
import json
import time

from ..sampling import SamplingParams
from .pipeline import Draining, PipelinedEngine, QueueFull

__all__ = ["GatewayServer"]

_MAX_BODY = 8 * 1024 * 1024


def _sampling_from(obj: dict | None) -> SamplingParams | None:
    if not obj:
        return None
    return SamplingParams(temperature=float(obj.get("temperature", 0.0)),
                          top_k=int(obj.get("top_k", 0)),
                          top_p=float(obj.get("top_p", 1.0)),
                          seed=int(obj.get("seed", 0)))


async def _read_request(reader: asyncio.StreamReader):
    """Parse one HTTP/1.1 request; returns (method, path, headers, body)
    or None on EOF / malformed input."""
    try:
        line = await reader.readline()
    except (ConnectionResetError, asyncio.IncompleteReadError):
        return None
    if not line:
        return None
    parts = line.decode("latin-1").strip().split()
    if len(parts) != 3:
        return None
    method, path, _version = parts
    headers: dict[str, str] = {}
    while True:
        raw = await reader.readline()
        if raw in (b"\r\n", b"\n", b""):
            break
        if b":" in raw:
            k, v = raw.decode("latin-1").split(":", 1)
            headers[k.strip().lower()] = v.strip()
    length = int(headers.get("content-length", "0") or "0")
    if length > _MAX_BODY:
        return None
    body = await reader.readexactly(length) if length else b""
    return method, path, headers, body


def _response(status: str, payload: dict, extra: dict | None = None) -> bytes:
    body = json.dumps(payload).encode()
    headers = [f"HTTP/1.1 {status}",
               "Content-Type: application/json",
               f"Content-Length: {len(body)}",
               "Connection: close"]
    for k, v in (extra or {}).items():
        headers.append(f"{k}: {v}")
    return ("\r\n".join(headers) + "\r\n\r\n").encode() + body


def _sse_frame(data: dict, event: str | None = None) -> bytes:
    head = f"event: {event}\n" if event else ""
    return (head + "data: " + json.dumps(data) + "\n\n").encode()


class GatewayServer:
    """HTTP/SSE front door for one :class:`PipelinedEngine`."""

    def __init__(self, pipe: PipelinedEngine, host: str = "127.0.0.1",
                 port: int = 0, retry_after_s: int = 1):
        self.pipe = pipe
        self.host = host
        self.port = port
        self.retry_after_s = retry_after_s
        self._server: asyncio.AbstractServer | None = None

    async def start(self) -> None:
        """Bind the listener and start the pipelined tick loop."""
        self._server = await asyncio.start_server(
            self._handle, self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        self.pipe.start()

    async def drain(self) -> None:
        """Graceful shutdown: refuse new connections, serve out every
        admitted request's stream, then stop the tick loop."""
        if self._server is not None:
            self._server.close()
        await self.pipe.drain()
        if self._server is not None:
            await self._server.wait_closed()

    # ------------------------------------------------------------------
    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        try:
            parsed = await _read_request(reader)
            if parsed is None:
                return
            method, path, _headers, body = parsed
            if method == "GET" and path == "/healthz":
                writer.write(_response("200 OK", {
                    "ok": self.pipe._loop_error is None,
                    "draining": self.pipe._draining}))
                await writer.drain()
            elif method == "GET" and path == "/v1/stats":
                writer.write(_response("200 OK", self.pipe.stats()))
                await writer.drain()
            elif method == "POST" and path == "/v1/generate":
                await self._generate(reader, writer, body)
            else:
                writer.write(_response("404 Not Found",
                                       {"error": f"no route {path}"}))
                await writer.drain()
        except (ConnectionResetError, BrokenPipeError, TimeoutError):
            pass
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionResetError, BrokenPipeError):
                pass

    async def _generate(self, reader: asyncio.StreamReader,
                        writer: asyncio.StreamWriter, body: bytes) -> None:
        submit_t = time.monotonic()   # arrival: queue wait starts here
        try:
            spec = json.loads(body.decode() or "{}")
            prompt = [int(t) for t in spec["prompt"]]
            max_new = int(spec.get("max_new_tokens", 16))
            sampling = _sampling_from(spec.get("sampling"))
        except (KeyError, ValueError, TypeError, json.JSONDecodeError) as e:
            writer.write(_response("400 Bad Request", {"error": str(e)}))
            await writer.drain()
            return
        try:
            stream = self.pipe.submit(prompt, max_new_tokens=max_new,
                                      sampling=sampling, submit_t=submit_t)
        except QueueFull:
            writer.write(_response(
                "429 Too Many Requests",
                {"error": "admission queue full"},
                {"Retry-After": str(self.retry_after_s)}))
            await writer.drain()
            return
        except Draining:
            writer.write(_response("503 Service Unavailable",
                                   {"error": "gateway draining"}))
            await writer.drain()
            return

        rid = stream.req.rid
        writer.write(("HTTP/1.1 200 OK\r\n"
                      "Content-Type: text/event-stream\r\n"
                      "Cache-Control: no-cache\r\n"
                      "Connection: close\r\n\r\n").encode())
        # EOF on the request socket = the client went away mid-stream
        watcher = asyncio.ensure_future(reader.read())
        try:
            while True:
                getter = asyncio.ensure_future(stream.next_event())
                await asyncio.wait({getter, watcher},
                                   return_when=asyncio.FIRST_COMPLETED)
                if not getter.done():     # disconnect won the race
                    getter.cancel()
                    self.pipe.cancel(rid)
                    return
                kind, payload = getter.result()
                if kind == "tokens":
                    writer.write(_sse_frame({"tokens": payload}))
                else:                     # done / cancelled: final frame
                    writer.write(_sse_frame(payload, event=kind))
                    await writer.drain()
                    return
                await writer.drain()
        except (ConnectionResetError, BrokenPipeError):
            self.pipe.cancel(rid)
        finally:
            watcher.cancel()
