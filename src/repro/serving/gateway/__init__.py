"""Async serving gateway: pipelined tick loop + HTTP/SSE front-end.

The gateway is the subsystem that turns the single-process
:class:`~repro.serving.engine.ServingEngine` into something a load
generator (or a browser) can actually talk to:

* :mod:`.pipeline` — :class:`PipelinedEngine`, the asyncio tick driver.
  It splits each engine tick into the engine's ``schedule`` /
  ``dispatch`` / ``emit`` phases and defers the host-device sync
  (``jax.block_until_ready``) to token emission, so network I/O and the
  next tick's admission work overlap the device compute of the current
  tick (double-buffered ticks).  Greedy outputs are bit-identical to the
  synchronous ``Engine.run()`` loop.
* :mod:`.server` — :class:`GatewayServer`, a dependency-free asyncio
  HTTP/1.1 server with per-token SSE streaming, a bounded admission
  queue with backpressure (429 + ``Retry-After`` when full), per-request
  cancellation on client disconnect (pages and prefix refcounts release
  cleanly), and graceful drain on shutdown.
* :mod:`.client` — minimal asyncio HTTP/SSE client helpers shared by
  the load generator (``benchmarks/loadgen.py``) and the tests.

The gateway's concurrency knobs — pipeline depth x admission batch —
are a ``GatewayPolicy`` dynamic-select AT region
(:meth:`repro.tuning.dynamic.DecodeAutoTuner.add_gateway`) committing on
goodput, persisted and warm-loaded like the decode/prefill/spec/prefix
winners.  See ``docs/SERVING.md`` (gateway section).
"""
from .client import get_json, post_json, sse_generate
from .pipeline import GatewayPolicyKnobs, PipelinedEngine, TokenStream
from .server import GatewayServer

__all__ = ["PipelinedEngine", "TokenStream", "GatewayPolicyKnobs",
           "GatewayServer", "sse_generate", "post_json", "get_json"]
