"""Minimal asyncio HTTP/SSE client for the gateway.

Shared by ``benchmarks/loadgen.py`` and the gateway tests: just enough
HTTP/1.1 over ``asyncio.open_connection`` to POST JSON, read a JSON
response, and iterate an SSE stream — no third-party HTTP stack.

:func:`sse_generate` is the load generator's workhorse: it POSTs one
generate request, then yields each SSE event as ``(kind, payload)``
pairs, stamping client-side receive times so TTFT/ITL can be measured
*end to end* (network + queueing + compute), not just inside the engine.
Non-200 responses surface as a single ``("http_error", {...})`` event
(the 429 backpressure path included) rather than an exception, so the
closed loop can count rejections and retry.
"""
from __future__ import annotations

import asyncio
import json

__all__ = ["sse_generate", "post_json", "get_json"]


async def _request(host: str, port: int, method: str, path: str,
                   body: bytes = b""):
    reader, writer = await asyncio.open_connection(host, port)
    head = (f"{method} {path} HTTP/1.1\r\n"
            f"Host: {host}:{port}\r\n"
            f"Content-Type: application/json\r\n"
            f"Content-Length: {len(body)}\r\n"
            f"Connection: close\r\n\r\n").encode()
    writer.write(head + body)
    await writer.drain()
    status_line = await reader.readline()
    status = int(status_line.split()[1])
    headers: dict[str, str] = {}
    while True:
        raw = await reader.readline()
        if raw in (b"\r\n", b"\n", b""):
            break
        if b":" in raw:
            k, v = raw.decode("latin-1").split(":", 1)
            headers[k.strip().lower()] = v.strip()
    return reader, writer, status, headers


async def _read_body(reader: asyncio.StreamReader,
                     headers: dict[str, str]) -> bytes:
    n = int(headers.get("content-length", "0") or "0")
    return await reader.readexactly(n) if n else await reader.read()


async def post_json(host: str, port: int, path: str,
                    payload: dict) -> tuple[int, dict]:
    """POST JSON, return ``(status, parsed-body)``."""
    body = json.dumps(payload).encode()
    reader, writer, status, headers = await _request(
        host, port, "POST", path, body)
    try:
        raw = await _read_body(reader, headers)
        return status, (json.loads(raw.decode()) if raw else {})
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except (ConnectionResetError, BrokenPipeError):
            pass


async def get_json(host: str, port: int, path: str) -> tuple[int, dict]:
    """GET a JSON route, return ``(status, parsed-body)``."""
    reader, writer, status, headers = await _request(
        host, port, "GET", path)
    try:
        raw = await _read_body(reader, headers)
        return status, (json.loads(raw.decode()) if raw else {})
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except (ConnectionResetError, BrokenPipeError):
            pass


async def sse_generate(host: str, port: int, prompt: list[int],
                       max_new_tokens: int = 16,
                       sampling: dict | None = None,
                       disconnect_after: int | None = None):
    """POST ``/v1/generate`` and yield SSE events as ``(kind, payload)``.

    ``kind`` is ``"tokens"`` (payload: token-id list), ``"done"`` /
    ``"cancelled"`` (payload: the final info dict), or ``"http_error"``
    (payload: ``{"status": ..., "body": ..., "retry_after": ...}``) when
    the gateway answers with a non-200 — a 429 bounce shows up here.

    ``disconnect_after`` closes the socket after that many *token
    events* without reading the rest of the stream — the client-abandons-
    mid-stream path the leak test drives.
    """
    payload: dict = {"prompt": prompt, "max_new_tokens": max_new_tokens}
    if sampling:
        payload["sampling"] = sampling
    body = json.dumps(payload).encode()
    reader, writer, status, headers = await _request(
        host, port, "POST", "/v1/generate", body)
    try:
        if status != 200:
            raw = await _read_body(reader, headers)
            try:
                parsed = json.loads(raw.decode()) if raw else {}
            except json.JSONDecodeError:
                parsed = {"raw": raw.decode("latin-1", "replace")}
            yield "http_error", {"status": status, "body": parsed,
                                 "retry_after": headers.get("retry-after")}
            return
        token_events = 0
        event_name = None
        data_lines: list[str] = []
        while True:
            raw = await reader.readline()
            if not raw:
                return
            line = raw.decode().rstrip("\n").rstrip("\r")
            if line.startswith("event:"):
                event_name = line[len("event:"):].strip()
            elif line.startswith("data:"):
                data_lines.append(line[len("data:"):].strip())
            elif line == "" and data_lines:      # frame boundary
                data = json.loads("\n".join(data_lines))
                kind = event_name or "tokens"
                event_name, data_lines = None, []
                if kind == "tokens":
                    token_events += 1
                    yield kind, data["tokens"]
                    if disconnect_after is not None \
                            and token_events >= disconnect_after:
                        return          # finally closes the socket early
                else:
                    yield kind, data
                    if kind in ("done", "cancelled"):
                        return
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except (ConnectionResetError, BrokenPipeError):
            pass
