"""Serving metrics: TTFT, inter-token latency, throughput, percentiles.

Collects per-request timing (submit / first token / per-token / finish)
from finished :class:`~repro.serving.scheduler.Request` objects and
aggregates the serving-latency quartet every inference stack reports:

* **queue wait** — submit to first lane occupancy (pure queueing delay;
  ``Request.admit_t`` is stamped by the engine at first admission, and
  the gateway stamps ``submit_t`` at HTTP arrival so network-side
  queueing is visible too);
* **TTFT** — time to first token (queueing + prefill);
* **ITL** — inter-token latency during decode;
* **tokens/s** and **requests/s** over the serving window;
* **prefix cache** — cache-hit tokens and the per-request hit rate
  (``Request.cached_tokens`` is stamped at admission when the engine's
  prefix cache seeds the lane from the hash index).

All timestamps come from ``time.monotonic()`` (stamped by the engine and
``Request``'s default): the quantities here are *durations*, and a
wall-clock adjustment mid-run (NTP slew, DST) must not yield negative
TTFT/ITL samples or a corrupted serving window.

p50/p99 use :func:`percentile` — ``numpy.percentile`` with
``method='linear'`` passed explicitly and the results pinned by a unit
test, so the gate's numbers cannot silently track a change in numpy's
default method.  Interpolation matters on tiny samples: serving smoke
runs aggregate a handful of requests, and under a nearest-rank
definition p99 of a 5-element series is just the max while p50 snaps to
whichever sample sits at the cut — percentiles would jump a full
sample-gap per added request, which is exactly what the bench
regression gate diffs.  With CPU-proxy step counts the absolute numbers
are placeholders, but the aggregation pipeline is the one the TPU path
will feed.
"""
from __future__ import annotations

import numpy as np

from .scheduler import Request


def percentile(xs, q: float) -> float | None:
    """The ``q``-th percentile with linear interpolation between the two
    nearest order statistics (``method='linear'`` passed explicitly, so
    the serving gate's numbers do not track numpy's default method).
    None on empty input; q outside [0, 100] raises.
    """
    if not 0.0 <= q <= 100.0:
        raise ValueError(f"percentile q must be in [0, 100], got {q}")
    arr = [float(x) for x in xs]
    if not arr:
        return None
    return float(np.percentile(arr, q, method="linear"))


def _pcts(xs: list[float]) -> dict:
    if not xs:
        return {"p50": None, "p99": None, "mean": None}
    return {"p50": percentile(xs, 50),
            "p99": percentile(xs, 99),
            "mean": float(np.asarray(xs, np.float64).mean())}


class ServingMetrics:
    """Aggregates finished requests into a serving report."""

    def __init__(self) -> None:
        self.requests: list[Request] = []
        self._t0: float | None = None
        self._t1: float | None = None

    def observe(self, req: Request) -> None:
        self.requests.append(req)
        if req.submit_t is not None:
            self._t0 = req.submit_t if self._t0 is None \
                else min(self._t0, req.submit_t)
        if req.finish_t is not None:
            self._t1 = req.finish_t if self._t1 is None \
                else max(self._t1, req.finish_t)

    # ------------------------------------------------------------------
    def ttfts(self) -> list[float]:
        return [r.first_token_t - r.submit_t for r in self.requests
                if r.first_token_t is not None]

    def queue_waits(self) -> list[float]:
        """Submit-to-first-lane-occupancy per request.  ``submit_t`` is
        stamped where the request *arrives* (the gateway's HTTP handler,
        or ``Request`` construction in direct-engine use) and ``admit_t``
        where the engine first gives it a lane — the gap is pure queueing
        delay, the thing TTFT alone hides under load."""
        return [r.admit_t - r.submit_t for r in self.requests
                if r.admit_t is not None]

    def inter_token_latencies(self) -> list[float]:
        out: list[float] = []
        for r in self.requests:
            out.extend(float(b - a)
                       for a, b in zip(r.token_ts, r.token_ts[1:]))
        return out

    def prefix_cache(self) -> dict:
        """Cache-hit tokens + prefix-hit rate over finished requests
        (zeros when the engine runs without a prefix cache)."""
        cached = [r.cached_tokens for r in self.requests]
        hit_requests = sum(1 for c in cached if c > 0)
        return {
            "hit_tokens": int(sum(cached)),
            "hit_requests": hit_requests,
            "hit_rate": (hit_requests / len(self.requests)
                         if self.requests else 0.0),
        }

    def summary(self) -> dict:
        n_tokens = sum(len(r.out_tokens) for r in self.requests)
        wall = (self._t1 - self._t0) if (self._t0 is not None
                                         and self._t1 is not None) else 0.0
        preempts = sum(r.preemptions for r in self.requests)
        return {
            "requests": len(self.requests),
            "generated_tokens": n_tokens,
            "wall_s": wall,
            "tokens_per_s": n_tokens / wall if wall > 0 else 0.0,
            "requests_per_s": len(self.requests) / wall if wall > 0 else 0.0,
            "queue_wait_s": _pcts(self.queue_waits()),
            "ttft_s": _pcts(self.ttfts()),
            "itl_s": _pcts(self.inter_token_latencies()),
            "preemptions": preempts,
            "prefix_cache": self.prefix_cache(),
        }
