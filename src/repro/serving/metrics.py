"""Serving metrics: TTFT, inter-token latency, throughput, percentiles.

Collects per-request timing (submit / first token / per-token / finish)
from finished :class:`~repro.serving.scheduler.Request` objects and
aggregates the serving-latency quartet every inference stack reports:

* **TTFT** — time to first token (queueing + prefill);
* **ITL** — inter-token latency during decode;
* **tokens/s** and **requests/s** over the serving window.

p50/p99 come from ``numpy.percentile``; with CPU-proxy step counts the
absolute numbers are placeholders, but the aggregation pipeline is the
one the TPU path will feed.
"""
from __future__ import annotations

import numpy as np

from .scheduler import Request


def _pcts(xs: list[float]) -> dict:
    if not xs:
        return {"p50": None, "p99": None, "mean": None}
    arr = np.asarray(xs, np.float64)
    return {"p50": float(np.percentile(arr, 50)),
            "p99": float(np.percentile(arr, 99)),
            "mean": float(arr.mean())}


class ServingMetrics:
    """Aggregates finished requests into a serving report."""

    def __init__(self) -> None:
        self.requests: list[Request] = []
        self._t0: float | None = None
        self._t1: float | None = None

    def observe(self, req: Request) -> None:
        self.requests.append(req)
        if req.submit_t is not None:
            self._t0 = req.submit_t if self._t0 is None \
                else min(self._t0, req.submit_t)
        if req.finish_t is not None:
            self._t1 = req.finish_t if self._t1 is None \
                else max(self._t1, req.finish_t)

    # ------------------------------------------------------------------
    def ttfts(self) -> list[float]:
        return [r.first_token_t - r.submit_t for r in self.requests
                if r.first_token_t is not None]

    def inter_token_latencies(self) -> list[float]:
        out: list[float] = []
        for r in self.requests:
            out.extend(float(b - a)
                       for a, b in zip(r.token_ts, r.token_ts[1:]))
        return out

    def summary(self) -> dict:
        n_tokens = sum(len(r.out_tokens) for r in self.requests)
        wall = (self._t1 - self._t0) if (self._t0 is not None
                                         and self._t1 is not None) else 0.0
        preempts = sum(r.preemptions for r in self.requests)
        return {
            "requests": len(self.requests),
            "generated_tokens": n_tokens,
            "wall_s": wall,
            "tokens_per_s": n_tokens / wall if wall > 0 else 0.0,
            "requests_per_s": len(self.requests) / wall if wall > 0 else 0.0,
            "ttft_s": _pcts(self.ttfts()),
            "itl_s": _pcts(self.inter_token_latencies()),
            "preemptions": preempts,
        }
