"""Per-request sampling for the serving engine: temperature / top-k /
top-p, explicit seeds, and the speculative accept/reject rule.

Every request carries a :class:`SamplingParams`; the engine never calls
``argmax`` directly.  Three properties the tests pin down:

* **greedy is exact** — ``temperature == 0`` routes through a literal
  ``argmax``, so the sampled serving stack stays bit-identical to the
  pre-sampling engine (and speculative greedy to plain greedy);
* **filtering renormalizes** — after temperature scaling, top-k and
  top-p masking, the distribution sums to 1 and never assigns mass
  outside the kept support;
* **seeding is positional, not positional-in-the-batch** — randomness is
  keyed by ``(request seed, emitted-token index, stream)``, so a fixed
  seed reproduces the same tokens no matter which lane the request lands
  on or what else is batched alongside it.

Speculative decoding uses the standard accept/reject rule (Leviathan et
al. 2023; Chen et al. 2023): draft token ``d_i`` is accepted with
probability ``min(1, p_i(d_i) / q_i(d_i))``; on rejection the correction
token is drawn from ``norm(max(p_i - q_i, 0))``; if every draft is
accepted a bonus token is drawn from the target's next distribution.
Emitted output is distributed exactly as sampling the target alone, and
in the greedy limit it degenerates to "accept while the draft equals the
target argmax" — bit-identical to non-speculative greedy decode.

Sampling runs host-side in float64 numpy: the logits are already on the
host between scheduler ticks, vocabularies are O(10^4-10^5), and the
accept/reject chain is inherently sequential per lane.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = ["SamplingParams", "filtered_probs", "sample_token",
           "sample_batch", "draft_rng", "propose_token",
           "speculative_accept"]

# independent deterministic streams per (seed, counter)
_STREAM_SAMPLE = 0     # plain (non-speculative) token draws
_STREAM_DRAFT = 1      # draft-model proposal draws
_STREAM_ACCEPT = 2     # accept tests + residual/bonus draws


@dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling knobs.  ``temperature == 0`` is greedy;
    ``top_k == 0`` and ``top_p == 1.0`` disable their filters."""

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0

    def __post_init__(self):
        if self.temperature < 0.0:
            raise ValueError("temperature must be >= 0")
        if self.top_k < 0:
            raise ValueError("top_k must be >= 0 (0 disables)")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError("top_p must be in (0, 1]")

    @property
    def greedy(self) -> bool:
        return self.temperature == 0.0


def _rng(seed: int, counter: int, stream: int) -> np.random.Generator:
    """Deterministic generator keyed by (request seed, emitted-token
    index, stream) — independent of lane placement and batch layout."""
    return np.random.default_rng((seed % (2 ** 32), counter, stream))


def filtered_probs(logits, sp: SamplingParams) -> np.ndarray:
    """The renormalized sampling distribution for one position.

    Temperature-scaled softmax, then top-k keeps the k highest-probability
    tokens and top-p keeps the smallest prefix (by descending
    probability) whose cumulative mass reaches ``top_p``; the survivors
    renormalize to sum exactly 1.  Greedy returns the argmax one-hot (the
    temperature -> 0 limit).
    """
    logits = np.asarray(logits, np.float64).reshape(-1)
    if sp.greedy:
        p = np.zeros_like(logits)
        p[int(np.argmax(logits))] = 1.0
        return p
    z = logits / sp.temperature
    z = z - z.max()
    p = np.exp(z)
    p /= p.sum()
    if 0 < sp.top_k < p.size:
        keep = np.argsort(-p, kind="stable")[:sp.top_k]
        mask = np.zeros(p.size, bool)
        mask[keep] = True
        p = np.where(mask, p, 0.0)
        p /= p.sum()            # top-p then filters the renormalized mass
    if sp.top_p < 1.0:
        order = np.argsort(-p, kind="stable")
        cut = int(np.searchsorted(np.cumsum(p[order]), sp.top_p)) + 1
        mask = np.zeros(p.size, bool)
        mask[order[:cut]] = True
        p = np.where(mask, p, 0.0)
    return p / p.sum()


def _draw(p: np.ndarray, rng: np.random.Generator) -> int:
    # inverse-CDF draw: tolerant of float64 renormalization residue,
    # never emits a zero-probability token
    u = rng.random() * p.sum()
    return int(np.searchsorted(np.cumsum(p), u, side="right").clip(
        0, p.size - 1))


def sample_token(logits, sp: SamplingParams, counter: int) -> int:
    """One token for the request's ``counter``-th emission (``counter`` =
    ``len(out_tokens)`` — an index into the request's own output stream,
    which is what makes a fixed seed layout-independent)."""
    if sp.greedy:
        return int(np.argmax(np.asarray(logits)))
    p = filtered_probs(logits, sp)
    return _draw(p, _rng(sp.seed, counter, _STREAM_SAMPLE))


def sample_batch(logits, params, counters) -> list[int]:
    """Sample one token per lane.  ``logits`` (B, V); ``params`` and
    ``counters`` are per-lane sequences.  Equivalent to per-lane
    :func:`sample_token` — batching is a layout, not a semantic."""
    logits = np.asarray(logits)
    return [sample_token(logits[i], sp, int(c))
            for i, (sp, c) in enumerate(zip(params, counters))]


# --------------------------------------------------------------------------
# speculative decoding
# --------------------------------------------------------------------------


def draft_rng(sp: SamplingParams, counter: int) -> np.random.Generator:
    """The proposal stream for one speculative tick (first emission index
    ``counter``); draw :func:`propose_token` from it k times."""
    return _rng(sp.seed, counter, _STREAM_DRAFT)


def propose_token(logits, sp: SamplingParams,
                  rng: np.random.Generator) -> tuple[int, np.ndarray]:
    """Draft proposal: returns ``(token, q)`` where ``q`` is the filtered
    draft distribution the accept rule divides by."""
    q = filtered_probs(logits, sp)
    if sp.greedy:
        return int(np.argmax(q)), q
    return _draw(q, rng), q


def speculative_accept(drafts, draft_probs, target_logits,
                       sp: SamplingParams, counter: int
                       ) -> tuple[list[int], int]:
    """The accept/reject rule over one verified chunk.

    ``drafts`` — k proposed tokens; ``draft_probs`` — their filtered draft
    distributions ``q_i``; ``target_logits`` — (k+1, V) target logits
    where row ``i`` scores the position of ``drafts[i]`` and row ``k`` is
    the all-accepted bonus position.  Returns ``(emitted, n_accepted)``
    with ``len(emitted) == n_accepted + 1``: the accepted prefix plus one
    correction (on rejection) or bonus (all accepted) token.
    """
    target_logits = np.asarray(target_logits)
    k = len(drafts)
    if sp.greedy:
        a = 0
        while a < k and drafts[a] == int(np.argmax(target_logits[a])):
            a += 1
        return list(drafts[:a]) + [int(np.argmax(target_logits[a]))], a
    rng = _rng(sp.seed, counter, _STREAM_ACCEPT)
    emitted: list[int] = []
    for i in range(k):
        p = filtered_probs(target_logits[i], sp)
        q = np.asarray(draft_probs[i], np.float64)
        d = int(drafts[i])
        if rng.random() < min(1.0, p[d] / max(q[d], 1e-300)):
            emitted.append(d)
            continue
        resid = np.maximum(p - q, 0.0)
        total = resid.sum()
        resid = resid / total if total > 0.0 else p
        return emitted + [_draw(resid, rng)], i
    p = filtered_probs(target_logits[k], sp)
    return emitted + [_draw(p, rng)], k
