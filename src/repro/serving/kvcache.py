"""KV-cache backends for the serving engine: dense lanes and paged blocks.

Two implementations of one interface:

* :class:`DenseKVCache` — the classic layout: every decode lane owns a
  contiguous ``max_len`` strip in a stacked ``(L, n_lanes, ...)`` cache.
  Memory is O(n_lanes * max_len) regardless of how many tokens are live.
* :class:`PagedKVCache` — vLLM-style paging: a shared physical pool of
  ``n_pages`` pages of ``page_size`` tokens each (per layer), with a
  per-lane page table mapping logical KV blocks to physical pages.
  Memory scales with *live tokens* (rounded up to page granularity), lane
  admission is page allocation instead of a pad/crop splice, and a
  preempted sequence's pages can be swapped out to host memory and later
  swapped back in without re-running prefill.

Page 0 of the pool is reserved as a *null page*: idle lanes decode with
``pos = 0`` and a zeroed page-table row, so their (discarded) KV writes
land there and can never corrupt a live sequence.

The paged pool can store K/V as **int8 pages** (``kv_dtype="int8"``):
pool tensors are int8 with fp32 per-(page, head, slot)-row scales in
``caches["kv_scale"]``, writes quantize on the way in (admit scatter,
chunk scatter, decode append) and the attention kernels dequantize
in-VMEM into the fp32 softmax accumulator.  Scale arrays keep the page
axis at position 1, so page-indexed treemaps (COW copies, swap
gather/scatter) cover them with no special cases.

With ``prefix_cache=True`` the paged pool is additionally
**content-addressed and refcounted**: every committed full page carries a
rolling hash key (its token ids chained with the parent page's key), a
global prefix index maps key -> physical page, and a new prompt whose
leading pages hash to indexed entries is *seeded* with those pages
instead of re-running prefill over them.  Seeded pages are shared —
``refcount[p]`` counts the lanes referencing page ``p`` — and shared
pages are copy-on-write: a lane that must append KV into a shared page
first allocates a private copy.  Releasing a reference never frees an
indexed page outright; a refcount-zero indexed page stays resident as
*cached* capacity and is only reclaimed by the allocator under pool
pressure (LRU by last-hit tick, or FIFO by publish order — the
``PrefixPolicy`` tuning knobs).  Cached KV is bit-exact (the same
tokens at the same positions through the same kernels), so outputs with
caching on are bit-identical to caching off.

The engine talks to both backends through the same methods::

    admit(lane, prefill_caches, prompt_len) -> bool
    ensure_capacity(lane, pos) -> bool        # page alloc on boundary
    ensure_tokens(lane, n_tokens) -> bool     # chunk-granular growth
    swap_out(lane) -> handle                  # preemption
    swap_in(lane, handle) -> bool
    release(lane)
    decode_extra(mask_lanes) -> tuple         # (page_table,) when paged;
                                              # mid-prefill lanes masked
"""
from __future__ import annotations

import hashlib
import math
from dataclasses import dataclass
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..distributed.compression import (dequantize_int8, quantize_int8,
                                       quantize_int8_rows)

NULL_PAGE = 0

PREFIX_EVICTION_POLICIES = ("lru", "fifo")

KV_DTYPES = ("fp", "int8")


def _mesh_model_axis(mesh) -> int:
    """Size of the mesh's tensor-parallel ``model`` axis (1 == no mesh /
    no model axis / 1-device axis — all take the unsharded path)."""
    if mesh is None or "model" not in mesh.axis_names:
        return 1
    return int(mesh.shape["model"])


def _pool_shardings(caches: dict, mesh):
    """NamedShardings placing the KV-head axis of every stacked pool leaf
    over the mesh's ``model`` axis: pools are (L, P, Hkv, psz, D), scales
    (L, P, Hkv, psz) — head axis 2 in both; everything else replicated."""
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    def spec(leaf):
        if leaf.ndim == 5:          # (L, P, Hkv, psz, D) pool
            return NamedSharding(mesh, P(None, None, "model", None, None))
        if leaf.ndim == 4:          # (L, P, Hkv, psz) scale
            return NamedSharding(mesh, P(None, None, "model", None))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map(spec, caches)


@dataclass
class PackedTree:
    """int8-quantized host copy of a cache pytree (one scale per leaf).

    The lossy host-swap representation for *fp* pools
    (``swap_compress=True``): each leaf is stored as an int8 array plus
    one fp32 scale, quartering bf16 host bytes vs a raw fp32 copy and
    halving them vs bf16.  int8 pools never need this — their page
    payload is already int8 + per-row scales and round-trips bit-exactly.
    """

    payload: list[tuple[np.ndarray, float]]
    treedef: Any

    def host_bytes(self) -> int:
        return sum(q.nbytes + 4 for q, _ in self.payload)


def _pack_tree(tree) -> PackedTree:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    payload = []
    for leaf in leaves:
        q, s = quantize_int8(jnp.asarray(leaf))
        payload.append((np.asarray(q), float(s)))
    return PackedTree(payload, treedef)


def _unpack_tree(packed: PackedTree):
    leaves = [dequantize_int8(jnp.asarray(q), s)
              for q, s in packed.payload]
    return jax.tree_util.tree_unflatten(packed.treedef, leaves)


def _tree_bytes(tree) -> int:
    return int(sum(np.asarray(leaf).nbytes
                   for leaf in jax.tree_util.tree_leaves(tree)))


def chain_hash(parent: str, tokens: Sequence[int]) -> str:
    """Rolling page key: the page's token ids chained with the parent
    page's key, so a hit on page *b* implies the whole prefix through
    *b* matches (content addressing over prefixes, not bags of pages)."""
    data = parent + "|" + ",".join(str(int(t)) for t in tokens)
    return hashlib.sha1(data.encode()).hexdigest()


def _lane_set(full: jax.Array, one: jax.Array, lane: int) -> jax.Array:
    """Write batch entry 0 of ``one`` into lane ``lane`` of the stacked
    cache.

    Leaves are (L, B, ...) (layer-stacked) or (napp, B, ...); the batch
    axis is axis 1.  Only the target lane is ever written — even when the
    source happens to be full-width — so concurrent lanes' state is never
    clobbered.
    """
    src = one[:, 0]
    # pad/crop trailing dims (prefill cache len == prompt len)
    dst_shape = full.shape[2:]
    pads = []
    slices = [slice(None)] * src.ndim
    for i, (s, d) in enumerate(zip(src.shape[1:], dst_shape)):
        if s < d:
            pads.append((0, d - s))
        else:
            pads.append((0, 0))
            slices[i + 1] = slice(0, d)
    src = src[tuple(slices)]
    if any(p != (0, 0) for p in pads):
        src = jnp.pad(src, [(0, 0)] + pads)
    return full.at[:, lane].set(src.astype(full.dtype))


class DenseKVCache:
    """Per-lane contiguous KV strips (the pre-paging layout)."""

    kind = "dense"
    prefix_cache = False
    kv_dtype = "fp"

    def __init__(self, model, n_lanes: int, max_len: int,
                 swap_compress: bool = False):
        self.n_lanes = n_lanes
        self.max_len = max_len
        self.swap_compress = swap_compress
        self.caches = model.init_caches(n_lanes, max_len)

    # -- engine interface ---------------------------------------------------
    def prefill_len(self, prompt_len: int) -> int:
        return self.max_len

    def admit(self, lane: int, prefill_caches: Any, prompt_len: int) -> bool:
        self.caches = jax.tree.map(
            lambda full, one: _lane_set(full, one, lane),
            self.caches, prefill_caches)
        return True

    def ensure_capacity(self, lane: int, pos: int) -> bool:
        return pos < self.max_len

    def ensure_tokens(self, lane: int, n_tokens: int) -> bool:
        """Capacity for the first ``n_tokens`` positions (no-op when dense:
        the lane's strip is pre-sized)."""
        return n_tokens <= self.max_len

    def truncate_to(self, lane: int, committed_len: int) -> int:
        """Speculative rollback (no-op when dense: the lane's strip is
        pre-sized and stale KV past ``committed_len`` is masked by the
        decode step's ``kv_len``).  Returns pages freed (always 0)."""
        return 0

    def release(self, lane: int) -> None:
        pass

    def swap_out(self, lane: int) -> Any:
        handle = jax.tree.map(lambda a: np.asarray(a[:, lane]), self.caches)
        if self.swap_compress:
            return _pack_tree(handle)
        return handle

    def swap_in(self, lane: int, handle: Any) -> bool:
        if isinstance(handle, PackedTree):
            handle = _unpack_tree(handle)
        self.caches = jax.tree.map(
            lambda full, one: full.at[:, lane].set(
                jnp.asarray(one).astype(full.dtype)),
            self.caches, handle)
        return True

    def decode_extra(self, mask_lanes=()) -> tuple:
        return ()

    # -- accounting ---------------------------------------------------------
    def cache_tokens(self) -> int:
        """Token capacity held in device memory (fixed for dense)."""
        return self.n_lanes * self.max_len

    def pool_bytes(self) -> int:
        """Device bytes held by the cache, from the actual array dtypes."""
        return int(sum(leaf.size * leaf.dtype.itemsize
                       for leaf in jax.tree_util.tree_leaves(self.caches)))

    def kv_bytes_per_token(self) -> float:
        return self.pool_bytes() / float(self.n_lanes * self.max_len)

    def capacity_tokens(self) -> int:
        return self.n_lanes * self.max_len

    def stats(self) -> dict:
        return {"kind": self.kind, "kv_dtype": self.kv_dtype,
                "cache_tokens": self.cache_tokens(),
                "pool_bytes": self.pool_bytes(),
                "kv_bytes_per_token": self.kv_bytes_per_token(),
                "capacity_tokens": self.capacity_tokens()}


@dataclass
class PageHandle:
    """Host-side copy of a swapped-out sequence's pages.

    ``chunks`` — pytree of np arrays (page axis at position 1) for raw
    swaps; ``packed`` — the int8 :class:`PackedTree` form when the cache
    compresses fp-pool swaps (exactly one of the two is set).
    """

    chunks: Any          # pytree of np arrays, page axis at position 1
    n_blocks: int
    packed: PackedTree | None = None

    def host_bytes(self) -> int:
        if self.packed is not None:
            return self.packed.host_bytes()
        return _tree_bytes(self.chunks)


class PagedKVCache:
    """Block/paged KV cache with a refcounted free-page pool, host swap
    space, and an optional content-addressed prefix index.

    ``n_pages`` pages of ``page_size`` tokens (per layer) back every lane;
    a lane's logical block *b* lives in physical page ``table[lane, b]``.
    Without prefix caching pages are lane-exclusive while allocated; with
    ``prefix_cache=True`` full committed pages publish into the hash
    index and may be referenced by several lanes at once (``refcount``),
    in which case writes go through :meth:`cow_writable` first so the
    decode/prefill scatter still never races between lanes.
    """

    kind = "paged"

    def __init__(self, model, n_lanes: int, max_len: int, n_pages: int,
                 page_size: int = 16, prefix_cache: bool = False,
                 prefix_min_match: int = 1, prefix_eviction: str = "lru",
                 kv_dtype: str = "fp", swap_compress: bool = False,
                 mesh=None):
        if not model.supports_paged_cache:
            raise ValueError(
                f"arch {model.cfg.name!r} does not support the paged KV "
                "cache; use cache='dense'")
        if n_pages < 2:
            raise ValueError("need at least 2 pages (page 0 is reserved)")
        if kv_dtype not in KV_DTYPES:
            raise ValueError(f"unknown kv_dtype {kv_dtype!r} "
                             f"(choose from {KV_DTYPES})")
        self.mesh = mesh
        model_axis = _mesh_model_axis(mesh)
        if model_axis > 1:
            hkv, h = model.cfg.n_kv_heads, model.cfg.n_heads
            if hkv % model_axis or h % model_axis:
                raise ValueError(
                    f"tensor-parallel paged serving shards the KV-head "
                    f"axis: arch {model.cfg.name!r} has kv_heads={hkv} "
                    f"(q heads {h}), not divisible by the mesh's 'model' "
                    f"axis of size {model_axis} — choose a mesh whose "
                    f"model axis divides the head counts, or drop --mesh")
        self.n_lanes = n_lanes
        self.max_len = max_len
        self.page_size = page_size
        self.n_pages = n_pages
        self.kv_dtype = kv_dtype
        self.quantized = kv_dtype == "int8"
        # int8 pools swap their (already-compact) int8 payload losslessly;
        # the opt-in flag additionally compresses *fp*-pool swaps (lossy:
        # the bit-identical swap-continuation guarantee becomes int8-
        # round-trip-identical)
        self.swap_compress = swap_compress and not self.quantized
        self.max_blocks = math.ceil(max_len / page_size)
        self.caches = model.init_paged_caches(n_pages, page_size,
                                              quantized=self.quantized)
        if model_axis > 1:
            # place each device's KV-head slice of every pool on its own
            # device up front: the per-layer stacked pools are
            # (L, P, Hkv, psz, D) / scales (L, P, Hkv, psz), head axis 2
            self.caches = jax.device_put(
                self.caches, _pool_shardings(self.caches, mesh))
        self.table = np.zeros((n_lanes, self.max_blocks), np.int32)
        self.n_blocks = [0] * n_lanes
        # page 0 is the null page (idle-lane write sink), never allocated
        self._free = list(range(n_pages - 1, 0, -1))
        self.refcount = np.zeros(n_pages, np.int32)   # lane refs per page
        self.swap_outs = 0
        self.swap_ins = 0
        # -- prefix cache (content-addressed index over full pages) --------
        self.prefix_cache = prefix_cache
        self.prefix_min_match = max(1, int(prefix_min_match))
        self.set_prefix_policy(eviction=prefix_eviction)
        self._index: dict[str, int] = {}      # chain hash -> physical page
        self._page_key: dict[int, str] = {}   # physical page -> chain hash
        self._last_hit: dict[int, int] = {}   # page -> last match/publish
        self._pub_order: dict[int, int] = {}  # page -> publish tick (FIFO)
        self._chain: list[tuple[str, int]] = [("", 0)] * n_lanes
        self._tick = 0
        self._n_cached = 0
        self.prefix_lookups = 0
        self.prefix_hits = 0
        self.hit_tokens = 0                   # prompt tokens never re-run
        self.pages_saved = 0                  # seeded (not re-prefilled)
        self.cow_copies = 0
        self.index_evictions = 0

    # -- page pool ----------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def cached_pages(self) -> int:
        """Refcount-zero pages kept resident by the prefix index — the
        reclaimable middle state between used and free.  A maintained
        counter (updated at the refcount 0<->1 and publish/unpublish
        transitions), not a scan: ``_alloc``/``can_admit`` read it on
        the admission and page-boundary hot paths."""
        return self._n_cached

    @property
    def used_pages(self) -> int:
        """Pages referenced by at least one lane (shared pages count
        once)."""
        return (self.n_pages - 1) - len(self._free) - self.cached_pages

    def _alloc(self, n: int) -> list[int] | None:
        """Take ``n`` private pages (refcount 1 each): free pages first,
        then refcount-zero index entries evicted by the reuse policy."""
        if n > len(self._free) + self.cached_pages:
            return None
        pages = []
        for _ in range(n):
            p = self._free.pop() if self._free else self._evict_one()
            self.refcount[p] = 1
            pages.append(p)
        return pages

    def _evict_one(self) -> int:
        """Reclaim one refcount-zero index entry.  Policy: ``lru`` evicts
        the page whose index entry was hit longest ago; ``fifo`` evicts
        the oldest-published page regardless of hits."""
        order = self._last_hit if self.prefix_eviction == "lru" \
            else self._pub_order
        victim = min(
            (p for p in self._page_key if self.refcount[p] == 0),
            key=lambda p: order.get(p, 0))
        self._unpublish(victim)
        self.index_evictions += 1
        return victim

    def _unpublish(self, p: int) -> None:
        key = self._page_key.pop(p, None)
        if key is not None:
            if self._index.get(key) == p:
                del self._index[key]
            if self.refcount[p] == 0:
                self._n_cached -= 1
        self._last_hit.pop(p, None)
        self._pub_order.pop(p, None)

    def _unref(self, p: int) -> None:
        """Drop one lane reference.  A page only returns to the free list
        at refcount zero AND outside the index — indexed pages stay
        resident as cached capacity until the allocator evicts them."""
        p = int(p)
        if p == NULL_PAGE:
            return
        self.refcount[p] -= 1
        if self.refcount[p] <= 0:
            if p in self._page_key:
                self._n_cached += 1
            else:
                self._free.append(p)

    def _free_lane(self, lane: int) -> None:
        nblk = self.n_blocks[lane]
        for p in self.table[lane, :nblk]:
            self._unref(p)
        self.table[lane, :] = NULL_PAGE
        self.n_blocks[lane] = 0
        self._chain[lane] = ("", 0)

    # -- engine interface ---------------------------------------------------
    def prefill_len(self, prompt_len: int) -> int:
        """Page-aligned prefill cache length (tight, not max_len)."""
        return math.ceil(prompt_len / self.page_size) * self.page_size

    def can_admit(self, prompt_len: int) -> bool:
        return math.ceil(prompt_len / self.page_size) \
            <= len(self._free) + self.cached_pages

    def admit(self, lane: int, prefill_caches: Any, prompt_len: int) -> bool:
        nblk = math.ceil(prompt_len / self.page_size)
        pages = self._alloc(nblk)
        if pages is None:
            return False
        arr = np.asarray(pages, np.int32)

        def chunked(dense):
            # dense: (L, 1, Hkv, nblk*psz, D) -> (L, nblk, Hkv, psz, D)
            l, _, hkv, s, d = dense.shape
            return dense[:, 0].reshape(
                l, hkv, nblk, self.page_size, d).transpose(0, 2, 1, 3, 4)

        if self.quantized:
            # quantize-on-admit: the monolithic prefill cache is fp, the
            # pool is int8 + per-row scales
            k8, v8 = self.caches["kv"]
            ks, vs = self.caches["kv_scale"]
            kq, ksc = quantize_int8_rows(chunked(prefill_caches["kv"][0]))
            vq, vsc = quantize_int8_rows(chunked(prefill_caches["kv"][1]))
            self.caches = {
                "kv": (k8.at[:, arr].set(kq), v8.at[:, arr].set(vq)),
                "kv_scale": (ks.at[:, arr].set(ksc),
                             vs.at[:, arr].set(vsc)),
            }
        else:
            self.caches = jax.tree.map(
                lambda pool, dense: pool.at[:, arr].set(
                    chunked(dense).astype(pool.dtype)),
                self.caches, prefill_caches)
        self.table[lane, :nblk] = arr
        self.n_blocks[lane] = nblk
        return True

    def ensure_capacity(self, lane: int, pos: int) -> bool:
        """Make sure the page holding ``pos`` is allocated (called before
        every decode step; allocation happens on page-boundary crossings)."""
        if pos >= self.max_len:
            return False
        blk = pos // self.page_size
        if blk < self.n_blocks[lane]:
            return True
        page = self._alloc(1)
        if page is None:
            return False
        self.table[lane, blk] = page[0]
        self.n_blocks[lane] = blk + 1
        return True

    def ensure_tokens(self, lane: int, n_tokens: int) -> bool:
        """Chunk-granular growth: allocate pages until the lane covers
        positions ``[0, n_tokens)``.  Pages acquired before a failure stay
        allocated (they are tracked in ``n_blocks`` and either used by a
        later retry or freed wholesale on release/swap-out)."""
        if n_tokens > self.max_len:
            return False
        need = math.ceil(n_tokens / self.page_size)
        while self.n_blocks[lane] < need:
            page = self._alloc(1)
            if page is None:
                return False
            self.table[lane, self.n_blocks[lane]] = page[0]
            self.n_blocks[lane] += 1
        return True

    def truncate_to(self, lane: int, committed_len: int) -> int:
        """Speculative rollback: return the lane's over-allocated pages.

        Keeps exactly the pages covering positions ``[0, committed_len)``
        and frees the rest back to the pool, pointing the vacated page-
        table rows at the null page.  KV *within* the last kept page past
        ``committed_len`` may be stale (rejected drafts) — that is fine:
        reads mask by ``kv_len`` and the next accepted token overwrites
        its slot.  Returns the number of pages freed.
        """
        keep = math.ceil(committed_len / self.page_size)
        nblk = self.n_blocks[lane]
        if keep >= nblk:
            return 0
        for p in self.table[lane, keep:nblk]:
            self._unref(p)          # never frees a page another lane or
            #                         the prefix index still holds
        self.table[lane, keep:nblk] = NULL_PAGE
        self.n_blocks[lane] = keep
        return nblk - keep

    def release(self, lane: int) -> None:
        self._free_lane(lane)

    def swap_out(self, lane: int) -> PageHandle:
        """Copy the lane's pages to host memory and free them.

        int8 pools swap their native payload (int8 pages + fp32 per-row
        scales: already ~half the fp bytes, and the round trip is
        bit-exact).  fp pools copy raw unless ``swap_compress`` is set,
        which packs each leaf through :func:`quantize_int8` instead —
        half the bf16 host bytes, int8-round-trip accuracy.
        """
        nblk = self.n_blocks[lane]
        pages = np.asarray(self.table[lane, :nblk], np.int32)
        chunks = jax.tree.map(lambda pool: np.asarray(pool[:, pages]),
                              self.caches)
        self._free_lane(lane)
        self.swap_outs += 1
        if self.swap_compress:
            return PageHandle(chunks=None, n_blocks=nblk,
                              packed=_pack_tree(chunks))
        return PageHandle(chunks=chunks, n_blocks=nblk)

    def swap_in(self, lane: int, handle: PageHandle) -> bool:
        pages = self._alloc(handle.n_blocks)
        if pages is None:
            return False
        arr = np.asarray(pages, np.int32)
        chunks = handle.chunks if handle.packed is None \
            else _unpack_tree(handle.packed)
        self.caches = jax.tree.map(
            lambda pool, chunk: pool.at[:, arr].set(
                jnp.asarray(chunk).astype(pool.dtype)),
            self.caches, chunks)
        self.table[lane, :handle.n_blocks] = arr
        self.table[lane, handle.n_blocks:] = NULL_PAGE
        self.n_blocks[lane] = handle.n_blocks
        self._chain[lane] = ("", 0)   # resumed prefill re-walks the chain
        self.swap_ins += 1
        return True

    def decode_extra(self, mask_lanes=()) -> tuple:
        """Page table for the decode step.  ``mask_lanes`` (mid-prefill
        lanes) get a zeroed row: the batched decode step still runs over
        every lane slot, and masking routes those lanes' dummy KV writes
        to the null page instead of their live prefill pages."""
        tbl = self.table
        if mask_lanes:
            tbl = tbl.copy()
            tbl[list(mask_lanes), :] = NULL_PAGE
        return (jnp.asarray(tbl),)

    def table_row(self, lane: int) -> jax.Array:
        """This lane's logical->physical mapping, shaped (1, nblk) for the
        single-sequence prefill-chunk step."""
        return jnp.asarray(self.table[lane:lane + 1])

    # -- prefix cache: match / seed / publish / copy-on-write --------------
    def set_prefix_policy(self, min_match: int | None = None,
                          eviction: str | None = None) -> None:
        """Reuse-policy knobs (the ``PrefixPolicy`` tuning region's PPs):
        ``min_match`` — minimum consecutive page hits before a match is
        used at all (tiny hits may not pay for their bookkeeping);
        ``eviction`` — ``lru`` | ``fifo`` reclaim order for refcount-zero
        index entries."""
        if min_match is not None:
            self.prefix_min_match = max(1, int(min_match))
        if eviction is not None:
            if eviction not in PREFIX_EVICTION_POLICIES:
                raise ValueError(
                    f"unknown prefix eviction policy {eviction!r} "
                    f"(choose from {PREFIX_EVICTION_POLICIES})")
            self.prefix_eviction = eviction

    def match_prefix(self, prompt: Sequence[int]
                     ) -> tuple[list[int], str]:
        """Walk the prompt's full pages through the chained-hash index.

        Returns (matched physical pages, chain key of the last hit) —
        the longest indexed prefix, cut to empty when shorter than the
        ``min_match`` granularity.  Pure lookup: no refcounts move.
        """
        pages: list[int] = []
        chain = ""
        if not self.prefix_cache:
            return pages, chain
        psz = self.page_size
        for b in range(len(prompt) // psz):
            key = chain_hash(chain, prompt[b * psz:(b + 1) * psz])
            p = self._index.get(key)
            if p is None:
                break
            pages.append(p)
            chain = key
        if len(pages) < self.prefix_min_match:
            return [], ""
        return pages, chain

    def seed_prefix(self, lane: int, prompt: Sequence[int]) -> int:
        """Admission-time reuse: point the lane's leading page-table rows
        at the matched shared pages (refcount++) instead of re-running
        prefill over them.

        Returns the position prefill starts at — the cached token count,
        capped at ``len(prompt) - 1`` so the final prompt position is
        always recomputed (its logits emit the request's first token).
        With a fully-cached page-aligned prompt that recomputed position
        lands *inside* the last shared page; the write triggers the
        copy-on-write path in :meth:`cow_writable`.
        """
        if not self.prefix_cache:
            return 0
        self._tick += 1
        self.prefix_lookups += 1
        pages, chain = self.match_prefix(prompt)
        if not pages:
            self._chain[lane] = ("", 0)
            return 0
        for p in pages:
            if self.refcount[p] == 0:       # cached -> used transition
                self._n_cached -= 1
            self.refcount[p] += 1
            self._last_hit[p] = self._tick
        self.table[lane, :len(pages)] = np.asarray(pages, np.int32)
        self.table[lane, len(pages):] = NULL_PAGE
        self.n_blocks[lane] = len(pages)
        self._chain[lane] = (chain, len(pages))
        start = min(len(pages) * self.page_size, len(prompt) - 1)
        self.prefix_hits += 1
        self.hit_tokens += start
        self.pages_saved += len(pages)
        return start

    def publish_prefix(self, lane: int, prompt: Sequence[int],
                       upto: int) -> None:
        """Publish the lane's newly-full committed prompt pages into the
        index (called after each prefill chunk lands; ``upto`` = prompt
        tokens committed so far).  Only *full* pages publish — a partial
        page's KV is still being appended to.  A key already indexed
        (another lane published the same prefix first) keeps its existing
        entry; this lane's copy stays private."""
        if not self.prefix_cache:
            return
        psz = self.page_size
        chain, done = self._chain[lane]
        n_full = min(int(upto), len(prompt)) // psz
        for b in range(done, n_full):
            key = chain_hash(chain, prompt[b * psz:(b + 1) * psz])
            self._tick += 1
            p = self._index.get(key)
            if p is None:
                p = int(self.table[lane, b])
                if p != NULL_PAGE:
                    self._index[key] = p
                    self._page_key[p] = key
                    self._pub_order[p] = self._tick
            self._last_hit[p] = self._tick
            chain = key
        self._chain[lane] = (chain, max(done, n_full))

    def cow_writable(self, lane: int, pos: int) -> bool:
        """Copy-on-write guard: make the page holding ``pos`` privately
        writable before a KV write lands there.

        A page is *not* writable in place when another lane references it
        (refcount > 1) or when it backs an index entry (writing would
        silently diverge its content from its hash).  Either way the lane
        gets a fresh private copy of the page's pool content and drops
        its shared reference.  Returns False only when the pool cannot
        supply the copy (page pressure — caller preempts).
        """
        if not self.prefix_cache:
            return True
        blk = int(pos) // self.page_size
        if blk >= self.n_blocks[lane]:
            return True                  # page not allocated yet: fresh
        p = int(self.table[lane, blk])
        if p == NULL_PAGE:
            return True
        if self.refcount[p] <= 1 and p not in self._page_key:
            return True                  # already private
        fresh = self._alloc(1)
        if fresh is None:
            return False
        q = fresh[0]
        self.caches = jax.tree.map(
            lambda pool: pool.at[:, q].set(pool[:, p]), self.caches)
        self._unref(p)
        self.table[lane, blk] = q
        self.cow_copies += 1
        return True

    # -- accounting ---------------------------------------------------------
    def cache_tokens(self) -> int:
        """Token capacity currently held by live sequences."""
        return self.used_pages * self.page_size

    def pool_bytes(self) -> int:
        """Device bytes held by the pool, from the *actual* leaf dtypes
        (int8 pools count 1 byte/element plus their fp32 scale rows, not
        the model compute dtype)."""
        return int(sum(leaf.size * leaf.dtype.itemsize
                       for leaf in jax.tree_util.tree_leaves(self.caches)))

    def kv_bytes_per_token(self) -> float:
        return self.pool_bytes() / float(self.n_pages * self.page_size)

    def capacity_tokens(self) -> int:
        """Allocatable token capacity (page 0 is the reserved null page)."""
        return (self.n_pages - 1) * self.page_size

    def stats(self) -> dict:
        out = {"kind": self.kind, "page_size": self.page_size,
               "n_pages": self.n_pages, "used_pages": self.used_pages,
               "free_pages": self.free_pages,
               "cached_pages": self.cached_pages,
               "cache_tokens": self.cache_tokens(),
               "kv_dtype": self.kv_dtype,
               "pool_bytes": self.pool_bytes(),
               "kv_bytes_per_token": self.kv_bytes_per_token(),
               "capacity_tokens": self.capacity_tokens(),
               "swap_outs": self.swap_outs, "swap_ins": self.swap_ins}
        if self.prefix_cache:
            out["prefix"] = {
                "lookups": self.prefix_lookups,
                "hits": self.prefix_hits,
                "hit_tokens": self.hit_tokens,
                "pages_saved": self.pages_saved,
                "cached_pages": self.cached_pages,
                "cow_copies": self.cow_copies,
                "evictions": self.index_evictions,
                "min_match": self.prefix_min_match,
                "eviction": self.prefix_eviction,
            }
        return out


def make_kv_cache(model, cache: str, n_lanes: int, max_len: int,
                  n_pages: int | None = None, page_size: int = 16,
                  prefix_cache: bool = False, prefix_min_match: int = 1,
                  prefix_eviction: str = "lru", kv_dtype: str = "fp",
                  swap_compress: bool = False, mesh=None):
    """Build a KV-cache backend by name (``dense`` | ``paged``)."""
    if cache == "dense":
        if prefix_cache:
            raise ValueError(
                "prefix caching shares pages of the paged pool; "
                "use cache='paged'")
        if kv_dtype != "fp":
            raise ValueError(
                "quantized KV storage is a paged-pool feature; "
                "use cache='paged'")
        if _mesh_model_axis(mesh) > 1:
            raise ValueError(
                "tensor-parallel serving shards the paged page pools; "
                "use cache='paged' with --mesh")
        return DenseKVCache(model, n_lanes, max_len,
                            swap_compress=swap_compress)
    if cache == "paged":
        if n_pages is None:
            # default pool: enough for every lane at full length (parity
            # with dense), callers shrink it to see paging pay off
            n_pages = n_lanes * math.ceil(max_len / page_size) + 1
        return PagedKVCache(model, n_lanes, max_len, n_pages, page_size,
                            prefix_cache=prefix_cache,
                            prefix_min_match=prefix_min_match,
                            prefix_eviction=prefix_eviction,
                            kv_dtype=kv_dtype, swap_compress=swap_compress,
                            mesh=mesh)
    raise ValueError(f"unknown cache backend {cache!r}")
