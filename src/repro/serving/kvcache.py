"""KV-cache backends for the serving engine: dense lanes and paged blocks.

Two implementations of one interface:

* :class:`DenseKVCache` — the classic layout: every decode lane owns a
  contiguous ``max_len`` strip in a stacked ``(L, n_lanes, ...)`` cache.
  Memory is O(n_lanes * max_len) regardless of how many tokens are live.
* :class:`PagedKVCache` — vLLM-style paging: a shared physical pool of
  ``n_pages`` pages of ``page_size`` tokens each (per layer), with a
  per-lane page table mapping logical KV blocks to physical pages.
  Memory scales with *live tokens* (rounded up to page granularity), lane
  admission is page allocation instead of a pad/crop splice, and a
  preempted sequence's pages can be swapped out to host memory and later
  swapped back in without re-running prefill.

Page 0 of the pool is reserved as a *null page*: idle lanes decode with
``pos = 0`` and a zeroed page-table row, so their (discarded) KV writes
land there and can never corrupt a live sequence.

The engine talks to both backends through the same methods::

    admit(lane, prefill_caches, prompt_len) -> bool
    ensure_capacity(lane, pos) -> bool        # page alloc on boundary
    ensure_tokens(lane, n_tokens) -> bool     # chunk-granular growth
    swap_out(lane) -> handle                  # preemption
    swap_in(lane, handle) -> bool
    release(lane)
    decode_extra(mask_lanes) -> tuple         # (page_table,) when paged;
                                              # mid-prefill lanes masked
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

NULL_PAGE = 0


def _lane_set(full: jax.Array, one: jax.Array, lane: int) -> jax.Array:
    """Write batch entry 0 of ``one`` into lane ``lane`` of the stacked
    cache.

    Leaves are (L, B, ...) (layer-stacked) or (napp, B, ...); the batch
    axis is axis 1.  Only the target lane is ever written — even when the
    source happens to be full-width — so concurrent lanes' state is never
    clobbered.
    """
    src = one[:, 0]
    # pad/crop trailing dims (prefill cache len == prompt len)
    dst_shape = full.shape[2:]
    pads = []
    slices = [slice(None)] * src.ndim
    for i, (s, d) in enumerate(zip(src.shape[1:], dst_shape)):
        if s < d:
            pads.append((0, d - s))
        else:
            pads.append((0, 0))
            slices[i + 1] = slice(0, d)
    src = src[tuple(slices)]
    if any(p != (0, 0) for p in pads):
        src = jnp.pad(src, [(0, 0)] + pads)
    return full.at[:, lane].set(src.astype(full.dtype))


class DenseKVCache:
    """Per-lane contiguous KV strips (the pre-paging layout)."""

    kind = "dense"

    def __init__(self, model, n_lanes: int, max_len: int):
        self.n_lanes = n_lanes
        self.max_len = max_len
        self.caches = model.init_caches(n_lanes, max_len)

    # -- engine interface ---------------------------------------------------
    def prefill_len(self, prompt_len: int) -> int:
        return self.max_len

    def admit(self, lane: int, prefill_caches: Any, prompt_len: int) -> bool:
        self.caches = jax.tree.map(
            lambda full, one: _lane_set(full, one, lane),
            self.caches, prefill_caches)
        return True

    def ensure_capacity(self, lane: int, pos: int) -> bool:
        return pos < self.max_len

    def ensure_tokens(self, lane: int, n_tokens: int) -> bool:
        """Capacity for the first ``n_tokens`` positions (no-op when dense:
        the lane's strip is pre-sized)."""
        return n_tokens <= self.max_len

    def truncate_to(self, lane: int, committed_len: int) -> int:
        """Speculative rollback (no-op when dense: the lane's strip is
        pre-sized and stale KV past ``committed_len`` is masked by the
        decode step's ``kv_len``).  Returns pages freed (always 0)."""
        return 0

    def release(self, lane: int) -> None:
        pass

    def swap_out(self, lane: int) -> Any:
        handle = jax.tree.map(lambda a: np.asarray(a[:, lane]), self.caches)
        return handle

    def swap_in(self, lane: int, handle: Any) -> bool:
        self.caches = jax.tree.map(
            lambda full, one: full.at[:, lane].set(
                jnp.asarray(one).astype(full.dtype)),
            self.caches, handle)
        return True

    def decode_extra(self, mask_lanes=()) -> tuple:
        return ()

    # -- accounting ---------------------------------------------------------
    def cache_tokens(self) -> int:
        """Token capacity held in device memory (fixed for dense)."""
        return self.n_lanes * self.max_len

    def stats(self) -> dict:
        return {"kind": self.kind, "cache_tokens": self.cache_tokens()}


@dataclass
class PageHandle:
    """Host-side copy of a swapped-out sequence's pages."""

    chunks: Any          # pytree of np arrays, page axis at position 1
    n_blocks: int


class PagedKVCache:
    """Block/paged KV cache with a free-page pool and host swap space.

    ``n_pages`` pages of ``page_size`` tokens (per layer) back every lane;
    a lane's logical block *b* lives in physical page ``table[lane, b]``.
    Pages are lane-exclusive while allocated, so the decode step's scatter
    can never race between lanes.
    """

    kind = "paged"

    def __init__(self, model, n_lanes: int, max_len: int, n_pages: int,
                 page_size: int = 16):
        if not model.supports_paged_cache:
            raise ValueError(
                f"arch {model.cfg.name!r} does not support the paged KV "
                "cache; use cache='dense'")
        if n_pages < 2:
            raise ValueError("need at least 2 pages (page 0 is reserved)")
        self.n_lanes = n_lanes
        self.max_len = max_len
        self.page_size = page_size
        self.n_pages = n_pages
        self.max_blocks = math.ceil(max_len / page_size)
        self.caches = model.init_paged_caches(n_pages, page_size)
        self.table = np.zeros((n_lanes, self.max_blocks), np.int32)
        self.n_blocks = [0] * n_lanes
        # page 0 is the null page (idle-lane write sink), never allocated
        self._free = list(range(n_pages - 1, 0, -1))
        self.swap_outs = 0
        self.swap_ins = 0

    # -- page pool ----------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return (self.n_pages - 1) - len(self._free)

    def _alloc(self, n: int) -> list[int] | None:
        if n > len(self._free):
            return None
        return [self._free.pop() for _ in range(n)]

    def _free_lane(self, lane: int) -> None:
        nblk = self.n_blocks[lane]
        self._free.extend(int(p) for p in self.table[lane, :nblk])
        self.table[lane, :] = NULL_PAGE
        self.n_blocks[lane] = 0

    # -- engine interface ---------------------------------------------------
    def prefill_len(self, prompt_len: int) -> int:
        """Page-aligned prefill cache length (tight, not max_len)."""
        return math.ceil(prompt_len / self.page_size) * self.page_size

    def can_admit(self, prompt_len: int) -> bool:
        return math.ceil(prompt_len / self.page_size) <= len(self._free)

    def admit(self, lane: int, prefill_caches: Any, prompt_len: int) -> bool:
        nblk = math.ceil(prompt_len / self.page_size)
        pages = self._alloc(nblk)
        if pages is None:
            return False
        arr = np.asarray(pages, np.int32)

        def scatter(pool, dense):
            # dense: (L, 1, Hkv, nblk*psz, D) -> (L, nblk, Hkv, psz, D)
            l, _, hkv, s, d = dense.shape
            chunks = dense[:, 0].reshape(
                l, hkv, nblk, self.page_size, d).transpose(0, 2, 1, 3, 4)
            return pool.at[:, arr].set(chunks.astype(pool.dtype))

        self.caches = jax.tree.map(scatter, self.caches, prefill_caches)
        self.table[lane, :nblk] = arr
        self.n_blocks[lane] = nblk
        return True

    def ensure_capacity(self, lane: int, pos: int) -> bool:
        """Make sure the page holding ``pos`` is allocated (called before
        every decode step; allocation happens on page-boundary crossings)."""
        if pos >= self.max_len:
            return False
        blk = pos // self.page_size
        if blk < self.n_blocks[lane]:
            return True
        page = self._alloc(1)
        if page is None:
            return False
        self.table[lane, blk] = page[0]
        self.n_blocks[lane] = blk + 1
        return True

    def ensure_tokens(self, lane: int, n_tokens: int) -> bool:
        """Chunk-granular growth: allocate pages until the lane covers
        positions ``[0, n_tokens)``.  Pages acquired before a failure stay
        allocated (they are tracked in ``n_blocks`` and either used by a
        later retry or freed wholesale on release/swap-out)."""
        if n_tokens > self.max_len:
            return False
        need = math.ceil(n_tokens / self.page_size)
        while self.n_blocks[lane] < need:
            page = self._alloc(1)
            if page is None:
                return False
            self.table[lane, self.n_blocks[lane]] = page[0]
            self.n_blocks[lane] += 1
        return True

    def truncate_to(self, lane: int, committed_len: int) -> int:
        """Speculative rollback: return the lane's over-allocated pages.

        Keeps exactly the pages covering positions ``[0, committed_len)``
        and frees the rest back to the pool, pointing the vacated page-
        table rows at the null page.  KV *within* the last kept page past
        ``committed_len`` may be stale (rejected drafts) — that is fine:
        reads mask by ``kv_len`` and the next accepted token overwrites
        its slot.  Returns the number of pages freed.
        """
        keep = math.ceil(committed_len / self.page_size)
        nblk = self.n_blocks[lane]
        if keep >= nblk:
            return 0
        self._free.extend(int(p) for p in self.table[lane, keep:nblk])
        self.table[lane, keep:nblk] = NULL_PAGE
        self.n_blocks[lane] = keep
        return nblk - keep

    def release(self, lane: int) -> None:
        self._free_lane(lane)

    def swap_out(self, lane: int) -> PageHandle:
        nblk = self.n_blocks[lane]
        pages = np.asarray(self.table[lane, :nblk], np.int32)
        chunks = jax.tree.map(lambda pool: np.asarray(pool[:, pages]),
                              self.caches)
        self._free_lane(lane)
        self.swap_outs += 1
        return PageHandle(chunks=chunks, n_blocks=nblk)

    def swap_in(self, lane: int, handle: PageHandle) -> bool:
        pages = self._alloc(handle.n_blocks)
        if pages is None:
            return False
        arr = np.asarray(pages, np.int32)
        self.caches = jax.tree.map(
            lambda pool, chunk: pool.at[:, arr].set(
                jnp.asarray(chunk).astype(pool.dtype)),
            self.caches, handle.chunks)
        self.table[lane, :handle.n_blocks] = arr
        self.table[lane, handle.n_blocks:] = NULL_PAGE
        self.n_blocks[lane] = handle.n_blocks
        self.swap_ins += 1
        return True

    def decode_extra(self, mask_lanes=()) -> tuple:
        """Page table for the decode step.  ``mask_lanes`` (mid-prefill
        lanes) get a zeroed row: the batched decode step still runs over
        every lane slot, and masking routes those lanes' dummy KV writes
        to the null page instead of their live prefill pages."""
        tbl = self.table
        if mask_lanes:
            tbl = tbl.copy()
            tbl[list(mask_lanes), :] = NULL_PAGE
        return (jnp.asarray(tbl),)

    def table_row(self, lane: int) -> jax.Array:
        """This lane's logical->physical mapping, shaped (1, nblk) for the
        single-sequence prefill-chunk step."""
        return jnp.asarray(self.table[lane:lane + 1])

    # -- accounting ---------------------------------------------------------
    def cache_tokens(self) -> int:
        """Token capacity currently held by live sequences."""
        return self.used_pages * self.page_size

    def stats(self) -> dict:
        return {"kind": self.kind, "page_size": self.page_size,
                "n_pages": self.n_pages, "used_pages": self.used_pages,
                "free_pages": self.free_pages,
                "cache_tokens": self.cache_tokens(),
                "swap_outs": self.swap_outs, "swap_ins": self.swap_ins}


def make_kv_cache(model, cache: str, n_lanes: int, max_len: int,
                  n_pages: int | None = None, page_size: int = 16):
    """Build a KV-cache backend by name (``dense`` | ``paged``)."""
    if cache == "dense":
        return DenseKVCache(model, n_lanes, max_len)
    if cache == "paged":
        if n_pages is None:
            # default pool: enough for every lane at full length (parity
            # with dense), callers shrink it to see paging pay off
            n_pages = n_lanes * math.ceil(max_len / page_size) + 1
        return PagedKVCache(model, n_lanes, max_len, n_pages, page_size)
    raise ValueError(f"unknown cache backend {cache!r}")
