"""Serving stack: scheduler-driven continuous batching over a paged or
dense KV cache, with run-time AT decode dispatch.

Layers (see ``docs/SERVING.md``):

* :mod:`.scheduler` — FIFO admission + preemptive continuous batching;
* :mod:`.kvcache` — ``DenseKVCache`` / ``PagedKVCache`` backends (the
  paged pool optionally refcounted + content-addressed for prefix
  caching);
* :mod:`.buckets` — the shared length-bucket ladders every tuning
  region family keys off;
* :mod:`.sampling` — per-request temperature/top-k/top-p + the
  speculative accept/reject rule;
* :mod:`.metrics` — TTFT / inter-token latency / throughput aggregation;
* :mod:`.engine` — the orchestrator tying them to the model's decode
  step (plain, chunked-prefill, and speculative), with each tick split
  into ``schedule`` / ``dispatch`` / ``emit`` phases;
* :mod:`.gateway` — the async HTTP/SSE front-end: pipelined tick loop
  (host scheduling overlaps device compute), bounded admission with
  backpressure, per-request cancellation, graceful drain.
"""
from .buckets import LENGTH_BUCKETS, REDUCED_BUCKETS
from .engine import LaneState, Request, ServingEngine, TickWork, length_bucket
from .kvcache import DenseKVCache, PagedKVCache, make_kv_cache
from .metrics import ServingMetrics
from .sampling import SamplingParams
from .scheduler import Scheduler

__all__ = ["ServingEngine", "Request", "LaneState", "TickWork",
           "length_bucket", "LENGTH_BUCKETS", "REDUCED_BUCKETS",
           "DenseKVCache", "PagedKVCache", "make_kv_cache", "Scheduler",
           "ServingMetrics", "SamplingParams"]
