from .engine import LaneState, Request, ServingEngine, length_bucket
__all__ = ["ServingEngine", "Request", "LaneState", "length_bucket"]
