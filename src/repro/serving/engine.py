"""Serving engine: the orchestration layer of the serving stack.

The engine wires four components together and owns none of their policy:

* :mod:`~repro.serving.scheduler` — FIFO admission queues + preemption
  decisions (continuous batching over fixed decode lanes);
* :mod:`~repro.serving.kvcache` — the KV backend: ``dense`` per-lane
  strips or ``paged`` block allocation with host swap;
* :mod:`~repro.serving.metrics` — TTFT / inter-token latency / throughput
  aggregation over finished requests;
* the decode dispatch — a jit'd fixed-shape decode step, optionally routed
  through a :class:`~repro.tuning.dynamic.DecodeAutoTuner` whose
  per-length-bucket ``dynamic select`` regions pick the decode variant at
  run time (the paper's Sample 6/7 pattern applied to serving).

With the paged backend the engine *serves* more concurrent requests than
it has decode lanes: queued work triggers time-slice preemption, the
victim's pages are swapped to host memory, and the sequence later resumes
by swap-in — no prefill re-run, bit-identical continuation.

With ``prefill_chunk=N`` (paged only) prompts stream into the KV cache
N tokens per scheduler tick instead of prefilling monolithically at
admission: each tick runs one prefill chunk per mid-prefill lane, then
one batched decode step over the decoding lanes — long prompts stop
head-of-line-blocking short requests (chunked prefill / continuous
batching; see docs/SERVING.md for the tick anatomy).
"""
from __future__ import annotations

import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from .kvcache import PagedKVCache, make_kv_cache
from .metrics import ServingMetrics
from .scheduler import LaneState, Request, Scheduler

__all__ = ["ServingEngine", "Request", "LaneState", "length_bucket"]


def length_bucket(n: int, buckets=(128, 512, 2048, 8192, 32768)) -> int:
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


class ServingEngine:
    def __init__(self, model, params, n_lanes: int = 4,
                 max_len: int = 512, eos_id: int | None = None,
                 decode_fn: Callable | None = None,
                 prefill_fn: Callable | None = None,
                 greedy: bool = True, autotuner=None,
                 cache: str = "dense", n_pages: int | None = None,
                 page_size: int = 16, timeslice: int | None = None,
                 prefill_chunk: int | None = None):
        self.model = model
        self.params = params
        self.n_lanes = n_lanes
        self.max_len = max_len
        self.eos_id = eos_id
        self.kv = make_kv_cache(model, cache, n_lanes, max_len,
                                n_pages=n_pages, page_size=page_size)
        if prefill_chunk is not None and self.kv.kind != "paged":
            raise ValueError(
                "chunked prefill streams the prompt into the paged KV "
                "cache; use cache='paged' (dense keeps monolithic prefill)")
        self.prefill_chunk = prefill_chunk
        self.scheduler = Scheduler(n_lanes, timeslice=timeslice)
        self.metrics = ServingMetrics()
        step_fn = model.paged_decode_step if self.kv.kind == "paged" \
            else model.decode_step
        self._decode = decode_fn or jax.jit(step_fn)
        self._prefill = prefill_fn or jax.jit(
            model.prefill, static_argnums=(3,))
        if prefill_chunk is not None:
            self._prefill_step = jax.jit(model.paged_prefill_step)
        # run-time AT hook (repro.at): a tuning/dynamic.DecodeAutoTuner
        # routing each decode step through the per-bucket dynamic select
        # region (and, when chunked prefill is on, each prefill chunk
        # through the per-(prompt-bucket x chunk) prefill region); None
        # keeps the plain jit'd paths.
        self.autotuner = autotuner
        self.active: dict[int, Request] = {}
        self.finished: list[Request] = []
        self.steps = 0
        self.prefill_chunks = 0          # chunk-steps executed (chunked)

    # -- compat views -------------------------------------------------------
    @property
    def lanes(self) -> list[LaneState]:
        return self.scheduler.lanes

    @property
    def queue(self):
        return self.scheduler.waiting

    @property
    def caches(self):
        return self.kv.caches

    # -- admission ---------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.scheduler.submit(req)

    def _finish(self, lane_id: int, req: Request, now: float) -> None:
        req.done = True
        req.finish_t = now
        self.finished.append(req)
        self.metrics.observe(req)
        self.active.pop(req.rid, None)
        self.kv.release(lane_id)
        self.scheduler.vacate(lane_id)

    def _is_eos(self, tok: int) -> bool:
        """Explicit EOS guard: ``eos_id=0`` is a valid stop token and
        ``eos_id=None`` disables EOS stopping entirely."""
        return self.eos_id is not None and tok == self.eos_id

    def _preempt_lane(self, lane_id: int, priority: bool = False) -> None:
        lane = self.scheduler.lanes[lane_id]
        req = self.active.pop(lane.rid)
        handle = self.kv.swap_out(lane_id)
        self.scheduler.preempt(lane_id, req, handle, priority=priority)

    def _admit(self) -> None:
        for lane_id in self.scheduler.free_lanes():
            nxt = self.scheduler.next_admission()
            if nxt is None:
                return
            kind, item = nxt
            if kind == "resume":
                if not self.kv.swap_in(lane_id, item.handle):
                    self.scheduler.push_back(kind, item)
                    return                 # no pages yet; retry next step
                self.scheduler.occupy(lane_id, item.req, item.pos,
                                      item.remaining, phase=item.phase)
                self.active[item.req.rid] = item.req
                continue
            req = item
            if self.prefill_chunk is not None:
                # chunked admission: the lane enters prefill phase with no
                # compute — the prefill tick streams the prompt in chunk
                # by chunk.  Gate on pages for the *first chunk* only.
                first = min(self.prefill_chunk, len(req.prompt))
                if not self.kv.can_admit(first):
                    self.scheduler.push_back(kind, req)
                    return                 # page pressure; stay queued
                self.scheduler.occupy(lane_id, req, 0, req.max_new_tokens,
                                      phase="prefill")
                self.active[req.rid] = req
                continue
            if isinstance(self.kv, PagedKVCache) \
                    and not self.kv.can_admit(len(req.prompt)):
                self.scheduler.push_back(kind, req)
                return                     # page pressure; stay queued
            plen = self.kv.prefill_len(len(req.prompt))
            logits, cache1 = self._prefill(
                self.params, jnp.asarray([req.prompt], jnp.int32),
                None, plen)
            if not self.kv.admit(lane_id, cache1, len(req.prompt)):
                self.scheduler.push_back(kind, req)
                return
            tok = int(jnp.argmax(logits[0]))
            now = time.time()
            req.out_tokens.append(tok)
            req.first_token_t = now
            req.token_ts.append(now)
            self.scheduler.occupy(lane_id, req, len(req.prompt),
                                  req.max_new_tokens - 1)
            self.active[req.rid] = req
            if req.max_new_tokens <= 1 or self._is_eos(tok):
                self._finish(lane_id, req, now)

    def _prefill_tick(self) -> None:
        """One prefill chunk for every mid-prefill lane.

        Each lane streams ``prefill_chunk`` prompt tokens into its paged
        KV cache per tick (pages allocated chunk-granularly, the ragged
        last chunk padded into the null page), so long prompts never
        head-of-line-block the decode step that follows in the same tick.
        The final chunk's last-valid-token logits seed decode — that is
        the request's first token (TTFT stamps here).
        """
        if self.prefill_chunk is None:
            return
        c = self.prefill_chunk
        for lane_id in self.scheduler.prefill_lanes():
            lane = self.scheduler.lanes[lane_id]
            req = self.active[lane.rid]
            plen = len(req.prompt)
            start, end = lane.pos, min(lane.pos + c, plen)
            if not self.kv.ensure_tokens(lane_id, end):
                if len(self.active) == 1:
                    raise RuntimeError(
                        f"page pool too small: sequence {lane.rid} needs "
                        f"pages for prompt positions [{start}, {end}) and "
                        "no other lane can be evicted")
                self._preempt_lane(lane_id, priority=True)
                continue
            chunk = req.prompt[start:end] + [0] * (c - (end - start))
            args = (self.params, self.kv.caches, self.kv.table_row(lane_id),
                    jnp.asarray([chunk], jnp.int32),
                    jnp.asarray([start], jnp.int32),
                    jnp.asarray([end], jnp.int32),
                    jnp.asarray([end - start - 1], jnp.int32))
            if self.autotuner is not None \
                    and getattr(self.autotuner, "prefill_regions", None):
                logits, new_caches = self.autotuner.prefill(plen, c, *args)
            else:
                logits, new_caches = self._prefill_step(*args)
            self.kv.caches = new_caches
            self.prefill_chunks += 1
            lane.pos = end
            if end < plen:
                continue                   # prompt still streaming in
            tok = int(jnp.argmax(logits[0]))
            now = time.time()
            req.out_tokens.append(tok)
            req.first_token_t = now
            req.token_ts.append(now)
            lane.phase = "decode"
            lane.remaining = req.max_new_tokens - 1
            if req.max_new_tokens <= 1 or self._is_eos(tok):
                self._finish(lane_id, req, now)

    def _ensure_capacity(self) -> None:
        """Pre-decode page check: every decoding lane must own the page its
        next token writes to; a lane that cannot allocate one is preempted
        (its pages swap out, freeing room for the rest).  Mid-prefill lanes
        are skipped — the prefill tick does its own chunk-granular
        allocation."""
        for lane_id in self.scheduler.decode_lanes():
            lane = self.scheduler.lanes[lane_id]
            if self.kv.ensure_capacity(lane_id, lane.pos):
                continue
            if len(self.active) == 1:
                raise RuntimeError(
                    f"page pool too small: sequence {lane.rid} needs "
                    f"another page at pos {lane.pos} and no other lane "
                    "can be evicted")
            self._preempt_lane(lane_id, priority=True)

    # -- one scheduler tick: prefill chunks + one decode step ---------------
    def step(self) -> None:
        victim = self.scheduler.pick_victim()
        if victim is not None:
            self._preempt_lane(victim)
        self._admit()
        self._prefill_tick()
        self._ensure_capacity()
        decoding = self.scheduler.decode_lanes()
        if not decoding:
            return
        token = np.zeros((self.n_lanes, 1), np.int32)
        pos = np.zeros((self.n_lanes,), np.int32)
        for i in decoding:
            lane = self.scheduler.lanes[i]
            req = self.active[lane.rid]
            token[i, 0] = req.out_tokens[-1]
            pos[i] = lane.pos
        # mid-prefill lanes ride along in the fixed-shape batched step with
        # a zeroed page-table row: their dummy KV write lands in the null
        # page, never in their live prefill pages
        extra = self.kv.decode_extra(
            mask_lanes=self.scheduler.prefill_lanes())
        args = (self.params, self.kv.caches, *extra,
                jnp.asarray(token), jnp.asarray(pos))
        if self.autotuner is not None:
            kv_len = int(pos.max()) + 1
            logits, new_caches = self.autotuner.decode(kv_len, *args)
        else:
            logits, new_caches = self._decode(*args)
        self.kv.caches = new_caches
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        now = time.time()
        self.steps += 1
        for i in decoding:
            lane = self.scheduler.lanes[i]
            req = self.active[lane.rid]
            tok = int(nxt[i])
            req.out_tokens.append(tok)
            req.token_ts.append(now)
            lane.pos += 1
            lane.remaining -= 1
            lane.steps_served += 1
            if lane.remaining <= 0 or self._is_eos(tok) \
                    or lane.pos >= self.max_len - 1:
                self._finish(i, req, now)

    def run(self, max_steps: int = 1000) -> list[Request]:
        while (self.scheduler.has_queued or self.active) \
                and self.steps < max_steps:
            steps_before, done_before = self.steps, len(self.finished)
            self.step()
            if not self.active and self.scheduler.has_queued \
                    and self.steps == steps_before \
                    and len(self.finished) == done_before:
                raise RuntimeError(
                    "admission stalled: queued work cannot obtain a lane "
                    "or pages (page pool smaller than one sequence?)")
        return self.finished
