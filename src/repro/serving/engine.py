"""Serving engine: the orchestration layer of the serving stack.

The engine wires four components together and owns none of their policy:

* :mod:`~repro.serving.scheduler` — FIFO admission queues + preemption
  decisions (continuous batching over fixed decode lanes);
* :mod:`~repro.serving.kvcache` — the KV backend: ``dense`` per-lane
  strips or ``paged`` block allocation with host swap;
* :mod:`~repro.serving.metrics` — TTFT / inter-token latency / throughput
  aggregation over finished requests;
* the decode dispatch — a jit'd fixed-shape decode step, optionally routed
  through a :class:`~repro.tuning.dynamic.DecodeAutoTuner` whose
  per-length-bucket ``dynamic select`` regions pick the decode variant at
  run time (the paper's Sample 6/7 pattern applied to serving).

With the paged backend the engine *serves* more concurrent requests than
it has decode lanes: queued work triggers time-slice preemption, the
victim's pages are swapped to host memory, and the sequence later resumes
by swap-in — no prefill re-run, bit-identical continuation.
"""
from __future__ import annotations

import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from .kvcache import PagedKVCache, make_kv_cache
from .metrics import ServingMetrics
from .scheduler import LaneState, Request, Scheduler

__all__ = ["ServingEngine", "Request", "LaneState", "length_bucket"]


def length_bucket(n: int, buckets=(128, 512, 2048, 8192, 32768)) -> int:
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


class ServingEngine:
    def __init__(self, model, params, n_lanes: int = 4,
                 max_len: int = 512, eos_id: int | None = None,
                 decode_fn: Callable | None = None,
                 prefill_fn: Callable | None = None,
                 greedy: bool = True, autotuner=None,
                 cache: str = "dense", n_pages: int | None = None,
                 page_size: int = 16, timeslice: int | None = None):
        self.model = model
        self.params = params
        self.n_lanes = n_lanes
        self.max_len = max_len
        self.eos_id = eos_id
        self.kv = make_kv_cache(model, cache, n_lanes, max_len,
                                n_pages=n_pages, page_size=page_size)
        self.scheduler = Scheduler(n_lanes, timeslice=timeslice)
        self.metrics = ServingMetrics()
        step_fn = model.paged_decode_step if self.kv.kind == "paged" \
            else model.decode_step
        self._decode = decode_fn or jax.jit(step_fn)
        self._prefill = prefill_fn or jax.jit(
            model.prefill, static_argnums=(3,))
        # run-time AT hook (repro.at): a tuning/dynamic.DecodeAutoTuner
        # routing each decode step through the per-bucket dynamic select
        # region; None keeps the plain jit'd decode path.
        self.autotuner = autotuner
        self.active: dict[int, Request] = {}
        self.finished: list[Request] = []
        self.steps = 0

    # -- compat views -------------------------------------------------------
    @property
    def lanes(self) -> list[LaneState]:
        return self.scheduler.lanes

    @property
    def queue(self):
        return self.scheduler.waiting

    @property
    def caches(self):
        return self.kv.caches

    # -- admission ---------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.scheduler.submit(req)

    def _finish(self, lane_id: int, req: Request, now: float) -> None:
        req.done = True
        req.finish_t = now
        self.finished.append(req)
        self.metrics.observe(req)
        self.active.pop(req.rid, None)
        self.kv.release(lane_id)
        self.scheduler.vacate(lane_id)

    def _is_eos(self, tok: int) -> bool:
        """Explicit EOS guard: ``eos_id=0`` is a valid stop token and
        ``eos_id=None`` disables EOS stopping entirely."""
        return self.eos_id is not None and tok == self.eos_id

    def _preempt_lane(self, lane_id: int, priority: bool = False) -> None:
        lane = self.scheduler.lanes[lane_id]
        req = self.active.pop(lane.rid)
        handle = self.kv.swap_out(lane_id)
        self.scheduler.preempt(lane_id, req, handle, priority=priority)

    def _admit(self) -> None:
        for lane_id in self.scheduler.free_lanes():
            nxt = self.scheduler.next_admission()
            if nxt is None:
                return
            kind, item = nxt
            if kind == "resume":
                if not self.kv.swap_in(lane_id, item.handle):
                    self.scheduler.push_back(kind, item)
                    return                 # no pages yet; retry next step
                self.scheduler.occupy(lane_id, item.req, item.pos,
                                      item.remaining)
                self.active[item.req.rid] = item.req
                continue
            req = item
            if isinstance(self.kv, PagedKVCache) \
                    and not self.kv.can_admit(len(req.prompt)):
                self.scheduler.push_back(kind, req)
                return                     # page pressure; stay queued
            plen = self.kv.prefill_len(len(req.prompt))
            logits, cache1 = self._prefill(
                self.params, jnp.asarray([req.prompt], jnp.int32),
                None, plen)
            if not self.kv.admit(lane_id, cache1, len(req.prompt)):
                self.scheduler.push_back(kind, req)
                return
            tok = int(jnp.argmax(logits[0]))
            now = time.time()
            req.out_tokens.append(tok)
            req.first_token_t = now
            req.token_ts.append(now)
            self.scheduler.occupy(lane_id, req, len(req.prompt),
                                  req.max_new_tokens - 1)
            self.active[req.rid] = req
            if req.max_new_tokens <= 1 or self._is_eos(tok):
                self._finish(lane_id, req, now)

    def _ensure_capacity(self) -> None:
        """Pre-decode page check: every active lane must own the page its
        next token writes to; a lane that cannot allocate one is preempted
        (its pages swap out, freeing room for the rest)."""
        for lane_id in self.scheduler.active_lanes():
            lane = self.scheduler.lanes[lane_id]
            if self.kv.ensure_capacity(lane_id, lane.pos):
                continue
            if len(self.active) == 1:
                raise RuntimeError(
                    f"page pool too small: sequence {lane.rid} needs "
                    f"another page at pos {lane.pos} and no other lane "
                    "can be evicted")
            self._preempt_lane(lane_id, priority=True)

    # -- one decode step over all lanes -------------------------------------
    def step(self) -> None:
        victim = self.scheduler.pick_victim()
        if victim is not None:
            self._preempt_lane(victim)
        self._admit()
        self._ensure_capacity()
        if not self.active:
            return
        token = np.zeros((self.n_lanes, 1), np.int32)
        pos = np.zeros((self.n_lanes,), np.int32)
        for i, lane in enumerate(self.scheduler.lanes):
            if lane.rid is not None:
                req = self.active[lane.rid]
                token[i, 0] = req.out_tokens[-1]
                pos[i] = lane.pos
        args = (self.params, self.kv.caches, *self.kv.decode_extra(),
                jnp.asarray(token), jnp.asarray(pos))
        if self.autotuner is not None:
            kv_len = int(pos.max()) + 1
            logits, new_caches = self.autotuner.decode(kv_len, *args)
        else:
            logits, new_caches = self._decode(*args)
        self.kv.caches = new_caches
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        now = time.time()
        self.steps += 1
        for i, lane in enumerate(self.scheduler.lanes):
            if lane.rid is None:
                continue
            req = self.active[lane.rid]
            tok = int(nxt[i])
            req.out_tokens.append(tok)
            req.token_ts.append(now)
            lane.pos += 1
            lane.remaining -= 1
            lane.steps_served += 1
            if lane.remaining <= 0 or self._is_eos(tok) \
                    or lane.pos >= self.max_len - 1:
                self._finish(i, req, now)

    def run(self, max_steps: int = 1000) -> list[Request]:
        while (self.scheduler.has_queued or self.active) \
                and self.steps < max_steps:
            steps_before, done_before = self.steps, len(self.finished)
            self.step()
            if not self.active and self.scheduler.has_queued \
                    and self.steps == steps_before \
                    and len(self.finished) == done_before:
                raise RuntimeError(
                    "admission stalled: queued work cannot obtain a lane "
                    "or pages (page pool smaller than one sequence?)")
        return self.finished
