"""Serving engine: continuous batching over fixed decode lanes.

The production pattern: a fixed-shape decode step (jit-compiled once) over
``n_lanes`` sequences; prefill fills a free lane, finished lanes are
recycled mid-flight (continuous batching).  Run-time auto-tuning hooks in
at two points (tuning/dynamic.py):

* decode-kernel variant per *sequence-length bucket* — a ``dynamic select``
  AT region chooses e.g. flash-decode block size / layout per bucket, the
  paper's Sample 6/7 pattern applied to serving;
* prefill chunking for long prompts.

Caches are stacked (L, lanes, ...); per-lane writes use
``jax.tree.map`` + indexed updates so lane recycling never re-compiles.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..models import Model


@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int = 16
    out_tokens: list[int] = field(default_factory=list)
    done: bool = False
    submit_t: float = field(default_factory=time.time)
    first_token_t: float | None = None
    finish_t: float | None = None


@dataclass
class LaneState:
    rid: int | None = None
    pos: int = 0
    remaining: int = 0


def length_bucket(n: int, buckets=(128, 512, 2048, 8192, 32768)) -> int:
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


class ServingEngine:
    def __init__(self, model: Model, params, n_lanes: int = 4,
                 max_len: int = 512, eos_id: int | None = None,
                 decode_fn: Callable | None = None,
                 prefill_fn: Callable | None = None,
                 greedy: bool = True, autotuner=None):
        self.model = model
        self.params = params
        self.n_lanes = n_lanes
        self.max_len = max_len
        self.eos_id = eos_id
        self.lanes = [LaneState() for _ in range(n_lanes)]
        self.caches = model.init_caches(n_lanes, max_len)
        self.queue: list[Request] = []
        self.active: dict[int, Request] = {}
        self.finished: list[Request] = []
        self._decode = decode_fn or jax.jit(model.decode_step)
        self._prefill = prefill_fn or jax.jit(
            model.prefill, static_argnums=(3,))
        # run-time AT hook (repro.at): a tuning/dynamic.DecodeAutoTuner
        # routing each decode step through the per-bucket dynamic select
        # region; None keeps the plain jit'd decode path.
        self.autotuner = autotuner
        self.steps = 0

    # -- admission ---------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for lane_id, lane in enumerate(self.lanes):
            if lane.rid is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            logits, cache1 = self._prefill(
                self.params, jnp.asarray([req.prompt], jnp.int32),
                None, self.max_len)
            # splice the single-sequence cache into this lane
            self.caches = jax.tree.map(
                lambda full, one: _lane_set(full, one, lane_id),
                self.caches, cache1)
            tok = int(jnp.argmax(logits[0]))
            req.out_tokens.append(tok)
            req.first_token_t = time.time()
            lane.rid = req.rid
            lane.pos = len(req.prompt)
            lane.remaining = req.max_new_tokens - 1
            self.active[req.rid] = req

    # -- one decode step over all lanes -------------------------------------
    def step(self) -> None:
        self._admit()
        if not self.active:
            return
        token = np.zeros((self.n_lanes, 1), np.int32)
        pos = np.zeros((self.n_lanes,), np.int32)
        for i, lane in enumerate(self.lanes):
            if lane.rid is not None:
                req = self.active[lane.rid]
                token[i, 0] = req.out_tokens[-1]
                pos[i] = lane.pos
        if self.autotuner is not None:
            kv_len = int(pos.max()) + 1
            logits, self.caches = self.autotuner.decode(
                kv_len, self.params, self.caches, jnp.asarray(token),
                jnp.asarray(pos))
        else:
            logits, self.caches = self._decode(
                self.params, self.caches, jnp.asarray(token),
                jnp.asarray(pos))
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        self.steps += 1
        for i, lane in enumerate(self.lanes):
            if lane.rid is None:
                continue
            req = self.active[lane.rid]
            tok = int(nxt[i])
            req.out_tokens.append(tok)
            lane.pos += 1
            lane.remaining -= 1
            if lane.remaining <= 0 or tok == self.eos_id \
                    or lane.pos >= self.max_len - 1:
                req.done = True
                req.finish_t = time.time()
                self.finished.append(req)
                del self.active[lane.rid]
                self.lanes[i] = LaneState()

    def run(self, max_steps: int = 1000) -> list[Request]:
        while (self.queue or self.active) and self.steps < max_steps:
            self.step()
        return self.finished


def _lane_set(full: jax.Array, one: jax.Array, lane: int) -> jax.Array:
    """Write a batch-1 cache leaf into lane ``lane`` of the stacked cache.

    Leaves are (L, B, ...) (layer-stacked) or (napp, B, ...); the batch
    axis is axis 1.
    """
    if one.shape[1] == full.shape[1]:      # already full-width (rare)
        return one.astype(full.dtype)
    src = one[:, 0]
    # pad/crop trailing dims (prefill cache len == prompt len)
    dst_shape = full.shape[2:]
    pads = []
    slices = [slice(None)] * src.ndim
    for i, (s, d) in enumerate(zip(src.shape[1:], dst_shape)):
        if s < d:
            pads.append((0, d - s))
        else:
            pads.append((0, 0))
            slices[i + 1] = slice(0, d)
    src = src[tuple(slices)]
    if any(p != (0, 0) for p in pads):
        src = jnp.pad(src, [(0, 0)] + pads)
    return full.at[:, lane].set(src.astype(full.dtype))
