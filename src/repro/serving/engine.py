"""Serving engine: the orchestration layer of the serving stack.

The engine wires four components together and owns none of their policy:

* :mod:`~repro.serving.scheduler` — FIFO admission queues + preemption
  decisions (continuous batching over fixed decode lanes);
* :mod:`~repro.serving.kvcache` — the KV backend: ``dense`` per-lane
  strips or ``paged`` block allocation with host swap;
* :mod:`~repro.serving.metrics` — TTFT / inter-token latency / throughput
  aggregation over finished requests;
* the decode dispatch — a jit'd fixed-shape decode step, optionally routed
  through a :class:`~repro.tuning.dynamic.DecodeAutoTuner` whose
  per-length-bucket ``dynamic select`` regions pick the decode variant at
  run time (the paper's Sample 6/7 pattern applied to serving).

With the paged backend the engine *serves* more concurrent requests than
it has decode lanes: queued work triggers time-slice preemption, the
victim's pages are swapped to host memory, and the sequence later resumes
by swap-in — no prefill re-run, bit-identical continuation.

With ``prefill_chunk=N`` (paged only) prompts stream into the KV cache
N tokens per scheduler tick instead of prefilling monolithically at
admission: each tick runs one prefill chunk per mid-prefill lane, then
one batched decode step over the decoding lanes — long prompts stop
head-of-line-blocking short requests (chunked prefill / continuous
batching; see docs/SERVING.md for the tick anatomy).

With ``prefix_cache=True`` (paged + chunked prefill) admissions first
match the prompt against the pool's content-addressed prefix index: the
lane's leading page-table rows are seeded with the shared pages
(refcounted, copy-on-write) and chunked prefill starts at the first
uncached token — a request behind an identical system prompt skips that
prompt's prefill entirely, and TTFT drops by exactly the skipped chunks.
Each committed full chunk-page is published back into the index.
Outputs are bit-identical with caching on or off.

With ``spec_k=k`` plus a draft model (paged only) the decode step is
*speculative*: a small draft proposes up to k tokens per tick, the
target scores all k+1 positions in ONE verify call (the chunked-prefill
kernel over ``[last token, draft_1..draft_k]``), and the accept/reject
rule emits 1..k+1 tokens per tick — decode throughput stops being
bounded by one paged-attention dispatch per emitted token.  Rejected
drafts' KV rolls back via ``PagedKVCache.truncate_to``.  Token choice
everywhere (greedy or sampled) routes through per-request
:class:`~repro.serving.sampling.SamplingParams`; greedy speculative
output is bit-identical to plain greedy decode.
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from . import sampling
from .buckets import LENGTH_BUCKETS
from .kvcache import DenseKVCache, PagedKVCache, make_kv_cache
from .metrics import ServingMetrics
from .scheduler import LaneState, Request, Scheduler

__all__ = ["ServingEngine", "Request", "LaneState", "TickWork",
           "length_bucket"]


def length_bucket(n: int, buckets=LENGTH_BUCKETS) -> int:
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


@dataclass
class TickWork:
    """One dispatched-but-unmaterialised decode tick.

    ``dispatch()`` returns the tick's device work as jax arrays (async
    futures under jax's dispatch model) plus the host-side batch context
    the emission needs.  Nothing has blocked yet: :meth:`block` (or the
    ``np.asarray`` inside :meth:`ServingEngine.emit`) is the first point
    the host waits on the device — which is what lets a pipelined caller
    overlap other host work (network I/O, queue drain) with the device
    compute of the current tick.
    """

    logits: Any                # (n_lanes, 1, V) jax array, un-materialised
    decoding: list[int]        # lane ids in this tick's decode batch
    reqs: list[Request]        # the lanes' requests, same order

    def block(self) -> None:
        """Wait for the tick's device compute (callable off-thread)."""
        jax.block_until_ready(self.logits)


class ServingEngine:
    def __init__(self, model, params, n_lanes: int = 4,
                 max_len: int = 512, eos_id: int | None = None,
                 decode_fn: Callable | None = None,
                 prefill_fn: Callable | None = None,
                 greedy: bool = True, autotuner=None,
                 cache: str = "dense", n_pages: int | None = None,
                 page_size: int = 16, timeslice: int | None = None,
                 prefill_chunk: int | None = None,
                 draft_model=None, draft_params=None,
                 spec_k: int | None = None,
                 prefix_cache: bool = False,
                 prefix_min_match: int = 1,
                 prefix_eviction: str = "lru",
                 kv_dtype: str = "fp",
                 swap_compress: bool = False,
                 mesh=None):
        self.model = model
        self.params = params
        self.n_lanes = n_lanes
        self.max_len = max_len
        self.eos_id = eos_id
        # tensor-parallel serving: a mesh with a multi-device "model" axis
        # shards the page pools' KV-head axis across devices and runs the
        # paged attention ops under shard_map (kernels/ops.py); the mesh
        # is closed over the jit'd step functions below (it is a static
        # hashable, not a traced argument).  A 1-device mesh (or None)
        # takes the unsharded code paths unchanged.
        self.mesh = mesh
        self.kv = make_kv_cache(model, cache, n_lanes, max_len,
                                n_pages=n_pages, page_size=page_size,
                                prefix_cache=prefix_cache,
                                prefix_min_match=prefix_min_match,
                                prefix_eviction=prefix_eviction,
                                kv_dtype=kv_dtype,
                                swap_compress=swap_compress,
                                mesh=mesh)
        if prefill_chunk is not None and self.kv.kind != "paged":
            raise ValueError(
                "chunked prefill streams the prompt into the paged KV "
                "cache; use cache='paged' (dense keeps monolithic prefill)")
        if prefix_cache and prefill_chunk is None:
            raise ValueError(
                "prefix caching admits at the first uncached token via "
                "chunked prefill; pass prefill_chunk")
        self.prefill_chunk = prefill_chunk
        self.scheduler = Scheduler(n_lanes, timeslice=timeslice)
        self.metrics = ServingMetrics()
        step_fn = model.paged_decode_step if self.kv.kind == "paged" \
            else model.decode_step
        if mesh is not None and self.kv.kind == "paged":
            step_fn = functools.partial(step_fn, mesh=mesh)
        self._decode = decode_fn or jax.jit(step_fn)
        prefill_base = model.prefill if mesh is None \
            else functools.partial(model.prefill, mesh=mesh)
        self._prefill = prefill_fn or jax.jit(
            prefill_base, static_argnums=(3,))
        if prefill_chunk is not None:
            chunk_fn = model.paged_prefill_step if mesh is None \
                else functools.partial(model.paged_prefill_step, mesh=mesh)
            self._prefill_step = jax.jit(chunk_fn)
        # -- speculative decoding ------------------------------------------
        self.spec_k = spec_k
        self.draft_model = draft_model
        self.draft_params = draft_params
        if spec_k is not None:
            if spec_k < 1:
                raise ValueError("spec_k must be >= 1")
            if draft_model is None or draft_params is None:
                raise ValueError(
                    "speculative decoding needs a draft model: pass "
                    "draft_model + draft_params (see ArchConfig."
                    "draft_config / Model.slice_draft_params)")
            if self.kv.kind != "paged":
                raise ValueError(
                    "speculative decoding verifies drafts through the "
                    "paged chunk kernel; use cache='paged'")
            # the draft keeps plain dense per-lane KV strips: rollback is
            # a position reset (stale KV past the committed point is
            # masked by kv_len and overwritten by the next draft), and a
            # preempted lane just rebuilds by draft-prefill on resume —
            # the draft's KV never needs to swap with the sequence
            self.draft_kv = DenseKVCache(draft_model, n_lanes, max_len)
            self.draft_pos = [0] * n_lanes   # tokens in the draft's cache
            verify_fn = model.speculative_step if mesh is None \
                else functools.partial(model.speculative_step, mesh=mesh)
            self._verify = jax.jit(verify_fn)
            self._draft_decode = jax.jit(draft_model.decode_step)
            self._draft_prefill = jax.jit(draft_model.prefill,
                                          static_argnums=(3,))
        # run-time AT hook (repro.at): a tuning/dynamic.DecodeAutoTuner
        # routing each decode step through the per-bucket dynamic select
        # region (and, when chunked prefill is on, each prefill chunk
        # through the per-(prompt-bucket x chunk) prefill region; with
        # speculation, each verify through the per-bucket SpecBucket
        # region); None keeps the plain jit'd paths.
        self.autotuner = autotuner
        self.active: dict[int, Request] = {}
        self.finished: list[Request] = []
        self.cancelled: list[Request] = []
        self.steps = 0
        self.prefill_chunks = 0          # chunk-steps executed (chunked)
        self.spec_ticks = 0              # speculative ticks executed
        self.drafted_tokens = 0          # draft tokens offered to verify
        self.accepted_tokens = 0         # draft tokens accepted

    # -- compat views -------------------------------------------------------
    @property
    def lanes(self) -> list[LaneState]:
        return self.scheduler.lanes

    @property
    def queue(self):
        return self.scheduler.waiting

    @property
    def caches(self):
        return self.kv.caches

    # -- admission ---------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.scheduler.submit(req)

    def _finish(self, lane_id: int, req: Request, now: float) -> None:
        req.done = True
        req.finish_t = now
        self.finished.append(req)
        self.metrics.observe(req)
        self.active.pop(req.rid, None)
        self.kv.release(lane_id)
        self.scheduler.vacate(lane_id)

    def cancel_request(self, rid: int) -> bool:
        """Abort a request wherever it lives — queued, preempted, or on a
        lane — releasing its lane and KV pages (refcounts on shared prefix
        pages drop cleanly; the pool's three-state accounting balances).

        The client-disconnect path of the gateway.  Cancelled requests do
        NOT feed the latency metrics (a half-served stream has no honest
        TTFT/ITL) and are tracked in :attr:`cancelled` instead of
        :attr:`finished`.  Must be called at a tick boundary, never
        between :meth:`dispatch` and :meth:`emit` — the pending tick's
        batch context still references the lane.  Returns False when
        ``rid`` is unknown (already finished or never submitted).
        """
        req = self.active.pop(rid, None)
        if req is not None:
            lane_id = next(i for i, l in enumerate(self.scheduler.lanes)
                           if l.rid == rid)
            self.kv.release(lane_id)
            self.scheduler.vacate(lane_id)
            self._reset_draft(lane_id)
        else:
            req = self.scheduler.remove_queued(rid)
            if req is None:
                return False
        req.done = True
        req.cancelled = True
        req.finish_t = time.monotonic()
        self.cancelled.append(req)
        return True

    def _is_eos(self, tok: int) -> bool:
        """Explicit EOS guard: ``eos_id=0`` is a valid stop token and
        ``eos_id=None`` disables EOS stopping entirely."""
        return self.eos_id is not None and tok == self.eos_id

    def _next_token(self, req: Request, logits) -> int:
        """Pick the request's next token from one logits row through its
        sampling params (greedy = exact argmax), keyed by the emission
        index so fixed seeds are independent of lane/batch layout."""
        return sampling.sample_token(np.asarray(logits), req.sampling,
                                     len(req.out_tokens))

    def _reset_draft(self, lane_id: int) -> None:
        """Invalidate the draft's cache for a (re)occupied lane; the next
        speculative tick rebuilds it with one draft prefill."""
        if self.spec_k is not None:
            self.draft_pos[lane_id] = 0

    def _seed_prefix(self, lane_id: int, req: Request) -> int:
        """Match + seed one admission through the prefix cache, routed
        through the ``PrefixPolicy`` dynamic-select region when a tuner
        declares one (the region's alternatives apply their
        (min-match x eviction) knobs before seeding; outputs are
        bit-identical under every policy, so the region measures freely).
        Returns the prefill start position and stamps the request."""
        if not getattr(self.kv, "prefix_cache", False):
            return 0
        if self.autotuner is not None \
                and getattr(self.autotuner, "prefix_region", None) \
                is not None:
            out = self.autotuner.prefix_policy(self.kv, lane_id,
                                               req.prompt)
            cached = out["cached"] if isinstance(out, dict) else int(out)
        else:
            cached = self.kv.seed_prefix(lane_id, req.prompt)
        req.cached_tokens = cached
        return cached

    def _preempt_lane(self, lane_id: int, priority: bool = False) -> None:
        lane = self.scheduler.lanes[lane_id]
        req = self.active.pop(lane.rid)
        handle = self.kv.swap_out(lane_id)
        self.scheduler.preempt(lane_id, req, handle, priority=priority)

    def _admit(self) -> None:
        for lane_id in self.scheduler.free_lanes():
            nxt = self.scheduler.next_admission()
            if nxt is None:
                return
            kind, item = nxt
            if kind == "resume":
                if not self.kv.swap_in(lane_id, item.handle):
                    self.scheduler.push_back(kind, item)
                    return                 # no pages yet; retry next step
                self.scheduler.occupy(lane_id, item.req, item.pos,
                                      item.remaining, phase=item.phase)
                self._reset_draft(lane_id)
                self.active[item.req.rid] = item.req
                continue
            req = item
            if self.prefill_chunk is not None:
                # chunked admission: the lane enters prefill phase with no
                # compute — the prefill tick streams the prompt in chunk
                # by chunk.  Gate on pages for the *first chunk* only.
                first = min(self.prefill_chunk, len(req.prompt))
                if not self.kv.can_admit(first):
                    self.scheduler.push_back(kind, req)
                    return                 # page pressure; stay queued
                # prefix caching: match the prompt against the hash index
                # and seed the lane's leading page-table rows with the
                # shared pages — prefill then starts at the first uncached
                # token (TTFT shrinks by exactly the skipped chunks)
                cached = self._seed_prefix(lane_id, req)
                if req.admit_t is None:
                    req.admit_t = time.monotonic()   # queue wait ends here
                self.scheduler.occupy(lane_id, req, cached,
                                      req.max_new_tokens, phase="prefill")
                self._reset_draft(lane_id)
                self.active[req.rid] = req
                continue
            if isinstance(self.kv, PagedKVCache) \
                    and not self.kv.can_admit(len(req.prompt)):
                self.scheduler.push_back(kind, req)
                return                     # page pressure; stay queued
            if req.admit_t is None:
                req.admit_t = time.monotonic()   # queue wait ends here
            plen = self.kv.prefill_len(len(req.prompt))
            logits, cache1 = self._prefill(
                self.params, jnp.asarray([req.prompt], jnp.int32),
                None, plen)
            if not self.kv.admit(lane_id, cache1, len(req.prompt)):
                self.scheduler.push_back(kind, req)
                return
            tok = self._next_token(req, logits[0])
            now = time.monotonic()
            req.out_tokens.append(tok)
            req.first_token_t = now
            req.token_ts.append(now)
            self.scheduler.occupy(lane_id, req, len(req.prompt),
                                  req.max_new_tokens - 1)
            self._reset_draft(lane_id)
            self.active[req.rid] = req
            if req.max_new_tokens <= 1 or self._is_eos(tok):
                self._finish(lane_id, req, now)

    def _prefill_tick(self) -> None:
        """One prefill chunk for every mid-prefill lane.

        Each lane streams ``prefill_chunk`` prompt tokens into its paged
        KV cache per tick (pages allocated chunk-granularly, the ragged
        last chunk padded into the null page), so long prompts never
        head-of-line-block the decode step that follows in the same tick.
        The final chunk's last-valid-token logits seed decode — that is
        the request's first token (TTFT stamps here).
        """
        if self.prefill_chunk is None:
            return
        c = self.prefill_chunk
        for lane_id in self.scheduler.prefill_lanes():
            lane = self.scheduler.lanes[lane_id]
            req = self.active[lane.rid]
            plen = len(req.prompt)
            start, end = lane.pos, min(lane.pos + c, plen)
            # the COW guard covers the seeded-prefix edge: a fully-cached
            # page-aligned prompt starts prefill at plen-1, *inside* the
            # last shared page, which must be privately copied before the
            # recomputed KV write lands
            if not self.kv.ensure_tokens(lane_id, end) \
                    or not self.kv.cow_writable(lane_id, start):
                if len(self.active) == 1:
                    raise RuntimeError(
                        f"page pool too small: sequence {lane.rid} needs "
                        f"pages for prompt positions [{start}, {end}) and "
                        "no other lane can be evicted")
                self._preempt_lane(lane_id, priority=True)
                continue
            chunk = req.prompt[start:end] + [0] * (c - (end - start))
            args = (self.params, self.kv.caches, self.kv.table_row(lane_id),
                    jnp.asarray([chunk], jnp.int32),
                    jnp.asarray([start], jnp.int32),
                    jnp.asarray([end], jnp.int32),
                    jnp.asarray([end - start - 1], jnp.int32))
            if self.autotuner is not None \
                    and getattr(self.autotuner, "prefill_regions", None):
                logits, new_caches = self.autotuner.prefill(plen, c, *args)
            else:
                logits, new_caches = self._prefill_step(*args)
            self.kv.caches = new_caches
            self.prefill_chunks += 1
            lane.pos = end
            # every newly-FULL committed chunk-page becomes a shared,
            # content-addressed index entry other admissions can hit
            self.kv.publish_prefix(lane_id, req.prompt, end)
            if end < plen:
                continue                   # prompt still streaming in
            tok = self._next_token(req, logits[0])
            now = time.monotonic()
            req.out_tokens.append(tok)
            req.first_token_t = now
            req.token_ts.append(now)
            lane.phase = "decode"
            lane.remaining = req.max_new_tokens - 1
            if req.max_new_tokens <= 1 or self._is_eos(tok):
                self._finish(lane_id, req, now)

    def _ensure_capacity(self) -> None:
        """Pre-decode page check: every decoding lane must own the page its
        next token writes to; a lane that cannot allocate one is preempted
        (its pages swap out, freeing room for the rest).  Mid-prefill lanes
        are skipped — the prefill tick does its own chunk-granular
        allocation."""
        for lane_id in self.scheduler.decode_lanes():
            lane = self.scheduler.lanes[lane_id]
            if self.kv.ensure_capacity(lane_id, lane.pos):
                continue
            if len(self.active) == 1:
                raise RuntimeError(
                    f"page pool too small: sequence {lane.rid} needs "
                    f"another page at pos {lane.pos} and no other lane "
                    "can be evicted")
            self._preempt_lane(lane_id, priority=True)

    # -- speculative decode: draft, verify, accept, roll back ---------------
    def _spec_capacity(self) -> tuple[dict[int, int], int]:
        """Per-lane effective draft length for this tick, plus the
        sequence-length key the tick's SpecBucket routing must reuse.

        ``k_eff`` is ``spec_k`` clamped by the lane's remaining token
        budget (a tick may emit at most ``remaining`` tokens), by
        ``max_len`` (the verify step writes KV at ``pos .. pos + k_eff``),
        and by page availability — on page pressure the chunk shrinks
        toward a plain decode step before the lane is evicted.

        The key is computed ONCE here and returned so that the region
        that capped the drafting is exactly the region that verifies it
        — recomputing it after this loop's page-pressure preemptions
        could land on a different bucket.
        """
        seq = max((self.scheduler.lanes[j].pos + 1
                   for j in self.scheduler.decode_lanes()), default=1)
        cap = self.spec_k
        if self.autotuner is not None \
                and getattr(self.autotuner, "spec_regions", None):
            # once the bucket's region has committed, stop drafting past
            # the winner's accept window — those draft-decode steps buy
            # tokens the committed verify would never even look at
            cap = self.autotuner.spec_draft_k(seq, self.spec_k)
        k_eff: dict[int, int] = {}
        for i in list(self.scheduler.decode_lanes()):
            lane = self.scheduler.lanes[i]
            ke = max(0, min(cap, lane.remaining - 1,
                            self.max_len - 1 - lane.pos))
            while ke >= 0 and not self.kv.ensure_tokens(
                    i, lane.pos + ke + 1):
                ke -= 1
            if ke < 0:
                if len(self.active) == 1:
                    raise RuntimeError(
                        f"page pool too small: sequence {lane.rid} needs "
                        f"another page at pos {lane.pos} and no other "
                        "lane can be evicted")
                self._preempt_lane(i, priority=True)
                continue
            k_eff[i] = ke
        return k_eff, seq

    def _draft_propose(self, k_eff: dict[int, int]
                       ) -> tuple[dict, dict]:
        """Propose up to ``k_eff[i]`` draft tokens per decoding lane.

        The draft's dense cache trails the committed sequence; a lane
        whose cache is empty or far behind (fresh admission, resume after
        preemption) catches up with ONE draft prefill over the committed
        tokens, otherwise the 1-2 missing tokens (the previous tick's
        un-fed last draft and/or bonus token) feed through the batched
        draft decode step along with the proposals themselves.  Lanes
        with nothing to feed ride the fixed-shape batch masked to the
        dead slot ``max_len - 1`` (never read: live lanes finish at
        ``max_len - 2``).  Returns ({lane: [draft tokens]},
        {lane: [draft probs]}).
        """
        drafts: dict[int, list[int]] = {i: [] for i in k_eff}
        dprobs: dict[int, list] = {i: [] for i in k_eff}
        pending: dict[int, list[int]] = {}
        rngs: dict[int, np.random.Generator] = {}
        sps = {}
        for i, ke in k_eff.items():
            if ke == 0:
                continue
            lane = self.scheduler.lanes[i]
            req = self.active[lane.rid]
            sps[i] = req.sampling
            rngs[i] = sampling.draft_rng(req.sampling, len(req.out_tokens))
            all_toks = req.prompt + req.out_tokens   # len == lane.pos + 1
            gap = len(all_toks) - self.draft_pos[i]
            if self.draft_pos[i] == 0 or gap > 2:
                # variable-length trace, like the admission prefill: one
                # retrace per distinct committed length (known cost; a
                # fixed-shape draft catch-up would need a logit_idx-style
                # padded prefill)
                logits_d, dcache = self._draft_prefill(
                    self.draft_params,
                    jnp.asarray([all_toks], jnp.int32), None, self.max_len)
                self.draft_kv.admit(i, dcache, len(all_toks))
                self.draft_pos[i] = len(all_toks)
                tok, q = sampling.propose_token(
                    np.asarray(logits_d[0]), sps[i], rngs[i])
                drafts[i].append(tok)
                dprobs[i].append(q)
                pending[i] = []
            else:
                pending[i] = all_toks[self.draft_pos[i]:]
        while True:
            feed: dict[int, int] = {}
            for i, ke in k_eff.items():
                if ke == 0:
                    continue
                if pending[i]:
                    feed[i] = pending[i][0]
                elif 0 < len(drafts[i]) < ke:
                    feed[i] = drafts[i][-1]   # extend the draft chain
            if not feed:
                break
            token = np.zeros((self.n_lanes, 1), np.int32)
            pos = np.full((self.n_lanes,), self.max_len - 1, np.int32)
            for i, t in feed.items():
                token[i, 0] = t
                pos[i] = self.draft_pos[i]
            logits_d, self.draft_kv.caches = self._draft_decode(
                self.draft_params, self.draft_kv.caches,
                jnp.asarray(token), jnp.asarray(pos))
            logits_np = np.asarray(logits_d)
            for i in feed:
                self.draft_pos[i] += 1
                if pending[i]:
                    pending[i].pop(0)
                    if pending[i]:
                        continue           # still catching up
                tok, q = sampling.propose_token(logits_np[i], sps[i],
                                                rngs[i])
                drafts[i].append(tok)
                dprobs[i].append(q)
        return drafts, dprobs

    def _spec_tick(self) -> None:
        """One speculative decode tick over the decoding lanes.

        Draft proposes, the target verifies the whole candidate chunk in
        one batched ``speculative_step`` (KV for every candidate is
        written into the pages), the accept rule emits 1..k+1 tokens per
        lane, and ``truncate_to`` returns the pages past the committed
        point to the pool.  Mid-prefill lanes ride along masked to the
        null page exactly as in the plain decode step.
        """
        k_eff, seq = self._spec_capacity()
        decoding = self.scheduler.decode_lanes()
        if not decoding:
            return
        drafts, dprobs = self._draft_propose(k_eff)
        c = self.spec_k + 1
        tokens = np.zeros((self.n_lanes, c), np.int32)
        start = np.zeros((self.n_lanes,), np.int32)
        kv_len = np.zeros((self.n_lanes,), np.int32)
        for i in decoding:
            lane = self.scheduler.lanes[i]
            req = self.active[lane.rid]
            row = [req.out_tokens[-1]] + drafts[i]
            tokens[i, :len(row)] = row
            start[i] = lane.pos
            kv_len[i] = lane.pos + len(row)
        extra = self.kv.decode_extra(
            mask_lanes=self.scheduler.prefill_lanes())
        args = (self.params, self.kv.caches, *extra,
                jnp.asarray(tokens), jnp.asarray(start),
                jnp.asarray(kv_len))
        if self.autotuner is not None \
                and getattr(self.autotuner, "spec_regions", None):
            # seq is the key _spec_capacity capped the drafting with —
            # reusing it (not recomputing post-preemption) keeps the
            # capping and verifying region the same
            out = self.autotuner.spec(
                seq, *args, measure=not self.autotuner.spec_committed(seq))
            if isinstance(out, dict):
                # tuned variants return an env dict so the region can
                # commit on time_per_token rather than raw call latency
                logits, new_caches = out["logits"], out["caches"]
            else:
                logits, new_caches = out
        else:
            logits, new_caches = self._verify(*args)
        self.kv.caches = new_caches
        logits_np = np.asarray(logits)
        # a tuner variant may verify a narrower chunk (tuned k): drafts
        # past its window are auto-rejected — their KV was never written
        window_max = logits_np.shape[1] - 1
        now = time.monotonic()
        self.steps += 1
        self.spec_ticks += 1
        for i in decoding:
            lane = self.scheduler.lanes[i]
            req = self.active[lane.rid]
            w = min(len(drafts[i]), window_max)
            emitted, n_acc = sampling.speculative_accept(
                drafts[i][:w], dprobs[i][:w], logits_np[i, :w + 1],
                req.sampling, len(req.out_tokens))
            self.drafted_tokens += w
            self.accepted_tokens += n_acc
            committed = lane.pos + n_acc + 1
            self.kv.truncate_to(i, committed)
            emit = []
            for tok in emitted:
                emit.append(tok)
                if self._is_eos(tok):
                    break
            req.out_tokens.extend(emit)
            req.token_ts.extend([now] * len(emit))
            lane.pos = committed
            lane.remaining -= len(emit)
            lane.steps_served += 1
            lane.tokens_served += len(emit)
            self.draft_pos[i] = min(self.draft_pos[i], committed)
            if lane.remaining <= 0 or self._is_eos(emit[-1]) \
                    or lane.pos >= self.max_len - 1:
                self._finish(i, req, now)

    def spec_stats(self) -> dict:
        """Speculation counters (zeros when speculation is off)."""
        return {
            "spec_k": self.spec_k,
            "spec_ticks": self.spec_ticks,
            "drafted_tokens": self.drafted_tokens,
            "accepted_tokens": self.accepted_tokens,
            "accept_rate": (self.accepted_tokens / self.drafted_tokens
                            if self.drafted_tokens else 0.0),
        }

    def tuning_db(self) -> dict | None:
        """Identity of the tuning DB feeding this engine's AT regions —
        backend name, path, record count and any golden overlay (``None``
        when serving untuned).  Surfaces which durability layer the
        committed winners live in, next to the winners themselves in the
        serve report."""
        session = getattr(self.autotuner, "session", None)
        if session is None:
            return None
        return session.records.describe()

    # -- one scheduler tick: schedule -> dispatch -> emit --------------------
    # step() is the synchronous composition; the gateway's pipelined loop
    # calls the three phases itself so the host can do other work (drain
    # arrivals, flush token streams over the network) between dispatch and
    # emit, while the device computes the tick.
    def schedule(self) -> None:
        """Host-side scheduling half of a tick: time-slice victim, queue
        admissions, prefill chunks (their device work dispatches async;
        only a final chunk's token emission materialises)."""
        victim = self.scheduler.pick_victim()
        if victim is not None:
            self._preempt_lane(victim)
        self._admit()
        self._prefill_tick()

    def dispatch(self) -> TickWork | None:
        """Dispatch the tick's batched decode step without waiting on it.

        ``kv.caches`` is advanced to the (asynchronously computing) output
        caches immediately, so any later work composes on the right value;
        the logits stay un-materialised inside the returned
        :class:`TickWork` until :meth:`emit`.  Speculative ticks run
        internally (their accept/reject rule is host-side by nature) and
        return None, as does a tick with no decoding lanes.
        """
        if self.spec_k is not None:
            self._spec_tick()
            return None
        self._ensure_capacity()
        decoding = self.scheduler.decode_lanes()
        if not decoding:
            return None
        token = np.zeros((self.n_lanes, 1), np.int32)
        pos = np.zeros((self.n_lanes,), np.int32)
        for i in decoding:
            lane = self.scheduler.lanes[i]
            req = self.active[lane.rid]
            token[i, 0] = req.out_tokens[-1]
            pos[i] = lane.pos
        # mid-prefill lanes ride along in the fixed-shape batched step with
        # a zeroed page-table row: their dummy KV write lands in the null
        # page, never in their live prefill pages
        extra = self.kv.decode_extra(
            mask_lanes=self.scheduler.prefill_lanes())
        args = (self.params, self.kv.caches, *extra,
                jnp.asarray(token), jnp.asarray(pos))
        if self.autotuner is not None:
            kv_len = int(pos.max()) + 1
            logits, new_caches = self.autotuner.decode(kv_len, *args)
        else:
            logits, new_caches = self._decode(*args)
        self.kv.caches = new_caches
        reqs = [self.active[self.scheduler.lanes[i].rid] for i in decoding]
        return TickWork(logits=logits, decoding=decoding, reqs=reqs)

    def emit(self, work: TickWork | None) -> None:
        """Materialise a dispatched tick's logits (the first host-device
        sync of the tick), sample each lane's token, and run the finish
        bookkeeping.  No-op for ``None`` (spec/idle ticks)."""
        if work is None:
            return
        logits_np = np.asarray(work.logits)
        toks = sampling.sample_batch(
            logits_np[work.decoding], [r.sampling for r in work.reqs],
            [len(r.out_tokens) for r in work.reqs])
        now = time.monotonic()
        self.steps += 1
        for i, req, tok in zip(work.decoding, work.reqs, toks):
            lane = self.scheduler.lanes[i]
            req.out_tokens.append(tok)
            req.token_ts.append(now)
            lane.pos += 1
            lane.remaining -= 1
            lane.steps_served += 1
            lane.tokens_served += 1
            if lane.remaining <= 0 or self._is_eos(tok) \
                    or lane.pos >= self.max_len - 1:
                self._finish(i, req, now)

    def step(self) -> None:
        self.schedule()
        self.emit(self.dispatch())

    def run(self, max_steps: int = 1000) -> list[Request]:
        while (self.scheduler.has_queued or self.active) \
                and self.steps < max_steps:
            steps_before, done_before = self.steps, len(self.finished)
            self.step()
            if not self.active and self.scheduler.has_queued \
                    and self.steps == steps_before \
                    and len(self.finished) == done_before:
                raise RuntimeError(
                    "admission stalled: queued work cannot obtain a lane "
                    "or pages (page pool smaller than one sequence?)")
        return self.finished
