"""Launchers: mesh construction, multi-pod dry-run, roofline, train, serve.

NOTE: never import .dryrun from tests — it force-sets the XLA device count.
"""
