"""Step-function builders: the exact functions the dry-run lowers and the
trainers/servers execute.

``build_train_step`` composes loss -> grad -> (microbatched accumulation)
-> AdamW; ``build_prefill_step`` / ``build_decode_step`` are the serving
entry points.  All are pure functions suitable for ``jax.jit`` with
explicit in/out shardings.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeConfig
from ..models import Model, use_plan
from ..models.sharding_ctx import LayoutPlan
from ..optim import adamw


@dataclass(frozen=True)
class StepBundle:
    fn: Callable
    donate: tuple = ()


def build_train_step(model: Model, plan: LayoutPlan,
                     opt_cfg: adamw.AdamWConfig,
                     param_shardings=None) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    ``param_shardings`` (a NamedSharding pytree) pins the microbatch
    gradient accumulator to the parameter layout: each microbatch's dW then
    lowers to a *reduce-scatter* onto the shard instead of a full fp32
    all-reduce — 1/model_axis of the wire bytes (§Perf hillclimb).
    """
    nmb = max(plan.num_microbatches, 1)

    def loss_fn(params, batch):
        with use_plan(plan):
            return model.train_loss(params, batch)

    def constrain_grads(g):
        if param_shardings is None:
            return g
        return jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(x, s), g,
            param_shardings)

    def train_step(params, opt_state, batch):
        if nmb == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            grads = constrain_grads(grads)
        else:
            def reshape(x):
                b = x.shape[0]
                return x.reshape((nmb, b // nmb) + x.shape[1:])

            micro = jax.tree.map(reshape, batch)

            def acc_step(carry, mb):
                loss_acc, g_acc = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                g = constrain_grads(g)
                return (loss_acc + l,
                        constrain_grads(jax.tree.map(jnp.add, g_acc, g))), \
                    None

            zeros = constrain_grads(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (loss, grads), _ = jax.lax.scan(
                acc_step, (jnp.float32(0.0), zeros), micro)
            loss = loss / nmb
            grads = jax.tree.map(lambda g: g / nmb, grads)
        new_params, new_opt, metrics = adamw.update(
            grads, opt_state, params, opt_cfg)
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    return train_step


def build_eval_loss(model: Model, plan: LayoutPlan) -> Callable:
    def eval_loss(params, batch):
        with use_plan(plan):
            return model.train_loss(params, batch)

    return eval_loss


def build_prefill_step(model: Model, plan: LayoutPlan) -> Callable:
    def prefill_step(params, batch):
        with use_plan(plan):
            return model.prefill(params, batch["tokens"],
                                 batch.get("frontend_embeds"))

    return prefill_step


def build_decode_step(model: Model, plan: LayoutPlan) -> Callable:
    def decode_step(params, caches, token, pos):
        with use_plan(plan):
            return model.decode_step(params, caches, token, pos)

    return decode_step


def step_for_shape(model: Model, shape: ShapeConfig, plan: LayoutPlan,
                   opt_cfg: adamw.AdamWConfig | None = None,
                   param_shardings=None) -> Callable:
    if shape.kind == "train":
        return build_train_step(model, plan,
                                opt_cfg or adamw.AdamWConfig(),
                                param_shardings=param_shardings)
    if shape.kind == "prefill":
        return build_prefill_step(model, plan)
    return build_decode_step(model, plan)
