"""HLO-text analysis: collective bytes, while-loop trip counts.

``compiled.cost_analysis()`` has no collective term, so we parse the
optimized HLO: every ``all-gather`` / ``all-reduce`` / ``reduce-scatter`` /
``all-to-all`` / ``collective-permute`` occurrence contributes its operand
bytes.  Ops inside ``while`` bodies are counted once by text parsing — the
roofline harness therefore multiplies loop-body contributions by the scan
trip count (extracted from the loop-bound constant) when asked.
"""
from __future__ import annotations

import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                     "all-to-all", "collective-permute")
# result type may be a tuple: (f32[...], f32[...]) = all-reduce(...)
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes_by_kind(hlo_text: str) -> dict[str, float]:
    """Sum result-shape bytes of every collective op in the module text.

    ``-start``/``-done`` async pairs are counted once (the ``-done`` line
    carries no shape payload in most dumps; we match the op name with
    optional suffix and dedupe by line).
    """
    out: dict[str, float] = {k: 0.0 for k in _COLLECTIVE_KINDS}
    seen_done = 0
    for m in _OP_RE.finditer(hlo_text):
        type_str, kind = m.group(1), m.group(2)
        line = hlo_text[m.start():hlo_text.find("\n", m.start())]
        if "-done(" in line:
            seen_done += 1
            continue        # bytes counted at the -start op
        out[kind] += _shape_bytes(type_str)
    out["total"] = sum(out[k] for k in _COLLECTIVE_KINDS)
    return out


def count_ops(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"\b{re.escape(opname)}\(", hlo_text))


# --------------------------------------------------------------------------
# loop-aware module analysis
#
# ``cost_analysis()`` counts a ``while`` body ONCE regardless of trip count
# (verified empirically: an 8-layer lax.scan reports 1 layer of flops), so
# scan-over-layers models under-report by ~L.  We therefore walk the
# optimized HLO per-computation: dot flops and collective bytes are summed
# per computation, and each ``while`` multiplies its body's totals by the
# trip count recovered from the loop condition's comparison constant.
# --------------------------------------------------------------------------

_COMP_HEAD_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^=]*?\)|\S+)\s+"
                     r"([\w\-]+)\(")
_WHILE_LINE_RE = re.compile(
    r"while\(.*?\)\s*,\s*condition=%?([\w.\-]+)\s*,\s*body=%?([\w.\-]+)")
_CALL_RE = re.compile(
    r"(?:fusion|call)\(.*?\),.*?(?:calls|to_apply)=%?([\w.\-]+)")
_CONST_CMP_RE = re.compile(r"constant\((\d+)\)")
_SHAPE_ONLY_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OPERANDS_RE = re.compile(r"\(([^)]*)\)")


def _dims(type_str: str) -> list[int]:
    m = _SHAPE_ONLY_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def split_computations(hlo_text: str) -> dict[str, list[str]]:
    """computation name -> body lines."""
    out: dict[str, list[str]] = {}
    name, buf = None, []
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if name is None and stripped.endswith("{") and "->" in stripped:
            m = _COMP_HEAD_RE.match(stripped)
            if m:
                name = m.group(1)
                buf = []
                continue
        if name is not None and line.startswith("}"):
            out[name] = buf
            name = None
            continue
        if name is not None:
            buf.append(line)
    return out


def _entry_name(hlo_text: str) -> str | None:
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HEAD_RE.match(line[len("ENTRY"):].strip())
            if m:
                return m.group(1)
    return None


def _symbol_types(body: list[str]) -> dict[str, str]:
    table: dict[str, str] = {}
    for line in body:
        m = _DEF_RE.match(line)
        if m:
            table[m.group(1)] = m.group(2)
    return table


def _dot_flops(line: str, table: dict[str, str]) -> float:
    m = _DEF_RE.match(line)
    if not m or m.group(3) != "dot":
        return 0.0
    result_dims = _dims(m.group(2))
    ops_m = _OPERANDS_RE.search(line[m.end(3):])
    contract = 1
    if ops_m:
        lhs = ops_m.group(1).split(",")[0].strip().lstrip("%")
        lhs_dims = _dims(table.get(lhs, ""))
        cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
        if cm and cm.group(1):
            for i in cm.group(1).split(","):
                idx = int(i)
                if idx < len(lhs_dims):
                    contract *= lhs_dims[idx]
    n = 1
    for d in result_dims:
        n *= d
    return 2.0 * n * contract


def _conv_flops(line: str, table: dict[str, str]) -> float:
    m = _DEF_RE.match(line)
    if not m or m.group(3) != "convolution":
        return 0.0
    result_dims = _dims(m.group(2))
    ops_m = _OPERANDS_RE.search(line[m.end(3):])
    if not ops_m:
        return 0.0
    parts = [p.strip().lstrip("%") for p in ops_m.group(1).split(",")]
    if len(parts) < 2:
        return 0.0
    k_dims = _dims(table.get(parts[1], ""))
    n = 1
    for d in result_dims:
        n *= d
    kernel = 1
    for d in k_dims[:-1]:     # all but the output-feature dim (approx)
        kernel *= d
    return 2.0 * n * kernel


def _trip_count(cond_body: list[str]) -> int:
    """Largest comparison constant in the loop condition (jax scans compare
    the induction variable against the trip count)."""
    cs = []
    for line in cond_body:
        cs.extend(int(m.group(1)) for m in _CONST_CMP_RE.finditer(line))
    return max(cs) if cs else 1


def analyze_module(hlo_text: str) -> dict:
    """Loop-aware totals: {'dot_flops', 'collective_bytes': {kind: bytes},
    'while_trips': [...]} — while bodies multiplied by their trip count."""
    comps = split_computations(hlo_text)
    cache: dict[str, tuple[float, dict]] = {}

    def comp_cost(name: str, stack: tuple = ()) -> tuple[float, dict]:
        if name in cache:
            return cache[name]
        if name not in comps or name in stack:
            return 0.0, {}
        body = comps[name]
        table = _symbol_types(body)
        flops = 0.0
        coll: dict[str, float] = {}
        for line in body:
            flops += _dot_flops(line, table)
            flops += _conv_flops(line, table)
            om = _OP_RE.match(line)
            if om and "-done(" not in line:
                coll[om.group(2)] = coll.get(om.group(2), 0.0) \
                    + _shape_bytes(om.group(1))
            wm = _WHILE_LINE_RE.search(line)
            if wm:
                cond, wbody = wm.groups()
                trips = _trip_count(comps.get(cond, []))
                f, c = comp_cost(wbody, stack + (name,))
                flops += trips * f
                for k, v in c.items():
                    coll[k] = coll.get(k, 0.0) + trips * v
                continue
            cm = _CALL_RE.search(line)
            if cm:
                f, c = comp_cost(cm.group(1), stack + (name,))
                flops += f
                for k, v in c.items():
                    coll[k] = coll.get(k, 0.0) + v
        cache[name] = (flops, coll)
        return flops, coll

    entry = _entry_name(hlo_text) or next(iter(comps), None)
    flops, coll = comp_cost(entry) if entry else (0.0, {})
    coll = dict(coll)
    coll["total"] = sum(coll.values())
    trips = []
    for name, body in comps.items():
        for line in body:
            wm = _WHILE_LINE_RE.search(line)
            if wm:
                trips.append(_trip_count(comps.get(wm.group(1), [])))
    return {"dot_flops": flops, "collective_bytes": coll,
            "while_trips": trips}
