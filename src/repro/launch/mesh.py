"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import, and smoke tests/benches must keep seeing 1 device.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 ("data", "model") single pod; 2x16x16 ("pod", "data",
    "model") for the 2-pod = 512-chip dry-run."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> Mesh:
    """Arbitrary mesh (elastic re-mesh path uses this)."""
    return jax.make_mesh(shape, axes)


def make_host_mesh() -> Mesh:
    """1-device mesh for CPU tests/examples."""
    return jax.make_mesh((1, 1), ("data", "model"))


def chips(mesh: Mesh) -> int:
    return mesh.devices.size
