"""First-principles FLOP/byte model per (arch x shape) — the roofline's
memory term and the MODEL_FLOPS yardstick.

Why analytic for bytes: XLA-CPU ``cost_analysis()['bytes accessed']`` models
*CPU* fusion, not TPU HBM traffic, and scan bodies are counted once; the
compiled numbers are still reported for cross-check, but the dominant-term
call uses this model (flash-aware attention traffic, capacity-dispatch MoE,
ring-buffer caches).  FLOPs here are exact einsum counts — they agree with
compiled HLO flops on matmul-dominated graphs to within a few percent.

Conventions: fwd matmul (m, k) @ (k, n) = 2mkn FLOPs; bwd = 2x fwd;
bf16 activations/weights on the wire, fp32 optimizer state.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from ..configs.base import ArchConfig, ShapeConfig

BF16 = 2
F32 = 4


@dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0          # HBM traffic
    detail: dict = field(default_factory=dict)

    def add(self, name: str, flops: float = 0.0, bytes: float = 0.0,
            mult: float = 1.0):
        self.flops += flops * mult
        self.bytes += bytes * mult
        self.detail[name] = {"flops": flops * mult, "bytes": bytes * mult}


def _attn_core(b, h, sq, sk, dh, causal=True, window=None):
    """Flash attention core: FLOPs and HBM traffic (KV streamed per q-block,
    q/out resident once; block_q=128 reuse factor on KV reads)."""
    frac = 1.0
    if window is not None and sk > window:
        frac = min(1.0, window / sk)
    elif causal and sq == sk:
        frac = 0.5
    flops = 4.0 * b * h * sq * sk * dh * frac + 6.0 * b * h * sq * sk * frac
    # traffic: q + out once; kv streamed once per q block-row that needs it
    n_q_blocks = max(sq // 128, 1)
    kv_reads = min(n_q_blocks, max(1.0, n_q_blocks * frac))
    bytes_ = (2 * b * h * sq * dh) * BF16 \
        + (2 * b * h * sk * dh) * BF16 * kv_reads
    return flops, bytes_


def layer_costs(cfg: ArchConfig, b: int, s: int, kind: str,
                decode_cache_len: int = 0) -> Costs:
    """One decoder layer, one *forward* pass over (b, s) tokens."""
    c = Costs()
    d = cfg.d_model
    t = b * s
    fam = cfg.family
    hq = cfg.n_heads * cfg.head_dim
    hkv = cfg.n_kv_heads * cfg.head_dim

    def dense(name, m, k, n, mult=1.0):
        c.add(name, flops=2.0 * m * k * n,
              bytes=(m * k + k * n + m * n) * BF16, mult=mult)

    if fam in ("dense", "vlm", "moe", "encdec"):
        dense("qkv", t, d, hq + 2 * hkv)
        dense("attn_out", t, hq, d)
        if kind == "decode":
            sk = min(decode_cache_len, cfg.window or decode_cache_len)
            fl, by = _attn_core(b, cfg.n_heads, 1, sk, cfg.head_dim,
                                causal=False)
            # decode reads the whole (windowed) cache once
            by = (2 * b * cfg.n_kv_heads * sk * cfg.head_dim) * BF16 \
                + 2 * b * hq * BF16
            c.add("attn_core", fl, by)
        else:
            fl, by = _attn_core(b, cfg.n_heads, s, s, cfg.head_dim,
                                causal=True, window=cfg.window)
            c.add("attn_core", fl, by)
    if fam in ("dense", "vlm", "encdec"):
        dense("mlp", t, d, 3 * cfg.d_ff)
    if fam == "moe":
        c.add("router", flops=2.0 * t * d * cfg.n_experts,
              bytes=(t * d + d * cfg.n_experts) * BF16)
        # capacity dispatch: top_k * capacity_factor tokens hit experts
        eff_t = t * cfg.top_k * cfg.capacity_factor
        dense("experts", eff_t, d, 3 * cfg.d_ff)
        # dispatch/combine einsums + all-to-all staging
        cap_elems = eff_t * d
        c.add("dispatch", flops=4.0 * cap_elems,
              bytes=4 * cap_elems * BF16)
        if cfg.n_shared_experts:
            dense("shared_expert", t, d,
                  3 * cfg.d_ff * cfg.n_shared_experts)
    if fam == "ssm" and cfg.ssm_version == 1:
        di, n = cfg.ssm_d_inner, cfg.ssm_state
        r = max(d // 16, 1)
        dense("in_proj", t, d, 2 * di)
        dense("x_proj", t, di, r + 2 * n)
        dense("dt_proj", t, r, di)
        dense("out_proj", t, di, d)
        # selective scan: ~10 flops per (t, di, n) cell
        c.add("scan", flops=10.0 * t * di * n,
              bytes=(4 * t * di + 2 * t * n) * BF16 + t * di * BF16)
        c.add("conv", flops=2.0 * t * di * 4, bytes=2 * t * di * BF16)
    if fam == "hybrid" and cfg.ssm_version == 2:
        di, n = cfg.ssm_d_inner, cfg.ssm_state
        h = cfg.ssm_n_heads
        q = cfg.ssm_chunk
        dense("in_proj", t, d, 2 * di + 2 * n + h)
        dense("out_proj", t, di, d)
        c.add("conv", flops=2.0 * t * (di + 2 * n) * 4,
              bytes=2 * t * (di + 2 * n) * BF16)
        if kind == "decode":
            c.add("ssd_step", flops=6.0 * b * h * n * cfg.ssm_head_dim,
                  bytes=2 * b * h * n * cfg.ssm_head_dim * F32)
        else:
            nc = max(s // q, 1)
            p = cfg.ssm_head_dim
            intra = 2.0 * b * nc * q * q * (n + h * p) + 3.0 * b * nc * h * q * q
            states = 4.0 * b * nc * q * h * n * p
            c.add("ssd", flops=intra + states,
                  bytes=(3 * t * di + 2 * t * n) * BF16
                  + 2 * b * nc * h * n * p * F32)
    return c


def shared_attn_costs(cfg: ArchConfig, b: int, s: int, kind: str,
                      cache_len: int = 0) -> Costs:
    """zamba2 shared attention+MLP block (one application)."""
    c = Costs()
    d = cfg.d_model
    t = b * s
    hq = cfg.n_heads * cfg.head_dim
    hkv = cfg.n_kv_heads * cfg.head_dim

    def dense(name, m, k, n):
        c.add(name, flops=2.0 * m * k * n,
              bytes=(m * k + k * n + m * n) * BF16)

    dense("qkv", t, d, hq + 2 * hkv)
    dense("attn_out", t, hq, d)
    dense("mlp", t, d, 3 * cfg.d_ff)
    if kind == "decode":
        sk = min(cache_len, cfg.window or cache_len)
        fl, _ = _attn_core(b, cfg.n_heads, 1, sk, cfg.head_dim, causal=False)
        by = (2 * b * cfg.n_kv_heads * sk * cfg.head_dim) * BF16
        c.add("attn_core", fl, by)
    else:
        fl, by = _attn_core(b, cfg.n_heads, s, s, cfg.head_dim, True,
                            cfg.window)
        c.add("attn_core", fl, by)
    return c


def embed_head_costs(cfg: ArchConfig, b: int, s: int, kind: str) -> Costs:
    c = Costs()
    d, v = cfg.d_model, cfg.padded_vocab
    t = b * s if kind != "decode" else b
    c.add("embed", flops=0.0, bytes=(t * d) * BF16 + t * 4)
    if kind in ("train",):
        c.add("head", flops=2.0 * t * d * v,
              bytes=(t * d + d * v + t * v) * BF16)
    else:
        tt = b  # prefill/decode: last-position logits only... prefill: b
        c.add("head", flops=2.0 * tt * d * v,
              bytes=(tt * d + d * v + tt * v) * BF16)
    return c


def optimizer_costs(cfg: ArchConfig) -> Costs:
    c = Costs()
    n = cfg.param_count()
    # read p, m, v, g; write p, m, v (fp32)
    c.add("adamw", flops=12.0 * n, bytes=7.0 * n * F32)
    return c


def step_costs(cfg: ArchConfig, shape: ShapeConfig) -> Costs:
    """Whole step: forward (+backward+optimizer for train)."""
    kind = shape.kind
    b, s = shape.global_batch, shape.seq_len
    if kind == "decode":
        per_layer = layer_costs(cfg, b, 1, "decode", decode_cache_len=s)
        eh = embed_head_costs(cfg, b, 1, "decode")
    else:
        per_layer = layer_costs(cfg, b, s, kind)
        eh = embed_head_costs(cfg, b, s, kind)
    total = Costs()
    bwd_mult = 3.0 if kind == "train" else 1.0   # fwd + 2x bwd
    total.add("layers", per_layer.flops * bwd_mult,
              per_layer.bytes * bwd_mult, mult=cfg.n_layers)
    # weight traffic: every parameter read once per fwd (+once per bwd)
    wt = cfg.param_count() * BF16 * (2 if kind == "train" else 1)
    total.add("weights", 0.0, wt)
    napp = cfg.n_shared_attn_applications()
    if napp:
        sc = shared_attn_costs(cfg, b, 1 if kind == "decode" else s, kind,
                               cache_len=s)
        total.add("shared_attn", sc.flops * bwd_mult, sc.bytes * bwd_mult,
                  mult=napp)
    if cfg.is_encoder_decoder and kind != "decode":
        enc_layer = layer_costs(cfg, b, cfg.frontend_seq or s, kind)
        total.add("encoder", enc_layer.flops * bwd_mult,
                  enc_layer.bytes * bwd_mult, mult=cfg.n_encoder_layers)
    total.add("embed_head", eh.flops * bwd_mult, eh.bytes * bwd_mult)
    if kind == "train":
        oc = optimizer_costs(cfg)
        total.add("optimizer", oc.flops, oc.bytes)
    return total


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference), N = active params."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch      # decode: one token per seq
