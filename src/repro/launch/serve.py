"""Serving driver: continuous-batching engine + run-time auto-tuning.

CPU-scale (reduced configs): submits a stream of synthetic requests,
reports throughput/latency, and demonstrates the run-time AT path (decode
bucket variants tuned on the first calls through a ``repro.at`` session,
then committed; committed winners persist in the session's record store,
so a restarted server starts warm).

Usage::

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --requests 8 \
        --autotune --workdir /tmp/at
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from .. import at
from ..configs import get_arch
from ..models import build_model
from ..serving import Request, ServingEngine


def serve(arch: str = "yi-6b", n_requests: int = 8, n_lanes: int = 4,
          max_len: int = 96, prompt_len: int = 16, max_new: int = 12,
          seed: int = 0, autotune: bool = False,
          workdir: str = ".") -> dict:
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    tuner = None
    if autotune:
        from ..tuning import DecodeAutoTuner
        session = at.AutoTuner(workdir)

        def make_decode(block_k):
            # each candidate gets its own jit cache and publishes its
            # block PP before its first trace, so the kernel path reads
            # its own block_k at trace time (on CPU the reference path
            # ignores it and the select exercises the paper's run-time
            # measurement flow rather than a real kernel trade-off)
            decode_bk = jax.jit(model.decode_step)

            def variant(p, caches, token, pos, block_k=block_k):
                at.publish("flash_decode", block_k=block_k)
                return decode_bk(p, caches, token, pos)
            return variant

        tuner = DecodeAutoTuner(session, make_decode,
                                buckets=(128, 512, 2048),
                                block_ks=(256, 512))
    engine = ServingEngine(model, params, n_lanes=n_lanes, max_len=max_len,
                           autotuner=tuner)
    rng = np.random.default_rng(seed)
    t0 = time.time()
    for rid in range(n_requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              size=rng.integers(4, prompt_len)).tolist()
        engine.submit(Request(rid=rid, prompt=prompt,
                              max_new_tokens=max_new))
    finished = engine.run(max_steps=n_requests * (max_new + 2))
    wall = time.time() - t0
    total_tokens = sum(len(r.out_tokens) for r in finished)
    ttfts = [r.first_token_t - r.submit_t for r in finished
             if r.first_token_t]
    return {
        "finished": len(finished), "requests": n_requests,
        "decode_steps": engine.steps, "generated_tokens": total_tokens,
        "tokens_per_s": total_tokens / wall if wall else 0.0,
        "mean_ttft_s": float(np.mean(ttfts)) if ttfts else None,
        "wall_s": wall,
        "committed_buckets": tuner.committed() if tuner else None,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--autotune", action="store_true",
                    help="run-time AT over decode buckets (repro.at)")
    ap.add_argument("--workdir", default=".",
                    help="AT session workdir (param files + record store)")
    args = ap.parse_args()
    out = serve(arch=args.arch, n_requests=args.requests,
                n_lanes=args.lanes, max_len=args.max_len,
                max_new=args.max_new, autotune=args.autotune,
                workdir=args.workdir)
    print(f"[serve] {out['finished']}/{out['requests']} requests, "
          f"{out['generated_tokens']} tokens in {out['wall_s']:.1f}s "
          f"({out['tokens_per_s']:.1f} tok/s, "
          f"ttft {out['mean_ttft_s']:.2f}s)")


if __name__ == "__main__":
    main()
