"""Serving driver: continuous-batching engine + run-time auto-tuning.

CPU-scale (reduced configs): submits a stream of synthetic requests,
reports throughput/latency, and demonstrates the run-time AT path (decode
bucket variants tuned on the first calls, then committed).

Usage::

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --requests 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import get_arch
from ..models import build_model
from ..serving import Request, ServingEngine


def serve(arch: str = "yi-6b", n_requests: int = 8, n_lanes: int = 4,
          max_len: int = 96, prompt_len: int = 16, max_new: int = 12,
          seed: int = 0) -> dict:
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    engine = ServingEngine(model, params, n_lanes=n_lanes, max_len=max_len)
    rng = np.random.default_rng(seed)
    t0 = time.time()
    for rid in range(n_requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              size=rng.integers(4, prompt_len)).tolist()
        engine.submit(Request(rid=rid, prompt=prompt,
                              max_new_tokens=max_new))
    finished = engine.run(max_steps=n_requests * (max_new + 2))
    wall = time.time() - t0
    total_tokens = sum(len(r.out_tokens) for r in finished)
    ttfts = [r.first_token_t - r.submit_t for r in finished
             if r.first_token_t]
    return {
        "finished": len(finished), "requests": n_requests,
        "decode_steps": engine.steps, "generated_tokens": total_tokens,
        "tokens_per_s": total_tokens / wall if wall else 0.0,
        "mean_ttft_s": float(np.mean(ttfts)) if ttfts else None,
        "wall_s": wall,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()
    out = serve(arch=args.arch, n_requests=args.requests,
                n_lanes=args.lanes, max_len=args.max_len,
                max_new=args.max_new)
    print(f"[serve] {out['finished']}/{out['requests']} requests, "
          f"{out['generated_tokens']} tokens in {out['wall_s']:.1f}s "
          f"({out['tokens_per_s']:.1f} tok/s, "
          f"ttft {out['mean_ttft_s']:.2f}s)")


if __name__ == "__main__":
    main()
