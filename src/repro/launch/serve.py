"""Serving driver: scheduler-driven engine + run-time auto-tuning.

CPU-scale (reduced configs): submits a stream of synthetic requests,
reports throughput/latency percentiles from the serving metrics layer,
and demonstrates the run-time AT path (decode bucket variants tuned on
the first calls through a ``repro.at`` session, then committed; committed
winners persist in the session's record store, so a restarted server
starts warm).

``--cache paged`` runs the paged-KV backend: memory scales with live
tokens, and with ``--timeslice`` the engine serves more concurrent
requests than it has decode lanes (preempted sequences' pages swap to
host and back).  ``--prefill-chunk N`` adds chunked prefill: prompts
stream into the paged cache N tokens per tick interleaved with decode,
and the prefill tile space (block_q x block_k per prompt bucket) becomes
a second run-time tuning region next to the decode buckets.

``--prefix-cache`` (paged + chunked prefill) turns on content-addressed
prefix caching: committed full pages publish into a hash index, new
admissions seed their page tables with matching shared pages (refcounted,
copy-on-write) and prefill only the uncached suffix.  With ``--autotune``
the cache's reuse policy (min-match granularity x eviction strategy)
becomes the ``PrefixPolicy`` tuning region.  ``--shared-prefix N`` makes
the synthetic workload share an N-token system prompt so the index
actually gets hits.

``--kv-dtype int8`` (paged) stores KV pages as int8 with per-row fp32
scales — ~3x more tokens per byte at this scale — dequantizing inside
the attention kernels; ``--kv-dtype auto`` (with ``--autotune``) lets
the ``KVPrecision_{b}`` regions calibrate fp vs int8 (x block_k) per
length bucket under a greedy-agreement quality guard, then builds the
pool from the majority winner.

``--draft`` turns on speculative decoding (paged only): a reduced-depth
draft sliced from the target's own layers proposes ``--spec-k`` tokens
per tick and the target verifies them in one chunked call; with
``--autotune`` the (k x verify tile) space becomes a third tuning region
family (``SpecBucket_{b}``).  ``--temperature/--top-k/--top-p`` switch
the synthetic requests from greedy to sampled decoding (per-request
seeds, reproducible).

Usage::

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --requests 8 \
        --cache paged --pages 64 --page-size 16 --prefill-chunk 8 \
        --draft --spec-k 4 --autotune --workdir /tmp/at
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import time

import jax
import numpy as np

from .. import at
from ..configs import get_arch
from ..distributed.sharding import make_serving_mesh
from ..models import build_model
from ..serving import REDUCED_BUCKETS, Request, SamplingParams, ServingEngine


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """The serve run shape as one typed object.

    Replaces the 27-keyword ``serve(...)`` signature and the flat argparse
    namespace as the source of truth: :meth:`from_args` builds it from the
    CLI, :meth:`to_dict` stamps it verbatim into the serve report and the
    bench payload config, so ``compare.py`` cell keys and the report both
    derive from the same record.  ``mesh`` is the tensor-parallel device
    mesh spec (``"RxC"``, data x model — e.g. ``"1x4"``; None = unsharded).
    """

    arch: str = "yi-6b"
    n_requests: int = 8
    n_lanes: int = 4
    max_len: int = 96
    prompt_len: int = 16
    max_new: int = 12
    seed: int = 0
    autotune: bool = False
    workdir: str = "."
    cache: str = "dense"
    n_pages: int | None = None
    page_size: int = 16
    timeslice: int | None = None
    prefill_chunk: int | None = None
    draft: bool = False
    spec_k: int = 4
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    prefix_cache: bool = False
    shared_prefix: int = 0
    gateway: bool = False
    port: int = 0
    queue_limit: int = 64
    policy_window: int = 2
    slo_ttft_s: float = 30.0
    slo_itl_s: float = 5.0
    kv_dtype: str = "fp"
    num_splits: int | None = None
    mesh: str | None = None
    tuning_backend: str = "jsonl"
    golden_db: str | None = None

    #: argparse dest -> field, for the names that differ
    _ARG_FIELDS = {"requests": "n_requests", "lanes": "n_lanes",
                   "pages": "n_pages", "slo_ttft": "slo_ttft_s",
                   "slo_itl": "slo_itl_s"}

    @classmethod
    def from_args(cls, args: argparse.Namespace) -> "ServeConfig":
        """Build from an argparse namespace (ignores unknown attributes,
        keeps dataclass defaults for flags the parser doesn't expose)."""
        fields = {f.name for f in dataclasses.fields(cls)}
        kw = {}
        for dest, value in vars(args).items():
            name = cls._ARG_FIELDS.get(dest, dest)
            if name in fields:
                kw[name] = value
        return cls(**kw)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def _make_kv_precision_bench(model, page_size: int, lanes: int = 2,
                             decode_steps: int = 3):
    """Calibration microbench for the KVPrecision regions.

    ``make_variant(bucket, kv_dtype, block_k)`` builds one candidate: a
    throwaway paged pool of the requested precision, one prefill over a
    synthetic prompt plus a few greedy decode steps, timed end to end.
    The variant reports ``time_per_token`` and ``agreement`` — the
    fraction of its greedy tokens matching the fp reference for the same
    bucket (fp candidates *are* the reference: agreement 1.0 by
    construction, so the quality-guarded pool is never empty).

    The prompt is capped at 4 pages so every bucket's calibration shares
    one trace per cache structure (fp / int8); buckets differ in prompt
    *content*, standing in for length-dependent behaviour at CPU scale
    without a retrace per bucket.
    """
    jnp = jax.numpy
    prefill_jit = jax.jit(model.paged_prefill_step)
    decode_jit = jax.jit(model.paged_decode_step)
    ref_tokens: dict[int, list] = {}

    def run(bucket, kv_dtype, block_k, params):
        plen = min(bucket, 4 * page_size)
        blocks = -(-(plen + decode_steps) // page_size)
        caches = model.init_paged_caches(lanes * blocks + 1, page_size,
                                         quantized=kv_dtype == "int8")
        table = jnp.asarray(
            (np.arange(lanes)[:, None] * blocks
             + np.arange(blocks)[None, :] + 1).astype(np.int32))
        rng = np.random.default_rng(bucket)
        prompt = jnp.asarray(np.tile(
            rng.integers(0, model.cfg.vocab_size, size=plen),
            (lanes, 1)).astype(np.int32))
        start = jnp.zeros((lanes,), jnp.int32)
        kv_len = jnp.full((lanes,), plen, jnp.int32)
        at.publish("flash_paged_decode", block_k=block_k)
        at.publish("flash_paged_prefill", block_k=block_k)
        t0 = time.perf_counter()
        logits, caches = prefill_jit(params, caches, table, prompt,
                                     start, kv_len, kv_len - 1)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        toks = [int(tok[0])]
        pos = kv_len
        for _ in range(decode_steps):
            logits, caches = decode_jit(params, caches, table,
                                        tok[:, None], pos)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            toks.append(int(tok[0]))
            pos = pos + 1
        dt = time.perf_counter() - t0
        return toks, dt / (1 + decode_steps)

    def make_variant(bucket, kv_dtype, block_k):
        def variant(params, bucket=bucket, kv_dtype=kv_dtype,
                    block_k=block_k):
            toks, tpt = run(bucket, kv_dtype, block_k, params)
            if kv_dtype == "fp":
                ref_tokens.setdefault(bucket, toks)
                agreement = 1.0
            else:
                if bucket not in ref_tokens:   # int8 measured first
                    ref_tokens[bucket], _ = run(bucket, "fp", block_k,
                                                params)
                ref = ref_tokens[bucket]
                agreement = sum(a == r for a, r in zip(toks, ref)) \
                    / max(len(ref), 1)
            return {"kv_dtype": kv_dtype, "block_k": block_k,
                    "time_per_token": tpt, "agreement": agreement}
        return variant

    return make_variant


def _make_autotuner(model, workdir: str, cache: str, page_size: int,
                    gateway: bool = False,
                    prefill_chunk: int | None = None,
                    spec_k: int | None = None,
                    prefix_cache: bool = False,
                    kv_precision: bool = False,
                    num_splits: int | None = None,
                    mesh=None, mesh_shape=None,
                    tuning_backend: str = "jsonl",
                    golden_db: str | None = None):
    """Per-bucket dynamic select over decode variants (repro.at session).

    Each candidate gets its own jit cache and publishes its block PPs
    before its first trace, so the kernel path reads its own block_k /
    page-gather granularity at trace time (on CPU the reference path
    ignores them and the select exercises the paper's run-time measurement
    flow rather than a real kernel trade-off).

    With chunked prefill the session also declares the prefill region
    family: one select per (prompt bucket × chunk size) over the
    ``flash_paged_prefill`` (block_q × block_k) tile space.

    ``mesh`` (a device Mesh) is closed into every variant's jit so
    tuner-routed calls run the same sharded computation as the engine's
    committed steady state; ``mesh_shape`` keys the region names so each
    mesh shape tunes and persists its own winners (a 1-device mesh keeps
    the legacy names and warm-loads existing DBs unchanged).  The
    KV-precision calibration bench stays unsharded: it measures on
    throwaway pools as a cost proxy, and its greedy-agreement guard
    compares like with like either way.

    ``tuning_backend`` selects the record store behind the session
    (``at.record_backends``: jsonl default, sqlite for concurrent
    workers); ``golden_db`` overlays a read-only fleet winner DB so a
    fresh deployment warm-loads committed optima it never measured.
    """
    from ..tuning import DecodeAutoTuner, divisor_block_ks
    session = at.AutoTuner(workdir, record_backend=tuning_backend,
                           golden_db=golden_db)

    def _jit_step(fn, **jit_kw):
        if mesh is not None:
            fn = functools.partial(fn, mesh=mesh)
        return jax.jit(fn, **jit_kw)

    if cache == "paged":
        # the paged kernel's run-time PPs are the split-K tile *within*
        # a page (page size itself is structural, fixed at pool build)
        # and the split-KV parallelism degree, so the per-bucket space is
        # block_k in divisors{psz/2, psz} x num_splits; a forced
        # --num-splits pins the candidate ladder to that single degree
        # (1 always leads, keeping legacy winner indices valid)
        splits = (1, 2, 4) if num_splits is None else (int(num_splits),)

        def make_decode(block_k, n_split):
            decode_bk = _jit_step(model.paged_decode_step)

            def variant(p, caches, table, token, pos, block_k=block_k,
                        n_split=n_split):
                at.publish("flash_paged_decode", block_k=block_k,
                           num_splits=n_split)
                return decode_bk(p, caches, table, token, pos)
            return variant

        tuner = DecodeAutoTuner(session, make_decode,
                                buckets=REDUCED_BUCKETS,
                                block_ks=divisor_block_ks(
                                    page_size,
                                    (max(1, page_size // 2), page_size)),
                                num_splits=splits,
                                mesh_shape=mesh_shape)
        if prefill_chunk is not None:
            def make_prefill(block_q, block_k):
                prefill_jit = _jit_step(model.paged_prefill_step)

                def variant(p, caches, table, tokens, start, kv_len,
                            logit_idx, block_q=block_q, block_k=block_k):
                    at.publish("flash_paged_prefill", block_q=block_q,
                               block_k=block_k)
                    return prefill_jit(p, caches, table, tokens, start,
                                       kv_len, logit_idx)
                return variant

            tuner.add_prefill(
                make_prefill, chunk_sizes=(prefill_chunk,),
                buckets=REDUCED_BUCKETS,
                block_qs=(max(1, prefill_chunk // 2), prefill_chunk),
                block_ks=divisor_block_ks(
                    page_size, (max(1, page_size // 2), page_size)))
        if spec_k is not None:
            # the accept-window k is itself tuned: a variant verifies only
            # its first k drafts (narrower chunk, fewer acceptable tokens)
            # — greedy output is bit-identical for every k, so the region
            # measures the acceptance-vs-verify-cost trade-off freely.
            # Each variant reports time_per_token (its call time over the
            # tokens its window would emit under the greedy accept rule):
            # raw per-call latency would always elect the narrowest k, so
            # the region commits on throughput, not verify cost alone.
            def make_verify(k, block_q, block_k):
                verify_jit = _jit_step(model.speculative_step)

                def variant(p, caches, table, tokens, start, kv_len,
                            k=k, block_q=block_q, block_k=block_k,
                            measure=True):
                    at.publish("flash_paged_verify", block_q=block_q,
                               block_k=block_k)
                    args = (p, caches, table, tokens[:, :k + 1], start,
                            jax.numpy.minimum(kv_len, start + k + 1))
                    if not measure:
                        # committed steady state: no sync, no host-side
                        # acceptance proxy — just the verify itself
                        return verify_jit(*args)
                    t0 = time.perf_counter()
                    logits, caches_out = verify_jit(*args)
                    logits.block_until_ready()
                    dt = time.perf_counter() - t0
                    # greedy-acceptance proxy over the live lanes (kv_len
                    # > start; masked/idle rows are excluded): leading
                    # draft/argmax matches + 1 bonus each = tokens this
                    # window emits per call
                    am = np.asarray(jax.numpy.argmax(logits[:, :-1], -1))
                    dr = np.asarray(tokens[:, 1:k + 1])
                    st_np = np.asarray(start)
                    kl = np.asarray(kv_len)
                    emitted = 0
                    for b in range(dr.shape[0]):
                        if kl[b] <= st_np[b]:
                            continue
                        w = min(k, int(kl[b] - st_np[b]) - 1)
                        a = 0
                        while a < w and dr[b, a] == am[b, a]:
                            a += 1
                        emitted += a + 1
                    return {"logits": logits, "caches": caches_out,
                            "time_per_token": dt / max(emitted, 1)}
                return variant

            tuner.add_spec(
                make_verify,
                ks=tuple(sorted({1, max(1, spec_k // 2), spec_k})),
                buckets=REDUCED_BUCKETS,
                block_qs=(spec_k + 1,),
                block_ks=divisor_block_ks(
                    page_size, (max(1, page_size // 2), page_size)))
        if prefix_cache:
            # the cache's REUSE POLICY is the tuned object (minimum match
            # granularity x eviction strategy): each alternative applies
            # its knobs to the live pool and performs one real admission
            # match.  Outputs are bit-identical under every policy, and
            # the region commits on the smallest uncached PROMPT FRACTION
            # (raw call latency would elect whichever policy matches
            # nothing; unnormalized token counts would let prompt length
            # pick the winner instead of the policy).
            def make_policy(min_match, eviction):
                def variant(kv, lane_id, prompt, min_match=min_match,
                            eviction=eviction):
                    kv.set_prefix_policy(min_match=min_match,
                                         eviction=eviction)
                    cached = kv.seed_prefix(lane_id, prompt)
                    miss = (len(prompt) - cached) / max(len(prompt), 1)
                    return {"cached": cached, "miss_fraction": miss}
                return variant

            tuner.add_prefix_policy(make_policy)
        if kv_precision:
            # pool precision is lossy, so the region couples latency to a
            # quality guard: an int8 candidate may only win while its
            # greedy tokens track the fp reference (fp reports agreement
            # 1.0, keeping the guarded pool non-empty)
            tuner.add_kv_precision(
                _make_kv_precision_bench(model, page_size),
                block_ks=divisor_block_ks(
                    page_size, (max(1, page_size // 2), page_size)),
                buckets=REDUCED_BUCKETS)
        if gateway:
            # the gateway's concurrency product (pipeline depth x
            # admission batch) — measured over traffic windows and
            # committed on time-per-good-token (inverse goodput)
            tuner.add_gateway(max_inflights=(1, 2), admit_batches=(2, 8))
        return tuner

    def make_decode(block_k):
        decode_bk = jax.jit(model.decode_step)

        def variant(p, caches, token, pos, block_k=block_k):
            at.publish("flash_decode", block_k=block_k)
            return decode_bk(p, caches, token, pos)
        return variant

    tuner = DecodeAutoTuner(session, make_decode,
                            buckets=REDUCED_BUCKETS,
                            block_ks=(256, 512))
    if gateway:
        tuner.add_gateway(max_inflights=(1, 2), admit_batches=(2, 8))
    return tuner


def _serve_gateway(engine, tuner, prompts, *, max_new: int, port: int,
                   queue_limit: int, policy_window: int,
                   slo_ttft_s: float, slo_itl_s: float,
                   temperature: float, top_k: int, top_p: float,
                   seed: int):
    """Serve the workload through the HTTP/SSE gateway: every request is
    a real localhost TCP client streaming SSE frames, the engine ticks in
    the pipelined asyncio loop, and the report carries goodput / SLO
    attainment next to the engine's own metrics."""
    import asyncio

    from ..serving.gateway import GatewayServer, PipelinedEngine, sse_generate
    from ..serving.gateway.pipeline import goodput_stats

    sampling = None
    if temperature > 0.0 or top_k or top_p < 1.0:
        sampling = {"temperature": temperature, "top_k": top_k,
                    "top_p": top_p}

    async def _run():
        pipe = PipelinedEngine(engine, queue_limit=queue_limit, tuner=tuner,
                               policy_window=policy_window,
                               slo_ttft_s=slo_ttft_s, slo_itl_s=slo_itl_s)
        srv = GatewayServer(pipe, port=port)
        await srv.start()
        t0 = time.monotonic()

        async def one(i, prompt):
            n_tokens, bounced = 0, 0
            while True:        # honor Retry-After on a 429 bounce
                final = None
                async for kind, payload in sse_generate(
                        "127.0.0.1", srv.port, prompt,
                        max_new_tokens=max_new,
                        sampling=dict(sampling, seed=seed + i)
                        if sampling else None):
                    if kind == "tokens":
                        n_tokens += len(payload)
                    else:
                        final = (kind, payload)
                if final and final[0] == "http_error" \
                        and final[1]["status"] == 429:
                    bounced += 1
                    await asyncio.sleep(
                        float(final[1].get("retry_after") or 1))
                    continue
                return n_tokens, bounced, final

        results = await asyncio.gather(
            *[one(i, p) for i, p in enumerate(prompts)])
        wall = time.monotonic() - t0
        await srv.drain()
        return pipe, results, wall

    pipe, results, wall = asyncio.run(_run())
    report = {
        "requests": len(prompts),
        "wall_s": wall,
        "client_retries_429": sum(r[1] for r in results),
        **{k: v for k, v in pipe.stats().items() if k != "draining"},
        **goodput_stats(engine.finished, wall, slo_ttft_s, slo_itl_s),
    }
    return engine.finished, report


def serve(**kwargs) -> dict:
    """Back-compat wrapper: build a :class:`ServeConfig` from keyword
    arguments and delegate to :func:`serve_config`."""
    return serve_config(ServeConfig(**kwargs))


def serve_config(scfg: ServeConfig) -> dict:
    arch, n_requests, n_lanes = scfg.arch, scfg.n_requests, scfg.n_lanes
    max_len, prompt_len, max_new = scfg.max_len, scfg.prompt_len, scfg.max_new
    seed, autotune, workdir = scfg.seed, scfg.autotune, scfg.workdir
    cache, n_pages, page_size = scfg.cache, scfg.n_pages, scfg.page_size
    timeslice, prefill_chunk = scfg.timeslice, scfg.prefill_chunk
    draft, spec_k = scfg.draft, scfg.spec_k
    temperature, top_k, top_p = scfg.temperature, scfg.top_k, scfg.top_p
    prefix_cache, shared_prefix = scfg.prefix_cache, scfg.shared_prefix
    gateway, port, queue_limit = scfg.gateway, scfg.port, scfg.queue_limit
    policy_window = scfg.policy_window
    slo_ttft_s, slo_itl_s = scfg.slo_ttft_s, scfg.slo_itl_s
    kv_dtype = scfg.kv_dtype
    if kv_dtype not in ("fp", "int8", "auto"):
        raise ValueError(f"unknown kv_dtype {kv_dtype!r}")
    if kv_dtype == "auto" and not (cache == "paged" and autotune):
        raise ValueError("--kv-dtype auto needs --cache paged --autotune")
    mesh = make_serving_mesh(scfg.mesh)
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    if scfg.num_splits is not None and cache == "paged":
        # forced split-KV degree: published before any engine or variant
        # jit trace so the tuned and untuned paths both read it
        # (num_splits=1 is the explicit legacy / sequential spelling)
        at.publish("flash_paged_decode", num_splits=int(scfg.num_splits))
        at.publish("flash_paged_verify", num_splits=int(scfg.num_splits))
    draft_model = draft_params = None
    if draft:
        # self-speculative draft: the target's own leading layers (shared
        # embed/head), so the draft's argmax actually agrees with the
        # target often enough for acceptances to happen at random init
        draft_model = model.draft_model()
        draft_params = model.slice_draft_params(params, draft_model)
    tuner = _make_autotuner(model, workdir, cache, page_size,
                            gateway=gateway,
                            prefill_chunk=prefill_chunk,
                            spec_k=spec_k if draft else None,
                            prefix_cache=prefix_cache,
                            kv_precision=kv_dtype == "auto",
                            num_splits=scfg.num_splits,
                            mesh=mesh, mesh_shape=scfg.mesh,
                            tuning_backend=scfg.tuning_backend,
                            golden_db=scfg.golden_db) \
        if autotune else None
    resolved_kv = kv_dtype
    if kv_dtype == "auto":
        # calibrate every (precision x block_k) candidate per bucket,
        # then collapse the committed winners into one structural pool
        # dtype (majority vote) — the pool is built once, up front.  A
        # warm restart finds every region already committed and runs
        # zero measurements.
        for b in tuner.kv_buckets:
            while not tuner.kv_precision_committed(b):
                tuner.kv_precision(b, params)
        resolved_kv = tuner.resolve_kv_dtype()
    engine = ServingEngine(model, params, n_lanes=n_lanes, max_len=max_len,
                           autotuner=tuner, cache=cache, n_pages=n_pages,
                           page_size=page_size, timeslice=timeslice,
                           prefill_chunk=prefill_chunk,
                           draft_model=draft_model,
                           draft_params=draft_params,
                           spec_k=spec_k if draft else None,
                           prefix_cache=prefix_cache,
                           kv_dtype=resolved_kv, mesh=mesh)
    rng = np.random.default_rng(seed)
    # shared_prefix > 0 prepends one common system prompt to every
    # request — the workload that makes the prefix cache earn its keep
    prefix = rng.integers(0, cfg.vocab_size,
                          size=shared_prefix).tolist() if shared_prefix \
        else []
    prompts = [prefix + rng.integers(
        0, cfg.vocab_size, size=rng.integers(4, prompt_len)).tolist()
        for _ in range(n_requests)]
    gateway_report = None
    if gateway:
        finished, gateway_report = _serve_gateway(
            engine, tuner, prompts, max_new=max_new, port=port,
            queue_limit=queue_limit, policy_window=policy_window,
            slo_ttft_s=slo_ttft_s, slo_itl_s=slo_itl_s,
            temperature=temperature, top_k=top_k, top_p=top_p, seed=seed)
    else:
        for rid in range(n_requests):
            engine.submit(Request(rid=rid, prompt=prompts[rid],
                                  max_new_tokens=max_new,
                                  sampling=SamplingParams(
                                      temperature=temperature, top_k=top_k,
                                      top_p=top_p, seed=seed + rid)))
        finished = engine.run(
            max_steps=n_requests * (max_new + 4 + shared_prefix))
    summary = engine.metrics.summary()
    prefix_stats = None
    if prefix_cache:
        kvp = engine.kv.stats().get("prefix", {})
        prefix_stats = {**summary["prefix_cache"],
                        "pages_saved": kvp.get("pages_saved", 0),
                        "cow_copies": kvp.get("cow_copies", 0),
                        "evictions": kvp.get("evictions", 0),
                        "cached_pages": kvp.get("cached_pages", 0)}
    return {
        "config": scfg.to_dict(),
        "mesh": scfg.mesh,
        # rid -> greedy token ids, the bit-identity surface for the
        # mesh-vs-unsharded correctness checks (CI + bench mesh cells)
        "outputs": {int(r.rid): [int(t) for t in r.out_tokens]
                    for r in finished},
        # zero-re-tuning surface: a warm restart from a committed DB must
        # report measurements == 0 / measured_regions [] — every region
        # shows up in warm_regions instead (mesh-suffixed regions tune
        # fresh the first time, then warm-load like the rest)
        "autotune": ({
            "executor_calls": tuner.session.executor_calls,
            "measurements": sum(
                len(st.tried)
                for st in tuner.ctx.dynamic_state.values()),
            "measured_regions": sorted(
                name for name, st in tuner.ctx.dynamic_state.items()
                if st.tried),
            "warm_regions": sorted(
                {name for _, name in tuner.session.warm_hits}),
        } if tuner else None),
        # which durability layer the winners live in (backend, path,
        # record count, golden overlay) — None when serving untuned
        "tuning_db": engine.tuning_db(),
        "finished": len(finished), "requests": n_requests,
        "decode_steps": engine.steps,
        "generated_tokens": summary["generated_tokens"],
        "tokens_per_s": summary["tokens_per_s"],
        "p50_queue_wait_s": summary["queue_wait_s"]["p50"],
        "p99_queue_wait_s": summary["queue_wait_s"]["p99"],
        "mean_ttft_s": summary["ttft_s"]["mean"],
        "p50_ttft_s": summary["ttft_s"]["p50"],
        "p99_ttft_s": summary["ttft_s"]["p99"],
        "p50_itl_s": summary["itl_s"]["p50"],
        "p99_itl_s": summary["itl_s"]["p99"],
        "wall_s": summary["wall_s"],
        "preemptions": summary["preemptions"],
        "prefill_chunks": engine.prefill_chunks,
        "spec": engine.spec_stats() if draft else None,
        "cache": engine.kv.stats(),
        "prefix_cache": prefix_stats,
        "committed_buckets": tuner.committed_params() if tuner else None,
        "committed_prefill": (
            {f"{b}_c{cs}": pp for (b, cs), pp
             in tuner.committed_prefill_params().items()}
            if tuner and tuner.prefill_regions else None),
        "committed_spec": (tuner.committed_spec_params()
                           if tuner and tuner.spec_regions else None),
        "committed_prefix": (tuner.committed_prefix_params()
                             if tuner and tuner.prefix_region is not None
                             else None),
        "gateway": gateway_report,
        "committed_gateway": (tuner.committed_gateway_params()
                              if tuner and tuner.gateway_region is not None
                              else None),
        "kv_dtype": resolved_kv,
        "committed_kv_precision": (tuner.committed_kv_precision_params()
                                   if tuner and tuner.kv_regions else None),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--cache", choices=("dense", "paged"), default="dense",
                    help="KV backend: dense lanes or paged block pool")
    ap.add_argument("--pages", type=int, default=None,
                    help="paged: physical page count (default: lane parity)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="paged: tokens per KV page")
    ap.add_argument("--timeslice", type=int, default=None,
                    help="preempt a lane after N decode steps when work is "
                         "queued (serve more requests than lanes)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="paged: stream prompts in N-token chunks "
                         "interleaved with decode (chunked prefill / "
                         "continuous batching); default: monolithic")
    ap.add_argument("--draft", action="store_true",
                    help="paged: speculative decoding with a reduced-depth "
                         "self-speculative draft (target's leading layers)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="draft tokens proposed per speculative tick")
    ap.add_argument("--num-splits", type=int, default=None,
                    help="paged: split-KV parallelism degree for decode/"
                         "verify (Flash-Decoding two-phase) — 1 forces "
                         "the sequential kernel; default: tuned per "
                         "length bucket over {1,2,4} with --autotune, "
                         "else 1")
    ap.add_argument("--kv-dtype", choices=("fp", "int8", "auto"),
                    default="fp",
                    help="paged: KV page precision — fp pool dtype, int8 "
                         "pages with per-row scales and in-kernel "
                         "dequant, or auto (KVPrecision regions "
                         "calibrate fp vs int8 under a greedy-agreement "
                         "guard; needs --autotune)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="paged+chunked: content-addressed prefix caching "
                         "(refcounted shared pages, copy-on-write)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="prepend one common N-token system prompt to "
                         "every request (the prefix-cache workload)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="top-k sampling filter (0 disables)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling mass (1.0 disables)")
    ap.add_argument("--gateway", action="store_true",
                    help="serve through the async HTTP/SSE gateway "
                         "(pipelined ticks, real localhost TCP clients)")
    ap.add_argument("--port", type=int, default=0,
                    help="gateway: listen port (0 = ephemeral)")
    ap.add_argument("--queue-limit", type=int, default=64,
                    help="gateway: admission-queue bound (429 beyond)")
    ap.add_argument("--policy-window", type=int, default=2,
                    help="gateway: finished requests per GatewayPolicy "
                         "measurement window")
    ap.add_argument("--slo-ttft", type=float, default=30.0,
                    help="gateway: TTFT SLO in seconds (goodput counts "
                         "only requests inside it)")
    ap.add_argument("--slo-itl", type=float, default=5.0,
                    help="gateway: p95 inter-token-latency SLO in seconds")
    ap.add_argument("--mesh", default=None,
                    help="tensor-parallel device mesh 'RxC' (data x "
                         "model), e.g. '1x4': paged KV pools and "
                         "attention heads shard over the model axis; "
                         "'1x1' is bit-identical to no mesh")
    ap.add_argument("--autotune", action="store_true",
                    help="run-time AT over decode buckets (repro.at)")
    ap.add_argument("--workdir", default=".",
                    help="AT session workdir (param files + record store)")
    ap.add_argument("--tuning-backend", default="jsonl",
                    help="tuning-DB backend under --workdir "
                         "(at.record_backends: jsonl | sqlite)")
    ap.add_argument("--golden-db", default=None,
                    help="read-only golden winner DB overlaid under the "
                         "local store (exported via 'python -m repro.at "
                         "export'): local record beats golden, golden "
                         "beats cold")
    args = ap.parse_args()
    out = serve_config(ServeConfig.from_args(args))
    def fmt(x, spec):
        return format(x, spec) if x is not None else "n/a"

    spec_note = ""
    if out["spec"] is not None:
        s = out["spec"]
        spec_note = (f", spec k={s['spec_k']} accept "
                     f"{s['accepted_tokens']}/{s['drafted_tokens']} "
                     f"({s['accept_rate']:.0%})")
    if out["prefix_cache"] is not None:
        p = out["prefix_cache"]
        spec_note += (f", prefix hit {p['hit_requests']}/"
                      f"{out['requests']} ({p['hit_rate']:.0%}, "
                      f"{p['hit_tokens']} tok, "
                      f"{p['pages_saved']} pages saved)")
    if out["kv_dtype"] != "fp":
        c = out["cache"]
        spec_note += (f", kv {out['kv_dtype']} "
                      f"({c['kv_bytes_per_token']:.0f} B/tok, "
                      f"cap {c['capacity_tokens']} tok)")
    if out["gateway"] is not None:
        g = out["gateway"]
        spec_note += (f", gateway {g['goodput_tok_s']:.1f} good tok/s "
                      f"(SLO {g['slo_attainment']:.0%}, "
                      f"{g['overlapped_ticks']}/{g['ticks']} ticks "
                      f"overlapped, {g['rejected_429']} bounced)")
    print(f"[serve] {out['finished']}/{out['requests']} requests, "
          f"{out['generated_tokens']} tokens in {out['wall_s']:.1f}s "
          f"({out['tokens_per_s']:.1f} tok/s, "
          f"queue p50 {fmt(out['p50_queue_wait_s'], '.3f')}s, "
          f"ttft p50 {fmt(out['p50_ttft_s'], '.3f')}s "
          f"p99 {fmt(out['p99_ttft_s'], '.3f')}s, "
          f"itl p50 {fmt(out['p50_itl_s'], '.4f')}s, "
          f"preemptions {out['preemptions']}{spec_note})")


if __name__ == "__main__":
    main()
