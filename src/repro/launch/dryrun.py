import os
os.environ["XLA_FLAGS"] = (os.environ.get("_REPRO_EXTRA_XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and record memory/cost/collective artifacts.

The two lines above MUST run before any jax import (device count locks on
first init), which is why this module sets XLA_FLAGS at the very top and
why nothing here is imported by tests/benches (they must see 1 device).

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek-7b \
        --shape train_4k [--multi-pod] [--all] [--out experiments/artifacts]

Artifacts (one JSON per cell x mesh) feed EXPERIMENTS.md §Dry-run and the
roofline analysis (§Roofline).
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from ..configs import all_cells, get_arch, get_shape  # noqa: E402
from ..distributed.sharding import (batch_shardings,  # noqa: E402
                                    cache_shardings, choose_plan_name,
                                    layer_param_specs, make_plan,
                                    param_shardings)
from ..models import build_model  # noqa: E402
from ..optim import adamw  # noqa: E402
from .hlo_analysis import analyze_module, collective_bytes_by_kind  # noqa: E402
from .mesh import make_production_mesh  # noqa: E402
from .steps import step_for_shape  # noqa: E402


def dryrun_cell(arch_name: str, shape_name: str, *, multi_pod: bool = False,
                plan_name: str | None = None, remat: str = "full",
                num_microbatches: int | None = None,
                loss_chunks: int | None = None,
                verbose: bool = True) -> dict:
    """Lower + compile one cell; returns the artifact record."""
    cfg = get_arch(arch_name)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build_model(cfg)
    kind = shape.kind
    if num_microbatches is None:
        num_microbatches = 8 if kind == "train" else 1
    plan = make_plan(cfg, kind, mesh, plan_name, remat=remat,
                     num_microbatches=num_microbatches)
    if loss_chunks is not None:
        plan.loss_chunks = loss_chunks

    t0 = time.time()
    with mesh:
        abs_params = model.abstract_params()
        p_shard = param_shardings(abs_params, cfg, plan, mesh)
        plan.layer_specs = layer_param_specs(abs_params, cfg, plan, mesh)
        specs = model.input_specs(shape)
        step = step_for_shape(model, shape, plan, param_shardings=p_shard)
        if kind == "train":
            abs_opt = jax.eval_shape(adamw.init, abs_params)
            o_shard = _opt_shardings(abs_opt, abs_params, p_shard, mesh)
            b_shard = batch_shardings(specs, mesh)
            # donate params + optimizer state: the update aliases them
            jitted = jax.jit(step, in_shardings=(p_shard, o_shard, b_shard),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(abs_params, abs_opt, specs)
        elif kind == "prefill":
            b_shard = batch_shardings(specs, mesh)
            jitted = jax.jit(step, in_shardings=(p_shard, b_shard))
            lowered = jitted.lower(abs_params, specs)
        else:  # decode — donate the caches (updated in place)
            c_shard = cache_shardings(specs["caches"], cfg, plan, mesh)
            tok_shard = batch_shardings(
                {"token": specs["token"], "pos": specs["pos"]}, mesh)
            jitted = jax.jit(step, in_shardings=(
                p_shard, c_shard, tok_shard["token"], tok_shard["pos"]),
                donate_argnums=(1,))
            lowered = jitted.lower(abs_params, specs["caches"],
                                   specs["token"], specs["pos"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    coll = collective_bytes_by_kind(hlo)
    loop_aware = analyze_module(hlo)
    n_chips = mesh.devices.size
    record = {
        "arch": arch_name, "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "axes": list(mesh.axis_names), "chips": n_chips,
        "plan": plan.name, "remat": plan.remat,
        "num_microbatches": plan.num_microbatches,
        "loss_chunks": plan.loss_chunks,
        "kind": kind,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
        },
        # live bytes: arguments + temps + non-aliased outputs
        "bytes_per_device": (mem.argument_size_in_bytes
                             + mem.temp_size_in_bytes
                             + max(mem.output_size_in_bytes
                                   - mem.alias_size_in_bytes, 0)),
        "cost_analysis": {
            "flops": cost.get("flops", 0.0),
            "bytes_accessed": cost.get("bytes accessed", 0.0),
        },
        "collective_bytes": coll,
        # loop-aware (while bodies x trip count): the roofline inputs
        "hlo_dot_flops": loop_aware["dot_flops"],
        "hlo_collective_bytes": loop_aware["collective_bytes"],
        "while_trips": loop_aware["while_trips"][:40],
        "model_params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    if verbose:
        print(f"[dryrun] {arch_name} x {shape_name} "
              f"mesh={record['mesh']} plan={plan.name} "
              f"lower={t_lower:.1f}s compile={t_compile:.1f}s")
        print(f"  memory_analysis: {mem}")
        print(f"  cost_analysis: flops={record['cost_analysis']['flops']:.3e}"
              f" bytes={record['cost_analysis']['bytes_accessed']:.3e}")
        print(f"  collective_bytes: { {k: f'{v:.3e}' for k, v in coll.items()} }")
    return record


def _opt_sharding(leaf, p_shard, mesh):
    return None


def _opt_shardings(abs_opt, abs_params, p_shard, mesh):
    """Optimizer moments share the parameter shardings; step is replicated."""
    from jax.sharding import NamedSharding, PartitionSpec

    rep = NamedSharding(mesh, PartitionSpec())
    return adamw.AdamWState(step=rep, m=p_shard, v=p_shard)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--plan", default=None)
    ap.add_argument("--remat", default="full")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--out", default="experiments/artifacts")
    args = ap.parse_args()

    cells = []
    if args.all:
        for a, s, ok, reason in all_cells(include_skipped=True):
            cells.append((a.name, s.name, ok, reason))
    else:
        cells.append((args.arch, args.shape, True, ""))

    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch, shape, ok, reason in cells:
        for mp in meshes:
            tag = f"{arch}__{shape}__{'multi' if mp else 'single'}"
            path = os.path.join(args.out, tag + ".json")
            if not ok:
                rec = {"arch": arch, "shape": shape, "skipped": True,
                       "reason": reason,
                       "mesh": "2x16x16" if mp else "16x16"}
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                print(f"[dryrun] SKIP {tag}: {reason}")
                continue
            if os.path.exists(path):
                print(f"[dryrun] cached {tag}")
                continue
            try:
                rec = dryrun_cell(arch, shape, multi_pod=mp,
                                  plan_name=args.plan, remat=args.remat,
                                  num_microbatches=args.microbatches)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
            except Exception as e:
                traceback.print_exc()
                failures.append((tag, str(e)))
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for tag, err in failures:
            print(f"  {tag}: {err[:200]}")
        raise SystemExit(1)
    print("\ndry-run complete.")


if __name__ == "__main__":
    main()
