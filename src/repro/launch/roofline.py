"""Roofline analysis (§Roofline): three terms per (arch x shape x mesh).

    compute    = FLOPs / (chips x 197 TFLOP/s bf16)
    memory     = HBM bytes / (chips x 819 GB/s)
    collective = collective bytes / (chips x 50 GB/s/link)

Sources and methodology (documented in EXPERIMENTS.md):

* **FLOPs** — loop-aware HLO dot flops parsed from the compiled dry-run
  (``hlo_analysis.analyze_module``: while bodies x trip count; verified
  exact on scanned matmuls).  The analytic first-principles count
  (analytic.py) is reported alongside; MODEL_FLOPS = 6·N_active·D.
* **HBM bytes** — the analytic traffic model (flash-aware attention,
  weight/optimizer/cache traffic).  XLA-*CPU* ``bytes accessed`` models CPU
  fusion, not TPU HBM, and under-counts loop bodies, so it is shown only
  as a cross-check column.
* **collective bytes** — loop-aware parse of every all-gather/all-reduce/
  reduce-scatter/all-to-all/collective-permute result shape in the
  compiled HLO.  These are whole-mesh bytes; the per-chip wire time
  divides by chips (each chip injects its share on its own links).

Reads dry-run artifacts (JSON) and emits the roofline table.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from dataclasses import dataclass

from ..configs import get_arch, get_shape
from .analytic import model_flops, step_costs

PEAK_FLOPS = 197e12        # bf16 / chip (TPU v5e)
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    plan: str
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops: float
    analytic_flops: float
    bytes_per_device: float
    skipped: bool = False
    reason: str = ""

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — how much compiled compute is useful."""
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Achievable MFU bound: MODEL_FLOPS-time / roofline step time."""
        ideal = self.model_flops / (self.chips * PEAK_FLOPS)
        return ideal / self.bound_s if self.bound_s else 0.0


def from_artifact(record: dict) -> Roofline:
    """NOTE on units: the compiled SPMD module is the *per-device* program,
    so the parsed dot-flops and collective bytes are already per-chip
    (verified: falcon-mamba train_4k parses to 2.27e14 flops/chip ==
    6·N·D x 4/3 remat recompute / 256 chips).  The analytic model is
    whole-step, so it divides by chips; for matvec-shaped decode graphs
    (which XLA-CPU lowers to fused reductions, not dots) the analytic
    per-chip count is the reliable one and we take the max."""
    if record.get("skipped"):
        return Roofline(record["arch"], record["shape"],
                        record.get("mesh", ""), 0, "", 0, 0, 0, 0, 0, 0, 0,
                        skipped=True, reason=record.get("reason", ""))
    cfg = get_arch(record["arch"])
    shape = get_shape(record["shape"])
    chips = record["chips"]
    hlo_flops_dev = record.get("hlo_dot_flops", 0.0)
    ana = step_costs(cfg, shape)
    mf = model_flops(cfg, shape)
    coll_dev = record.get("hlo_collective_bytes", {}).get("total", 0.0)
    flops_dev = max(hlo_flops_dev, ana.flops / chips)
    return Roofline(
        arch=record["arch"], shape=record["shape"], mesh=record["mesh"],
        chips=chips, plan=record.get("plan", ""),
        compute_s=flops_dev / PEAK_FLOPS,
        memory_s=ana.bytes / (chips * HBM_BW),
        collective_s=coll_dev / ICI_BW,
        model_flops=mf, hlo_flops=hlo_flops_dev * chips,
        analytic_flops=ana.flops,
        bytes_per_device=record.get("bytes_per_device", 0.0),
    )


def table(artifact_dir: str, mesh_filter: str | None = "single"
          ) -> list[Roofline]:
    rows = []
    for path in sorted(glob.glob(os.path.join(artifact_dir, "*.json"))):
        if mesh_filter and not path.endswith(f"__{mesh_filter}.json"):
            continue
        with open(path) as f:
            rows.append(from_artifact(json.load(f)))
    return rows


def render(rows: list[Roofline]) -> str:
    hdr = (f"{'arch':<24}{'shape':<13}{'plan':<11}"
           f"{'compute_s':>10}{'memory_s':>10}{'collect_s':>10}"
           f"{'dominant':>11}{'MF/HLO':>8}{'roofl%':>8}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        if r.skipped:
            lines.append(f"{r.arch:<24}{r.shape:<13}SKIP: {r.reason[:60]}")
            continue
        lines.append(
            f"{r.arch:<24}{r.shape:<13}{r.plan:<11}"
            f"{r.compute_s:>10.4f}{r.memory_s:>10.4f}{r.collective_s:>10.4f}"
            f"{r.dominant:>11}{r.useful_ratio:>8.2f}"
            f"{100 * r.roofline_fraction:>7.1f}%")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifacts", default="experiments/artifacts")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()
    rows = table(args.artifacts, args.mesh)
    print(render(rows))
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump([r.__dict__ | {"dominant": r.dominant,
                                     "bound_s": r.bound_s,
                                     "useful_ratio": r.useful_ratio,
                                     "roofline_fraction":
                                         r.roofline_fraction}
                       for r in rows], f, indent=1)


if __name__ == "__main__":
    main()
