"""End-to-end training driver with fault tolerance.

CPU-scale by default (reduced configs); the same loop drives the
production mesh on real hardware.  Features exercised here and asserted in
tests/examples:

* deterministic data keyed by (seed, step, shard) -> exact resume;
* checkpoint/restart: async atomic checkpoints + retention; on start,
  auto-resume from the newest checkpoint;
* straggler watchdog + heartbeat monitor wired into the step loop
  (simulated hosts on CPU);
* before-execute-time AT: layout plan + microbatching chosen by
  tuning/static.py before the first step (the paper's phase ordering:
  install -> static -> run).

Usage::

    PYTHONPATH=src python -m repro.launch.train --arch deepseek-7b \
        --steps 50 --reduced --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from .. import checkpoint
from ..configs import get_arch
from ..data import DataConfig, batch_for_step
from ..distributed.fault_tolerance import (HeartbeatMonitor,
                                           StragglerWatchdog)
from ..models import LayoutPlan, build_model
from ..optim import adamw
from .steps import build_train_step


def train(arch: str = "deepseek-7b", steps: int = 20, reduced: bool = True,
          ckpt_dir: str | None = None, ckpt_every: int = 10,
          seq_len: int = 64, batch: int = 8, lr: float = 3e-4,
          remat: str = "none", num_microbatches: int = 1,
          log_every: int = 5, seed: int = 0,
          run_steps: int | None = None, autotune: bool = False,
          tune_shape: str = "train_4k") -> dict:
    """``steps`` fixes the schedule horizon; ``run_steps`` optionally stops
    this invocation early (simulated preemption for restart tests).

    ``autotune=True`` runs before-execute-time AT (paper phase ordering:
    install -> static -> run) through a ``repro.at`` session before the
    first step: the production-mesh layout plan for ``(arch, tune_shape)``
    is selected on the roofline estimate and persisted in the session
    record store under ``ckpt_dir`` (or cwd), so later launches of the
    same cell skip the selection.
    """
    cfg = get_arch(arch)
    if reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    tuned_plan = None
    if autotune:
        from .. import at
        from ..tuning import tune_layout
        session = at.AutoTuner(ckpt_dir or ".")
        tuned_plan = tune_layout(session, arch, tune_shape)
        print(f"[train] static AT: layout plan for ({arch}, {tune_shape}) "
              f"-> {tuned_plan!r}")
    plan = LayoutPlan(name="host", remat=remat,
                      num_microbatches=num_microbatches)
    opt_cfg = adamw.AdamWConfig(lr=lr, warmup_steps=max(2, steps // 10),
                                total_steps=steps)
    step_fn = jax.jit(build_train_step(model, plan, opt_cfg))

    dcfg = DataConfig(
        vocab_size=cfg.vocab_size, seq_len=seq_len, global_batch=batch,
        seed=seed,
        frontend_seq=cfg.frontend_seq if cfg.frontend != "none"
        or cfg.is_encoder_decoder else 0,
        d_model=cfg.d_model)

    start_step = 0
    params = None
    opt_state = None
    ckptr = checkpoint.AsyncCheckpointer(ckpt_dir) if ckpt_dir else None
    if ckpt_dir and checkpoint.latest_step(ckpt_dir) is not None:
        abstract = jax.eval_shape(
            lambda: {"params": model.init(jax.random.PRNGKey(seed)),
                     "opt": adamw.init(model.init(
                         jax.random.PRNGKey(seed)))})
        restored, meta = checkpoint.restore(ckpt_dir, abstract)
        params, opt_state = restored["params"], restored["opt"]
        start_step = int(meta["step"]) + 1
        print(f"[train] resumed from step {meta['step']}")
    if params is None:
        params = model.init(jax.random.PRNGKey(seed))
        opt_state = adamw.init(params)

    hb = HeartbeatMonitor(n_hosts=1, timeout_s=600)
    watchdog = StragglerWatchdog(n_hosts=1)
    losses = []
    t_start = time.time()
    end_step = steps if run_steps is None else min(steps,
                                                   start_step + run_steps)
    for step in range(start_step, end_step):
        t0 = time.time()
        batch_data = batch_for_step(dcfg, step)
        params, opt_state, metrics = step_fn(params, opt_state, batch_data)
        loss = float(metrics["loss"])
        losses.append(loss)
        dt = time.time() - t0
        hb.beat(0)
        watchdog.observe(0, dt)
        if step % log_every == 0 or step == end_step - 1:
            print(f"[train] step {step:5d} loss {loss:8.4f} "
                  f"gnorm {float(metrics['grad_norm']):7.3f} "
                  f"lr {float(metrics['lr']):.2e} {dt:5.2f}s")
        if ckptr and (step + 1) % ckpt_every == 0:
            ckptr.save(step, {"params": params, "opt": opt_state},
                       extra={"arch": cfg.name})
    if ckptr:
        ckptr.save(end_step - 1, {"params": params, "opt": opt_state},
                   extra={"arch": cfg.name})
        ckptr.wait()
    wall = time.time() - t_start
    return {"losses": losses, "final_loss": losses[-1] if losses else None,
            "steps": end_step - start_step, "wall_s": wall,
            "params": params, "opt_state": opt_state,
            "tuned_plan": tuned_plan}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--autotune", action="store_true",
                    help="static-AT layout selection before step 0")
    ap.add_argument("--tune-shape", default="train_4k")
    args = ap.parse_args()
    out = train(arch=args.arch, steps=args.steps, reduced=args.reduced,
                ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                seq_len=args.seq_len, batch=args.batch, lr=args.lr,
                remat=args.remat, num_microbatches=args.microbatches,
                autotune=args.autotune, tune_shape=args.tune_shape)
    print(f"[train] done: {out['steps']} steps, final loss "
          f"{out['final_loss']:.4f}, {out['wall_s']:.1f}s")


if __name__ == "__main__":
    main()
