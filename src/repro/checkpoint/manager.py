"""Mesh-aware checkpointing: atomic directories, async commit, retention.

Layout::

    <dir>/step_000123/          # one directory per step
        meta.json               # step, arch, mesh shape, tree structure
        shard_<i>.npz           # per-leaf arrays (host-local shards)
    <dir>/step_000123.tmp/      # staging; os.replace() commits atomically

Fault-tolerance contract: a checkpoint is visible iff its directory name
has no ``.tmp`` suffix, so a killed writer never leaves a half checkpoint
that restore would trust.  ``AsyncCheckpointer`` runs the serialize+rename
on a worker thread, overlapping I/O with the next training steps (the
standard large-scale pattern); ``wait()`` joins before the next save or
exit.  Retention keeps the newest ``keep`` checkpoints plus every
``keep_period``-th step.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
from dataclasses import dataclass, field

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d{9})$")


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path)
        out[key] = np.asarray(leaf)
    return out, treedef


def save(directory: str, step: int, tree, extra: dict | None = None) -> str:
    """Synchronous atomic save.  Returns the committed path."""
    flat, _ = _flatten(tree)
    final = os.path.join(directory, f"step_{step:09d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    np.savez(os.path.join(tmp, "shard_0.npz"), **flat)
    meta = {"step": step, "keys": sorted(flat), "time": time.time()}
    meta.update(extra or {})
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def available_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        m = _STEP_RE.match(name)
        if m and os.path.exists(os.path.join(directory, name, "meta.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(directory: str) -> int | None:
    steps = available_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, tree_like, step: int | None = None,
            shardings=None):
    """Restore into the structure of ``tree_like`` (abstract ok).

    With ``shardings`` given, leaves are device_put with the target
    sharding — this is the elastic-remesh path: a checkpoint written on one
    mesh restores onto any other mesh shape.
    """
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:09d}")
    data = np.load(os.path.join(path, "shard_0.npz"))
    flat, treedef = _flatten(tree_like)
    leaves = []
    for key in flat:
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        leaves.append(data[key])
    restored = jax.tree_util.tree_unflatten(
        treedef, [jax.numpy.asarray(x) for x in leaves])
    if shardings is not None:
        restored = jax.device_put(restored, shardings)
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    return restored, meta


def apply_retention(directory: str, keep: int = 3,
                    keep_period: int = 0) -> list[int]:
    """Delete old checkpoints; returns the steps removed."""
    steps = available_steps(directory)
    protect = set(steps[-keep:]) if keep else set()
    if keep_period:
        protect |= {s for s in steps if s % keep_period == 0}
    removed = []
    for s in steps:
        if s not in protect:
            shutil.rmtree(os.path.join(directory, f"step_{s:09d}"))
            removed.append(s)
    return removed


@dataclass
class AsyncCheckpointer:
    directory: str
    keep: int = 3
    keep_period: int = 0
    _thread: threading.Thread | None = field(default=None, repr=False)
    _error: list = field(default_factory=list, repr=False)

    def save(self, step: int, tree, extra: dict | None = None) -> None:
        self.wait()
        # materialise on host before handing to the thread (device buffers
        # must not be donated/mutated mid-write)
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def work():
            try:
                save(self.directory, step, host_tree, extra)
                apply_retention(self.directory, self.keep, self.keep_period)
            except Exception as e:          # surfaced on next wait()
                self._error.append(e)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error:
            raise self._error.pop()
