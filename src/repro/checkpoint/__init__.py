from .manager import (AsyncCheckpointer, apply_retention, available_steps,
                      latest_step, restore, save)
__all__ = ["AsyncCheckpointer", "save", "restore", "latest_step",
           "available_steps", "apply_retention"]
