"""Architecture registry: ``--arch <id>`` -> ArchConfig."""
from __future__ import annotations

from .base import SHAPES, ArchConfig, ShapeConfig
from .deepseek_7b import CONFIG as deepseek_7b
from .falcon_mamba_7b import CONFIG as falcon_mamba_7b
from .h2o_danube_1p8b import CONFIG as h2o_danube_1p8b
from .llama4_scout_17b_a16e import CONFIG as llama4_scout
from .moonshot_v1_16b_a3b import CONFIG as moonshot
from .phi4_mini_3p8b import CONFIG as phi4_mini
from .pixtral_12b import CONFIG as pixtral_12b
from .whisper_tiny import CONFIG as whisper_tiny
from .yi_6b import CONFIG as yi_6b
from .zamba2_7b import CONFIG as zamba2_7b

ARCHS: dict[str, ArchConfig] = {c.name: c for c in [
    zamba2_7b, whisper_tiny, deepseek_7b, phi4_mini, yi_6b,
    h2o_danube_1p8b, pixtral_12b, moonshot, llama4_scout, falcon_mamba_7b,
]}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]


def cell_applicable(arch: ArchConfig, shape: ShapeConfig
                    ) -> tuple[bool, str]:
    """Is (arch, shape) a runnable cell?  Returns (ok, reason-if-skipped).

    Skips per spec: long_500k needs sub-quadratic attention; encoder-decoder
    whisper is full-attention (skip long_500k).
    """
    if shape.name == "long_500k" and not arch.sub_quadratic:
        return False, ("long_500k skipped: pure full-attention arch "
                       "(O(L^2) attention / O(L) KV at 524288 tokens)")
    return True, ""


def all_cells(include_skipped: bool = False):
    """The 40 (arch x shape) cells, with applicability flags."""
    out = []
    for a in ARCHS.values():
        for s in SHAPES.values():
            ok, reason = cell_applicable(a, s)
            if ok or include_skipped:
                out.append((a, s, ok, reason))
    return out
