"""Architecture configuration schema + input-shape registry.

One :class:`ArchConfig` per assigned architecture lives in
``src/repro/configs/<id>.py``; ``registry.py`` maps ``--arch`` ids to them.
``reduced()`` returns the CPU smoke-test configuration of the same family.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


def pad_to(x: int, mult: int) -> int:
    return x + (-x) % mult


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0             # 0 => d_model // n_heads
    act: str = "silu"
    norm: str = "rms"           # rms | ln
    rope_theta: float = 10000.0
    use_rope: bool = True
    qkv_bias: bool = False
    tie_embeddings: bool = False
    window: int | None = None   # sliding-window attention
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    moe_group_size: int = 512
    # --- SSM ---
    ssm_version: int = 0        # 0 = none, 1 = mamba1, 2 = mamba2
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_chunk: int = 64
    d_inner: int = 0            # 0 => 2 * d_model
    # --- hybrid (zamba2): shared attention block period ---
    attn_period: int = 0        # 0 = never
    # --- encoder-decoder (whisper) ---
    n_encoder_layers: int = 0
    # --- modality frontend stub ---
    frontend: str = "none"      # none | audio | vision
    frontend_seq: int = 0       # stub embedding length (frames / patches)
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    cache_dtype: str = "bfloat16"

    # ------------------------------------------------------------------
    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def ssm_d_inner(self) -> int:
        return self.d_inner or 2 * self.d_model

    @property
    def ssm_n_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def padded_vocab(self) -> int:
        return pad_to(self.vocab_size, 128)

    @property
    def is_encoder_decoder(self) -> bool:
        return self.n_encoder_layers > 0

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run long_500k?  SSM / hybrid / SWA qualify."""
        return self.ssm_version > 0 or self.window is not None

    def layer_kinds(self) -> list[str]:
        """Per-decoder-layer block kind."""
        if self.family in ("dense", "vlm"):
            return ["attn_mlp"] * self.n_layers
        if self.family == "moe":
            return ["attn_moe"] * self.n_layers
        if self.family == "ssm":
            return ["mamba1"] * self.n_layers
        if self.family == "hybrid":
            return ["mamba2"] * self.n_layers
        if self.family == "encdec":
            return ["encdec_layer"] * self.n_layers
        raise ValueError(self.family)

    def n_shared_attn_applications(self) -> int:
        if self.attn_period <= 0:
            return 0
        return len(range(self.attn_period - 1, self.n_layers,
                         self.attn_period))

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (for 6ND MODEL_FLOPS)."""
        d, ff, v = self.d_model, self.d_ff, self.padded_vocab
        hq = self.n_heads * self.head_dim
        hkv = self.n_kv_heads * self.head_dim
        attn = d * hq + 2 * d * hkv + hq * d
        mlp = 3 * d * ff
        n = v * d * (1 if self.tie_embeddings else 2)
        if self.family in ("dense", "vlm"):
            n += self.n_layers * (attn + mlp)
        elif self.family == "moe":
            experts = 3 * d * ff * self.n_experts
            shared = 3 * d * ff * self.n_shared_experts
            router = d * self.n_experts
            n += self.n_layers * (attn + experts + shared + router)
        elif self.family == "ssm":
            di, ns = self.ssm_d_inner, self.ssm_state
            r = max(d // 16, 1)
            m1 = (d * 2 * di + di * (r + 2 * ns) + r * di + di * d
                  + 4 * di + di * ns)
            n += self.n_layers * m1
        elif self.family == "hybrid":
            di, ns = self.ssm_d_inner, self.ssm_state
            h = self.ssm_n_heads
            m2 = d * (2 * di + 2 * ns + h) + di * d + (di + 2 * ns) * 4
            n += self.n_layers * m2
            n += attn + mlp        # one shared attention block
        elif self.family == "encdec":
            n += self.n_encoder_layers * (attn + mlp)
            n += self.n_layers * (2 * attn + mlp)   # self + cross
        return n

    def active_param_count(self) -> int:
        """Activated parameters per token (MoE: top-k + shared only)."""
        if self.family != "moe":
            return self.param_count()
        d, ff, v = self.d_model, self.d_ff, self.padded_vocab
        hq = self.n_heads * self.head_dim
        hkv = self.n_kv_heads * self.head_dim
        attn = d * hq + 2 * d * hkv + hq * d
        active_experts = 3 * d * ff * (self.top_k + self.n_shared_experts)
        router = d * self.n_experts
        n = v * d * (1 if self.tie_embeddings else 2)
        n += self.n_layers * (attn + active_experts + router)
        return n

    def draft_config(self, depth_frac: float = 0.5,
                     width_frac: float = 1.0) -> "ArchConfig":
        """Speculative-decoding draft: the same family at reduced depth
        (and optionally width), sharing the target's vocabulary.

        The default keeps the width so the draft can be *self-speculative*:
        its parameters are sliced straight out of the target's layer stack
        (:func:`repro.models.transformer.slice_draft_params`) and the
        embedding / head are shared, which is what makes the draft's
        argmax actually agree with the target's often enough to pay off.
        ``width_frac < 1`` instead describes an independently-trained
        draft (own embedding geometry — no parameter sharing possible).
        """
        def scale(n: int, frac: float, floor: int = 1) -> int:
            return max(floor, int(n * frac))

        kw: dict = {
            "name": f"{self.name}-draft",
            "n_layers": scale(self.n_layers, depth_frac),
        }
        if width_frac < 1.0:
            kw.update(
                d_model=scale(self.d_model, width_frac, 32),
                n_heads=scale(self.n_heads, width_frac),
                d_ff=scale(self.d_ff, width_frac, 32),
                d_head=self.head_dim,       # keep head geometry
            )
            kw["n_kv_heads"] = max(1, min(self.n_kv_heads, kw["n_heads"]))
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ArchConfig":
        """CPU smoke-test config of the same family."""
        return dataclasses.replace(
            self,
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            d_head=16,
            d_ff=128,
            vocab_size=256,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            n_shared_experts=min(self.n_shared_experts, 1),
            moe_group_size=16,
            # drop-free at smoke scale so decode == forward exactly;
            # capacity dropping itself is unit-tested in test_moe.py
            capacity_factor=4.0 if self.n_experts else self.capacity_factor,
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_version else 64,
            ssm_chunk=8,
            d_inner=128 if self.ssm_version else 0,
            attn_period=2 if self.attn_period else 0,
            n_encoder_layers=2 if self.n_encoder_layers else 0,
            window=min(self.window, 32) if self.window else None,
            frontend_seq=8 if self.frontend != "none" else 0,
            # exact prefill->decode smoke checks; float32 compute keeps
            # mathematically-equivalent dispatch shapes (decode step vs
            # k+1-wide speculative verify of the same position) from
            # flipping argmax on bf16-rounding near-ties, which is what
            # the engine bit-identity suites compare.  bf16 numerics stay
            # covered by the explicit-dtype kernel sweeps (test_kernels)
            # and by the full-size configs, which keep the bf16 default.
            compute_dtype="float32",
            cache_dtype="float32",
        )


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
