"""yi-6b — llama-arch GQA with kv=4.  [arXiv:2403.04652; hf]

32L d_model=4096 32H (kv=4) d_ff=11008 vocab=64000.  4 kv heads cannot be
sharded over a 16-way model axis: decode uses the seq-sharded
(flash-decoding) KV layout.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="yi-6b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=4,
    d_ff=11008, vocab_size=64000, rope_theta=5000000.0,
)
