"""falcon-mamba-7b — attention-free Mamba-1.  [arXiv:2410.05355; unverified]

64L d_model=4096 d_ff=0 vocab=65024 ssm_state=16, d_inner=8192.
Attention-sharding AT knobs are inapplicable (DESIGN.md
§Arch-applicability); the arch runs fully without them.  long_500k decode
runs: O(1)-in-sequence recurrent state.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=32, n_kv_heads=32,
    d_ff=0, vocab_size=65024,
    ssm_version=1, ssm_state=16, d_inner=8192,
)
