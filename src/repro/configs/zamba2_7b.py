"""zamba2-7b — Mamba-2 backbone + shared attention blocks.
[arXiv:2411.15242; unverified]

81L d_model=3584 32H (kv=32) d_ff=14336 vocab=32000 ssm_state=64.
Hybrid: SSD (Mamba-2) layers with a SHARED full transformer block applied
every 6 layers (weights shared across applications).  long_500k runs: SSM
state is O(1) in sequence; the shared attention uses a 4096-token sliding
window for long-context decode (documented adaptation, DESIGN.md
§Arch-applicability).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14336, vocab_size=32000,
    ssm_version=2, ssm_state=64, ssm_head_dim=64, ssm_chunk=128,
    d_inner=7168, attn_period=6, window=4096,
)
