"""moonshot-v1-16b-a3b (Moonlight) — MoE 64e top-6 + 2 shared experts.
[hf:moonshotai/Moonlight-16B-A3B; hf]

48L d_model=2048 16H (kv=16) d_ff=1408(per-expert) vocab=163840.
DeepSeek-V3-style fine-grained experts; expert-parallel over the model
axis (EP=16 -> 4 experts/chip).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab_size=163840,
    n_experts=64, top_k=6, n_shared_experts=2,
)
