"""pixtral-12b — VLM: pixtral-ViT frontend (stub) + mistral-nemo decoder.
[hf:mistralai/Pixtral-12B-2409; unverified]

40L d_model=5120 32H (kv=8) d_ff=14336 vocab=131072.  The vision tower is a
stub per spec: ``input_specs()`` provides 1024 precomputed patch embeddings
prepended to the text sequence; loss is computed on text positions only.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="pixtral-12b", family="vlm",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=131072, rope_theta=1000000000.0,
    frontend="vision", frontend_seq=1024,
)
