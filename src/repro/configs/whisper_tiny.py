"""whisper-tiny — encoder-decoder audio backbone (conv frontend stubbed).
[arXiv:2212.04356; unverified]

4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865.  Encoder-decoder: 4 encoder
+ 4 decoder layers, LayerNorm, GELU MLP, no RoPE (sinusoidal/learned
positions), tied embeddings.  ``input_specs()`` supplies precomputed frame
embeddings (B, 1500, d) — the conv stem is a stub per spec.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny", family="encdec",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
    d_ff=1536, vocab_size=51865,
    n_encoder_layers=4, norm="ln", act="gelu", use_rope=False,
    qkv_bias=True, tie_embeddings=True,
    frontend="audio", frontend_seq=1500,
)
