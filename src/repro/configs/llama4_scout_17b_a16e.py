"""llama4-scout-17b-16e — MoE 16e top-1 + 1 shared expert, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

48L d_model=5120 40H (kv=8) d_ff=8192(per-expert) vocab=202048.  40 heads
do not divide the 16-way model axis (layout select case); top-1 routing
with a shared expert per Llama-4.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab_size=202048, rope_theta=500000.0,
    n_experts=16, top_k=1, n_shared_experts=1,
)
