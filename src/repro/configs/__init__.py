"""Assigned-architecture configs (+ the paper's own FDM kernel config)."""
from .base import SHAPES, ArchConfig, ShapeConfig
from .registry import ARCHS, all_cells, cell_applicable, get_arch, get_shape

__all__ = ["ARCHS", "SHAPES", "ArchConfig", "ShapeConfig", "all_cells",
           "cell_applicable", "get_arch", "get_shape"]
