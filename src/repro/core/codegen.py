"""OATCodeGen — the preprocessor (paper §4.3, §5) adapted to Python source.

Parses ``#OAT$`` comment directives out of a Python function, applies the
paper's loop transformations to the annotated region, and writes generated
variant functions to an ``OAT/`` directory (mirroring the paper's
``./OAT/OAT_test.f`` output), returning runnable callables.

Supported region features:

* ``unroll`` — loop unrolling by PP factors (Samples 1/4), with remainder
  loops; unroll depth per loop variable.
* ``LoopFusionSplit`` — §5.2: loop split at any level named by
  ``SplitPoint (k, j, i)``, with flow-dependent scalars re-computed via
  ``SplitPointCopyDef`` / ``SplitPointCopyInsert``; loop fusion (collapse)
  of 2 or 3 nest levels; and their compositions.  For a 3-nest with a split
  point this yields exactly the paper's 8 variants.
* ``LoopFusion`` — §5.3: fusion variants × statement re-ordering
  (``RotationOrder sub region``), dependence-checked via stagegraph.

Restrictions (documented DSL contract): loops must be ``for v in range(...)``
with 1–2 arguments; statements inside AT regions are single-line.
"""
from __future__ import annotations

import ast
import copy
import inspect
import os
import re
import textwrap
from dataclasses import dataclass, field
from typing import Any, Callable

from .errors import OATCodegenError
from .stagegraph import (RW, interleave_orders, order_legal, stmt_rw,
                         uncovered_flow_deps)

# --------------------------------------------------------------------------
# loop IR
# --------------------------------------------------------------------------


@dataclass
class Stmt:
    src: str
    recompute: bool = False      # inside a SplitPointCopyDef region
    rotation_group: int = -1     # RotationOrder group index, -1 = unmarked


@dataclass
class SplitMarker:
    vars: tuple[str, ...]


@dataclass
class CopyInsertMarker:
    pass


@dataclass
class Loop:
    var: str
    range_args: list[str]
    body: list = field(default_factory=list)

    @property
    def lo(self) -> str:
        return "0" if len(self.range_args) == 1 else self.range_args[0]

    @property
    def hi(self) -> str:
        return self.range_args[-1] if len(self.range_args) <= 2 \
            else self.range_args[1]

    @property
    def length(self) -> str:
        if len(self.range_args) == 1:
            return f"({self.range_args[0]})"
        return f"(({self.hi}) - ({self.lo}))"


Node = Any  # Stmt | Loop | SplitMarker | CopyInsertMarker

_FOR_RE = re.compile(r"^for\s+(\w+)\s+in\s+range\((.*)\)\s*:\s*$")
_OAT_RE = re.compile(r"^#\s*[oO][aA][tT]\$\s*(.*)$")


def _split_args(s: str) -> list[str]:
    """Split a range(...) argument list at top-level commas."""
    out, depth, cur = [], 0, []
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur).strip())
    return [a for a in out if a]


def parse_loop_nest(lines: list[str]) -> list[Node]:
    """Parse dedented region-body source lines into the loop IR."""
    # normalise: keep (indent, content) for non-empty lines
    items: list[tuple[int, str]] = []
    for raw in lines:
        if not raw.strip():
            continue
        items.append((len(raw) - len(raw.lstrip()), raw.strip()))

    pos = 0
    in_copydef = False
    rotation_group = -1
    next_group = 0

    def parse_block(indent: int) -> list[Node]:
        nonlocal pos, in_copydef, rotation_group, next_group
        nodes: list[Node] = []
        while pos < len(items):
            ind, text = items[pos]
            if ind < indent:
                return nodes
            m = _OAT_RE.match(text)
            if m:
                d = m.group(1).strip()
                pos += 1
                low = d.lower()
                if low.startswith("splitpointcopydef"):
                    in_copydef = "start" in low
                elif low.startswith("splitpointcopyinsert"):
                    nodes.append(CopyInsertMarker())
                elif low.startswith("splitpoint"):
                    vars_m = re.search(r"\((.*)\)", d)
                    vs = tuple(v.strip() for v in
                               vars_m.group(1).split(",")) if vars_m else ()
                    nodes.append(SplitMarker(vs))
                elif low.startswith("rotationorder"):
                    if "start" in low:
                        rotation_group = next_group
                        next_group += 1
                    else:
                        rotation_group = -1
                # other directives (name/varied/...) handled by dsl.py
                continue
            fm = _FOR_RE.match(text)
            if fm:
                pos += 1
                body = parse_block(ind + 1)
                nodes.append(Loop(fm.group(1), _split_args(fm.group(2)), body))
                continue
            nodes.append(Stmt(text, recompute=in_copydef,
                              rotation_group=rotation_group))
            pos += 1
        return nodes

    return parse_block(0)


def render(nodes: list[Node], indent: int = 0) -> list[str]:
    pad = "    " * indent
    out: list[str] = []
    for n in nodes:
        if isinstance(n, Loop):
            out.append(f"{pad}for {n.var} in range("
                       f"{', '.join(n.range_args)}):")
            out.extend(render(n.body, indent + 1))
        elif isinstance(n, Stmt):
            out.append(pad + n.src)
        # markers render to nothing
    return out


# --------------------------------------------------------------------------
# transforms
# --------------------------------------------------------------------------


def _subst(src: str, var: str, repl: str) -> str:
    return re.sub(rf"\b{re.escape(var)}\b", repl, src)


def _subst_tree(nodes: list[Node], var: str, repl: str) -> list[Node]:
    out = []
    for n in nodes:
        if isinstance(n, Loop):
            if n.var == var:       # shadowed
                out.append(n)
                continue
            out.append(Loop(n.var, [_subst(a, var, repl)
                                    for a in n.range_args],
                            _subst_tree(n.body, var, repl)))
        elif isinstance(n, Stmt):
            out.append(Stmt(_subst(n.src, var, repl), n.recompute,
                            n.rotation_group))
        else:
            out.append(n)
    return out


def _strip_markers(nodes: list[Node]) -> list[Node]:
    out = []
    for n in nodes:
        if isinstance(n, Loop):
            out.append(Loop(n.var, list(n.range_args),
                            _strip_markers(n.body)))
        elif isinstance(n, Stmt):
            out.append(n)
    return out


def _find_loop(nodes: list[Node], var: str
               ) -> tuple[list[Node], int] | None:
    """(containing body list, index) of the loop with variable ``var``."""
    for i, n in enumerate(nodes):
        if isinstance(n, Loop):
            if n.var == var:
                return nodes, i
            found = _find_loop(n.body, var)
            if found:
                return found
    return None


def _scalar_writes(stmts: list[Stmt]) -> set[str]:
    out: set[str] = set()
    for s in stmts:
        try:
            tree = ast.parse(s.src)
        except SyntaxError:
            continue
        for node in ast.walk(tree):
            if isinstance(node, ast.Assign):
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        out.add(t.id)
            elif isinstance(node, ast.AugAssign) and isinstance(
                    node.target, ast.Name):
                out.add(node.target.id)
    return out


def transform_split(nodes: list[Node], var: str) -> list[Node]:
    """Loop fission at the loop named ``var`` (paper §5.2).

    Statements before the ``SplitPoint`` marker go to the first nest;
    the re-computation copies plus post-split statements to the second.
    """
    nodes = copy.deepcopy(nodes)
    found = _find_loop(nodes, var)
    if not found:
        raise OATCodegenError(f"no loop over {var!r} to split")
    parent_body, idx = found

    def has_marker(ns: list[Node]) -> bool:
        return any(isinstance(n, SplitMarker) or
                   (isinstance(n, Loop) and has_marker(n.body)) for n in ns)

    if not has_marker([parent_body[idx]]):
        raise OATCodegenError(f"loop {var!r} contains no SplitPoint")

    def dup(loop: Loop) -> tuple[Loop, Loop]:
        inner = next((n for n in loop.body if isinstance(n, Loop)
                      and has_marker([n])), None)
        if inner is not None:
            pre_i, post_i = dup(inner)
            pre_body = [pre_i if n is inner else copy.deepcopy(n)
                        for n in loop.body if not isinstance(n, SplitMarker)]
            # the second nest keeps only the loop (scalars before the split
            # level would be recomputed via copydef if needed)
            post_body = [post_i if n is inner else copy.deepcopy(n)
                         for n in loop.body
                         if isinstance(n, Loop) or isinstance(
                             n, CopyInsertMarker)]
            return (Loop(loop.var, list(loop.range_args), pre_body),
                    Loop(loop.var, list(loop.range_args), post_body))
        # innermost: partition statements at the SplitMarker
        pre: list[Node] = []
        post: list[Node] = []
        recompute: list[Stmt] = []
        seen_split = False
        for n in loop.body:
            if isinstance(n, SplitMarker):
                seen_split = True
                continue
            if isinstance(n, CopyInsertMarker):
                if seen_split:
                    post.extend(copy.deepcopy(s) for s in recompute)
                continue
            if isinstance(n, Stmt) and n.recompute:
                recompute.append(n)
            (post if seen_split else pre).append(copy.deepcopy(n))
        if not seen_split:
            raise OATCodegenError("SplitPoint marker not found in innermost "
                                  "loop body")
        if not any(isinstance(n, Stmt) and n.recompute for n in post):
            post = [copy.deepcopy(s) for s in recompute] + post
        # legality: scalar flow deps pre->post must be covered (§5.2)
        pre_s = [n for n in pre if isinstance(n, Stmt)]
        post_s = [n for n in post if isinstance(n, Stmt) and not n.recompute]
        uncovered = uncovered_flow_deps(
            [stmt_rw(s.src) for s in pre_s],
            [stmt_rw(s.src) for s in post_s],
            recompute_writes=set().union(
                *[stmt_rw(s.src).writes for s in recompute]) if recompute
            else set(),
            loop_carried=set().union(
                *[stmt_rw(s.src).writes for s in pre_s]) - _scalar_writes(
                    pre_s) if pre_s else set())
        if uncovered:
            raise OATCodegenError(
                f"loop split at {var!r} breaks flow dependences on "
                f"{sorted(uncovered)} — add SplitPointCopyDef (paper §5.2)")
        # re-computation must be idempotent: its inputs may not be
        # overwritten by the first nest (Sample 8's QG reads only
        # untouched fields)
        if recompute:
            rc_reads = set().union(*[stmt_rw(s.src).reads
                                     for s in recompute])
            rc_writes = set().union(*[stmt_rw(s.src).writes
                                      for s in recompute])
            pre_writes = set().union(
                *[stmt_rw(s.src).writes for s in pre_s]) if pre_s else set()
            clobbered = (rc_reads - rc_writes) & pre_writes
            if clobbered:
                raise OATCodegenError(
                    f"SplitPointCopyDef inputs {sorted(clobbered)} are "
                    f"overwritten before the split point — re-computation "
                    f"would not reproduce the value (paper §5.2)")
        return (Loop(loop.var, list(loop.range_args), pre),
                Loop(loop.var, list(loop.range_args), post))

    pre_l, post_l = dup(parent_body[idx])
    parent_body[idx:idx + 1] = [pre_l, post_l]
    return _strip_markers(nodes)


def transform_fuse(nodes: list[Node], vars: tuple[str, ...],
                   tag: str = "") -> list[Node]:
    """Collapse the first occurrence of the ``vars`` loop chain
    (outer..inner) into a single loop with index reconstruction."""
    nodes = copy.deepcopy(nodes)
    found = _find_loop(nodes, vars[0])
    if not found:
        raise OATCodegenError(f"no loop over {vars[0]!r} to fuse")
    parent_body, idx = found
    chain: list[Loop] = [parent_body[idx]]
    for v in vars[1:]:
        inner = [n for n in chain[-1].body if isinstance(n, Loop)]
        others = [n for n in chain[-1].body
                  if isinstance(n, Stmt) and n.src.strip()]
        if len(inner) != 1 or inner[0].var != v or others:
            raise OATCodegenError(
                f"loops {vars} are not perfectly nested; cannot fuse")
        chain.append(inner[0])
    fvar = "_".join(["_f", tag] + [l.var for l in chain])
    lens = [l.length for l in chain]
    total = "*".join(lens)
    decode: list[Node] = []
    rem = fvar
    for d, l in enumerate(chain):
        inner_prod = "*".join(lens[d + 1:]) if d + 1 < len(chain) else ""
        if inner_prod:
            decode.append(Stmt(
                f"{l.var} = ({l.lo}) + ({rem}) // ({inner_prod})"))
            nrem = f"_r{d}_{fvar}"
            decode.append(Stmt(f"{nrem} = ({rem}) % ({inner_prod})"))
            rem = nrem
        else:
            decode.append(Stmt(f"{l.var} = ({l.lo}) + ({rem})"))
    parent_body[idx] = Loop(fvar, [total], decode + list(chain[-1].body))
    return nodes


def transform_fuse_all(nodes: list[Node], vars: tuple[str, ...]
                       ) -> list[Node]:
    """Fuse every occurrence of the ``vars`` chain (post-split: both nests)."""
    nodes = copy.deepcopy(nodes)
    count = 0
    while _find_loop(nodes, vars[0]) is not None:
        parent_body, idx = _find_loop(nodes, vars[0])
        sub = transform_fuse([parent_body[idx]], vars, tag=str(count))
        parent_body[idx] = sub[0]
        count += 1
        if count > 16:
            raise OATCodegenError("fusion did not terminate")
    return nodes


def transform_unroll(nodes: list[Node], var: str, factor: int) -> list[Node]:
    """Unroll the loop named ``var`` by ``factor`` with a remainder loop."""
    if factor <= 1:
        return _strip_markers(copy.deepcopy(nodes))
    nodes = copy.deepcopy(nodes)
    found = _find_loop(nodes, var)
    if not found:
        raise OATCodegenError(f"no loop over {var!r} to unroll")
    parent_body, idx = found
    loop = parent_body[idx]
    if len(loop.range_args) == 3:
        raise OATCodegenError("unroll supports step-1 range loops only")
    lo, hi = loop.lo, loop.hi
    main_hi = f"({lo}) + (({hi}) - ({lo})) // {factor} * {factor}"
    main_body: list[Node] = []
    for d in range(factor):
        repl = loop.var if d == 0 else f"({loop.var} + {d})"
        main_body.extend(_subst_tree(_strip_markers(loop.body),
                                     loop.var, repl))
    main = Loop(loop.var, [str(lo), main_hi, str(factor)], main_body)
    rem = Loop(loop.var, [main_hi, str(hi)], _strip_markers(loop.body))
    parent_body[idx:idx + 1] = [main, rem]
    return _strip_markers(nodes)


def transform_rotation(nodes: list[Node], mode: str) -> list[Node]:
    """RotationOrder (§5.3): 'grouped' keeps source order, 'interleave'
    round-robins the marked statement groups (dependence-checked)."""
    nodes = copy.deepcopy(nodes)
    if mode == "grouped":
        return _strip_markers(nodes)

    def visit(body: list[Node]) -> list[Node]:
        for n in body:
            if isinstance(n, Loop):
                n.body = visit(n.body)
        marked_idx = [i for i, n in enumerate(body)
                      if isinstance(n, Stmt) and n.rotation_group >= 0]
        if not marked_idx:
            return body
        gids = sorted({body[i].rotation_group for i in marked_idx})
        sizes = [sum(1 for i in marked_idx
                     if body[i].rotation_group == g) for g in gids]
        stmts = [body[i] for i in marked_idx]
        perm = interleave_orders(sizes)[1]
        rws = [stmt_rw(s.src) for s in stmts]
        if not order_legal(rws, perm):
            raise OATCodegenError(
                "RotationOrder interleave violates dependences")
        reordered = [stmts[p] for p in perm]
        out, it = [], iter(reordered)
        for i, n in enumerate(body):
            out.append(next(it) if i in marked_idx else n)
        return out

    return _strip_markers(visit(nodes))


# --------------------------------------------------------------------------
# variant enumeration per region feature
# --------------------------------------------------------------------------


@dataclass
class Variant:
    index: int
    description: str
    nodes: list[Node]
    pps: dict[str, Any] = field(default_factory=dict)


def enumerate_fusionsplit_variants(nodes: list[Node]) -> list[Variant]:
    """Paper §5.2 Sample 8 — for a 3-nest (k,j,i) with a SplitPoint this
    returns exactly the 8 enumerated candidates."""
    def find_split_vars(ns) -> tuple[str, ...]:
        for n in ns:
            if isinstance(n, SplitMarker):
                return n.vars
            if isinstance(n, Loop):
                v = find_split_vars(n.body)
                if v:
                    return v
        return ()

    split_vars = find_split_vars(nodes)
    loops: list[str] = []

    def collect(ns):
        for n in ns:
            if isinstance(n, Loop):
                loops.append(n.var)
                collect(n.body)

    collect(nodes)
    out: list[Variant] = [Variant(1, "baseline", _strip_markers(
        copy.deepcopy(nodes)))]
    i = 2
    for v in split_vars:
        out.append(Variant(i, f"split@{v}", transform_split(nodes, v)))
        i += 1
    if len(loops) >= 2:
        fuse2 = tuple(loops[:2])
        out.append(Variant(i, f"fuse{fuse2}", transform_fuse_all(
            _strip_markers(copy.deepcopy(nodes)), fuse2)))
        i += 1
        if split_vars:
            out.append(Variant(
                i, f"split@{split_vars[0]}+fuse{fuse2}",
                transform_fuse_all(transform_split(nodes, split_vars[0]),
                                   fuse2)))
            i += 1
    if len(loops) >= 3:
        fuse3 = tuple(loops[:3])
        out.append(Variant(i, f"collapse{fuse3}", transform_fuse_all(
            _strip_markers(copy.deepcopy(nodes)), fuse3)))
        i += 1
        if split_vars:
            out.append(Variant(
                i, f"split@{split_vars[0]}+collapse{fuse3}",
                transform_fuse_all(transform_split(nodes, split_vars[0]),
                                   fuse3)))
            i += 1
    return out


def enumerate_fusion_variants(nodes: list[Node]) -> list[Variant]:
    """Paper §5.3 Sample 9 — fusion options × rotation orders."""
    loops: list[str] = []

    def collect(ns):
        for n in ns:
            if isinstance(n, Loop):
                loops.append(n.var)
                collect(n.body)

    collect(nodes)
    fusions: list[tuple[str, tuple[str, ...] | None]] = [("nofuse", None)]
    if len(loops) >= 2:
        fusions.append((f"fuse{tuple(loops[:2])}", tuple(loops[:2])))
    if len(loops) >= 3:
        fusions.append((f"collapse{tuple(loops[:3])}", tuple(loops[:3])))
    out: list[Variant] = []
    i = 1
    for fname, fvars in fusions:
        for mode in ("grouped", "interleave"):
            base = transform_rotation(nodes, mode)
            if fvars is not None:
                base = transform_fuse_all(base, fvars)
            out.append(Variant(i, f"{fname}+{mode}", base))
            i += 1
    return out


def enumerate_unroll_variants(nodes: list[Node], factors: dict[str, int]
                              ) -> Variant:
    """One unroll variant for the given {loop var: factor} assignment."""
    cur = copy.deepcopy(nodes)
    for var, f in factors.items():
        cur = transform_unroll(cur, var, int(f))
    return Variant(0, "unroll" + str(sorted(factors.items())), cur,
                   dict(factors))


# --------------------------------------------------------------------------
# source-level orchestration (the OATCodeGen command, §4.3)
# --------------------------------------------------------------------------


@dataclass
class GeneratedVariant:
    name: str
    index: int
    description: str
    source: str
    fn: Callable
    pps: dict[str, Any] = field(default_factory=dict)


@dataclass
class RegionSource:
    at_type: str
    feature: str
    name: str
    body_lines: list[str]
    header_span: tuple[int, int]       # line span in the function body
    subtypes: dict[str, str] = field(default_factory=dict)


_REGION_RE = re.compile(
    r"^#\s*[oO][aA][tT]\$\s*(install|static|dynamic)\s+(\w+)\s*"
    r"(?:\(([^)]*)\))?\s*region\s+(start|end)\s*$")
_SUBTYPE_RE = re.compile(r"^#\s*[oO][aA][tT]\$\s*(name|varied|fitting|search|"
                         r"parameter|according|number|debug)\s+(.*)$")


def extract_regions(src: str) -> tuple[list[str], list[RegionSource]]:
    """Find top-level AT regions in (dedented) function source lines."""
    lines = src.splitlines()
    regions: list[RegionSource] = []
    i = 0
    while i < len(lines):
        m = _REGION_RE.match(lines[i].strip())
        if m and m.group(4) == "start":
            at_type, feature = m.group(1), m.group(2)
            start = i
            subtypes: dict[str, str] = {}
            j = i + 1
            while j < len(lines):
                sm = _SUBTYPE_RE.match(lines[j].strip())
                if sm:
                    subtypes[sm.group(1)] = sm.group(2).strip()
                    j += 1
                    continue
                break
            body_start = j
            depth = 1
            while j < len(lines):
                em = _REGION_RE.match(lines[j].strip())
                if em:
                    depth += 1 if em.group(4) == "start" else -1
                    if depth == 0:
                        break
                j += 1
            if depth != 0:
                raise OATCodegenError(
                    f"unterminated region at line {start + 1}")
            regions.append(RegionSource(
                at_type=at_type, feature=feature,
                name=subtypes.get("name", f"region{len(regions)}"),
                body_lines=lines[body_start:j],
                header_span=(start, j), subtypes=subtypes))
            i = j + 1
            continue
        i += 1
    return lines, regions


class OATCodeGen:
    """``OATCodeGen test.py`` (paper §4.3): generate variant code under
    ``<outdir>/OAT/`` and return runnable callables."""

    def __init__(self, outdir: str = ".", debug: bool = False,
                 visualization: bool = False):
        self.outdir = os.path.join(outdir, "OAT")
        self.debug = debug
        self.visualization = visualization

    def generate(self, fn: Callable) -> dict[str, list[GeneratedVariant]]:
        src = textwrap.dedent(inspect.getsource(fn))
        src_lines = src.splitlines()
        def_idx = next(i for i, l in enumerate(src_lines)
                       if l.startswith("def "))
        header = src_lines[def_idx]
        body = textwrap.dedent("\n".join(src_lines[def_idx + 1:]))
        lines, regions = extract_regions(body)
        if not regions:
            raise OATCodegenError(f"{fn.__name__} has no #OAT$ regions")

        out: dict[str, list[GeneratedVariant]] = {}
        all_sources: list[str] = [
            "# Auto-generated by OATCodeGen (ppOpen-AT reproduction).",
            "# One function per variant; numerically identical to the "
            "baseline.", ""]
        for reg in regions:
            body_ir = parse_loop_nest(reg.body_lines)
            if reg.feature == "LoopFusionSplit":
                variants = enumerate_fusionsplit_variants(body_ir)
            elif reg.feature == "LoopFusion":
                variants = enumerate_fusion_variants(body_ir)
            elif reg.feature == "unroll":
                varied = reg.subtypes.get("varied", "")
                vm = re.match(r"\(([^)]*)\)\s*from\s+(\S+)\s+to\s+(\S+)",
                              varied)
                if not vm:
                    raise OATCodegenError(
                        f"unroll region {reg.name!r} needs "
                        f"'varied (v,...) from X to Y'")
                uvars = [v.strip() for v in vm.group(1).split(",")]
                lo, hi = int(vm.group(2)), int(vm.group(3))
                variants = []
                # variant per factor assignment is generated lazily in real
                # tuning; for the generated file emit the diagonal plus edges
                for f in sorted({lo, max(lo, min(4, hi)), hi}):
                    v = enumerate_unroll_variants(
                        body_ir, {u: f for u in uvars})
                    v.index = len(variants) + 1
                    variants.append(v)
            else:
                raise OATCodegenError(
                    f"unsupported codegen feature {reg.feature!r}")

            gen: list[GeneratedVariant] = []
            for v in variants:
                vname = f"{fn.__name__}__{reg.name}__v{v.index}"
                new_body = list(lines)
                s, e = reg.header_span
                rendered = render(v.nodes)
                new_body[s:e + 1] = rendered or ["pass"]
                vsrc = (header.replace(f"def {fn.__name__}(",
                                       f"def {vname}(", 1) + "\n" +
                        textwrap.indent("\n".join(new_body), "    "))
                ns: dict = dict(fn.__globals__)
                try:
                    exec(compile(vsrc, f"<OAT:{vname}>", "exec"), ns)
                except SyntaxError as exc:
                    raise OATCodegenError(
                        f"generated variant {vname} does not compile: {exc}\n"
                        f"{vsrc}") from exc
                gen.append(GeneratedVariant(vname, v.index, v.description,
                                            vsrc, ns[vname], v.pps))
                all_sources.append(f"# --- {reg.name} variant {v.index}: "
                                   f"{v.description}")
                all_sources.append(vsrc)
                all_sources.append("")
            out[reg.name] = gen

        os.makedirs(self.outdir, exist_ok=True)
        path = os.path.join(self.outdir, f"OAT_{fn.__name__}.py")
        with open(path, "w") as f:
            f.write("\n".join(all_sources))
        return out

    def unroll_variant(self, fn: Callable, region_name: str,
                       factors: dict[str, int]) -> GeneratedVariant:
        """Generate one unroll variant on demand (used by install-time AT)."""
        src = textwrap.dedent(inspect.getsource(fn))
        src_lines = src.splitlines()
        def_idx = next(i for i, l in enumerate(src_lines)
                       if l.startswith("def "))
        header = src_lines[def_idx]
        body = textwrap.dedent("\n".join(src_lines[def_idx + 1:]))
        lines, regions = extract_regions(body)
        reg = next(r for r in regions if r.name == region_name)
        v = enumerate_unroll_variants(parse_loop_nest(reg.body_lines),
                                      factors)
        vname = f"{fn.__name__}__{region_name}__u" + "_".join(
            f"{k}{val}" for k, val in sorted(factors.items()))
        new_body = list(lines)
        s, e = reg.header_span
        new_body[s:e + 1] = render(v.nodes)
        vsrc = (header.replace(f"def {fn.__name__}(", f"def {vname}(", 1)
                + "\n" + textwrap.indent("\n".join(new_body), "    "))
        ns: dict = dict(fn.__globals__)
        exec(compile(vsrc, f"<OAT:{vname}>", "exec"), ns)
        return GeneratedVariant(vname, 0, v.description, vsrc, ns[vname],
                                dict(factors))
