"""Parameter information files — the paper's S-expression format (§4.2.1, §6.2).

Example from the paper::

    (SetCacheParam
    (CacheSize 64)
    (CacheLine 8)
    )

and the nested before-execute-time form::

    (MyMatMul
    (OAT_NUMPROCS 4)
    (OAT_SAMPDIST 1024)
    (OAT_PROBSIZE 1024
    (MyMatMul_I 4)
    (MyMatMul_J 8))
    )

We model a file as a list of ``Node`` trees.  A ``Node`` has a ``name``, an
optional scalar ``value`` (the paper's ``(key value)`` pairs and the keyed
``(OAT_PROBSIZE 1024 ...)`` group headers), and child nodes.

File-name conventions (§6.2) are provided by :func:`param_path`:
``OAT_InstallParam{X}.dat`` etc., where X is the AT region name ('' for the
global file).
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Iterator

from .errors import OATSpecError

Scalar = int | float | str | bool


def _fmt_scalar(v: Scalar) -> str:
    if isinstance(v, bool):
        return ".true." if v else ".false."
    if isinstance(v, float):
        return repr(v)
    if isinstance(v, str):
        # quote anything that would not tokenize back to the same string
        needs_quote = (not v or any(c.isspace() for c in v)
                       or any(c in '()"' for c in v)
                       or _parse_scalar(v) != v)
        return f'"{v}"' if needs_quote else v
    return str(v)


def _parse_scalar(tok: str) -> Scalar:
    if tok == ".true.":
        return True
    if tok == ".false.":
        return False
    try:
        return int(tok)
    except ValueError:
        pass
    try:
        return float(tok)
    except ValueError:
        pass
    if len(tok) >= 2 and tok[0] == '"' and tok[-1] == '"':
        return tok[1:-1]
    return tok


@dataclass
class Node:
    """One parenthesised record: ``(name [value] child*)``."""

    name: str
    value: Scalar | None = None
    children: list["Node"] = field(default_factory=list)

    # -- convenience accessors -------------------------------------------
    def child(self, name: str) -> "Node | None":
        for c in self.children:
            if c.name == name:
                return c
        return None

    def child_value(self, name: str, default: Scalar | None = None) -> Scalar | None:
        c = self.child(name)
        return default if c is None or c.value is None else c.value

    def set(self, name: str, value: Scalar) -> None:
        c = self.child(name)
        if c is None:
            self.children.append(Node(name, value))
        else:
            c.value = value

    def keyed_child(self, name: str, value: Scalar) -> "Node | None":
        """Find e.g. the ``(OAT_PROBSIZE 1024 ...)`` group for value 1024."""
        for c in self.children:
            if c.name == name and c.value == value:
                return c
        return None

    def as_dict(self) -> dict:
        """Flatten leaf children to a dict (group headers keep subtrees)."""
        out: dict = {}
        for c in self.children:
            if c.children:
                out.setdefault(c.name, []).append((c.value, c.as_dict()))
            else:
                out[c.name] = c.value
        return out

    def walk(self) -> Iterator["Node"]:
        yield self
        for c in self.children:
            yield from c.walk()


# --------------------------------------------------------------------------
# serialisation
# --------------------------------------------------------------------------

def dumps(nodes: list[Node]) -> str:
    lines: list[str] = []

    def emit(n: Node, depth: int) -> None:
        head = f"({n.name}" + (f" {_fmt_scalar(n.value)}" if n.value is not None else "")
        if not n.children:
            lines.append(head + ")")
            return
        lines.append(head)
        for c in n.children:
            emit(c, depth + 1)
        lines.append(")")

    for n in nodes:
        emit(n, 0)
    return "\n".join(lines) + "\n"


def _tokenize(text: str) -> list[str]:
    toks: list[str] = []
    i, n = 0, len(text)
    while i < n:
        ch = text[i]
        if ch in "()":
            toks.append(ch)
            i += 1
        elif ch == '"':
            j = text.find('"', i + 1)
            if j < 0:
                raise OATSpecError("unterminated string in parameter file")
            toks.append(text[i : j + 1])
            i = j + 1
        elif ch.isspace():
            i += 1
        else:
            j = i
            while j < n and not text[j].isspace() and text[j] not in "()":
                j += 1
            toks.append(text[i:j])
            i = j
    return toks


def loads(text: str) -> list[Node]:
    toks = _tokenize(text)
    pos = 0

    def parse() -> Node:
        nonlocal pos
        if toks[pos] != "(":
            raise OATSpecError(f"expected '(' at token {pos}: {toks[pos]!r}")
        pos += 1
        if pos >= len(toks) or toks[pos] in "()":
            raise OATSpecError("expected a name after '('")
        node = Node(toks[pos])
        pos += 1
        # optional scalar value
        if pos < len(toks) and toks[pos] not in "()":
            node.value = _parse_scalar(toks[pos])
            pos += 1
        while pos < len(toks) and toks[pos] == "(":
            node.children.append(parse())
        if pos >= len(toks) or toks[pos] != ")":
            raise OATSpecError(f"missing ')' for node {node.name}")
        pos += 1
        return node

    nodes: list[Node] = []
    while pos < len(toks):
        nodes.append(parse())
    return nodes


# --------------------------------------------------------------------------
# file conventions (paper §6.2)
# --------------------------------------------------------------------------

PHASE_FILE = {"install": "OAT_InstallParam", "static": "OAT_StaticParam",
              "dynamic": "OAT_DynamicParam"}


def param_path(workdir: str, phase: str, region: str = "", user: bool = False) -> str:
    """Path of a system (output) or user (``...Def``) parameter file."""
    if phase not in PHASE_FILE:
        raise OATSpecError(f"unknown phase {phase!r}")
    stem = PHASE_FILE[phase] + ("Def" if user else "") + region + ".dat"
    return os.path.join(workdir, stem)


def load_file(path: str) -> list[Node]:
    if not os.path.exists(path):
        return []
    with open(path, "r") as f:
        return loads(f.read())


def save_file(path: str, nodes: list[Node]) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        f.write(dumps(nodes))
    os.replace(tmp, path)  # atomic on POSIX
