"""Parameter inference — the ``fitting`` subtype specifier (paper §3.4.3).

Given measured costs at *sampled* PP values, infer the cost over the whole
``varied`` range and return the predicted-optimal PP value:

  * ``least-squares <order>`` — polynomial least squares.
  * ``dspline``               — discrete (natural cubic) spline through the
                                samples, evaluated on the integer grid; the
                                paper credits the d-spline method to the
                                Tanaka Laboratory, Kogakuin University.
  * ``user-defined <expr>``   — least squares over user basis terms; the
                                expression is linear in free coefficients
                                ``c0..cK`` and may reference ``x`` and BPs.
  * ``auto``                  — model selection by leave-one-out CV among
                                polynomial orders 1..5 and the d-spline.

If ``fitting`` is omitted the search is exhaustive over the full range
(handled by search.py; this module is only consulted when sampling is used).
"""
from __future__ import annotations

import math
import re
from typing import Callable, Sequence

import numpy as np

from .errors import OATSpecError
from .region import Fitting


# --------------------------------------------------------------------------
# basic fitters: fit(xs, ys) -> predict(grid) -> np.ndarray
# --------------------------------------------------------------------------

def fit_polynomial(xs: Sequence[float], ys: Sequence[float], order: int
                   ) -> Callable[[np.ndarray], np.ndarray]:
    xs = np.asarray(xs, dtype=np.float64)
    ys = np.asarray(ys, dtype=np.float64)
    order = min(order, len(xs) - 1) if len(xs) > 1 else 0
    # scale x for conditioning
    mu, sd = xs.mean(), xs.std() or 1.0
    coeffs = np.polyfit((xs - mu) / sd, ys, order)

    def predict(grid: np.ndarray) -> np.ndarray:
        return np.polyval(coeffs, (np.asarray(grid, np.float64) - mu) / sd)

    return predict


def fit_dspline(xs: Sequence[float], ys: Sequence[float]
                ) -> Callable[[np.ndarray], np.ndarray]:
    """Natural cubic spline through (xs, ys), evaluated on a discrete grid.

    Classic tridiagonal construction; linear extrapolation outside the hull.
    """
    xs = np.asarray(xs, dtype=np.float64)
    ys = np.asarray(ys, dtype=np.float64)
    order = np.argsort(xs)
    xs, ys = xs[order], ys[order]
    n = len(xs)
    if n < 3:
        return fit_polynomial(xs, ys, 1)
    h = np.diff(xs)
    if np.any(h == 0):
        raise OATSpecError("dspline requires distinct sample points")
    # second derivatives M solve: tridiagonal natural-spline system
    a = np.zeros((n, n))
    rhs = np.zeros(n)
    a[0, 0] = a[-1, -1] = 1.0
    for i in range(1, n - 1):
        a[i, i - 1] = h[i - 1]
        a[i, i] = 2.0 * (h[i - 1] + h[i])
        a[i, i + 1] = h[i]
        rhs[i] = 6.0 * ((ys[i + 1] - ys[i]) / h[i] - (ys[i] - ys[i - 1]) / h[i - 1])
    m = np.linalg.solve(a, rhs)

    def predict(grid: np.ndarray) -> np.ndarray:
        g = np.asarray(grid, dtype=np.float64)
        out = np.empty_like(g)
        for j, x in enumerate(g):
            if x <= xs[0]:
                slope = (ys[1] - ys[0]) / h[0] - h[0] * m[1] / 6.0
                out[j] = ys[0] + slope * (x - xs[0])
                continue
            if x >= xs[-1]:
                slope = (ys[-1] - ys[-2]) / h[-1] + h[-1] * m[-2] / 6.0
                out[j] = ys[-1] + slope * (x - xs[-1])
                continue
            i = int(np.searchsorted(xs, x) - 1)
            i = min(max(i, 0), n - 2)
            t0, t1 = x - xs[i], xs[i + 1] - x
            out[j] = (m[i] * t1 ** 3 + m[i + 1] * t0 ** 3) / (6 * h[i]) \
                + (ys[i] / h[i] - m[i] * h[i] / 6) * t1 \
                + (ys[i + 1] / h[i] - m[i + 1] * h[i] / 6) * t0
        return out

    return predict


_COEF_RE = re.compile(r"\bc(\d+)\b")
_SAFE_FUNCS = {"log": np.log, "dlog": np.log, "log2": np.log2, "exp": np.exp,
               "sqrt": np.sqrt, "abs": np.abs, "min": np.minimum,
               "max": np.maximum, "pi": math.pi}


def fit_user_defined(xs: Sequence[float], ys: Sequence[float], expr: str,
                     env: dict | None = None
                     ) -> Callable[[np.ndarray], np.ndarray]:
    """Least squares with a user expression linear in coefficients c0..cK.

    e.g. ``"c0 + c1*x + c2*x*log(x)"`` (paper: 'infer using the least squares
    method, using the mathematical expression specified by the user').
    Implemented by evaluating the expression's gradient w.r.t. each
    coefficient (finite basis extraction: set ck=1, others=0 — valid because
    the model is linear in c).
    """
    ks = sorted({int(m) for m in _COEF_RE.findall(expr)})
    if not ks:
        raise OATSpecError(f"user-defined fitting expr has no coefficients: {expr!r}")
    xs = np.asarray(xs, dtype=np.float64)
    ys = np.asarray(ys, dtype=np.float64)

    def eval_expr(x: np.ndarray, coef: dict[int, float]) -> np.ndarray:
        ns = dict(_SAFE_FUNCS)
        ns.update(env or {})
        ns["x"] = x
        for k in ks:
            ns[f"c{k}"] = coef.get(k, 0.0)
        return np.asarray(eval(expr, {"__builtins__": {}}, ns), dtype=np.float64)  # noqa: S307

    zero = eval_expr(xs, {})
    basis = np.stack([eval_expr(xs, {k: 1.0}) - zero for k in ks], axis=1)
    coef, *_ = np.linalg.lstsq(basis, ys - zero, rcond=None)
    cmap = {k: float(c) for k, c in zip(ks, coef)}

    def predict(grid: np.ndarray) -> np.ndarray:
        return eval_expr(np.asarray(grid, np.float64), cmap)

    return predict


def _loo_cv_error(xs, ys, fitter: Callable) -> float:
    xs = np.asarray(xs, np.float64)
    ys = np.asarray(ys, np.float64)
    if len(xs) < 4:
        return float("inf")
    errs = []
    for i in range(len(xs)):
        m = np.ones(len(xs), bool)
        m[i] = False
        try:
            pred = fitter(xs[m], ys[m])(np.array([xs[i]]))[0]
        except Exception:
            return float("inf")
        errs.append((pred - ys[i]) ** 2)
    return float(np.mean(errs))


def fit_auto(xs: Sequence[float], ys: Sequence[float]
             ) -> Callable[[np.ndarray], np.ndarray]:
    """'auto': model selection by leave-one-out CV (poly 1..5 vs dspline)."""
    candidates: list[tuple[float, Callable]] = []
    for order in range(1, 6):
        if order >= len(xs):
            break
        err = _loo_cv_error(xs, ys, lambda a, b, o=order: fit_polynomial(a, b, o))
        candidates.append((err, fit_polynomial(xs, ys, order)))
    err = _loo_cv_error(xs, ys, fit_dspline)
    candidates.append((err, fit_dspline(xs, ys)))
    candidates.sort(key=lambda t: t[0])
    return candidates[0][1]


# --------------------------------------------------------------------------
# entry point used by search.py
# --------------------------------------------------------------------------

def fitted_minimum(fitting: Fitting, xs: Sequence[int], ys: Sequence[float],
                   grid: Sequence[int], env: dict | None = None) -> int:
    """Fit the sampled costs and return the grid point of minimum predicted
    cost (the paper's 'optimum parameter determined by inference')."""
    if len(xs) == 0:
        raise OATSpecError("no sample points measured")
    if len(xs) == 1:
        return int(xs[0])
    if fitting.method == "least-squares":
        predict = fit_polynomial(xs, ys, fitting.order)
    elif fitting.method == "dspline":
        predict = fit_dspline(xs, ys)
    elif fitting.method == "user-defined":
        if not fitting.expr:
            raise OATSpecError("user-defined fitting requires an expression")
        predict = fit_user_defined(xs, ys, fitting.expr, env)
    elif fitting.method == "auto":
        predict = fit_auto(xs, ys)
    else:
        raise OATSpecError(f"unknown fitting method {fitting.method!r}")
    g = np.asarray(list(grid), dtype=np.float64)
    pred = predict(g)
    return int(g[int(np.argmin(pred))])


def auto_sample_points(lo: int, hi: int, budget: int = 8) -> list[int]:
    """``sampled auto`` — geometric-ish spread over [lo, hi]."""
    if hi - lo + 1 <= budget:
        return list(range(lo, hi + 1))
    pts = np.unique(np.round(np.geomspace(max(lo, 1), hi, budget)).astype(int))
    pts = pts[(pts >= lo) & (pts <= hi)]
    out = sorted(set([lo, hi]) | set(int(p) for p in pts))
    return out
