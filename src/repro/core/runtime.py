"""FIBER runtime — the paper's §4 API (OAT_ATexec / ATset / ATdel / ...).

Implements install-time, before-execute-time (*static*) and run-time
(*dynamic*) auto-tuning over registered :class:`~.region.ATRegion` objects,
with the paper's exact semantics:

* execution priority install -> static -> dynamic; deviation raises
  :class:`OATPriorityError` (§3.2);
* install/static AT will not run unless the default basic parameters are set
  (§4.2.2) — :class:`OATMissingBasicParamError`;
* static AT sweeps the BP sample points ``OAT_STARTTUNESIZE ..
  OAT_ENDTUNESIZE step OAT_SAMPDIST`` (plus any user BPs registered with
  ``OAT_BPset``/``OAT_BPsetName``) and records per-BP-point optima in nested
  ``(OAT_PROBSIZE <size> (Region_P v) ...)`` records (§4.2.2);
* parameter collision (§6.3): a PP pinned in a user ``...Def`` file halts AT
  for that parameter and the user value is force-set;
* run-time AT is only *armed* by ``OAT_ATexec(OAT_DYNAMIC, ...)``; actual
  tuning happens when the region is invoked (§4.1), one candidate per call
  until all alternatives have been observed, then the winner is committed;
* ``OAT_DynPerfThis`` executes a region with previously-optimised parameters
  and performs no tuning (§4.2.3).
"""
from __future__ import annotations

import itertools
import time
from dataclasses import dataclass, field
from typing import Any, Callable

from . import paramfile
from .cost import According
from .errors import (OATMissingBasicParamError, OATParamCollisionError,
                     OATPriorityError, OATSpecError)
from .executor import WallClockExecutor
from .fitting import fit_dspline, fit_polynomial, fit_user_defined
from .params import (DEFAULT_BASIC_PARAMS, OAT_DEBUG, OAT_ENDTUNESIZE,
                     OAT_NUMPROCS, OAT_SAMPDIST, OAT_STARTTUNESIZE,
                     OAT_TUNEDYNAMIC, OAT_TUNESTATIC, ParamStore)
from .region import ATRegion, RegionRegistry
from .search import SearchPlan

# paper §6.1 tuning-type constants
OAT_ALL = 0
OAT_INSTALL = 1
OAT_STATIC = 2
OAT_DYNAMIC = 3

_KIND_PHASE = {OAT_INSTALL: "install", OAT_STATIC: "static",
               OAT_DYNAMIC: "dynamic"}

OAT_PROBSIZE = "OAT_PROBSIZE"


@dataclass
class BPSpec:
    """A user basic parameter registered via OAT_BPset / OAT_BPsetName."""

    name: str
    start_name: str = ""
    end_name: str = ""
    dist_name: str = ""
    cdf: str | None = None     # OAT_BPsetCDF method string

    def sample_points(self, store: ParamStore) -> list[int]:
        start = store.get_bp(self.start_name or OAT_STARTTUNESIZE)
        end = store.get_bp(self.end_name or OAT_ENDTUNESIZE)
        dist = store.get_bp(self.dist_name or OAT_SAMPDIST)
        if start is None or end is None or dist is None:
            raise OATMissingBasicParamError(
                f"sample range for basic parameter {self.name!r} is not set")
        return list(range(int(start), int(end) + 1, int(dist)))


@dataclass
class DynamicState:
    tried: dict[int, float] = field(default_factory=dict)   # cand -> cost
    committed: int | None = None
    env_seen: dict[int, dict] = field(default_factory=dict)


class ATContext:
    """One auto-tuning universe: registry + parameter store + files."""

    def __init__(self, workdir: str = ".", feedback: bool = False,
                 executor_factory: Callable[..., Callable] | None = None):
        self.workdir = workdir
        self.store = ParamStore(feedback=feedback)
        self.registry = RegionRegistry()
        self.phase_ran = {"install": False, "static": False, "dynamic": False}
        self.routines: dict[str, list[str]] = {
            "install": [], "static": [], "dynamic": []}
        self.bp_specs: dict[str, BPSpec] = {}
        self.collisions: list[tuple[str, str, Any]] = []   # (region, pp, value)
        self.dynamic_state: dict[str, DynamicState] = {}
        self.dynamic_armed = False
        self.debug_lines: list[str] = []
        self.search_log: dict[str, int] = {}
        # executor_factory(region, bp_env) -> measure(assignment)->cost;
        # default: wall-clock over the region's variant generator.
        self._executor_factory = executor_factory or self._default_executor
        # searcher(plan, measure, init) -> SearchResult; None keeps the
        # paper's per-region method composition (SearchPlan.run).  The
        # repro.at session sets this from its `searchers` registry.
        self.searcher: Callable | None = None

    # ------------------------------------------------------------------
    # registration (decorators in directives.py call these)
    # ------------------------------------------------------------------
    def register(self, region: ATRegion) -> ATRegion:
        self.registry.register(region)
        if region.parent is None:
            self.routines[region.at_type].append(region.name)
        return region

    # paper API ---------------------------------------------------------
    def OAT_ATset(self, kind: int, names: list[str] | str) -> None:
        phase = _KIND_PHASE[kind]
        if isinstance(names, str):
            names = [names]
        for n in names:
            self.registry.get(n)           # must exist
            if n not in self.routines[phase]:
                self.routines[phase].append(n)

    def OAT_ATdel(self, routines: str, name: str) -> None:
        phase = {"OAT_InstallRoutines": "install",
                 "OAT_StaticRoutines": "static",
                 "OAT_DynamicRoutines": "dynamic"}.get(routines, routines)
        if phase not in self.routines:
            raise OATSpecError(f"unknown routine set {routines!r}")
        if name in self.routines[phase]:
            self.routines[phase].remove(name)

    def OAT_ATInstallInit(self, routines: str | None = None) -> None:
        """Undo install-time tuning so it can run again (§4.2.1)."""
        self.phase_ran["install"] = False
        self.store.layers["install"].clear()

    def OAT_BPset(self, name: str) -> None:
        self.bp_specs.setdefault(name, BPSpec(name))

    def OAT_BPsetName(self, kind: str, bp: str, param_name: str) -> None:
        spec = self.bp_specs.setdefault(bp, BPSpec(bp))
        k = kind.strip().upper().strip('"')
        if k == "STARTTUNESIZE":
            spec.start_name = param_name
        elif k == "ENDTUNESIZE":
            spec.end_name = param_name
        elif k == "SAMPDIST":
            spec.dist_name = param_name
        else:
            raise OATSpecError(f"unknown BPsetName kind {kind!r}")

    def OAT_BPsetCDF(self, bp: str, cdf: str) -> None:
        self.bp_specs.setdefault(bp, BPSpec(bp)).cdf = cdf

    # ------------------------------------------------------------------
    # OAT_ATexec — the main entry (§4.1)
    # ------------------------------------------------------------------
    def OAT_ATexec(self, kind: int, routines: list[str] | str | None = None
                   ) -> None:
        kinds = [OAT_INSTALL, OAT_STATIC, OAT_DYNAMIC] if kind == OAT_ALL \
            else [kind]
        for k in kinds:
            phase = _KIND_PHASE[k]
            names = self._resolve_routines(phase, routines)
            self._check_priority(phase)
            if phase == "install":
                self._run_install(names)
            elif phase == "static":
                self._run_static(names)
            else:
                self._arm_dynamic(names)
            self.phase_ran[phase] = True

    def _resolve_routines(self, phase: str, routines) -> list[str]:
        if routines is None or routines in (
                "OAT_InstallRoutines", "OAT_StaticRoutines",
                "OAT_DynamicRoutines"):
            return list(self.routines[phase])
        if routines == "OAT_AllRoutines":
            return [n for n in self.registry.all_names()
                    if self.registry.get(n).at_type == phase]
        if isinstance(routines, str):
            return [routines]
        return list(routines)

    def _check_priority(self, phase: str) -> None:
        """§3.2 — install -> static -> dynamic, strictly."""
        if phase == "static":
            if self.routines["install"] and not self.phase_ran["install"]:
                raise OATPriorityError(
                    "before execute-time AT requested but install-time AT has "
                    "not run (paper §3.2 execution priority)")
            if not self.store.has_default_bps():
                raise OATMissingBasicParamError(
                    "before execute-time AT will not run if the basic "
                    "parameters are not set (paper §4.2.2)")
            if not bool(self.store.get_bp(OAT_TUNESTATIC, True)):
                return
        if phase == "dynamic":
            if self.routines["static"] and not self.phase_ran["static"] \
                    and bool(self.store.get_bp(OAT_TUNESTATIC, True)):
                raise OATPriorityError(
                    "run-time AT requested but before execute-time AT has "
                    "not run (paper §3.2 execution priority)")
        if phase == "install" and not self.store.has_default_bps():
            raise OATMissingBasicParamError(
                "install-time AT will not run unless OAT_NUMPROCS, "
                "OAT_STARTTUNESIZE, OAT_ENDTUNESIZE and OAT_SAMPDIST are set "
                "(paper §4.2.2)")

    # ------------------------------------------------------------------
    # install-time
    # ------------------------------------------------------------------
    def _default_executor(self, region: ATRegion, bp_env: dict
                          ) -> Callable[[dict], float]:
        def make_variant(assignment: dict) -> Callable[[], Any]:
            kwargs = self._bare(region, assignment)
            kwargs.update({k: v for k, v in bp_env.items()
                           if k in region.bp_names})
            return lambda: region.fn(**kwargs)
        return WallClockExecutor(make_variant, repeats=1, warmup=0)

    @staticmethod
    def _bare(region: ATRegion, assignment: dict) -> dict:
        """Map qualified PP names (MyMatMul_I) back to bare kwargs (i)."""
        out = {}
        for r in region.flatten():
            if r.varied is None:
                continue
            for bare, pp in zip(r.varied.names, r.pp_names):
                if pp in assignment:
                    out[bare] = assignment[pp]
        return out

    def _pinned_values(self, phase: str, region: ATRegion) -> dict[str, Any]:
        """User Def-file pins for this region (collision source, §6.3)."""
        pins: dict[str, Any] = {}
        for path in (paramfile.param_path(self.workdir, phase, "", user=True),
                     paramfile.param_path(self.workdir, phase, region.name,
                                          user=True)):
            for node in paramfile.load_file(path):
                if node.name in (region.name, "BasicParam"):
                    for c in node.walk():
                        if not c.children and c.value is not None:
                            pins[c.name] = c.value
        return pins

    def _tune_one(self, region: ATRegion, phase: str, bp_env: dict,
                  strict_collision: bool = False) -> dict[str, Any]:
        """Search one region tree; returns {qualified PP: value}."""
        if region.prepro:
            region.prepro()
        try:
            if region.feature == "define":
                # run the body; it returns {out-param: value}
                out = region.fn(**{k: v for k, v in bp_env.items()
                                   if k in region.bp_names}) or {}
                for p in region.params:
                    if p.attr == "out" and p.name not in out:
                        raise OATSpecError(
                            f"define region {region.name!r} did not produce "
                            f"out parameter {p.name!r}")
                return dict(out)

            pins = self._pinned_values(phase, region)
            plan = SearchPlan(region)
            pp_names = [a.name for a in plan.all_axes]
            colliding = {k: v for k, v in pins.items() if k in pp_names}
            for k, v in colliding.items():
                self.collisions.append((region.name, k, v))
            if colliding:
                if strict_collision:
                    raise OATParamCollisionError(
                        f"parameter collision in region {region.name!r}: "
                        f"{sorted(colliding)} pinned by user file (§6.3)")
                if set(colliding) >= set(pp_names):
                    return dict(colliding)   # fully pinned: AT halts, force-set

            if region.feature == "select" and region.subregions and all(
                    s.according is not None and s.according.estimated
                    is not None for s in region.subregions):
                # cost-estimated selection — no execution (Sample 5)
                env = dict(self.store.env(phase))
                env.update(bp_env)
                costs = [s.according.estimated_cost(env)
                         for s in region.subregions]
                best = min(range(len(costs)), key=costs.__getitem__)
                return {region.pp_names[0]: best}

            measure = self._executor_factory(region, bp_env)
            if self.searcher is not None:
                res = self.searcher(plan, measure, init=colliding or None)
            else:
                res = plan.run(measure, init=colliding or None)
            self.search_log[region.name] = res.n_evaluations
            best = dict(res.best)
            best.update(colliding)           # pins always win
            if int(self.store.get_bp(OAT_DEBUG, 0) or 0) >= 1:
                self.debug_lines.append(
                    f"[OAT_DEBUG] {phase} {region.name} pp={best} "
                    f"cost={res.best_cost:.3e} evals={res.n_evaluations}")
            return best
        finally:
            if region.postpro:
                region.postpro()

    def _run_install(self, names: list[str]) -> None:
        nodes: list[paramfile.Node] = []
        for name in names:
            region = self.registry.get(name)
            best = self._tune_one(region, "install", dict(self.store.bp))
            rec = paramfile.Node(region.name)
            for k, v in best.items():
                self.store.set_pp(k, v, "install")
                rec.set(k, v)
            nodes.append(rec)
        path = paramfile.param_path(self.workdir, "install")
        existing = {n.name: n for n in paramfile.load_file(path)}
        for n in nodes:
            existing[n.name] = n
        paramfile.save_file(path, list(existing.values()))

    # ------------------------------------------------------------------
    # before-execute-time (static)
    # ------------------------------------------------------------------
    def _bp_grid(self) -> list[dict[str, int]]:
        """Cartesian grid over the default BP sweep and user BPs."""
        default_pts = list(range(
            int(self.store.get_bp(OAT_STARTTUNESIZE)),
            int(self.store.get_bp(OAT_ENDTUNESIZE)) + 1,
            int(self.store.get_bp(OAT_SAMPDIST))))
        axes: list[tuple[str, list[int]]] = [(OAT_PROBSIZE, default_pts)]
        for spec in self.bp_specs.values():
            axes.append((spec.name, spec.sample_points(self.store)))
        out = []
        for combo in itertools.product(*[pts for _, pts in axes]):
            out.append({k: v for (k, _), v in zip(axes, combo)})
        return out

    def _run_static(self, names: list[str]) -> None:
        if not bool(self.store.get_bp(OAT_TUNESTATIC, True)):
            return
        grid = self._bp_grid()
        nodes: list[paramfile.Node] = []
        header = paramfile.Node("BasicParam")
        for k in DEFAULT_BASIC_PARAMS:
            if self.store.get_bp(k) is not None:
                header.set(k, self.store.get_bp(k))
        nodes.append(header)
        for name in names:
            region = self.registry.get(name)
            rec = paramfile.Node(region.name)
            rec.set(OAT_NUMPROCS, self.store.get_bp(OAT_NUMPROCS))
            rec.set(OAT_SAMPDIST, self.store.get_bp(OAT_SAMPDIST))
            for bp_env in grid:
                env = dict(self.store.bp)
                env.update(bp_env)
                best = self._tune_one(region, "static", env)
                group = paramfile.Node(OAT_PROBSIZE, bp_env[OAT_PROBSIZE])
                for k, v in bp_env.items():
                    if k != OAT_PROBSIZE:
                        group.set(k, v)
                for k, v in best.items():
                    group.set(k, v)
                rec.children.append(group)
                bp_key = tuple(sorted(bp_env.items()))
                for k, v in best.items():
                    self.store.set_pp(f"{k}@{bp_key}", v, "static")
                    # latest BP point also lands on the plain name so
                    # downstream phases can read it without the BP key
                    self.store.set_pp(k, v, "static")
            nodes.append(rec)
        path = paramfile.param_path(self.workdir, "static")
        existing = {n.name: n for n in paramfile.load_file(path)}
        for n in nodes:
            existing[n.name] = n
        paramfile.save_file(path, list(existing.values()))

    def static_pp(self, region_name: str, pp: str, probsize: int,
                  reader_phase: str = "dynamic") -> Any:
        """Read a static-tuned PP for an arbitrary problem size.

        Sample points are read exactly; non-sample points are inferred with
        the BP's CDF (OAT_BPsetCDF; default dspline) over the recorded
        (probsize, pp) pairs.
        """
        path = paramfile.param_path(self.workdir, "static")
        xs, ys = [], []
        for node in paramfile.load_file(path):
            if node.name != region_name:
                continue
            for g in node.children:
                if g.name == OAT_PROBSIZE and g.child(pp) is not None:
                    xs.append(int(g.value))
                    ys.append(g.child_value(pp))
        if not xs:
            raise OATSpecError(
                f"no static parameter {pp!r} recorded for {region_name!r}")
        if probsize in xs:
            return ys[xs.index(probsize)]
        cdf = None
        for spec in self.bp_specs.values():
            if spec.cdf:
                cdf = spec.cdf
                break
        ysf = [float(y) for y in ys]
        if cdf and cdf.startswith("least-squares"):
            order = int(cdf.split()[1]) if len(cdf.split()) > 1 else 2
            pred = fit_polynomial(xs, ysf, order)
        elif cdf and cdf.startswith("user-defined"):
            pred = fit_user_defined(xs, ysf, cdf.split(None, 1)[1])
        else:
            pred = fit_dspline(xs, ysf)
        import numpy as np
        val = float(pred(np.array([probsize]))[0])
        return int(round(val)) if all(
                isinstance(y, int) for y in ys) else val

    # ------------------------------------------------------------------
    # run-time (dynamic)
    # ------------------------------------------------------------------
    def _arm_dynamic(self, names: list[str]) -> None:
        self.dynamic_armed = True
        for n in names:
            self.dynamic_state.setdefault(n, DynamicState())

    def execute(self, name: str, *args, **kwargs) -> Any:
        """Invoke a tuning region.

        For a dynamic region that is armed and uncommitted, each call measures
        the next untried candidate; once all have been observed the winner is
        committed (per its ``according`` criterion, default wall-clock).
        """
        region = self.registry.get(name)
        if region.at_type != "dynamic" or not self.dynamic_armed \
                or name not in self.dynamic_state:
            return self._run_committed(region, args, kwargs)
        st = self.dynamic_state[name]
        if st.committed is not None:
            return self._run_candidate(region, st.committed, args, kwargs)[0]

        n_cands = region.n_candidates()
        nxt = next((i for i in range(n_cands) if i not in st.tried), None)
        if nxt is None:
            st.committed = self._commit_dynamic(region, st)
            return self._run_candidate(region, st.committed, args, kwargs)[0]
        out, cost, env = self._run_candidate(region, nxt, args, kwargs,
                                             want_env=True)
        st.tried[nxt] = cost
        st.env_seen[nxt] = env
        if all(i in st.tried for i in range(n_cands)):
            st.committed = self._commit_dynamic(region, st)
            self._write_dynamic_file(region, st)
        return out

    def _commit_dynamic(self, region: ATRegion, st: DynamicState) -> int:
        acc: According | None = region.according
        cands = list(st.tried)
        if acc is not None and acc.minimize:
            ok = [i for i in cands
                  if acc.conditions_hold(st.env_seen.get(i, {}))]
            pool = ok or cands
            return min(pool, key=lambda i: st.env_seen.get(i, {}).get(
                acc.minimize, st.tried[i]))
        return min(cands, key=st.tried.__getitem__)

    def _run_candidate(self, region: ATRegion, idx: int, args, kwargs,
                       want_env: bool = False):
        if region.prepro:
            region.prepro()
        try:
            t0 = time.perf_counter()
            if region.feature == "select":
                fn = region.subregions[idx].fn
                out = fn(*args, **kwargs)
            else:
                vals = list(region.varied.candidates())
                pp = {b: vals[min(idx, len(vals) - 1)]
                      for b in region.varied.names}
                out = region.fn(*args, **pp, **kwargs)
            cost = time.perf_counter() - t0
        finally:
            if region.postpro:
                region.postpro()
        env = out if isinstance(out, dict) else {}
        if want_env:
            return out, cost, env
        return out, cost

    def _run_committed(self, region: ATRegion, args, kwargs) -> Any:
        """Run with previously-optimised PPs (also OAT_DynPerfThis §4.2.3)."""
        if region.feature == "select":
            idx = 0
            st = self.dynamic_state.get(region.name)
            if st and st.committed is not None:
                idx = st.committed
            else:
                e = self.store.entry(region.pp_names[0])
                if e is not None:
                    idx = int(e.value)
            return region.subregions[idx].fn(*args, **kwargs)
        pp = {}
        for bare, q in zip(region.varied.names if region.varied else (),
                           region.pp_names):
            e = self.store.entry(q)
            if e is not None:
                pp[bare] = e.value
        if not pp and region.varied is not None:
            pp = {b: region.varied.candidates()[0]
                  for b in region.varied.names}
        return region.fn(*args, **pp, **kwargs)

    def OAT_DynPerfThis(self, name: str, *args, **kwargs) -> Any:
        """Execute with optimised parameters; no tuning here (§4.2.3)."""
        return self._run_committed(self.registry.get(name), args, kwargs)

    def _write_dynamic_file(self, region: ATRegion, st: DynamicState) -> None:
        rec = paramfile.Node(region.name)
        rec.set(region.pp_names[0] if region.pp_names else "SELECT",
                st.committed)
        path = paramfile.param_path(self.workdir, "dynamic", region.name)
        paramfile.save_file(path, [rec])
        self.store.set_pp(region.pp_names[0] if region.pp_names else
                          f"{region.name}_SELECT", st.committed, "dynamic")


# module-level default context mirroring the paper's common-block globals
_default: ATContext | None = None


def default_context() -> ATContext:
    global _default
    if _default is None:
        _default = ATContext()
    return _default


def reset_default_context(workdir: str = ".", **kw) -> ATContext:
    global _default
    _default = ATContext(workdir=workdir, **kw)
    return _default
