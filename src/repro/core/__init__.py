"""ppOpen-AT core — the paper's contribution, adapted to Python/JAX.

Module map (application code should import ``repro.at`` instead — this
package is the engine underneath it):

===============  ==========================================================
module           role
===============  ==========================================================
``region``       AT region model (§3.4): types/features, nesting legality
                 (Tables 1-2), the ``RegionRegistry``
``params``       BP/PP parameter store + FIBER visibility hierarchy
                 (Fig. 4), ``Varied`` ranges, reserved words (§6.1)
``runtime``      ``ATContext`` — ``OAT_ATexec`` and the §4 API: phase
                 priority, BP sweeps, run-time candidate selection;
                 pluggable ``searcher`` / ``executor_factory`` hooks the
                 ``repro.at`` backend registries plug into
``search``       §6.4.2 search composition (Sample 10 counts exactly)
``fitting``      §3.4.3 fitting: least-squares / d-Spline / user-defined
``cost``         ``according`` clauses: min/condition/estimated + roofline
``executor``     measurement backends: wall-clock / cost-model / table
``paramfile``    the S-expression parameter files (§4.2.1, §6.2)
``dsl``          ``#OAT$`` comment-directive parsing (the paper surface)
``codegen``      §5 loop transforms: split/fusion/collapse/unroll variants
``directives``   DEPRECATED decorator frontend — thin shims over
                 ``region()`` kept for compatibility; use
                 ``repro.at.AutoTuner.autotune`` (docs/API.md)
``stagegraph``   stage-graph execution planning over tuned regions
``errors``       the ``OAT*Error`` hierarchy
===============  ==========================================================

Layered on top (not imported here): ``repro.at`` — the public session API
(AutoTuner, backend registries, the persistent ``ATRecordStore``).
"""
from .cost import According, RooflineTerms, roofline_seconds, roofline_terms
from .directives import (SelectRegion, dynamic_select, dynamic_unroll,
                         dynamic_variable, install_define, install_select,
                         install_unroll, install_variable, region,
                         static_select, static_unroll, static_variable)
from .errors import (OATCodegenError, OATError, OATHierarchyError,
                     OATMissingBasicParamError, OATNestingError,
                     OATParamCollisionError, OATPriorityError, OATSpecError)
from .executor import (CostModelExecutor, CountingExecutor, TableExecutor,
                       WallClockExecutor)
from .params import (DEFAULT_BASIC_PARAMS, OAT_DEBUG, OAT_ENDTUNESIZE,
                     OAT_NUMPROCS, OAT_SAMPDIST, OAT_STARTTUNESIZE,
                     OAT_TUNEDYNAMIC, OAT_TUNESTATIC, ParamDecl, ParamStore,
                     Varied)
from .region import ATRegion, Fitting, RegionRegistry, Subregion
from .runtime import (OAT_ALL, OAT_DYNAMIC, OAT_INSTALL, OAT_STATIC,
                      ATContext, default_context, reset_default_context)
from .search import SearchPlan, predicted_count, search_region

__all__ = [
    "ATContext", "ATRegion", "According", "CostModelExecutor",
    "CountingExecutor", "Fitting", "OATError", "ParamStore", "RegionRegistry",
    "SearchPlan", "SelectRegion", "TableExecutor", "Varied",
    "WallClockExecutor", "OAT_ALL", "OAT_INSTALL", "OAT_STATIC",
    "OAT_DYNAMIC", "default_context", "predicted_count",
    "reset_default_context", "roofline_terms", "search_region",
]
