"""ppOpen-AT core — the paper's contribution, adapted to Python/JAX.

Public API re-exports.
"""
from .cost import According, RooflineTerms, roofline_seconds, roofline_terms
from .directives import (SelectRegion, dynamic_select, dynamic_unroll,
                         dynamic_variable, install_define, install_select,
                         install_unroll, install_variable, region,
                         static_select, static_unroll, static_variable)
from .errors import (OATCodegenError, OATError, OATHierarchyError,
                     OATMissingBasicParamError, OATNestingError,
                     OATParamCollisionError, OATPriorityError, OATSpecError)
from .executor import (CostModelExecutor, CountingExecutor, TableExecutor,
                       WallClockExecutor)
from .params import (DEFAULT_BASIC_PARAMS, OAT_DEBUG, OAT_ENDTUNESIZE,
                     OAT_NUMPROCS, OAT_SAMPDIST, OAT_STARTTUNESIZE,
                     OAT_TUNEDYNAMIC, OAT_TUNESTATIC, ParamDecl, ParamStore,
                     Varied)
from .region import ATRegion, Fitting, RegionRegistry, Subregion
from .runtime import (OAT_ALL, OAT_DYNAMIC, OAT_INSTALL, OAT_STATIC,
                      ATContext, default_context, reset_default_context)
from .search import SearchPlan, predicted_count, search_region

__all__ = [
    "ATContext", "ATRegion", "According", "CostModelExecutor",
    "CountingExecutor", "Fitting", "OATError", "ParamStore", "RegionRegistry",
    "SearchPlan", "SelectRegion", "TableExecutor", "Varied",
    "WallClockExecutor", "OAT_ALL", "OAT_INSTALL", "OAT_STATIC",
    "OAT_DYNAMIC", "default_context", "predicted_count",
    "reset_default_context", "roofline_terms", "search_region",
]
