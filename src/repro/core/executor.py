"""Measurement backends for the AT search.

The paper measures wall-clock on the target machine.  Here three executors
share one interface — ``__call__(assignment) -> cost`` — so search.py is
agnostic to how cost is obtained:

* :class:`WallClockExecutor` — times a variant callable (JAX-aware:
  ``block_until_ready`` on the result; warmup run excluded so jit tracing is
  not measured).  Used by install-time AT (Pallas interpret mode on CPU,
  real kernels on TPU).
* :class:`CostModelExecutor` — evaluates an analytic cost (``according
  estimated`` / the roofline model) without executing anything.  Used by the
  static driver against compiled dry-run artifacts.
* :class:`TableExecutor` — replays a {assignment-key: cost} table (tests,
  and the paper-count benchmarks where only the trajectory matters).

``CountingExecutor`` wraps any of them to assert evaluation counts.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

from .cost import eval_expr
from .errors import OATSpecError


def _block(x: Any) -> None:
    try:
        import jax
        jax.block_until_ready(x)
    except Exception:
        pass


@dataclass
class WallClockExecutor:
    """cost = min wall-clock seconds over ``repeats`` runs of the variant.

    ``make_variant(assignment)`` returns a zero-arg callable; its result is
    blocked on (JAX async dispatch) before the clock stops.
    """

    make_variant: Callable[[dict], Callable[[], Any]]
    repeats: int = 3
    warmup: int = 1

    def __call__(self, assignment: dict[str, Any]) -> float:
        fn = self.make_variant(assignment)
        for _ in range(self.warmup):
            _block(fn())
        best = float("inf")
        for _ in range(self.repeats):
            t0 = time.perf_counter()
            _block(fn())
            best = min(best, time.perf_counter() - t0)
        return best


@dataclass
class CostModelExecutor:
    """cost = analytic expression/callable over (assignment + env)."""

    cost: str | Callable[[dict], float]
    env: dict[str, Any] = field(default_factory=dict)

    def __call__(self, assignment: dict[str, Any]) -> float:
        ns = dict(self.env)
        ns.update(assignment)
        if callable(self.cost):
            return float(self.cost(ns))
        return float(eval_expr(self.cost, ns))


@dataclass
class TableExecutor:
    """cost looked up from a table keyed by sorted assignment items."""

    table: dict[tuple, float]
    default: float | None = None

    @staticmethod
    def key(assignment: dict[str, Any]) -> tuple:
        return tuple(sorted(assignment.items()))

    def __call__(self, assignment: dict[str, Any]) -> float:
        k = self.key(assignment)
        if k in self.table:
            return self.table[k]
        if self.default is not None:
            return self.default
        raise OATSpecError(f"no cost recorded for assignment {assignment}")


class CountingExecutor:
    """Wraps an executor and counts calls (paper-count assertions)."""

    def __init__(self, inner: Callable[[dict], float]):
        self.inner = inner
        self.count = 0
        self.trajectory: list[dict] = []

    def __call__(self, assignment: dict[str, Any]) -> float:
        self.count += 1
        self.trajectory.append(dict(assignment))
        return self.inner(assignment)
