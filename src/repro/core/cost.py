"""Selection criteria — the ``according`` subtype specifier (paper §3.4.3).

    according (<conditional expression> | estimated <expr>)

    <conditional expression> ::=
        [ (min(<param>) | condition(<cond>)) <connector> ]
        <connector> ::= [.and. | .or.] <conditional expression>

``estimated <expr>`` selects the sub-region with the minimum user-defined
cost, evaluated over the visible parameter environment (Sample 5 uses
``2.0d0*CacheSize*OAT_PROBSIZE**2 / (3.0d0*OAT_NUMPROC)`` style formulas).

We accept Fortran-flavoured expressions (``2.0d0``, ``dlog``, ``.and.``,
``.or.``, ``.true.``) and translate them to Python before evaluation in a
restricted namespace.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Any, Callable

from .errors import OATSpecError

_SAFE = {
    "log": math.log, "dlog": math.log, "log2": math.log2, "exp": math.exp,
    "sqrt": math.sqrt, "dsqrt": math.sqrt, "abs": math.abs if hasattr(math, "abs") else abs,
    "min": min, "max": max, "ceil": math.ceil, "floor": math.floor,
    "pi": math.pi, "true": True, "false": False,
}


def fortran_to_python(expr: str) -> str:
    """Translate Fortran90-style formula syntax to Python."""
    e = expr
    e = re.sub(r"(\d+(?:\.\d*)?)[dD]([+-]?\d+)", r"\1e\2", e)   # 2.0d0 -> 2.0e0
    e = e.replace(".and.", " and ").replace(".AND.", " and ")
    e = e.replace(".or.", " or ").replace(".OR.", " or ")
    e = e.replace(".not.", " not ").replace(".NOT.", " not ")
    e = e.replace(".true.", "True").replace(".false.", "False")
    e = re.sub(r"(?<![*/])\*\*", "**", e)                        # keep powers
    e = re.sub(r"\.eq\.", "==", e, flags=re.I)
    e = re.sub(r"\.ne\.", "!=", e, flags=re.I)
    e = re.sub(r"\.lt\.", "<", e, flags=re.I)
    e = re.sub(r"\.le\.", "<=", e, flags=re.I)
    e = re.sub(r"\.gt\.", ">", e, flags=re.I)
    e = re.sub(r"\.ge\.", ">=", e, flags=re.I)
    return e


def eval_expr(expr: str, env: dict[str, Any]) -> Any:
    ns = dict(_SAFE)
    ns.update(env)
    try:
        return eval(fortran_to_python(expr), {"__builtins__": {}}, ns)  # noqa: S307
    except NameError as e:
        raise OATSpecError(f"unknown name in expression {expr!r}: {e}") from e
    except Exception as e:
        raise OATSpecError(f"failed to evaluate expression {expr!r}: {e}") from e


@dataclass
class According:
    """Selection criterion for a ``select`` (sub-)region.

    Exactly one of:
      * ``estimated`` — cost expression string or callable(env)->float,
        minimised across sub-regions;
      * ``minimize``  — parameter name whose *measured* value is minimised
        (the paper's ``min(eps)``), optionally combined with ``conditions``
        via ``.and.``/``.or.`` connectors.
    """

    estimated: str | Callable | None = None
    minimize: str | None = None
    conditions: list[str] = field(default_factory=list)
    connectors: list[str] = field(default_factory=list)  # 'and' | 'or', len = len(conditions) joined with minimize

    @classmethod
    def parse(cls, text: str) -> "According":
        """Parse the paper's textual form, e.g.
        ``min (eps) .and. condition (iter < 5)`` or ``estimated <expr>``."""
        t = text.strip()
        if t.lower().startswith("estimated"):
            return cls(estimated=t[len("estimated"):].strip())
        acc = cls()
        # split on .and. / .or. at top level
        parts = re.split(r"(\.and\.|\.or\.)", t)
        for p in parts:
            p = p.strip()
            if not p:
                continue
            if p in (".and.", ".or."):
                acc.connectors.append(p.strip("."))
                continue
            m = re.match(r"min\s*\((.+)\)\s*$", p)
            if m:
                acc.minimize = m.group(1).strip()
                continue
            m = re.match(r"condition\s*\((.+)\)\s*$", p)
            if m:
                acc.conditions.append(m.group(1).strip())
                continue
            raise OATSpecError(f"cannot parse according clause {p!r}")
        return acc

    # ------------------------------------------------------------------
    def estimated_cost(self, env: dict[str, Any]) -> float:
        if self.estimated is None:
            raise OATSpecError("according has no estimated cost")
        if callable(self.estimated):
            return float(self.estimated(env))
        return float(eval_expr(self.estimated, env))

    def conditions_hold(self, env: dict[str, Any]) -> bool:
        """Evaluate condition(...) clauses.  Connector semantics: clauses are
        combined left-to-right with the recorded connectors ('and' default)."""
        if not self.conditions:
            return True
        vals = [bool(eval_expr(c, env)) for c in self.conditions]
        out = vals[0]
        # connectors may also join min() with conditions; use trailing ones
        conns = self.connectors[-(len(vals) - 1):] if len(vals) > 1 else []
        for v, c in zip(vals[1:], conns + ["and"] * (len(vals) - 1 - len(conns))):
            out = (out or v) if c == "or" else (out and v)
        return out


# --------------------------------------------------------------------------
# Built-in roofline cost model (TPU v5e) — used as a first-class `estimated`
# callable by the static-AT driver and available to user expressions as
# roofline_seconds(flops, bytes, coll_bytes, chips).
# --------------------------------------------------------------------------

PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link


@dataclass(frozen=True)
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        """Roofline lower bound on step time (max of the three terms)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def overlap_s(self) -> float:
        """Perfectly-overlapped model: max(compute, memory, collective)."""
        return self.bound_s


def roofline_terms(total_flops: float, total_bytes: float,
                   collective_bytes: float, chips: int,
                   peak_flops: float = PEAK_FLOPS_BF16,
                   hbm_bw: float = HBM_BW, ici_bw: float = ICI_BW
                   ) -> RooflineTerms:
    """The three roofline terms in seconds (totals across `chips`)."""
    return RooflineTerms(
        compute_s=total_flops / (chips * peak_flops),
        memory_s=total_bytes / (chips * hbm_bw),
        collective_s=collective_bytes / (chips * ici_bw),
    )


def roofline_seconds(flops: float, bytes_: float, coll_bytes: float,
                     chips: int = 1) -> float:
    return roofline_terms(flops, bytes_, coll_bytes, chips).bound_s
