"""`#OAT$` directive language — parsing + the full preprocessor pipeline.

This is the literal adaptation of the paper's annotation flow: a Python
function carrying ``#OAT$`` comment directives is preprocessed by
:class:`~.codegen.OATCodeGen` into variants under ``./OAT/``, and this module
turns each annotated region into a registered :class:`~.region.ATRegion` so
``OAT_ATexec`` can tune it.

Subtype-specifier parsers accept the paper's surface syntax::

    varied (i, j) from 1 to 16
    fitting least-squares 5 sampled (1-5, 8, 16)
    parameter (bp n, in CacheSize, out CacheLine)
    according min (eps) .and. condition (iter < 5)
    according estimated 2.0d0*CacheSize*OAT_PROBSIZE**2/(3.0d0*OAT_NUMPROC)
    search AD-HOC | search Brute-force
"""
from __future__ import annotations

import re
from typing import Callable

from .codegen import (GeneratedVariant, OATCodeGen, RegionSource,
                      extract_regions)
from .cost import According
from .errors import OATSpecError
from .params import ParamDecl, Varied, parse_sampled
from .region import ATRegion, Fitting, Subregion
from .runtime import ATContext

_VARIED_RE = re.compile(
    r"\(?\s*([\w\s,]+?)\s*\)?\s+from\s+(-?\d+)\s+to\s+(-?\d+)"
    r"(?:\s+step\s+(-?\d+))?\s*$")


def parse_varied(text: str) -> Varied:
    m = _VARIED_RE.match(text.strip())
    if not m:
        raise OATSpecError(f"cannot parse varied clause {text!r}")
    names = tuple(n.strip() for n in m.group(1).split(","))
    return Varied(names, int(m.group(2)), int(m.group(3)),
                  int(m.group(4) or 1))


def parse_fitting(text: str) -> Fitting:
    t = text.strip()
    m = re.match(r"(least-squares\s+\d+|dspline|auto|user-defined\s+.+?)"
                 r"(?:\s+sampled\s+(.+))?$", t)
    if not m:
        raise OATSpecError(f"cannot parse fitting clause {text!r}")
    method_part, sampled_part = m.group(1), m.group(2)
    sampled = None
    if sampled_part and sampled_part.strip() != "auto":
        sampled = parse_sampled(sampled_part)
    if method_part.startswith("least-squares"):
        return Fitting("least-squares", order=int(method_part.split()[1]),
                       sampled=sampled)
    if method_part == "dspline":
        return Fitting("dspline", sampled=sampled)
    if method_part == "auto":
        return Fitting("auto", sampled=sampled)
    return Fitting("user-defined", expr=method_part.split(None, 1)[1],
                   sampled=sampled)


def parse_parameter(text: str) -> list[ParamDecl]:
    t = text.strip().strip("()")
    out: list[ParamDecl] = []
    for item in t.split(","):
        parts = item.split()
        if not parts:
            continue
        if len(parts) == 2:
            out.append(ParamDecl(parts[1], parts[0]))
        else:
            out.append(ParamDecl(parts[0]))
    return out


def parse_search(text: str) -> str:
    t = text.strip().lower()
    if t in ("brute-force", "bruteforce", "exhaustive"):
        return "brute-force"
    if t in ("ad-hoc", "adhoc"):
        return "ad-hoc"
    raise OATSpecError(f"unknown search method {text!r}")


def region_from_source(reg: RegionSource) -> ATRegion:
    """Build an (unregistered, fn-less) ATRegion from parsed directives."""
    kw: dict = {}
    if "varied" in reg.subtypes:
        kw["varied"] = parse_varied(reg.subtypes["varied"])
    if "fitting" in reg.subtypes:
        kw["fitting"] = parse_fitting(reg.subtypes["fitting"])
    if "parameter" in reg.subtypes:
        kw["params"] = parse_parameter(reg.subtypes["parameter"])
    if "according" in reg.subtypes:
        kw["according"] = According.parse(reg.subtypes["according"])
    if "search" in reg.subtypes:
        kw["search"] = parse_search(reg.subtypes["search"])
    if "number" in reg.subtypes:
        kw["number"] = int(reg.subtypes["number"])
    if "debug" in reg.subtypes:
        kw["debug"] = tuple(
            d.strip() for d in reg.subtypes["debug"].strip("()").split(","))
    feature = reg.feature
    if feature in ("LoopFusionSplit", "LoopFusion"):
        feature = "select"      # variant selection among generated codes
    return ATRegion(at_type=reg.at_type, feature=feature, name=reg.name,
                    **kw)


def preprocess(fn: Callable, ctx: ATContext, outdir: str | None = None
               ) -> dict[str, ATRegion]:
    """The complete paper pipeline for one annotated function.

    Runs OATCodeGen over ``fn``, registers one ATRegion per ``#OAT$`` region:

    * ``LoopFusionSplit`` / ``LoopFusion`` regions become ``select`` regions
      whose sub-regions are the generated variants (Sample 8's 8 candidates);
    * ``unroll`` regions become ``unroll`` regions whose variant generator
      produces the unrolled code on demand for each searched factor.
    """
    import inspect
    import textwrap
    gen = OATCodeGen(outdir or ctx.workdir)
    generated = gen.generate(fn)

    src = textwrap.dedent(inspect.getsource(fn))
    src_lines = src.splitlines()
    def_idx = next(i for i, l in enumerate(src_lines)
                   if l.startswith("def "))
    body = textwrap.dedent("\n".join(src_lines[def_idx + 1:]))
    _, reg_sources = extract_regions(body)

    out: dict[str, ATRegion] = {}
    for reg_src in reg_sources:
        region = region_from_source(reg_src)
        variants = generated.get(reg_src.name, [])
        if reg_src.feature in ("LoopFusionSplit", "LoopFusion"):
            for v in variants:
                region.subregions.append(
                    Subregion(fn=v.fn, name=v.description))
            region.metadata["variants"] = variants
        elif reg_src.feature == "unroll":
            def make_unrolled(fn=fn, name=reg_src.name,
                              varied=region.varied):
                cache: dict[tuple, GeneratedVariant] = {}

                def variant_gen(*args, **kwargs):
                    factors = {v: int(kwargs.pop(v))
                               for v in varied.names if v in kwargs}
                    key = tuple(sorted(factors.items()))
                    if key not in cache:
                        cache[key] = gen.unroll_variant(fn, name, factors)
                    f = cache[key].fn
                    if args or kwargs:
                        return f(*args, **kwargs)
                    return f

                return variant_gen

            region.fn = make_unrolled()
            region.metadata["codegen"] = gen
        ctx.register(region)
        out[reg_src.name] = region
    return out
