"""Basic / performance parameters and the FIBER visibility hierarchy.

Paper §3.3: two parameter kinds —
  * **BP** (basic parameters): set by the end user (problem size, #procs).
  * **PP** (performance parameters): chosen by the tuner, conditioned on BPs.

Paper Fig. 4 (hierarchy of parameter information referencing):
  * install-determined params are visible to static and dynamic phases;
  * static-determined params are visible only to the dynamic phase;
  * dynamic-determined params are visible only to the dynamic phase;
  * exception — the *feedback model*: static may re-read dynamic results.

Paper §6.3 (collisions): a value pinned in a *user specification file* halts
AT for that parameter and is force-set.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

from .errors import OATHierarchyError, OATSpecError

PHASES = ("install", "static", "dynamic")
PHASE_RANK = {p: i for i, p in enumerate(PHASES)}

# Default basic parameters (paper §4.2.2 / §6.1 reserved words)
OAT_NUMPROCS = "OAT_NUMPROCS"
OAT_STARTTUNESIZE = "OAT_STARTTUNESIZE"
OAT_ENDTUNESIZE = "OAT_ENDTUNESIZE"
OAT_SAMPDIST = "OAT_SAMPDIST"
OAT_TUNESTATIC = "OAT_TUNESTATIC"
OAT_TUNEDYNAMIC = "OAT_TUNEDYNAMIC"
OAT_DEBUG = "OAT_DEBUG"

DEFAULT_BASIC_PARAMS = (OAT_NUMPROCS, OAT_STARTTUNESIZE, OAT_ENDTUNESIZE,
                        OAT_SAMPDIST)

RESERVED_WORDS = frozenset(DEFAULT_BASIC_PARAMS) | {
    OAT_TUNESTATIC, OAT_TUNEDYNAMIC, OAT_DEBUG,
    "OAT_ALL", "OAT_INSTALL", "OAT_STATIC", "OAT_DYNAMIC",
    "OAT_AllRoutines", "OAT_InstallRoutines", "OAT_StaticRoutines",
    "OAT_DynamicRoutines",
}


@dataclass(frozen=True)
class ParamDecl:
    """A ``parameter (<attr> <name>, ...)`` entry (paper §3.4.3).

    attr is one of ``in`` (defined externally, read here), ``out`` (defined in
    this tuning region) or ``bp`` (basic parameter).
    """

    name: str
    attr: str = "in"  # in | out | bp

    def __post_init__(self):
        if self.attr not in ("in", "out", "bp"):
            raise OATSpecError(f"bad parameter attribute {self.attr!r}")


@dataclass
class ParamEntry:
    value: Any
    phase: str                # phase that determined it
    bp_key: tuple | None = None   # BP context it was tuned under (static PPs)
    pinned: bool = False      # came from a user Def file (collision source)


class ParamStore:
    """Layered parameter store implementing the FIBER hierarchy.

    Values live in one of three phase layers plus a BP layer (BPs are set by
    the user and visible everywhere).  ``get`` enforces Fig. 4 visibility:
    a reader at phase *r* may see values determined at phase *d* iff
    ``rank(d) <= rank(r)``, except that dynamic-determined values are visible
    only to dynamic readers (which the rank rule already gives) and, when
    ``feedback`` is enabled, static readers may also see dynamic values
    (the FIBER feedback model, paper §3.1 footnote).
    """

    def __init__(self, feedback: bool = False):
        self.feedback = feedback
        self.bp: dict[str, Any] = {}
        self.layers: dict[str, dict[str, ParamEntry]] = {p: {} for p in PHASES}

    # -- BPs --------------------------------------------------------------
    def set_bp(self, name: str, value: Any) -> None:
        self.bp[name] = value

    def get_bp(self, name: str, default: Any = None) -> Any:
        return self.bp.get(name, default)

    def has_default_bps(self) -> bool:
        return all(k in self.bp for k in DEFAULT_BASIC_PARAMS)

    # -- PPs --------------------------------------------------------------
    def set_pp(self, name: str, value: Any, phase: str,
               bp_key: tuple | None = None, pinned: bool = False) -> None:
        if phase not in PHASES:
            raise OATSpecError(f"unknown phase {phase!r}")
        self.layers[phase][name] = ParamEntry(value, phase, bp_key, pinned)

    def entry(self, name: str) -> ParamEntry | None:
        # dynamic shadows static shadows install (later phases refine)
        for p in reversed(PHASES):
            if name in self.layers[p]:
                return self.layers[p][name]
        return None

    def get(self, name: str, reader_phase: str, default: Any = None) -> Any:
        """Visibility-checked read (paper Fig. 4)."""
        if name in self.bp:
            return self.bp[name]
        e = self.entry(name)
        if e is None:
            return default
        if PHASE_RANK[e.phase] > PHASE_RANK[reader_phase]:
            if self.feedback and reader_phase == "static" and e.phase == "dynamic":
                return e.value  # FIBER feedback model
            raise OATHierarchyError(
                f"parameter {name!r} determined at {e.phase!r} is not visible "
                f"to a {reader_phase!r} reader (FIBER hierarchy, paper Fig.4)")
        return e.value

    def is_pinned(self, name: str) -> bool:
        e = self.entry(name)
        return e is not None and e.pinned

    def env(self, reader_phase: str) -> dict[str, Any]:
        """All parameters visible to ``reader_phase`` (for cost expressions)."""
        out: dict[str, Any] = {}
        for p in PHASES:
            if PHASE_RANK[p] > PHASE_RANK[reader_phase] and not (
                    self.feedback and reader_phase == "static" and p == "dynamic"):
                continue
            for k, e in self.layers[p].items():
                out[k] = e.value
        out.update(self.bp)
        return out


@dataclass
class Varied:
    """``varied (p[, p]) from X to Y [step S]`` — the PP search range."""

    names: tuple[str, ...]
    lo: int
    hi: int
    step: int = 1
    values: tuple | None = None   # explicit candidate list overrides lo..hi

    def __init__(self, names, lo: int = 1, hi: int = 1, step: int = 1,
                 values=None):
        if isinstance(names, str):
            names = (names,)
        self.names = tuple(names)
        self.lo, self.hi, self.step = lo, hi, step
        self.values = tuple(values) if values is not None else None

    def candidates(self) -> tuple:
        if self.values is not None:
            return self.values
        return tuple(range(self.lo, self.hi + 1, self.step))

    @property
    def n(self) -> int:
        return len(self.candidates())


def parse_sampled(spec: str | list | tuple) -> list[int]:
    """Parse the paper's ``sampled (1-5, 8, 16)`` notation."""
    if isinstance(spec, (list, tuple)):
        return [int(x) for x in spec]
    s = spec.strip().strip("()")
    out: list[int] = []
    for part in s.split(","):
        part = part.strip()
        if not part:
            continue
        if "-" in part[1:]:  # allow negative first char
            a, b = part.split("-", 1)
            out.extend(range(int(a), int(b) + 1))
        else:
            out.append(int(part))
    return out
