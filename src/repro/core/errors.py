"""ppOpen-AT error model.

The paper (§3.2) specifies that violating the install -> static -> dynamic
execution priority generates an *error code* and halts auto-tuning.  We keep
numeric codes so the behaviour is observable/testable the way the paper
describes it, while still raising real Python exceptions.
"""
from __future__ import annotations


class OATError(RuntimeError):
    """Base error for the auto-tuning system.  Carries a numeric code."""

    code: int = 1

    def __init__(self, message: str, code: int | None = None):
        super().__init__(message)
        if code is not None:
            self.code = code


class OATPriorityError(OATError):
    """Execution priority violation (paper §3.2).

    e.g. running before-execute-time AT before install-time AT completed.
    """

    code = 10


class OATMissingBasicParamError(OATError):
    """Before execute-time AT requires the basic parameters to be set
    (paper §4.2.2: "before execute-time auto tuning will not run if the
    basic parameters are not set")."""

    code = 11


class OATParamCollisionError(OATError):
    """Parameter collision (paper §6.3): auto tuning attempted on a parameter
    pinned by a user specification file.  AT halts for that region and the
    user value is force-set.  This exception is raised only when the caller
    asks for strict behaviour; the default runtime path records the collision
    and force-sets the value as the paper specifies."""

    code = 12


class OATHierarchyError(OATError):
    """Parameter-visibility violation (paper Fig. 4): e.g. an install-time
    routine reading a parameter determined at run-time."""

    code = 13


class OATNestingError(OATError):
    """Illegal nesting (paper §6.4.1, Tables 1 and 2), e.g. `unroll` nesting
    another feature, or `install` nesting `static`; or nesting depth > 3."""

    code = 14


class OATSpecError(OATError):
    """Malformed directive / specifier / subtype specifier."""

    code = 15


class OATCodegenError(OATError):
    """Code generation failed (unsupported construct inside an AT region)."""

    code = 16
