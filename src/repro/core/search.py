"""Parameter search — paper §6.4.2 (brute-force / AD-HOC + nesting rules).

Semantics reproduced exactly from the paper's Sample 10 worked example.

Regions are ordered outermost-first: ``P = (V(P_1), ..., V(P_m))`` where
``P_m`` is the innermost/last-declared region.  The search "begins from the
innermost AT region, and is made to match the outermost search method":

* **all exhaustive** — one joint Cartesian product across *every scalar
  parameter of every region*: ``prod(N_i)`` evaluations (Sample 10 case 1:
  16 * 32**4 = 16,777,216; the paper prints 1,677,216, an arithmetic typo we
  note and correct).
* **otherwise** — regions are processed sequentially from innermost to
  outermost; each region optimises *its own* parameters with all other
  parameters frozen at their current best:
    - an AD-HOC region descends its scalars one coordinate at a time
      (``sum(N_ij)`` over its scalars), innermost scalar first;
    - a brute-force region takes the joint product over its own scalars
      (``prod(N_ij)``).
  Sample 10: all-AD-HOC = 16+32+32+32+32 = 144; exhaustive-outer/AD-HOC-inner
  = 144 (the AD-HOC regions are fixed first, "treated as constant values",
  then the outer searched); AD-HOC-outer/exhaustive-inner = 16+32*32+32*32
  = 2,064.

Fitting (paper §3.4.3): when a region carries a ``fitting`` spec with sample
points, only the sampled candidates are measured and the optimum over the
full grid is *inferred* (fitting.py).  Without ``fitting`` the search over
that scalar is exhaustive over its ``varied`` range.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Sequence

from .errors import OATSpecError
from .fitting import auto_sample_points, fitted_minimum
from .region import ATRegion

# --------------------------------------------------------------------------
# scalar axes: one per (region, scalar-parameter)
# --------------------------------------------------------------------------


@dataclass
class Axis:
    """One scalar search coordinate: a single name of a ``varied`` tuple or a
    select region's alternative index."""

    region: ATRegion
    name: str                 # qualified PP name (e.g. MyMatMul_I)
    candidates: tuple
    sampled: tuple | None = None   # measured subset when fitting is active

    @property
    def n(self) -> int:
        return len(self.candidates)

    def measured_points(self) -> tuple:
        return self.sampled if self.sampled is not None else self.candidates


def region_axes(region: ATRegion) -> list[Axis]:
    """Scalar axes of one region (no descendants)."""
    if region.feature == "select":
        return [Axis(region, region.pp_names[0],
                     tuple(range(len(region.subregions))))]
    if region.varied is None:
        return []
    cands = region.varied.candidates()
    sampled = None
    if region.fitting is not None:
        if region.fitting.sampled is not None:
            sampled = tuple(x for x in region.fitting.sampled if x in cands) \
                or tuple(region.fitting.sampled)
        else:  # 'sampled auto'
            sampled = tuple(auto_sample_points(min(cands), max(cands)))
    return [Axis(region, pp, cands, sampled) for pp in region.pp_names]


def tree_axes(root: ATRegion) -> list[Axis]:
    """All axes of a region tree, outermost-first / declaration order."""
    out: list[Axis] = []
    for r in root.flatten():
        out.extend(region_axes(r))
    return out


# --------------------------------------------------------------------------
# search plan — composable, with exact predicted evaluation counts
# --------------------------------------------------------------------------


@dataclass
class SearchResult:
    best: dict[str, Any]
    best_cost: float
    n_evaluations: int
    history: list[tuple[dict, float]] = field(default_factory=list)
    fitted: dict[str, bool] = field(default_factory=dict)


class SearchPlan:
    """A compiled search over a region tree (paper §6.4.2 composition)."""

    def __init__(self, root: ATRegion):
        self.root = root
        self.regions = root.flatten()          # outermost-first
        self.methods = [r.search_method or "brute-force" for r in self.regions]
        self.axes_per_region = [region_axes(r) for r in self.regions]
        self.all_axes = [a for axs in self.axes_per_region for a in axs]
        if not self.all_axes:
            raise OATSpecError(
                f"region {root.name!r} has nothing to search (define-only?)")

    # -- predicted counts (paper's arithmetic, asserted in tests) ----------
    @property
    def all_exhaustive(self) -> bool:
        return all(m == "brute-force" for m in self.methods)

    def num_evaluations(self) -> int:
        """Exact evaluation count of :meth:`run` (the paper's arithmetic)."""
        if self.all_exhaustive and not any(
                a.sampled is not None for a in self.all_axes):
            n = 1
            for a in self.all_axes:
                n *= a.n
            return n
        total = 0
        for axs, m in zip(self.axes_per_region, self.methods):
            if not axs:
                continue
            if m == "brute-force" and len(axs) > 1 and all(
                    a.sampled is None for a in axs):
                p = 1
                for a in axs:
                    p *= a.n
                total += p
            else:  # coordinate pass: one scalar at a time (AD-HOC / fitted)
                total += sum(len(a.measured_points()) for a in axs)
        return total

    # -- execution ---------------------------------------------------------
    def run(self, measure: Callable[[dict[str, Any]], float],
            init: dict[str, Any] | None = None) -> SearchResult:
        """Run the composed search.

        ``measure(assignment)`` returns the cost of one full PP assignment
        (every axis bound).  Lower is better.
        """
        history: list[tuple[dict, float]] = []

        def ev(asg: dict[str, Any]) -> float:
            c = float(measure(dict(asg)))
            history.append((dict(asg), c))
            return c

        current = {a.name: a.candidates[0] for a in self.all_axes}
        if init:
            current.update({k: v for k, v in init.items() if k in current})
        fitted_axes: dict[str, bool] = {}

        if self.all_exhaustive and not any(
                a.sampled is not None for a in self.all_axes):
            best, best_cost = None, float("inf")
            names = [a.name for a in self.all_axes]
            for combo in itertools.product(
                    *[a.candidates for a in self.all_axes]):
                asg = dict(zip(names, combo))
                c = ev(asg)
                if c < best_cost:
                    best, best_cost = asg, c
            return SearchResult(best, best_cost, len(history), history,
                                fitted_axes)

        # sequential inner->outer composition (also used when fitting makes
        # a notionally-exhaustive region sampled: the per-region pass below
        # handles fitting inference per scalar axis).
        for axs, m, region in zip(reversed(self.axes_per_region),
                                  reversed(self.methods),
                                  reversed(self.regions)):
            if not axs:
                continue
            if m == "brute-force" and len(axs) > 1 and all(
                    a.sampled is None for a in axs):
                # joint product over this region's scalars
                best_local, best_cost = None, float("inf")
                for combo in itertools.product(*[a.candidates for a in axs]):
                    asg = dict(current)
                    asg.update(dict(zip([a.name for a in axs], combo)))
                    c = ev(asg)
                    if c < best_cost:
                        best_local, best_cost = combo, c
                current.update(dict(zip([a.name for a in axs], best_local)))
                continue
            # coordinate pass (AD-HOC, single-axis brute-force, or fitted):
            # innermost scalar of the region first (paper Sample 10 varies
            # the last tuple element first).
            for a in reversed(axs):
                pts = list(a.measured_points())
                costs = []
                for v in pts:
                    asg = dict(current)
                    asg[a.name] = v
                    costs.append(ev(asg))
                if a.sampled is not None and a.region.fitting is not None:
                    best_v = fitted_minimum(a.region.fitting, pts, costs,
                                            a.candidates)
                    fitted_axes[a.name] = True
                else:
                    best_v = pts[int(min(range(len(costs)),
                                         key=costs.__getitem__))]
                current[a.name] = best_v

        # cost of the chosen assignment: exact history match when the final
        # pass measured it; for a fitted (inferred, unmeasured) optimum we do
        # NOT re-measure (the paper's flow stops at inference) and report the
        # best measured cost as the achieved bound.
        final_cost = min((c for asg, c in history
                          if all(asg.get(k) == v for k, v in current.items())),
                         default=min(c for _, c in history))
        return SearchResult(dict(current), final_cost, len(history), history,
                            fitted_axes)


# --------------------------------------------------------------------------
# convenience wrappers
# --------------------------------------------------------------------------


def search_region(region: ATRegion,
                  measure: Callable[[dict[str, Any]], float],
                  init: dict[str, Any] | None = None) -> SearchResult:
    return SearchPlan(region).run(measure, init=init)


def predicted_count(region: ATRegion) -> int:
    return SearchPlan(region).num_evaluations()
