"""AT region model (paper §3.4).

A *tuning region* (AT region) is the program fragment between
``!OAT$ ... region start`` and ``!OAT$ ... region end``.  It carries:

  * an auto-tuning type (``install`` / ``static`` / ``dynamic``),
  * a feature name (``define`` / ``variable`` / ``select`` / ``unroll``),
  * subtype specifiers (``name``, ``parameter``, ``varied``, ``fitting``,
    ``according``, ``number``, ``prepro``/``postpro``, ``debug``),
  * nested child regions (nesting legality per paper §6.4.1).

In the JAX adaptation a region wraps a *variant generator*: a callable that,
given concrete PP values as keyword arguments, returns a runnable (and
jit-able) implementation.  ``select`` regions carry a list of sub-region
alternatives instead.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

from .errors import OATNestingError, OATSpecError
from .params import ParamDecl, Varied

FEATURES = ("define", "variable", "select", "unroll")
AT_TYPES = ("install", "static", "dynamic")

# Paper Table 1 — nesting availability by auto-tuning type
# (superior row may nest subordinate column)
_TYPE_NEST = {
    "install": {"install"},
    "static": {"install", "static"},
    "dynamic": {"install", "static", "dynamic"},
}

# Paper Table 2 — nesting availability by feature (unroll may nest nothing)
_FEATURE_NEST = {
    "define": set(FEATURES),
    "variable": set(FEATURES),
    "select": set(FEATURES),
    "unroll": set(),
}

MAX_NEST_DEPTH = 3  # paper §6.4.1

# Paper §6.4.2 — default search method per feature
DEFAULT_SEARCH = {
    "define": None,          # no search needed
    "variable": "brute-force",
    "select": "ad-hoc",
    "unroll": "brute-force",
}


@dataclass
class Fitting:
    """``fitting <method> sampled <scope>`` (paper §3.4.3)."""

    method: str = "auto"           # least-squares | dspline | user-defined | auto
    order: int = 2                 # polynomial order for least-squares
    expr: str | None = None        # user-defined basis expression over 'x'
    sampled: list[int] | None = None  # sample points; None => 'auto'

    @classmethod
    def least_squares(cls, order: int, sampled=None) -> "Fitting":
        return cls("least-squares", order=order, sampled=list(sampled) if sampled else None)

    @classmethod
    def dspline(cls, sampled=None) -> "Fitting":
        return cls("dspline", sampled=list(sampled) if sampled else None)

    @classmethod
    def user_defined(cls, expr: str, sampled=None) -> "Fitting":
        return cls("user-defined", expr=expr, sampled=list(sampled) if sampled else None)

    @classmethod
    def auto(cls) -> "Fitting":
        return cls("auto")


@dataclass
class Subregion:
    """One alternative of a ``select`` region (``select sub region``)."""

    fn: Callable
    according: Any = None       # According object (cost.py) or None
    name: str = ""


@dataclass
class ATRegion:
    at_type: str
    feature: str
    name: str
    fn: Callable | None = None              # variant generator (PPs as kwargs)
    params: list[ParamDecl] = field(default_factory=list)
    varied: Varied | None = None
    fitting: Fitting | None = None
    according: Any = None                   # region-level According (select)
    subregions: list[Subregion] = field(default_factory=list)
    number: int | None = None               # processing order override
    prepro: Callable | None = None
    postpro: Callable | None = None
    debug: tuple = ()
    search: str | None = None               # brute-force | ad-hoc | None=default
    children: list["ATRegion"] = field(default_factory=list)
    parent: "ATRegion | None" = None
    metadata: dict = field(default_factory=dict)

    def __post_init__(self):
        if self.at_type not in AT_TYPES:
            raise OATSpecError(f"unknown auto-tuning type {self.at_type!r}")
        if self.feature not in FEATURES:
            raise OATSpecError(f"unknown feature {self.feature!r}")
        if self.feature in ("variable", "unroll") and self.varied is None:
            raise OATSpecError(
                f"{self.feature} region {self.name!r} requires a `varied` range")

    # ---------------------------------------------------------------
    @property
    def search_method(self) -> str | None:
        return self.search if self.search is not None else DEFAULT_SEARCH[self.feature]

    @property
    def pp_names(self) -> tuple[str, ...]:
        """Qualified PP names, paper style: ``MyMatMul_I`` etc."""
        if self.feature == "select":
            return (f"{self.name}_SELECT",)
        if self.varied is None:
            return ()
        return tuple(f"{self.name}_{n.upper()}" for n in self.varied.names)

    @property
    def bp_names(self) -> tuple[str, ...]:
        return tuple(p.name for p in self.params if p.attr == "bp")

    def n_candidates(self) -> int:
        if self.feature == "select":
            return len(self.subregions)
        if self.varied is None:
            return 1
        return self.varied.n ** len(self.varied.names)

    # ---------------------------------------------------------------
    def add_child(self, child: "ATRegion") -> "ATRegion":
        if child.at_type not in _TYPE_NEST[self.at_type]:
            raise OATNestingError(
                f"a {self.at_type!r} region may not nest a {child.at_type!r} "
                f"region (paper Table 1)")
        if child.feature not in _FEATURE_NEST[self.feature]:
            raise OATNestingError(
                f"a {self.feature!r} region may not nest a {child.feature!r} "
                f"region (paper Table 2)")
        if self.depth() + 1 >= MAX_NEST_DEPTH + 1:
            raise OATNestingError(
                f"maximum nesting depth is {MAX_NEST_DEPTH} (paper §6.4.1)")
        child.parent = self
        self.children.append(child)
        return child

    def depth(self) -> int:
        d, r = 1, self
        while r.parent is not None:
            d += 1
            r = r.parent
        return d

    def flatten(self) -> list["ATRegion"]:
        """Self + descendants, in declaration order (respecting `number`)."""
        out = [self]
        for c in self.children:
            out.extend(c.flatten())
        return out


class RegionRegistry:
    """The paper's OAT_AllRoutines / OAT_<Phase>Routines storage (§4.1)."""

    def __init__(self):
        self._regions: dict[str, ATRegion] = {}

    def register(self, region: ATRegion) -> ATRegion:
        if region.name in self._regions:
            raise OATSpecError(f"duplicate tuning region name {region.name!r}")
        self._regions[region.name] = region
        return region

    def delete(self, name: str) -> None:
        """OAT_ATdel semantics — remove a region from the candidates."""
        self._regions.pop(name, None)

    def get(self, name: str) -> ATRegion:
        if name not in self._regions:
            raise OATSpecError(f"unknown tuning region {name!r}")
        return self._regions[name]

    def __contains__(self, name: str) -> bool:
        return name in self._regions

    def by_phase(self, phase: str) -> list[ATRegion]:
        rs = [r for r in self._regions.values()
              if r.at_type == phase and r.parent is None]
        # `number` overrides declaration (first-to-last) order; only outermost
        # regions may carry a number (paper §3.4.3)
        numbered = sorted((r for r in rs if r.number is not None),
                          key=lambda r: r.number)
        rest = [r for r in rs if r.number is None]
        return numbered + rest

    def all_names(self) -> list[str]:
        return list(self._regions)

    def clear(self) -> None:
        self._regions.clear()
