"""Legacy directive frontend — superseded by the ``repro.at`` session API.

.. deprecated::
    The per-(type, feature) decorators (``install_unroll`` ...) and the
    ``SelectRegion`` builder are deprecation shims kept so existing code
    and tests run unchanged.  New code declares regions through one
    surface: ``repro.at.AutoTuner.autotune`` (see ``docs/API.md`` for the
    migration table).  The low-level :func:`region` decorator remains the
    shared implementation both frontends dispatch through, so shimmed and
    new declarations land in the same registry and are tuned identically.

Example (paper Sample Program 1, current surface)::

    import repro.at as at
    tuner = at.AutoTuner(workdir)
    @tuner.autotune("install", "unroll", name="MyMatMul",
                    varied=at.Varied(("i", "j"), 1, 16),
                    fitting=at.Fitting.least_squares(5,
                        sampled=[1, 2, 3, 4, 5, 8, 16]))
    def my_matmul(i=1, j=1):
        return lambda: run_matmul(unroll_i=i, unroll_j=j)
"""
from __future__ import annotations

import warnings
from typing import Any, Callable, Sequence

from .cost import According
from .params import ParamDecl, Varied
from .region import ATRegion, Fitting, Subregion
from .runtime import ATContext, default_context


def _warn_deprecated(old: str, new: str, stacklevel: int = 3) -> None:
    warnings.warn(
        f"repro.core.directives.{old} is deprecated; use {new} "
        f"(repro.at session API)", DeprecationWarning, stacklevel=stacklevel)


def _coerce_params(params) -> list[ParamDecl]:
    out = []
    for p in params or ():
        if isinstance(p, ParamDecl):
            out.append(p)
        elif isinstance(p, (tuple, list)):
            name, attr = p
            out.append(ParamDecl(name, attr))
        else:  # "bp n" / "in CacheSize" / bare name
            parts = str(p).split()
            if len(parts) == 2:
                out.append(ParamDecl(parts[1], parts[0]))
            else:
                out.append(ParamDecl(parts[0]))
    return out


def region(ctx: ATContext | None, at_type: str, feature: str, name: str, *,
           varied: Varied | None = None, fitting: Fitting | None = None,
           params: Sequence = (), according: According | str | None = None,
           search: str | None = None, number: int | None = None,
           prepro: Callable | None = None, postpro: Callable | None = None,
           debug: tuple = (), parent: ATRegion | None = None,
           metadata: dict | None = None) -> Callable:
    """Decorator declaring a tuning region around a variant generator."""
    ctx = ctx or default_context()
    if isinstance(according, str):
        according = According.parse(according)

    def deco(fn: Callable) -> ATRegion:
        r = ATRegion(at_type=at_type, feature=feature, name=name, fn=fn,
                     params=_coerce_params(params), varied=varied,
                     fitting=fitting, according=according, search=search,
                     number=number, prepro=prepro, postpro=postpro,
                     debug=tuple(debug), metadata=metadata or {})
        if parent is not None:
            parent.add_child(r)
            ctx.registry.register(r)
        else:
            ctx.register(r)
        return r

    return deco


# deprecation shims, one per (type, feature) pair used in the paper; each
# dispatches through region() into the same registry the session API uses
def _shim(at_type: str, feature: str, ctx, kw) -> Callable:
    # stacklevel 4: user -> wrapper (install_unroll) -> _shim -> warn
    _warn_deprecated(f"{at_type}_{feature}",
                     f"AutoTuner.autotune({at_type!r}, {feature!r}, ...)",
                     stacklevel=4)
    return region(ctx, at_type, feature, kw.pop("name"), **kw)


def install_unroll(ctx=None, **kw):  # Sample 1
    return _shim("install", "unroll", ctx, kw)


def install_define(ctx=None, **kw):  # Sample 2
    return _shim("install", "define", ctx, kw)


def install_variable(ctx=None, **kw):
    return _shim("install", "variable", ctx, kw)


def static_unroll(ctx=None, **kw):   # Sample 4
    return _shim("static", "unroll", ctx, kw)


def static_variable(ctx=None, **kw):
    return _shim("static", "variable", ctx, kw)


def dynamic_variable(ctx=None, **kw):
    return _shim("dynamic", "variable", ctx, kw)


def dynamic_unroll(ctx=None, **kw):  # Sample 7
    return _shim("dynamic", "unroll", ctx, kw)


class SelectRegion:
    """Deprecated builder for ``select`` regions (Samples 5 and 6) — use
    ``AutoTuner.autotune(phase, "select", name=...)`` instead, which needs
    no ``finalize`` step.  Original usage::

        sel = SelectRegion(ctx, "dynamic", name="PrecondSelect",
                           params=["in eps", "in iter"],
                           according="min (eps) .and. condition (iter < 5)")

        @sel.alternative()
        def process_1(...): ...

        @sel.alternative(according="estimated 4.0d0*CacheSize*...")
        def process_2(...): ...

        sel.finalize()
    """

    def __init__(self, ctx: ATContext | None, at_type: str, name: str, *,
                 params: Sequence = (), according: According | str | None = None,
                 search: str | None = None, number: int | None = None,
                 parent: ATRegion | None = None, metadata: dict | None = None):
        _warn_deprecated("SelectRegion",
                         "AutoTuner.autotune(phase, 'select', name=...)")
        self.ctx = ctx or default_context()
        if isinstance(according, str):
            according = According.parse(according)
        self.region = ATRegion(
            at_type=at_type, feature="select", name=name,
            params=_coerce_params(params), according=according,
            search=search, number=number, metadata=metadata or {})
        self._parent = parent
        self._registered = False

    def alternative(self, according: According | str | None = None,
                    name: str = "") -> Callable:
        if isinstance(according, str):
            according = According.parse(according)

        def deco(fn: Callable) -> Callable:
            self.region.subregions.append(
                Subregion(fn=fn, according=according,
                          name=name or fn.__name__))
            return fn

        return deco

    def finalize(self) -> ATRegion:
        if not self._registered:
            if self._parent is not None:
                self._parent.add_child(self.region)
                self.ctx.registry.register(self.region)
            else:
                self.ctx.register(self.region)
            self._registered = True
        return self.region

    def __call__(self, *args, **kwargs) -> Any:
        """Invoke the (possibly still-tuning) region through the runtime."""
        return self.ctx.execute(self.region.name, *args, **kwargs)


def static_select(ctx=None, **kw) -> SelectRegion:
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        sel = SelectRegion(ctx, "static", kw.pop("name"), **kw)
    _warn_deprecated("static_select",
                     "AutoTuner.autotune('static', 'select', name=...)")
    return sel


def dynamic_select(ctx=None, **kw) -> SelectRegion:
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        sel = SelectRegion(ctx, "dynamic", kw.pop("name"), **kw)
    _warn_deprecated("dynamic_select",
                     "AutoTuner.autotune('dynamic', 'select', name=...)")
    return sel


def install_select(ctx=None, **kw) -> SelectRegion:
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        sel = SelectRegion(ctx, "install", kw.pop("name"), **kw)
    _warn_deprecated("install_select",
                     "AutoTuner.autotune('install', 'select', name=...)")
    return sel
