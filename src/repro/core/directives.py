"""Pythonic directive frontend — the JAX-side `!OAT$` analogue.

Two ways to annotate code:

1. **Decorator / object API** (this module) — first-class in the JAX
   framework: regions wrap *variant generators* (callables taking PPs as
   keyword arguments).
2. **Literal comment directives** (`#OAT$ ...`, dsl.py) — parsed out of
   Python source and expanded by codegen.py, mirroring the paper's
   preprocessor flow exactly.

Example (paper Sample Program 1)::

    ctx = ATContext(workdir)
    @install_unroll(ctx, name="MyMatMul", varied=Varied(("i", "j"), 1, 16),
                    fitting=Fitting.least_squares(5, sampled=[1,2,3,4,5,8,16]),
                    debug=("pp",))
    def my_matmul(i=1, j=1):
        return lambda: run_matmul(unroll_i=i, unroll_j=j)
"""
from __future__ import annotations

from typing import Any, Callable, Sequence

from .cost import According
from .params import ParamDecl, Varied
from .region import ATRegion, Fitting, Subregion
from .runtime import ATContext, default_context


def _coerce_params(params) -> list[ParamDecl]:
    out = []
    for p in params or ():
        if isinstance(p, ParamDecl):
            out.append(p)
        elif isinstance(p, (tuple, list)):
            name, attr = p
            out.append(ParamDecl(name, attr))
        else:  # "bp n" / "in CacheSize" / bare name
            parts = str(p).split()
            if len(parts) == 2:
                out.append(ParamDecl(parts[1], parts[0]))
            else:
                out.append(ParamDecl(parts[0]))
    return out


def region(ctx: ATContext | None, at_type: str, feature: str, name: str, *,
           varied: Varied | None = None, fitting: Fitting | None = None,
           params: Sequence = (), according: According | str | None = None,
           search: str | None = None, number: int | None = None,
           prepro: Callable | None = None, postpro: Callable | None = None,
           debug: tuple = (), parent: ATRegion | None = None,
           metadata: dict | None = None) -> Callable:
    """Decorator declaring a tuning region around a variant generator."""
    ctx = ctx or default_context()
    if isinstance(according, str):
        according = According.parse(according)

    def deco(fn: Callable) -> ATRegion:
        r = ATRegion(at_type=at_type, feature=feature, name=name, fn=fn,
                     params=_coerce_params(params), varied=varied,
                     fitting=fitting, according=according, search=search,
                     number=number, prepro=prepro, postpro=postpro,
                     debug=tuple(debug), metadata=metadata or {})
        if parent is not None:
            parent.add_child(r)
            ctx.registry.register(r)
        else:
            ctx.register(r)
        return r

    return deco


# convenience wrappers, one per (type, feature) pair used in the paper
def install_unroll(ctx=None, **kw):  # Sample 1
    return region(ctx, "install", "unroll", kw.pop("name"), **kw)


def install_define(ctx=None, **kw):  # Sample 2
    return region(ctx, "install", "define", kw.pop("name"), **kw)


def install_variable(ctx=None, **kw):
    return region(ctx, "install", "variable", kw.pop("name"), **kw)


def static_unroll(ctx=None, **kw):   # Sample 4
    return region(ctx, "static", "unroll", kw.pop("name"), **kw)


def static_variable(ctx=None, **kw):
    return region(ctx, "static", "variable", kw.pop("name"), **kw)


def dynamic_variable(ctx=None, **kw):
    return region(ctx, "dynamic", "variable", kw.pop("name"), **kw)


def dynamic_unroll(ctx=None, **kw):  # Sample 7
    return region(ctx, "dynamic", "unroll", kw.pop("name"), **kw)


class SelectRegion:
    """Builder for ``select`` regions (Samples 5 and 6)::

        sel = SelectRegion(ctx, "dynamic", name="PrecondSelect",
                           params=["in eps", "in iter"],
                           according="min (eps) .and. condition (iter < 5)")

        @sel.alternative()
        def process_1(...): ...

        @sel.alternative(according="estimated 4.0d0*CacheSize*...")
        def process_2(...): ...

        sel.finalize()
    """

    def __init__(self, ctx: ATContext | None, at_type: str, name: str, *,
                 params: Sequence = (), according: According | str | None = None,
                 search: str | None = None, number: int | None = None,
                 parent: ATRegion | None = None, metadata: dict | None = None):
        self.ctx = ctx or default_context()
        if isinstance(according, str):
            according = According.parse(according)
        self.region = ATRegion(
            at_type=at_type, feature="select", name=name,
            params=_coerce_params(params), according=according,
            search=search, number=number, metadata=metadata or {})
        self._parent = parent
        self._registered = False

    def alternative(self, according: According | str | None = None,
                    name: str = "") -> Callable:
        if isinstance(according, str):
            according = According.parse(according)

        def deco(fn: Callable) -> Callable:
            self.region.subregions.append(
                Subregion(fn=fn, according=according,
                          name=name or fn.__name__))
            return fn

        return deco

    def finalize(self) -> ATRegion:
        if not self._registered:
            if self._parent is not None:
                self._parent.add_child(self.region)
                self.ctx.registry.register(self.region)
            else:
                self.ctx.register(self.region)
            self._registered = True
        return self.region

    def __call__(self, *args, **kwargs) -> Any:
        """Invoke the (possibly still-tuning) region through the runtime."""
        return self.ctx.execute(self.region.name, *args, **kwargs)


def static_select(ctx=None, **kw) -> SelectRegion:
    return SelectRegion(ctx, "static", kw.pop("name"), **kw)


def dynamic_select(ctx=None, **kw) -> SelectRegion:
    return SelectRegion(ctx, "dynamic", kw.pop("name"), **kw)


def install_select(ctx=None, **kw) -> SelectRegion:
    return SelectRegion(ctx, "install", kw.pop("name"), **kw)
