"""Dependence-aware statement graph for the §5 loop transforms.

The paper's extended functions (loop split/fusion with flow dependences,
statement re-ordering) require knowing which statements read/write which
names.  We extract read/write sets from Python statement source via ``ast``
and provide the legality predicates used by codegen.py:

* split legality — a value *defined* before the split point and *used* after
  it must be covered by a re-computation copy (``SplitPointCopyDef``), else
  the split is illegal (the paper: "There is a flow dependency ... hence in
  general it is difficult to perform loop splitting using compilers");
* re-ordering legality — a permutation of statements is legal iff every
  (RAW, WAR, WAW) dependent pair keeps its relative order.

Array accesses are treated at name granularity (A[i,j] reads/writes "A"),
which is conservative and safe for the paper's kernels.
"""
from __future__ import annotations

import ast
import itertools
from dataclasses import dataclass, field


@dataclass
class RW:
    reads: set[str] = field(default_factory=set)
    writes: set[str] = field(default_factory=set)


def _base_name(node: ast.AST) -> str | None:
    while isinstance(node, (ast.Subscript, ast.Attribute)):
        node = node.value
    if isinstance(node, ast.Name):
        return node.id
    return None


def stmt_rw(src: str) -> RW:
    """Read/write sets of one (single- or multi-line) Python statement."""
    tree = ast.parse(src.strip() or "pass")
    rw = RW()

    class V(ast.NodeVisitor):
        def visit_Assign(self, n: ast.Assign):
            for t in n.targets:
                b = _base_name(t)
                if b:
                    rw.writes.add(b)
                if isinstance(t, ast.Subscript):
                    # index expressions are reads
                    self.visit(t.slice)
                    if b:
                        rw.reads.add(b)  # partial write: old value observable
            self.visit(n.value)

        def visit_AugAssign(self, n: ast.AugAssign):
            b = _base_name(n.target)
            if b:
                rw.writes.add(b)
                rw.reads.add(b)
            if isinstance(n.target, ast.Subscript):
                self.visit(n.target.slice)
            self.visit(n.value)

        def visit_Name(self, n: ast.Name):
            if isinstance(n.ctx, ast.Load):
                rw.reads.add(n.id)

    V().visit(tree)
    return rw


def depends(a: RW, b: RW) -> bool:
    """True if statement b depends on a (RAW, WAR or WAW) when a precedes b."""
    return bool((a.writes & b.reads) or (a.reads & b.writes)
                or (a.writes & b.writes))


def order_legal(stmts_rw: list[RW], perm: list[int]) -> bool:
    """Is permutation ``perm`` of statements (given original order) legal?"""
    pos = {s: i for i, s in enumerate(perm)}
    for i, j in itertools.combinations(range(len(stmts_rw)), 2):
        if depends(stmts_rw[i], stmts_rw[j]) and pos[i] > pos[j]:
            return False
    return True


def interleave_orders(group_sizes: list[int]) -> list[list[int]]:
    """Candidate orders for RotationOrder groups (paper Sample 9).

    Statements are indexed globally in original order, groups are contiguous.
    Returns [grouped (original), round-robin interleaved]."""
    offsets = [0]
    for s in group_sizes:
        offsets.append(offsets[-1] + s)
    n = offsets[-1]
    grouped = list(range(n))
    rr: list[int] = []
    for k in range(max(group_sizes)):
        for g, size in enumerate(group_sizes):
            if k < size:
                rr.append(offsets[g] + k)
    return [grouped, rr]


def uncovered_flow_deps(pre_rw: list[RW], post_rw: list[RW],
                        recompute_writes: set[str],
                        loop_carried: set[str] = frozenset()) -> set[str]:
    """Names defined in the pre-split body and used post-split that are NOT
    re-computed — these make the split illegal (paper §5.2).

    ``loop_carried`` names (arrays indexed by the loop vars) are excluded:
    array elements written pre-split persist in memory across the fission.
    Only *scalars* (privatised per-iteration temporaries) need re-computation.
    """
    defined_pre = set().union(*[r.writes for r in pre_rw]) if pre_rw else set()
    used_post = set().union(*[r.reads for r in post_rw]) if post_rw else set()
    return (defined_pre & used_post) - recompute_writes - set(loop_carried)
