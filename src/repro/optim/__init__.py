from .adamw import AdamWConfig, AdamWState, global_norm, init, schedule, update
__all__ = ["AdamWConfig", "AdamWState", "init", "update", "schedule",
           "global_norm"]
