"""AdamW + cosine schedule + global-norm clipping (pure JAX, optax-free).

Optimizer state shares the parameter sharding (each moment is elementwise),
so FSDP sharding of params extends to the full optimizer memory.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


class AdamWState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict


def init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32),
                         params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def schedule(step: jax.Array, cfg: AdamWConfig) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gn


def update(grads, state: AdamWState, params, cfg: AdamWConfig):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    grads, gn = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = schedule(step, cfg)
    b1, b2 = cfg.beta1, cfg.beta2
    m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) * g, state.m, grads)
    v = jax.tree.map(lambda vv, g: b2 * vv + (1 - b2) * g * g, state.v,
                     grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, mm, vv):
        mhat = mm / bc1
        vhat = vv / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay \
            * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, AdamWState(step, m, v), {"grad_norm": gn, "lr": lr}
