from .pipeline import DataConfig, DataIterator, batch_for_step
__all__ = ["DataConfig", "DataIterator", "batch_for_step"]
