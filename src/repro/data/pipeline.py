"""Deterministic sharded synthetic token pipeline.

Every batch is a pure function of (seed, step, shard) — the property that
makes checkpoint/restart *exact*: resuming at step k regenerates the same
remaining stream with no data-state to save.  Tokens follow a Zipfian-ish
mixture so losses move like real text rather than uniform noise.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    frontend_seq: int = 0
    d_model: int = 0


def _fold(seed: int, step: int, shard: int) -> jax.Array:
    key = jax.random.PRNGKey(seed)
    key = jax.random.fold_in(key, step)
    return jax.random.fold_in(key, shard)


def batch_for_step(cfg: DataConfig, step: int, shard: int = 0,
                   n_shards: int = 1) -> dict:
    """One shard's batch: tokens/labels (b_shard, S) int32 (+ frontend)."""
    b = cfg.global_batch // n_shards
    key = _fold(cfg.seed, step, shard)
    k1, k2, k3 = jax.random.split(key, 3)
    # Zipf-ish: exponential transform of uniforms concentrates low ids
    u = jax.random.uniform(k1, (b, cfg.seq_len + 1))
    toks = (jnp.exp(u * np.log(cfg.vocab_size)) - 1).astype(jnp.int32)
    toks = jnp.clip(toks, 0, cfg.vocab_size - 1)
    # inject local structure: every position p depends weakly on p-1
    toks = toks.at[:, 1:].set((toks[:, 1:] + toks[:, :-1]) % cfg.vocab_size)
    out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.frontend_seq:
        out["frontend_embeds"] = jax.random.normal(
            k3, (b, cfg.frontend_seq, cfg.d_model), jnp.float32)
    return out


class DataIterator:
    """Stateless-resumable iterator: ``DataIterator(cfg, start_step=k)``."""

    def __init__(self, cfg: DataConfig, start_step: int = 0, shard: int = 0,
                 n_shards: int = 1):
        self.cfg = cfg
        self.step = start_step
        self.shard = shard
        self.n_shards = n_shards

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        b = batch_for_step(self.cfg, self.step, self.shard, self.n_shards)
        self.step += 1
        return b
