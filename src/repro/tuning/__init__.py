"""FIBER tuning drivers wired to framework knobs: install-time (kernel
block shapes), before-execute-time (layout plans), run-time (serving
bucket variants)."""
from .dynamic import DecodeAutoTuner, divisor_block_ks
from .install import register_kernel_regions, run_install_tuning
from .static import analytic_plan_cost, candidate_plans, tune_layout
__all__ = ["register_kernel_regions", "run_install_tuning", "tune_layout",
           "analytic_plan_cost", "candidate_plans", "DecodeAutoTuner",
           "divisor_block_ks"]
