"""Install-time AT driver: machine-dependent kernel performance parameters.

The paper's install-time phase tunes PPs that depend only on the machine
(Sample 1: unroll depth).  Here those are the Pallas kernel block shapes.
``varied`` ranges are MXU/VMEM-aligned (multiples of 128 on lane dims, 8 on
sublane dims) — the documented hardware adaptation of the paper's 1..16
unroll range.

Declared through the ``repro.at`` session API.  Executor backends
(``at.executors``):

* ``analytic-cost`` (default here) — the VMEM-pressure cost model below:
  fast, deterministic; penalises tiles that bust the ~16 MB
  more-than-half-VMEM budget and rewards MXU-shaped tiles;
* ``interp`` (registered by this module) — interpret-mode wall-clock over
  the real Pallas kernels at small shapes (CPU container); on TPU the
  session default ``wall-clock`` times the real kernels.

Results are published to :func:`repro.at.tuned` under the kernel names
(``matmul`` / ``flash_attention`` / ``ssm_scan``) — every later phase and
the serving engine picks them up (the FIBER hierarchy) — and persist in
the session's :class:`~repro.at.records.ATRecordStore`, so a second
process on the same machine warm-loads them without re-timing.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import at
from ..core import ATContext, Fitting, Varied, WallClockExecutor
from ..kernels.flash_attention import attention_vmem_bytes
from ..kernels.matmul import matmul_vmem_bytes
from ..kernels.ssm_scan import ssm_vmem_bytes

VMEM_BUDGET = 16 * 1024 * 1024      # ~half of v5e VMEM for double-buffering


def _vmem_cost(used: int, mxu_aligned: bool, grid_steps: float) -> float:
    """Analytic install-time cost: grid overhead + VMEM pressure penalty.

    Smaller grids amortise better until the working set busts VMEM; tiles
    not multiple-of-128 on the MXU dims waste systolic cycles.
    """
    over = max(0.0, used / VMEM_BUDGET - 1.0)
    return grid_steps * (1.0 + 4.0 * over) * (1.0 if mxu_aligned else 2.0)


def register_kernel_regions(session: "at.AutoTuner | ATContext", *,
                            m: int = 2048, n: int = 2048, k: int = 2048,
                            seq: int = 2048, d_head: int = 128,
                            d_inner: int = 4096, d_state: int = 16) -> None:
    """Declare the install-time regions for every kernel PP."""
    session = at.AutoTuner.for_context(session)

    @session.autotune(
        "install", "variable", name="MatmulBlocks",
        varied=Varied(("bm", "bn", "bk"), values=(128, 256, 512)),
        search="ad-hoc", executor="analytic-cost",
        publish=("matmul", {"bm": "block_m", "bn": "block_n",
                            "bk": "block_k"}))
    def matmul_blocks(bm=128, bn=128, bk=128):
        used = matmul_vmem_bytes(bm, bn, bk)
        grid = (m / bm) * (n / bn) * (k / bk)
        return _vmem_cost(used, bm % 8 == 0 and bn % 128 == 0
                          and bk % 128 == 0, grid)

    @session.autotune(
        "install", "variable", name="FlashBlocks",
        varied=Varied(("block_q", "block_k"), values=(128, 256, 512, 1024)),
        search="ad-hoc", executor="analytic-cost",
        publish=("flash_attention", {"block_q": "block_q",
                                     "block_k": "block_k"}))
    def flash_blocks(block_q=128, block_k=128):
        used = attention_vmem_bytes(block_q, block_k, d_head)
        grid = (seq / block_q) * (seq / block_k)
        return _vmem_cost(used, block_q % 128 == 0
                          and block_k % 128 == 0, grid)

    @session.autotune(
        "install", "variable", name="SsmChunk",
        varied=Varied(("chunk",), values=(32, 64, 128, 256, 512)),
        fitting=Fitting.dspline(), executor="analytic-cost",
        publish=("ssm_scan", {"chunk": "chunk"}))
    def ssm_chunk(chunk=64):
        used = ssm_vmem_bytes(chunk, d_inner, d_state)
        grid = seq / chunk
        return _vmem_cost(used, chunk % 8 == 0, grid)


_KERNEL_REGIONS = ("MatmulBlocks", "FlashBlocks", "SsmChunk")
_KERNEL_OF = {"MatmulBlocks": "matmul", "FlashBlocks": "flash_attention",
              "SsmChunk": "ssm_scan"}


def run_install_tuning(session: "at.AutoTuner | ATContext",
                       wall_clock: bool = False) -> dict:
    """Execute install-time AT and publish tuned PPs to the kernel layer.

    ``wall_clock=True`` switches the kernel regions to the ``interp``
    executor (interpret-mode Pallas wall-clock).  A session whose record
    store already holds results for this machine re-loads them without
    invoking any executor.
    """
    session = at.AutoTuner.for_context(session)
    session.ensure_default_bps(numprocs=1, start=1024, end=4096, dist=1024)
    names = [n for n in _KERNEL_REGIONS if n in session.ctx.registry]
    for name in names:
        # set (not just override) so a later call with the other setting
        # restores the analytic default
        session.ctx.registry.get(name).metadata["executor"] = \
            "interp" if wall_clock else "analytic-cost"
    session.run("install", names)
    tuned: dict[str, dict] = {}
    for region_name in names:
        spec = session._publish_maps.get(region_name)
        if spec is None:
            continue
        _, mapping = spec
        pps = {}
        for src, dst in mapping.items():
            e = session.ctx.store.entry(f"{region_name}_{src.upper()}")
            if e is not None:
                pps[dst] = int(e.value)
        if pps:
            tuned[region_name] = pps
    return tuned


@at.executors.register("interp")
def _interp_executor(region, bp_env):
    """Interpret-mode wall-clock executor (small shapes, CPU)."""
    key = jax.random.PRNGKey(0)

    def make_variant(assignment):
        bare = {k.split("_", 1)[1].lower(): v for k, v in assignment.items()}
        if region.name == "MatmulBlocks":
            x = jax.random.normal(key, (256, 256), jnp.float32)
            y = jax.random.normal(key, (256, 256), jnp.float32)
            from ..kernels.matmul import matmul
            return lambda: matmul(x, y, block_m=bare["bm"], block_n=bare["bn"],
                                  block_k=bare["bk"], interpret=True)
        if region.name == "FlashBlocks":
            q = jax.random.normal(key, (1, 2, 256, 64), jnp.float32)
            from ..kernels.flash_attention import flash_attention
            return lambda: flash_attention(
                q, q, q, block_q=min(bare["block_q"], 256),
                block_k=min(bare["block_k"], 256), interpret=True)
        x = jax.random.normal(key, (1, 256, 64), jnp.float32)
        a = -jnp.ones((64, 8), jnp.float32)
        b = jax.random.normal(key, (1, 256, 8), jnp.float32)
        d = jnp.ones((64,), jnp.float32)
        from ..kernels.ssm_scan import selective_scan
        return lambda: selective_scan(
            x, jax.nn.softplus(x), a, b, b, d,
            chunk=min(bare["chunk"], 256), interpret=True)

    return WallClockExecutor(make_variant, repeats=1, warmup=1)
