"""Install-time AT driver: machine-dependent kernel performance parameters.

The paper's install-time phase tunes PPs that depend only on the machine
(Sample 1: unroll depth).  Here those are the Pallas kernel block shapes.
``varied`` ranges are MXU/VMEM-aligned (multiples of 128 on lane dims, 8 on
sublane dims) — the documented hardware adaptation of the paper's 1..16
unroll range.

Executors:
* on TPU — wall-clock over the real kernel (WallClockExecutor);
* on CPU (this container) — interpret-mode wall-clock for small shapes,
  or the analytic VMEM-pressure cost model (default: fast, deterministic;
  penalises tiles that bust the ~16 MB more-than-half-VMEM budget and
  rewards MXU-shaped tiles).

Results land in ``ops.set_tuned`` + ``OAT_InstallParam.dat`` so every later
phase (and the serving engine) picks them up — the FIBER hierarchy.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core import (ATContext, Fitting, OAT_INSTALL, Varied,
                    WallClockExecutor)
from ..core.directives import install_unroll, install_variable
from ..kernels import ops
from ..kernels.flash_attention import attention_vmem_bytes
from ..kernels.matmul import matmul_vmem_bytes
from ..kernels.ssm_scan import ssm_vmem_bytes

VMEM_BUDGET = 16 * 1024 * 1024      # ~half of v5e VMEM for double-buffering


def _vmem_cost(used: int, mxu_aligned: bool, grid_steps: float) -> float:
    """Analytic install-time cost: grid overhead + VMEM pressure penalty.

    Smaller grids amortise better until the working set busts VMEM; tiles
    not multiple-of-128 on the MXU dims waste systolic cycles.
    """
    over = max(0.0, used / VMEM_BUDGET - 1.0)
    return grid_steps * (1.0 + 4.0 * over) * (1.0 if mxu_aligned else 2.0)


def register_kernel_regions(ctx: ATContext, *, m: int = 2048,
                            n: int = 2048, k: int = 2048,
                            seq: int = 2048, d_head: int = 128,
                            d_inner: int = 4096, d_state: int = 16) -> None:
    """Declare the install-time regions for every kernel PP."""

    @install_variable(
        ctx, name="MatmulBlocks",
        varied=Varied(("bm", "bn", "bk"), values=(128, 256, 512)),
        search="ad-hoc")
    def matmul_blocks(bm=128, bn=128, bk=128):
        used = matmul_vmem_bytes(bm, bn, bk)
        grid = (m / bm) * (n / bn) * (k / bk)
        return lambda: _vmem_cost(used, bm % 8 == 0 and bn % 128 == 0
                                  and bk % 128 == 0, grid)

    @install_variable(
        ctx, name="FlashBlocks",
        varied=Varied(("block_q", "block_k"), values=(128, 256, 512, 1024)),
        search="ad-hoc")
    def flash_blocks(block_q=128, block_k=128):
        used = attention_vmem_bytes(block_q, block_k, d_head)
        grid = (seq / block_q) * (seq / block_k)
        return lambda: _vmem_cost(used, block_q % 128 == 0
                                  and block_k % 128 == 0, grid)

    @install_variable(
        ctx, name="SsmChunk", varied=Varied(("chunk",),
                                            values=(32, 64, 128, 256, 512)),
        fitting=Fitting.dspline())
    def ssm_chunk(chunk=64):
        used = ssm_vmem_bytes(chunk, d_inner, d_state)
        grid = seq / chunk
        return lambda: _vmem_cost(used, chunk % 8 == 0, grid)


def run_install_tuning(ctx: ATContext, wall_clock: bool = False) -> dict:
    """Execute install-time AT and publish tuned PPs to the kernel layer."""
    if not ctx.store.has_default_bps():
        for k_, v in (("OAT_NUMPROCS", 1), ("OAT_STARTTUNESIZE", 1024),
                      ("OAT_ENDTUNESIZE", 4096), ("OAT_SAMPDIST", 1024)):
            ctx.store.set_bp(k_, v)
    if wall_clock:
        ctx._executor_factory = _wallclock_factory
    ctx.OAT_ATexec(OAT_INSTALL, None)
    tuned = {}
    for region, mapping in (
            ("MatmulBlocks", {"MatmulBlocks_BM": "block_m",
                              "MatmulBlocks_BN": "block_n",
                              "MatmulBlocks_BK": "block_k"}),
            ("FlashBlocks", {"FlashBlocks_BLOCK_Q": "block_q",
                             "FlashBlocks_BLOCK_K": "block_k"}),
            ("SsmChunk", {"SsmChunk_CHUNK": "chunk"})):
        pps = {}
        for qual, bare in mapping.items():
            e = ctx.store.entry(qual)
            if e is not None:
                pps[bare] = int(e.value)
        if pps:
            tuned[region] = pps
    if "MatmulBlocks" in tuned:
        ops.set_tuned("matmul", **tuned["MatmulBlocks"])
    if "FlashBlocks" in tuned:
        ops.set_tuned("flash_attention", **tuned["FlashBlocks"])
    if "SsmChunk" in tuned:
        ops.set_tuned("ssm_scan", **tuned["SsmChunk"])
    return tuned


def _wallclock_factory(region, bp_env):
    """Interpret-mode wall-clock executor (small shapes, CPU)."""
    key = jax.random.PRNGKey(0)

    def make_variant(assignment):
        bare = {k.split("_", 1)[1].lower(): v for k, v in assignment.items()}
        if region.name == "MatmulBlocks":
            x = jax.random.normal(key, (256, 256), jnp.float32)
            y = jax.random.normal(key, (256, 256), jnp.float32)
            from ..kernels.matmul import matmul
            return lambda: matmul(x, y, block_m=bare["bm"], block_n=bare["bn"],
                                  block_k=bare["bk"], interpret=True)
        if region.name == "FlashBlocks":
            q = jax.random.normal(key, (1, 2, 256, 64), jnp.float32)
            from ..kernels.flash_attention import flash_attention
            return lambda: flash_attention(
                q, q, q, block_q=min(bare["block_q"], 256),
                block_k=min(bare["block_k"], 256), interpret=True)
        x = jax.random.normal(key, (1, 256, 64), jnp.float32)
        a = -jnp.ones((64, 8), jnp.float32)
        b = jax.random.normal(key, (1, 256, 8), jnp.float32)
        d = jnp.ones((64,), jnp.float32)
        from ..kernels.ssm_scan import selective_scan
        return lambda: selective_scan(
            x, jax.nn.softplus(x), a, b, b, d,
            chunk=min(bare["chunk"], 256), interpret=True)

    return WallClockExecutor(make_variant, repeats=1, warmup=1)
