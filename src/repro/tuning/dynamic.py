"""Run-time AT driver: serving-time variant selection per request bucket.

The paper's ``dynamic select`` (Samples 6/7) applied to the decode path:
each sequence-length bucket gets a dynamic AT region whose alternatives are
decode implementations (kernel block sizes / layouts); the first calls in
each bucket measure the candidates (run-time auto-tuning happens at the
call site, §4.1), then the winner is committed and ``OAT_DynPerfThis``
semantics apply — later calls run the optimised variant with no tuning.

Declared through the ``repro.at`` session: committed winners persist in
the session's record store, so a restarted server starts every bucket
already committed (no first-call tuning jitter on the warm path).
"""
from __future__ import annotations

from typing import Callable

from .. import at
from ..core import ATContext
from ..serving.engine import length_bucket

DEFAULT_BLOCK_KS = (256, 512, 1024)


class DecodeAutoTuner:
    """Per-bucket dynamic select over decode variants."""

    def __init__(self, session: "at.AutoTuner | ATContext",
                 make_decode: Callable[[int], Callable],
                 buckets=(512, 2048, 8192, 32768),
                 block_ks=DEFAULT_BLOCK_KS):
        self.session = at.AutoTuner.for_context(session)
        self.ctx = self.session.ctx
        self.buckets = buckets
        self.regions = {}
        for b in buckets:
            name = f"DecodeBucket_{b}"
            sel = self.session.autotune("dynamic", "select", name=name)
            for bk in block_ks:
                sel.alternative(name=f"block_k={bk}")(make_decode(bk))
            self.regions[b] = sel.region
        self.session.run("dynamic",
                         [f"DecodeBucket_{b}" for b in buckets])

    def decode(self, kv_len: int, *args, **kwargs):
        b = length_bucket(kv_len, self.buckets)
        return self.session.execute(f"DecodeBucket_{b}", *args, **kwargs)

    def committed(self) -> dict[int, int | None]:
        return {b: self.ctx.dynamic_state[f"DecodeBucket_{b}"].committed
                for b in self.buckets}
