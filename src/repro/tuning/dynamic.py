"""Run-time AT driver: serving-time variant selection per request bucket.

The paper's ``dynamic select`` (Samples 6/7) applied to the decode path:
each sequence-length bucket gets a dynamic AT region whose alternatives
are decode implementations; the first calls in each bucket measure the
candidates (run-time auto-tuning happens at the call site, §4.1), then the
winner is committed and ``OAT_DynPerfThis`` semantics apply — later calls
run the optimised variant with no tuning.

The BP space is (length bucket × block_k) for the dense decode kernel
and, when ``page_sizes`` is given, the full (length bucket × block_k ×
page_size) product for the paged path (arXiv 2312.05779's bucket-wise
runtime re-selection, with the page-gather granularity as the second
axis).  ``num_splits`` adds the split-KV *parallelism degree* as a third
axis — per the ppOpen-AT follow-up, the number of parallel workers
belongs in the tuned space alongside tile shape — with ``num_splits=1``
(the sequential kernel) always present so short buckets can commit the
no-split variant and legacy winners remain valid spellings.

Chunked prefill adds a second tunable region family
(:meth:`DecodeAutoTuner.add_prefill`): one ``dynamic select`` per
(prompt-length bucket × chunk size) whose alternatives are
``flash_paged_prefill`` tile assignments (block_q × block_k) — the
prefill hot path becomes a tuning region exactly like decode did.

Speculative decoding adds a third (:meth:`DecodeAutoTuner.add_spec`):
one ``SpecBucket_{b}`` ``dynamic select`` per sequence-length bucket over
the (k × verify block_q × block_k) product — the accept/reject policy's
window k is itself a tuned parameter (Xabclib-style fully auto-tuned
policy selection), alongside the verify kernel's tile.  A variant with a
smaller k verifies a narrower chunk of the drafted tokens; greedy output
is bit-identical for every k, so the region is free to measure and
commit whichever trades acceptance against verify cost best per bucket.

Prefix caching adds a fourth (:meth:`DecodeAutoTuner.add_prefix_policy`):
a single ``PrefixPolicy`` ``dynamic select`` over the cache's *reuse
policy* product (minimum match granularity × eviction strategy).  Like
the spec region this tunes pure policy, not kernel tiles — the ANTAREX
separation of adaptation policy from functional code: outputs are
bit-identical under every candidate, so the region measures admissions
freely and commits per its ``according`` criterion (default: the policy
whose admissions leave the fewest uncached prompt tokens).

Quantized paged KV adds a sixth (:meth:`DecodeAutoTuner.add_kv_precision`):
one ``KVPrecision_{b}`` ``dynamic select`` per sequence-length bucket over
the (kv precision × block_k) product — fp pages vs int8 pages with
in-kernel dequant.  Unlike every other region family the candidates are
*not* output-identical: int8 pages round each K/V row through a per-row
scale, so the region's ``according`` couples latency to a quality guard
(``min (time_per_token) .and. condition (agreement >= floor)``) — a
quantized candidate may only win if its greedy tokens agree with the fp
reference at or above the floor.  fp candidates report agreement 1.0 by
construction, so the region can never commit to an empty pool.

The serving gateway adds a fifth (:meth:`DecodeAutoTuner.add_gateway`):
a single ``GatewayPolicy`` ``dynamic select`` over the gateway's
concurrency product (pipeline depth × admission batch).  Candidates are
measured over *windows* of live traffic rather than single calls, and
the criterion is ``min (time_per_good_token)`` — the inverse of goodput,
tokens from within-SLO requests per wall second.

Declared through the ``repro.at`` session: committed winners (decode,
prefill, spec, prefix-policy and gateway-policy alike) persist in the
session's record store, so a restarted server starts every region
already committed (no first-call tuning jitter on the warm path).

Every bucketed region family keys off the shared
:mod:`repro.serving.buckets` ladders — one table, no drift between the
declaring and the routing side.
"""
from __future__ import annotations

from typing import Callable

from .. import at
from ..core import ATContext
from ..serving.buckets import LENGTH_BUCKETS
from ..serving.engine import length_bucket

DEFAULT_BLOCK_KS = (256, 512, 1024)


def divisor_block_ks(page_size: int, block_ks) -> tuple[int, ...]:
    """Filter candidate ``block_k`` tiles to divisors of ``page_size``.

    The paged kernels require the split-K tile to divide the page; a
    non-divisor candidate silently coerces to the whole page inside the
    kernel (now with a warning), so tuning it would measure a duplicate
    of the ``block_k=page_size`` candidate under a misleading label.
    Candidates are clamped to the page first (a tile larger than the
    page is the whole-page tile), deduplicated preserving order, and the
    whole page itself is the fallback when nothing survives.
    """
    out: list[int] = []
    for bk in block_ks:
        bk = min(int(bk), page_size)
        if bk > 0 and page_size % bk == 0 and bk not in out:
            out.append(bk)
    return tuple(out) or (page_size,)


# -- region naming ----------------------------------------------------------
#
# Canonical region-name builder.  With no mesh (or any 1-device mesh) the
# canonical names ARE the historical ad-hoc strings — which is what lets
# pre-mesh tuning DBs warm-load unchanged — while a multi-device mesh
# appends a ``_mesh{R}x{C}`` suffix so winners are tuned and persisted per
# mesh shape (arXiv 1309.1894: a winner is only valid in the environment
# that measured it, and the parallelism degree is part of the environment).

_REGION_FORMATS = {
    "decode": "DecodeBucket_{bucket}",
    "prefill": "PrefillBucket_{bucket}_c{chunk}",
    "spec": "SpecBucket_{bucket}",
    "kv_precision": "KVPrecision_{bucket}",
    "prefix": "PrefixPolicy",
    "gateway": "GatewayPolicy",
}

#: Legacy name prefix -> canonical kind.  Pre-mesh tuning DBs recorded
#: exactly these strings; :func:`region_key` still emits them whenever the
#: mesh has one device, so existing records warm-load with zero re-tuning.
LEGACY_REGION_ALIASES = {
    "DecodeBucket": "decode",
    "PrefillBucket": "prefill",
    "SpecBucket": "spec",
    "KVPrecision": "kv_precision",
    "PrefixPolicy": "prefix",
    "GatewayPolicy": "gateway",
}


def normalize_mesh_shape(mesh_shape) -> tuple[int, ...]:
    """Normalize a mesh shape given as ``None``, an ``"RxC"`` string, an
    int, or an iterable of ints into a tuple of ints (``()`` == no mesh)."""
    if mesh_shape is None:
        return ()
    if isinstance(mesh_shape, str):
        parts = [p for p in mesh_shape.lower().split("x") if p]
        try:
            return tuple(int(p) for p in parts)
        except ValueError:
            raise ValueError(
                f"bad mesh shape {mesh_shape!r}: expected 'RxC' like '1x4'"
            ) from None
    if isinstance(mesh_shape, int):
        return (mesh_shape,)
    return tuple(int(d) for d in mesh_shape)


def mesh_suffix(mesh_shape) -> str:
    """The region-name suffix for a mesh shape: empty for no mesh or any
    1-device mesh (those runs are bit-identical to the unsharded engine,
    so they share its winners), ``_mesh{R}x{C}`` otherwise."""
    shape = normalize_mesh_shape(mesh_shape)
    n = 1
    for d in shape:
        n *= d
    if n <= 1:
        return ""
    return "_mesh" + "x".join(str(d) for d in shape)


def region_key(kind: str, bucket: int | None = None, *,
               chunk: int | None = None, mesh_shape=None) -> str:
    """Build the canonical region name for one tuning region.

    ``kind`` is one of ``decode`` / ``prefill`` / ``spec`` /
    ``kv_precision`` / ``prefix`` / ``gateway``; bucketed kinds require
    ``bucket`` and prefill additionally requires ``chunk``.  The
    ``mesh_shape`` axis keys winners per execution environment — a
    1-device shape collapses to the legacy (unsuffixed) name.
    """
    fmt = _REGION_FORMATS.get(kind)
    if fmt is None:
        raise ValueError(
            f"unknown region kind {kind!r}: expected one of "
            f"{sorted(_REGION_FORMATS)}")
    if "{bucket}" in fmt and bucket is None:
        raise ValueError(f"{kind!r} regions are bucketed: pass bucket")
    if "{chunk}" in fmt and chunk is None:
        raise ValueError(f"{kind!r} regions need chunk= (one region per "
                         f"bucket x chunk size)")
    return fmt.format(bucket=bucket, chunk=chunk) + mesh_suffix(mesh_shape)


def parse_region(name: str) -> tuple[str, int | None, int | None,
                                     tuple[int, ...]]:
    """Split a region name into ``(kind, bucket, chunk, mesh_shape)``.

    Understands both legacy (unsuffixed) and mesh-suffixed names via
    :data:`LEGACY_REGION_ALIASES`.  Raises ``KeyError`` for names no
    alias matches.
    """
    base, _, mesh = name.partition("_mesh")
    shape = normalize_mesh_shape(mesh) if mesh else ()
    for prefix, kind in LEGACY_REGION_ALIASES.items():
        if base == prefix:
            return kind, None, None, shape
        if base.startswith(prefix + "_"):
            rest = base[len(prefix) + 1:]
            try:
                if kind == "prefill":
                    b, _, c = rest.partition("_c")
                    return kind, int(b), int(c), shape
                return kind, int(rest), None, shape
            except ValueError:
                break
    raise KeyError(name)


def describe_region(name: str) -> dict | None:
    """Parse a region name into a display record for the ``repro.at``
    CLI: ``{"kind", "bucket", "chunk", "mesh"}`` (``mesh`` is the
    ``"RxC"`` spelling, ``""`` for legacy/unsuffixed names).  ``None``
    for names outside the serving families (install/static kernel
    regions enumerate under their literal names instead)."""
    try:
        kind, bucket, chunk, shape = parse_region(name)
    except (KeyError, ValueError):
        return None
    return {"kind": kind, "bucket": bucket, "chunk": chunk,
            "mesh": "x".join(str(d) for d in shape)}


def resolve_region(name: str) -> str:
    """Canonicalize a possibly-legacy region name through the alias
    table.  Today every legacy name is already canonical (that identity
    is what keeps old tuning DBs warm-loading), so unknown names pass
    through unchanged rather than erroring."""
    try:
        kind, bucket, chunk, shape = parse_region(name)
    except (KeyError, ValueError):
        return name
    return region_key(kind, bucket, chunk=chunk, mesh_shape=shape or None)


class DecodeAutoTuner:
    """Per-bucket dynamic select over decode variants.

    ``make_decode(block_k)`` — or ``make_decode(block_k, page_size)`` when
    ``page_sizes`` is given — builds one decode callable per variant; the
    region measures each candidate once and commits the fastest.

    ``num_splits`` grows the space with the split-KV parallelism degree
    (``make_decode`` then takes it as its last positional): the variant
    list is ordered with the full legacy (``num_splits=1``) block first,
    so winner *indices* recorded by a pre-split-KV tuning DB still name
    the same variants — those records stay valid spellings (the
    record-store ``OAT_NUMALT`` stamp decides whether they warm-load or
    the grown region re-measures).
    """

    def __init__(self, session: "at.AutoTuner | ATContext",
                 make_decode: Callable[..., Callable],
                 buckets=LENGTH_BUCKETS,
                 block_ks=DEFAULT_BLOCK_KS,
                 page_sizes=None,
                 num_splits=None,
                 mesh_shape=None):
        self.session = at.AutoTuner.for_context(session)
        self.ctx = self.session.ctx
        self.buckets = buckets
        # winners are keyed per mesh shape (1-device shapes collapse to
        # the legacy names, so unsharded winners keep warm-loading)
        self.mesh_shape = normalize_mesh_shape(mesh_shape)
        self.param_names = ("block_k",) if page_sizes is None \
            else ("block_k", "page_size")
        self.variants = [(bk,) for bk in block_ks] if page_sizes is None \
            else [(bk, ps) for bk in block_ks for ps in page_sizes]
        if num_splits is not None:
            # 1 (the sequential kernel) always leads, and the ns=1 block
            # keeps the legacy variant order as its prefix
            splits = tuple(dict.fromkeys([1, *(int(n) for n in num_splits)]))
            self.param_names = (*self.param_names, "num_splits")
            self.variants = [(*var, ns) for ns in splits
                             for var in self.variants]
        self.regions = {}
        for b in buckets:
            name = self._key("decode", b)
            sel = self.session.autotune("dynamic", "select", name=name)
            for var in self.variants:
                label = ",".join(f"{k}={v}"
                                 for k, v in zip(self.param_names, var))
                sel.alternative(name=label)(make_decode(*var))
            self.regions[b] = sel.region
        self.prefill_buckets: tuple = ()
        self.prefill_variants: list[tuple] = []
        self.prefill_param_names: tuple = ()
        self.prefill_regions: dict[tuple[int, int], object] = {}
        self.spec_buckets: tuple = ()
        self.spec_variants: list[tuple] = []
        self.spec_param_names: tuple = ()
        self.spec_regions: dict[int, object] = {}
        self.prefix_variants: list[tuple] = []
        self.prefix_param_names: tuple = ()
        self.prefix_region = None
        self.gateway_variants: list[tuple] = []
        self.gateway_param_names: tuple = ()
        self.gateway_region = None
        self.kv_buckets: tuple = ()
        self.kv_variants: list[tuple] = []
        self.kv_param_names: tuple = ()
        self.kv_regions: dict[int, object] = {}
        self.session.run("dynamic",
                         [self._key("decode", b) for b in buckets])

    def _key(self, kind: str, bucket: int | None = None,
             chunk: int | None = None) -> str:
        """This tuner's canonical name for one region — every region this
        class declares or routes to goes through here, so the mesh-shape
        axis applies uniformly."""
        return region_key(kind, bucket, chunk=chunk,
                          mesh_shape=self.mesh_shape)

    # -- prefill region (chunked prefill) ------------------------------------
    def add_prefill(self, make_prefill: Callable[..., Callable],
                    chunk_sizes=(64,), buckets=LENGTH_BUCKETS,
                    block_qs=(64, 128), block_ks=(256, 512)) -> None:
        """Declare the prefill tuning region family.

        One ``dynamic select`` per (prompt-length bucket × chunk size);
        alternatives are built by ``make_prefill(block_q, block_k)`` —
        the (bucket × chunk × block_q × block_k) product space of the
        ``flash_paged_prefill`` kernel.  Winners commit per region and
        persist in the session's record store next to the decode winners.
        """
        self.prefill_buckets = tuple(buckets)
        self.prefill_param_names = ("block_q", "block_k")
        self.prefill_variants = [(bq, bk) for bq in block_qs
                                 for bk in block_ks]
        names = []
        for b in buckets:
            for cs in chunk_sizes:
                name = self._key("prefill", b, cs)
                sel = self.session.autotune("dynamic", "select", name=name)
                for var in self.prefill_variants:
                    label = ",".join(
                        f"{k}={v}"
                        for k, v in zip(self.prefill_param_names, var))
                    sel.alternative(name=label)(make_prefill(*var))
                self.prefill_regions[(b, cs)] = sel.region
                names.append(name)
        self.session.run("dynamic", names)

    # -- speculative region (draft + verify) ---------------------------------
    def add_spec(self, make_verify: Callable[..., Callable],
                 ks=(4,), buckets=LENGTH_BUCKETS,
                 block_qs=(8,), block_ks=(256,),
                 according: str | None = "min (time_per_token)") -> None:
        """Declare the speculative-verify tuning region family.

        One ``SpecBucket_{b}`` ``dynamic select`` per sequence-length
        bucket; alternatives are built by ``make_verify(k, block_q,
        block_k)`` — the (k × verify tile) product space.  A variant's k
        may be smaller than the engine's drafting width: it verifies (and
        can accept) only the first k drafts, which is exactly the
        accept-window policy the region is tuning.

        Raw per-call latency would degenerately prefer the smallest k
        (narrower verify chunk, fewer tokens out), so the default
        ``according`` criterion commits on ``time_per_token`` instead —
        variants report their measured call time divided by the tokens
        the accept rule emits (the paper's ``min (eps)`` form with a
        throughput-normalised eps).  Variants that return a plain value
        fall back to wall-clock.  Winners commit per bucket and persist
        in the session's record store next to the decode and prefill
        winners (warm restart = zero re-tuning).
        """
        self.spec_buckets = tuple(buckets)
        self.spec_param_names = ("k", "block_q", "block_k")
        self.spec_variants = [(k, bq, bk) for k in ks for bq in block_qs
                              for bk in block_ks]
        names = []
        for b in buckets:
            name = self._key("spec", b)
            sel = self.session.autotune("dynamic", "select", name=name,
                                        according=according)
            for var in self.spec_variants:
                label = ",".join(f"{k}={v}"
                                 for k, v in zip(self.spec_param_names, var))
                sel.alternative(name=label)(make_verify(*var))
            self.spec_regions[b] = sel.region
            names.append(name)
        self.session.run("dynamic", names)

    # -- kv-precision region (quantized paged KV) ----------------------------
    def add_kv_precision(self, make_variant: Callable[..., Callable],
                         precisions=("fp", "int8"), block_ks=(256,),
                         buckets=LENGTH_BUCKETS,
                         agreement_floor: float = 0.95) -> None:
        """Declare the KV-precision tuning region family.

        One ``KVPrecision_{b}`` ``dynamic select`` per sequence-length
        bucket; alternatives are built by ``make_variant(bucket,
        kv_dtype, block_k)`` — the (pool precision × dequant tile)
        product.  ``fp`` candidates keep full-precision pages; ``int8``
        candidates store int8 pages with per-row fp32 scales and
        dequantize inside the attention kernel.

        Quantization is *lossy*, so raw latency is the wrong criterion: a
        fast candidate that corrupts the pages would still win.  Each
        variant therefore reports ``{"time_per_token", "agreement"}`` —
        agreement being the fraction of greedily decoded tokens matching
        the fp reference on the calibration prompt — and the region
        commits per ``min (time_per_token) .and. condition (agreement >=
        floor)``: the fastest candidate *among those above the quality
        floor*.  fp candidates agree with themselves by construction
        (agreement 1.0), so the guarded pool is never empty.  Winners
        persist in the session's record store and warm-load like every
        other region (restart = zero re-tuning, no re-calibration).

        ``precisions`` lists fp first so the first measured candidate
        establishes the reference output for the bucket.
        """
        self.kv_buckets = tuple(buckets)
        self.kv_param_names = ("kv_dtype", "block_k")
        self.kv_variants = [(pr, bk) for pr in precisions for bk in block_ks]
        according = (f"min (time_per_token) .and. "
                     f"condition (agreement >= {agreement_floor})")
        names = []
        for b in buckets:
            name = self._key("kv_precision", b)
            sel = self.session.autotune("dynamic", "select", name=name,
                                        according=according)
            for var in self.kv_variants:
                label = ",".join(f"{k}={v}"
                                 for k, v in zip(self.kv_param_names, var))
                sel.alternative(name=label)(make_variant(b, *var))
            self.kv_regions[b] = sel.region
            names.append(name)
        self.session.run("dynamic", names)

    def kv_precision(self, kv_len: int, *args, **kwargs):
        """Route one calibration measurement through the bucket's
        KVPrecision region (measure-then-commit)."""
        b = length_bucket(kv_len, self.kv_buckets)
        return self.session.execute(self._key("kv_precision", b),
                                    *args, **kwargs)

    def kv_precision_committed(self, kv_len: int) -> bool:
        """Has this bucket's KVPrecision region committed a winner?"""
        b = length_bucket(kv_len, self.kv_buckets)
        st = self.ctx.dynamic_state.get(self._key("kv_precision", b))
        return st is not None and st.committed is not None

    def committed_kv_precision(self) -> dict[int, int | None]:
        return {b: self.ctx.dynamic_state[
                    self._key("kv_precision", b)].committed
                for b in self.kv_regions}

    def committed_kv_precision_params(self) -> dict[int, dict | None]:
        """Committed KV-precision winners as (kv_dtype, block_k)
        assignments per sequence-length bucket."""
        out: dict[int, dict | None] = {}
        for b, idx in self.committed_kv_precision().items():
            out[b] = None if idx is None \
                else dict(zip(self.kv_param_names, self.kv_variants[idx]))
        return out

    def resolve_kv_dtype(self, default: str = "fp") -> str:
        """Collapse the per-bucket winners into one pool dtype.

        The pool's precision is structural — it is fixed when the cache
        is built, before any request's length is known — so the
        per-bucket winners are resolved by majority vote among committed
        buckets.  Ties break toward ``int8`` (the capacity win is the
        point of quantizing); no committed buckets → ``default``.
        """
        votes: dict[str, int] = {}
        for params in self.committed_kv_precision_params().values():
            if params is not None:
                votes[params["kv_dtype"]] = votes.get(params["kv_dtype"],
                                                      0) + 1
        if not votes:
            return default
        best = max(votes.values())
        winners = {d for d, n in votes.items() if n == best}
        return "int8" if "int8" in winners else winners.pop()

    # -- prefix-policy region (prefix caching) -------------------------------
    def add_prefix_policy(self, make_policy: Callable[..., Callable],
                          min_matches=(1, 2), evictions=("lru", "fifo"),
                          according: str | None = "min (miss_fraction)"
                          ) -> None:
        """Declare the prefix-cache reuse-policy tuning region.

        One ``PrefixPolicy`` ``dynamic select`` whose alternatives are
        built by ``make_policy(min_match, eviction)`` — the (minimum
        match granularity × eviction strategy) product.  Each variant
        applies its knobs to the live cache and performs one admission
        match; outputs are bit-identical under every policy, so the
        region measures real admissions.  Raw call latency is
        meaningless here (a policy that never matches is the fastest
        call), so the default ``according`` commits on the variant whose
        admission left the smallest *fraction* of its prompt uncached —
        normalized so long- and short-prompt admissions are comparable.
        Like every serving region that measures live traffic (a
        SpecBucket candidate sees whatever acceptance its tick happens
        to offer), candidates here sample different admissions — in
        particular the index warms up across the measurement window, so
        treat the winner as a traffic-shape heuristic, not a controlled
        experiment.  The winner persists in the session's record store
        and warm-loads exactly like the decode/prefill/spec winners.
        """
        self.prefix_param_names = ("min_match", "eviction")
        self.prefix_variants = [(g, ev) for g in min_matches
                                for ev in evictions]
        sel = self.session.autotune("dynamic", "select",
                                    name=self._key("prefix"),
                                    according=according)
        for var in self.prefix_variants:
            label = ",".join(f"{k}={v}"
                             for k, v in zip(self.prefix_param_names, var))
            sel.alternative(name=label)(make_policy(*var))
        self.prefix_region = sel.region
        self.session.run("dynamic", [self._key("prefix")])

    # -- gateway-policy region (pipelined serving front-end) -----------------
    def add_gateway(self, max_inflights=(1, 2), admit_batches=(1, 4, 16),
                    according: str | None = "min (time_per_good_token)"
                    ) -> None:
        """Declare the gateway concurrency-policy tuning region.

        One ``GatewayPolicy`` ``dynamic select`` over the (pipeline depth
        × admission batch) product: ``max_inflight`` is how many ticks
        may be in flight on the device before the host blocks (1 = the
        synchronous loop, 2 = double-buffered), ``admit_batch`` how many
        queued arrivals the gateway moves into the scheduler per tick.
        Pure policy again — greedy outputs are bit-identical under every
        candidate — but unlike the kernel regions a candidate cannot be
        measured by one call: the gateway runs a *window* of traffic
        (``policy_window`` finished requests) under each candidate's
        knobs and reports the window's aggregate through
        :meth:`gateway_policy`.  Raw latency is the wrong criterion (an
        admission policy that starves the queue makes individual calls
        fast), so the default ``according`` commits on
        ``time_per_good_token`` — wall seconds per token generated by
        requests that met their SLO; minimising it is maximising
        goodput.  The winner persists in the record store and
        warm-loads like every other region: a restarted gateway applies
        the committed knobs immediately and runs zero measurement
        windows.
        """
        self.gateway_param_names = ("max_inflight", "admit_batch")
        self.gateway_variants = [(mi, ab) for mi in max_inflights
                                 for ab in admit_batches]
        sel = self.session.autotune("dynamic", "select",
                                    name=self._key("gateway"),
                                    according=according)
        for var in self.gateway_variants:
            label = ",".join(f"{k}={v}"
                             for k, v in zip(self.gateway_param_names, var))
            mi, ab = var

            def report(stats: dict, _mi=mi, _ab=ab) -> dict:
                # the window already ran under these knobs; attribute its
                # aggregate to this candidate as the region's env
                return {**stats, "max_inflight": _mi, "admit_batch": _ab}

            sel.alternative(name=label)(report)
        self.gateway_region = sel.region
        self.session.run("dynamic", [self._key("gateway")])

    def gateway_policy(self, stats: dict, **kwargs):
        """Report one measurement window's aggregate stats through the
        GatewayPolicy region (measure-then-commit; the committed path is
        a no-op passthrough)."""
        return self.session.execute(self._key("gateway"), stats, **kwargs)

    def gateway_candidate(self) -> int:
        """The candidate index whose knobs the gateway should apply for
        the *next* window: the committed winner if any, else the next
        untried index — the same iteration order ``execute`` uses, so
        window stats are attributed to the knobs that produced them."""
        st = self.ctx.dynamic_state.get(self._key("gateway"))
        if st is None:
            return 0
        if st.committed is not None:
            return st.committed
        nxt = next((i for i in range(len(self.gateway_variants))
                    if i not in st.tried), None)
        return 0 if nxt is None else nxt

    def committed_gateway(self) -> int | None:
        st = self.ctx.dynamic_state.get(self._key("gateway"))
        return None if st is None else st.committed

    def committed_gateway_params(self) -> dict | None:
        """The committed GatewayPolicy winner as a (max_inflight,
        admit_batch) assignment (None while still measuring)."""
        idx = self.committed_gateway()
        return None if idx is None \
            else dict(zip(self.gateway_param_names,
                          self.gateway_variants[idx]))

    def decode(self, kv_len: int, *args, **kwargs):
        b = length_bucket(kv_len, self.buckets)
        return self.session.execute(self._key("decode", b), *args, **kwargs)

    def prefix_policy(self, *args, **kwargs):
        """Route one admission's prefix match through the PrefixPolicy
        region (measure-then-commit, like every dynamic select)."""
        return self.session.execute(self._key("prefix"), *args, **kwargs)

    def spec(self, kv_len: int, *args, **kwargs):
        """Route one speculative verify through its bucket's region."""
        b = length_bucket(kv_len, self.spec_buckets)
        return self.session.execute(self._key("spec", b), *args, **kwargs)

    def spec_committed(self, kv_len: int) -> bool:
        """Has this bucket's SpecBucket region committed a winner?  The
        engine uses this to stop paying per-call measurement overhead
        (device sync + host-side acceptance proxy) once tuning is done."""
        b = length_bucket(kv_len, self.spec_buckets)
        st = self.ctx.dynamic_state.get(self._key("spec", b))
        return st is not None and st.committed is not None

    def spec_draft_k(self, kv_len: int, default: int) -> int:
        """How many tokens are worth drafting for this bucket: the
        committed variant's accept window k, or ``default`` while the
        bucket is still measuring (every candidate, including the widest,
        must stay measurable).  Lets the engine stop paying draft-decode
        steps for tokens the committed verify would never accept."""
        b = length_bucket(kv_len, self.spec_buckets)
        st = self.ctx.dynamic_state.get(self._key("spec", b))
        if st is None or st.committed is None:
            return default
        return min(default, self.spec_variants[st.committed][0])

    def prefill(self, prompt_len: int, chunk_size: int, *args, **kwargs):
        """Route one prefill chunk through its (bucket × chunk) region."""
        b = length_bucket(prompt_len, self.prefill_buckets)
        return self.session.execute(self._key("prefill", b, chunk_size),
                                    *args, **kwargs)

    def committed(self) -> dict[int, int | None]:
        return {b: self.ctx.dynamic_state[self._key("decode", b)].committed
                for b in self.buckets}

    def committed_params(self) -> dict[int, dict | None]:
        """Committed winners decoded into PP assignments per bucket."""
        out: dict[int, dict | None] = {}
        for b, idx in self.committed().items():
            out[b] = None if idx is None \
                else dict(zip(self.param_names, self.variants[idx]))
        return out

    def committed_prefill(self) -> dict[tuple[int, int], int | None]:
        return {key: self.ctx.dynamic_state[
                    self._key("prefill", key[0], key[1])].committed
                for key in self.prefill_regions}

    def committed_prefill_params(self) -> dict[tuple[int, int], dict | None]:
        """Committed prefill winners as PP assignments per
        (prompt bucket, chunk size)."""
        out: dict[tuple[int, int], dict | None] = {}
        for key, idx in self.committed_prefill().items():
            out[key] = None if idx is None \
                else dict(zip(self.prefill_param_names,
                              self.prefill_variants[idx]))
        return out

    def committed_spec(self) -> dict[int, int | None]:
        return {b: self.ctx.dynamic_state[self._key("spec", b)].committed
                for b in self.spec_regions}

    def committed_spec_params(self) -> dict[int, dict | None]:
        """Committed speculative winners as (k, block_q, block_k)
        assignments per sequence-length bucket."""
        out: dict[int, dict | None] = {}
        for b, idx in self.committed_spec().items():
            out[b] = None if idx is None \
                else dict(zip(self.spec_param_names, self.spec_variants[idx]))
        return out

    def committed_prefix(self) -> int | None:
        st = self.ctx.dynamic_state.get(self._key("prefix"))
        return None if st is None else st.committed

    def committed_prefix_params(self) -> dict | None:
        """The committed PrefixPolicy winner as a (min_match, eviction)
        assignment (None while still measuring)."""
        idx = self.committed_prefix()
        return None if idx is None \
            else dict(zip(self.prefix_param_names,
                          self.prefix_variants[idx]))
