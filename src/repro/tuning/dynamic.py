"""Run-time AT driver: serving-time variant selection per request bucket.

The paper's ``dynamic select`` (Samples 6/7) applied to the decode path:
each sequence-length bucket gets a dynamic AT region whose alternatives are
decode implementations (kernel block sizes / layouts); the first calls in
each bucket measure the candidates (run-time auto-tuning happens at the
call site, §4.1), then the winner is committed and ``OAT_DynPerfThis``
semantics apply — later calls run the optimised variant with no tuning.
"""
from __future__ import annotations

from typing import Callable

from ..core import ATContext, OAT_DYNAMIC
from ..core.directives import dynamic_select
from ..serving.engine import length_bucket

DEFAULT_BLOCK_KS = (256, 512, 1024)


class DecodeAutoTuner:
    """Per-bucket dynamic select over decode variants."""

    def __init__(self, ctx: ATContext, make_decode: Callable[[int], Callable],
                 buckets=(512, 2048, 8192, 32768),
                 block_ks=DEFAULT_BLOCK_KS):
        self.ctx = ctx
        self.buckets = buckets
        self.regions = {}
        for b in buckets:
            name = f"DecodeBucket_{b}"
            sel = dynamic_select(ctx, name=name)
            for bk in block_ks:
                sel.alternative(name=f"block_k={bk}")(make_decode(bk))
            self.regions[b] = sel.finalize()
        ctx.OAT_ATexec(OAT_DYNAMIC, [f"DecodeBucket_{b}" for b in buckets])

    def decode(self, kv_len: int, *args, **kwargs):
        b = length_bucket(kv_len, self.buckets)
        return self.ctx.execute(f"DecodeBucket_{b}", *args, **kwargs)

    def committed(self) -> dict[int, int | None]:
        return {b: self.ctx.dynamic_state[f"DecodeBucket_{b}"].committed
                for b in self.buckets}
