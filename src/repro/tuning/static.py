"""Before-execute-time AT driver: distribution-layout selection per
(arch x shape x mesh) — the paper's ``static select according estimated``
made first-class.

Basic parameters (the paper's BP concept): arch name, seq_len,
global_batch, mesh shape.  Performance parameters: layout plan name, remat
policy, microbatch count.  The cost definition function is the three-term
roofline (cost.py's ``roofline_seconds``), evaluated either

* **analytically** (fast path, used in tests): analytic.step_costs + a
  per-plan collective model; or
* **measured from a real dry-run compile** (the §Perf path): the candidate
  is lowered + compiled on the production mesh and the parsed loop-aware
  HLO terms are the cost — this is 'measurement' in the paper's sense,
  with compile-time roofline standing in for wall-clock (CPU container).

Results are recorded per BP tuple in ``OAT_StaticParam.dat`` exactly like
the paper's ``(OAT_PROBSIZE 1024 (MyMatMul_I 4) ...)`` records.
"""
from __future__ import annotations

from typing import Callable

from .. import at
from ..configs import get_arch, get_shape
from ..core import ATContext
from ..core.cost import roofline_terms
from ..launch.analytic import step_costs
from ..launch.roofline import HBM_BW, ICI_BW, PEAK_FLOPS

TRAIN_PLANS = ("tp", "fsdp")
DECODE_PLANS = ("tp", "decode_seq", "decode_resident")


def candidate_plans(kind: str) -> tuple[str, ...]:
    return DECODE_PLANS if kind == "decode" else TRAIN_PLANS


def analytic_plan_cost(arch_name: str, shape_name: str, plan: str,
                       chips: int = 256, model_axis: int = 16) -> float:
    """Roofline bound (s) for one layout plan, fully analytic.

    Collective model per plan (bytes per step, whole mesh):
    * tp      — per-layer activation all-reduce on the model axis
                (2 x hidden per layer, both matmul families) + grad
                reduce-scatter (train);
    * fsdp    — per-layer weight all-gather (layer params / model axis)
                + grad reduce-scatter;
    * decode_seq — LSE merge all-reduce over the model axis per layer
                (tiny) + cache stays put.
    """
    cfg = get_arch(arch_name)
    shape = get_shape(shape_name)
    ana = step_costs(cfg, shape)
    b, s = shape.global_batch, shape.seq_len
    t = b * (1 if shape.kind == "decode" else s)
    d = cfg.d_model
    data_axis = max(chips // model_axis, 1)
    layer_params = (cfg.param_count()
                    - cfg.padded_vocab * d * (1 if cfg.tie_embeddings
                                              else 2)) / max(cfg.n_layers, 1)
    bf16 = 2
    compute_scale = 1.0
    mem_scale = 1.0
    if plan == "tp":
        # activation all-reduce on the model axis, per matmul family
        coll = 2 * t * d * bf16 * cfg.n_layers * 2
        if shape.kind == "decode" and cfg.ssm_version == 0 \
                and cfg.n_kv_heads % model_axis != 0:
            # KV cache cannot shard over model: cache reads replicate
            mem_scale = float(model_axis)
    elif plan == "fsdp":
        # per-layer weight all-gather; model axis does replicated compute
        coll = layer_params * bf16 * cfg.n_layers * (model_axis - 1) \
            / model_axis
        compute_scale = float(model_axis)
    elif plan == "decode_resident":
        # weights resident on the model axis: per-layer activation
        # all-reduce only; no weight gather ever
        coll = 2 * t * d * bf16 * cfg.n_layers * 2
    else:  # decode_seq: seq-sharded cache + LSE-merge all-reduce (tiny)
        coll = 2 * t * cfg.n_heads * (cfg.head_dim + 2) * 4 * cfg.n_layers
    if shape.kind == "decode" and plan != "decode_resident":
        # weights FSDP-sharded over data are re-gathered every step
        coll += layer_params * bf16 * cfg.n_layers * (data_axis - 1) \
            / data_axis
    if shape.kind == "train":
        coll += cfg.param_count() * 4          # grad reduce-scatter fp32
        if plan == "fsdp":
            coll += layer_params * bf16 * cfg.n_layers  # bwd re-gather
    terms = roofline_terms(ana.flops * compute_scale,
                           ana.bytes * mem_scale, coll, chips,
                           peak_flops=PEAK_FLOPS, hbm_bw=HBM_BW,
                           ici_bw=ICI_BW)
    # rank by the un-overlapped sum: plans that tie on the dominant term
    # still separate on the terms they actually change
    return terms.compute_s + terms.memory_s + terms.collective_s


def compiled_plan_cost(arch_name: str, shape_name: str, plan: str,
                       multi_pod: bool = False, **overrides) -> float:
    """The measured path: dry-run compile the candidate and score it."""
    from ..launch.dryrun import dryrun_cell
    from ..launch.roofline import from_artifact
    rec = dryrun_cell(arch_name, shape_name, multi_pod=multi_pod,
                      plan_name=plan, verbose=False, **overrides)
    return from_artifact(rec).bound_s


def tune_layout(session: "at.AutoTuner | ATContext", arch_name: str,
                shape_name: str,
                cost_fn: Callable[[str], float] | None = None,
                chips: int = 256) -> str:
    """Static-AT select over layout plans; returns the winner and records
    it in the FIBER store + static param file + the session record store
    (so a later process skips the selection for an already-tuned cell)."""
    session = at.AutoTuner.for_context(session)
    cfg = get_arch(arch_name)
    shape = get_shape(shape_name)
    plans = candidate_plans(shape.kind)
    cost_fn = cost_fn or (
        lambda p: analytic_plan_cost(arch_name, shape_name, p, chips))

    region_name = f"Layout_{arch_name}_{shape_name}".replace("-", "_") \
        .replace(".", "_")
    sel = session.autotune("static", "select", name=region_name,
                           params=["bp OAT_PROBSIZE", "bp OAT_NUMPROCS"])
    for p in plans:
        cost = cost_fn(p)
        sel.alternative(according=f"estimated {cost!r}", name=p)(
            lambda p=p: p)

    if not session.ctx.store.has_default_bps():
        session.set_bps(numprocs=chips, start=shape.seq_len,
                        end=shape.seq_len, dist=max(shape.seq_len, 1))
    session.ctx.phase_ran["install"] = True   # layout AT has no install deps
    session.run("static", [region_name])
    best = session.best(region_name)
    idx = int(best.get(f"{region_name}_SELECT", 0))
    return plans[idx]


def tune_all_layouts(session: "at.AutoTuner | ATContext", cells,
                     cost_fn=None) -> dict:
    session = at.AutoTuner.for_context(session)
    out = {}
    for arch_name, shape_name in cells:
        out[(arch_name, shape_name)] = tune_layout(
            session, arch_name, shape_name, cost_fn)
    return out
