"""Mamba-1 selective-scan Pallas kernel (chunked recurrence).

The SSM hot-spot of falcon-mamba-7b / zamba2-7b.  Recurrence per channel d
and state n::

    h[t] = exp(dt[t,d] * A[d,n]) * h[t-1] + dt[t,d] * B[t,n] * x[t,d]
    y[t,d] = sum_n C[t,n] * h[t,n] + D[d] * x[t,d]

TPU adaptation: the CUDA selective-scan kernel parallelises over threads
within a block and uses shared-memory warp scans.  On TPU the (d_inner x
d_state) state plane lives in a VMEM scratch accumulator, the time loop
walks a *chunk* of the sequence per grid step (grid innermost dim is
sequential — "arbitrary" semantics), and each step is a full-width VPU
op over the state plane.  Performance parameter (install-time AT):
``chunk`` — the sequence block per grid step, trading VMEM residency of
x/dt/B/C slices against grid overhead.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams around 0.5; accept both
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _scan_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, o_ref, h_ref, *,
                 chunk: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[...].astype(jnp.float32)            # (Di, N)
    dskip = d_ref[...].astype(jnp.float32)        # (Di,)

    def step(t, h):
        xt = x_ref[0, t].astype(jnp.float32)      # (Di,)
        dtt = dt_ref[0, t].astype(jnp.float32)    # (Di,)
        bt = b_ref[0, t].astype(jnp.float32)      # (N,)
        ct = c_ref[0, t].astype(jnp.float32)      # (N,)
        da = jnp.exp(dtt[:, None] * a)            # (Di, N)
        h = da * h + (dtt * xt)[:, None] * bt[None, :]
        y = (h * ct[None, :]).sum(axis=1) + dskip * xt
        o_ref[0, t] = y.astype(o_ref.dtype)
        return h

    h_ref[...] = jax.lax.fori_loop(0, chunk, step, h_ref[...])


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def selective_scan(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
                   c: jax.Array, d: jax.Array, *, chunk: int = 64,
                   interpret: bool = False) -> jax.Array:
    """x, dt: (B, L, Di); a: (Di, N); b, c: (B, L, N); d: (Di,) -> (B, L, Di).

    ``dt`` must already be positive (softplus applied by the caller).
    """
    bsz, l, di = x.shape
    n = a.shape[1]
    ch = min(chunk, l)
    p = (-l) % ch
    if p:
        pad3 = ((0, 0), (0, p), (0, 0))
        x, dt, b, c = (jnp.pad(t, pad3) for t in (x, dt, b, c))
    lp = x.shape[1]
    grid = (bsz, lp // ch)
    out = pl.pallas_call(
        functools.partial(_scan_kernel, chunk=ch),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, ch, di), lambda bb, ic: (bb, ic, 0)),
            pl.BlockSpec((1, ch, di), lambda bb, ic: (bb, ic, 0)),
            pl.BlockSpec((di, n), lambda bb, ic: (0, 0)),
            pl.BlockSpec((1, ch, n), lambda bb, ic: (bb, ic, 0)),
            pl.BlockSpec((1, ch, n), lambda bb, ic: (bb, ic, 0)),
            pl.BlockSpec((di,), lambda bb, ic: (0,)),
        ],
        out_specs=pl.BlockSpec((1, ch, di), lambda bb, ic: (bb, ic, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, lp, di), x.dtype),
        scratch_shapes=[pltpu.VMEM((di, n), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, a, b, c, d)
    return out[:, :l, :]


def ssm_vmem_bytes(chunk: int, d_inner: int, d_state: int,
                   bytes_per_el: int = 2) -> int:
    """Analytic VMEM footprint per grid step (CPU-side AT cost model)."""
    return (2 * chunk * d_inner + 2 * chunk * d_state) * bytes_per_el \
        + d_inner * d_state * (bytes_per_el + 4) \
        + chunk * d_inner * bytes_per_el
