"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, y: jax.Array, bias: jax.Array | None = None,
               epilogue: str = "none", out_dtype=None) -> jax.Array:
    out = jnp.dot(x.astype(jnp.float32), y.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    if epilogue == "gelu":
        out = jax.nn.gelu(out)
    elif epilogue == "silu":
        out = jax.nn.silu(out)
    elif epilogue == "relu":
        out = jnp.maximum(out, 0)
    return out.astype(out_dtype or x.dtype)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int | None = None,
                  scale: float | None = None,
                  kv_len: jax.Array | None = None) -> jax.Array:
    """GQA attention.  q: (B, H, Sq, D); k, v: (B, Hkv, Sk, D)."""
    b, h, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    g = h // hkv
    scale = float(scale if scale is not None else d ** -0.5)
    kk = jnp.repeat(k, g, axis=1)
    vv = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * scale
    qi = jnp.arange(sq)[:, None] + (sk - sq)   # align ends (decode offset)
    kj = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= qi >= kj
    if window is not None:
        mask &= (qi - kj) < window
    s = jnp.where(mask[None, None], s, -1e30)
    if kv_len is not None:
        s = jnp.where(kj[None, None, :, :] < kv_len[:, None, None, None],
                      s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      vv.astype(jnp.float32)).astype(q.dtype)


def decode_ref(q: jax.Array, k: jax.Array, v: jax.Array,
               kv_len: jax.Array | None = None,
               scale: float | None = None) -> jax.Array:
    """One-token decode oracle (q: (B, H, 1, D))."""
    return attention_ref(q, k, v, causal=False, scale=scale, kv_len=kv_len)


def _dequantize_pools(k_pool, v_pool, k_scale, v_scale):
    """int8 pools -> fp32 via per-row scales (P, Hkv, psz); no-op when no
    scales are given (fp pools)."""
    if k_scale is not None:
        k_pool = k_pool.astype(jnp.float32) * k_scale[..., None]
    if v_scale is not None:
        v_pool = v_pool.astype(jnp.float32) * v_scale[..., None]
    return k_pool, v_pool


def paged_decode_ref(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                     page_table: jax.Array,
                     kv_len: jax.Array | None = None,
                     scale: float | None = None,
                     k_scale: jax.Array | None = None,
                     v_scale: jax.Array | None = None) -> jax.Array:
    """Paged one-token decode oracle.

    q: (B, H, 1, D); pools (P, Hkv, psz, D) hold pages shared by all
    sequences; ``page_table`` (B, nblk) maps each sequence's logical KV
    block to a physical page (entries beyond ``kv_len`` are ignored — they
    may point anywhere, typically page 0).  Gathers the pages into a dense
    (B, Hkv, nblk*psz, D) view and reuses the dense decode oracle.
    Quantized mode: int8 pools plus ``k_scale``/``v_scale`` per-row fp32
    scales (P, Hkv, psz) dequantize before the gather.
    """
    b = q.shape[0]
    _, hkv, psz, d = k_pool.shape
    nblk = page_table.shape[1]
    k_pool, v_pool = _dequantize_pools(k_pool, v_pool, k_scale, v_scale)
    k = k_pool[page_table].transpose(0, 2, 1, 3, 4).reshape(
        b, hkv, nblk * psz, d)
    v = v_pool[page_table].transpose(0, 2, 1, 3, 4).reshape(
        b, hkv, nblk * psz, d)
    return decode_ref(q, k, v, kv_len, scale)


def combine_split_states(m: jax.Array, l: jax.Array,
                         acc: jax.Array) -> tuple[jax.Array, jax.Array,
                                                  jax.Array]:
    """Merge per-split partial softmax states along the split axis.

    ``m``/``l``: (..., ns, rows); ``acc``: (..., ns, rows, d) — the
    (running max, denominator, unnormalized accumulator) triple each
    split-KV segment emits.  Returns the merged (m*, l*, acc*) with the
    split axis reduced: ``m* = max_i m_i``, ``l* = sum_i l_i e^{m_i-m*}``,
    ``acc* = sum_i acc_i e^{m_i-m*}``.  This is the phase-2 math of the
    split kernels, and the object of the combine property tests
    (associative, order-invariant, segmentation-invariant).
    """
    m_star = m.max(axis=-2)                               # (..., rows)
    alpha = jnp.exp(m - m_star[..., None, :])             # (..., ns, rows)
    l_star = (l * alpha).sum(axis=-2)
    acc_star = (acc * alpha[..., None]).sum(axis=-3)
    return m_star, l_star, acc_star


def finalize_split_states(l: jax.Array, acc: jax.Array) -> jax.Array:
    """Normalize a merged (l, acc) pair into the attention output; the
    ``l == 0`` guard matches the kernels' all-masked convention (output
    exactly zero, not NaN)."""
    l = jnp.where(l == 0.0, 1.0, l)
    return acc / l[..., None]


def paged_decode_split_ref(q: jax.Array, k_pool: jax.Array,
                           v_pool: jax.Array, page_table: jax.Array,
                           kv_len: jax.Array, num_splits: int,
                           scale: float | None = None,
                           k_scale: jax.Array | None = None,
                           v_scale: jax.Array | None = None) -> jax.Array:
    """Split-aware paged decode oracle: per-segment masked softmax states
    merged via :func:`combine_split_states` — mirrors the two-phase
    kernel structurally (each segment computes its own running max over
    its own keys only) instead of reusing the dense single-pass oracle.
    """
    b, h, one, d = q.shape
    _, hkv, psz, _ = k_pool.shape
    nblk = page_table.shape[1]
    g = h // hkv
    scale = float(scale if scale is not None else d ** -0.5)
    k_pool, v_pool = _dequantize_pools(k_pool, v_pool, k_scale, v_scale)
    k = k_pool[page_table].transpose(0, 2, 1, 3, 4).reshape(
        b, hkv, nblk * psz, d).astype(jnp.float32)
    v = v_pool[page_table].transpose(0, 2, 1, 3, 4).reshape(
        b, hkv, nblk * psz, d).astype(jnp.float32)
    qg = q.reshape(b, hkv, g, d).astype(jnp.float32)
    s_total = nblk * psz
    seg = -(-s_total // num_splits)
    pad = num_splits * seg - s_total
    kj = jnp.arange(s_total)
    valid = kj[None, :] < kv_len[:, None]                 # (B, S)
    scores = jnp.einsum("bhgd,bhkd->bhgk", qg, k) * scale
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    if pad:
        scores = jnp.pad(scores, ((0, 0),) * 3 + ((0, pad),),
                         constant_values=-1e30)
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    # (B, Hkv, G, ns, seg): each segment runs its own softmax state
    ss = scores.reshape(b, hkv, g, num_splits, seg)
    m_i = ss.max(axis=-1)                                 # (B, Hkv, G, ns)
    p = jnp.exp(ss - m_i[..., None])
    # a fully-masked segment's max is -1e30 -> exp(0)=1 rows; zero them
    # out the way the kernel's @pl.when skip leaves (m=NEG_INF, l=0)
    empty = m_i <= -1e30
    p = jnp.where(empty[..., None], 0.0, p)
    m_i = jnp.where(empty, -1e30, m_i)
    l_i = p.sum(axis=-1)                                  # (B, Hkv, G, ns)
    vv = v.reshape(b, hkv, 1, num_splits, seg, d)
    acc_i = (p[..., None] * vv).sum(axis=-2)              # (B,Hkv,G,ns,d)
    # combine axes expect (..., ns, rows[, d]): move G behind ns
    m_c = jnp.moveaxis(m_i, -1, -2)                       # (B, Hkv, ns, G)
    l_c = jnp.moveaxis(l_i, -1, -2)
    acc_c = jnp.moveaxis(acc_i, -2, -3)                   # (B,Hkv,ns,G,d)
    _, l_star, acc_star = combine_split_states(m_c, l_c, acc_c)
    out = finalize_split_states(l_star, acc_star)         # (B, Hkv, G, d)
    return out.reshape(b, h, 1, d).astype(q.dtype)


def paged_prefill_ref(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                      page_table: jax.Array, start: jax.Array,
                      kv_len: jax.Array,
                      scale: float | None = None,
                      k_scale: jax.Array | None = None,
                      v_scale: jax.Array | None = None) -> jax.Array:
    """Chunked-prefill attention oracle over a paged KV cache.

    q: (B, H, C, D) — one prompt *chunk* whose first token sits at absolute
    position ``start[b]``; pools (P, Hkv, psz, D); ``page_table`` (B, nblk)
    maps logical KV blocks to physical pages.  The chunk's own KV must
    already be scattered into the pages (the caller writes before it
    reads), so key ``j`` is valid iff ``j < kv_len[b]``; query ``i``
    (absolute position ``start[b] + i``) attends causally to keys at
    absolute positions ``<= start[b] + i`` — i.e. the whole committed
    prefix plus the chunk's own causal triangle.  Gathers the pages into a
    dense (B, Hkv, nblk*psz, D) view, exactly like
    :func:`paged_decode_ref`.
    """
    b, h, c, d = q.shape
    _, hkv, psz, _ = k_pool.shape
    nblk = page_table.shape[1]
    g = h // hkv
    scale = float(scale if scale is not None else d ** -0.5)
    k_pool, v_pool = _dequantize_pools(k_pool, v_pool, k_scale, v_scale)
    k = k_pool[page_table].transpose(0, 2, 1, 3, 4).reshape(
        b, hkv, nblk * psz, d)
    v = v_pool[page_table].transpose(0, 2, 1, 3, 4).reshape(
        b, hkv, nblk * psz, d)
    kk = jnp.repeat(k, g, axis=1)
    vv = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * scale
    qi = start[:, None] + jnp.arange(c)[None, :]          # (B, C) absolute
    kj = jnp.arange(nblk * psz)[None, :]                  # (1, Sk)
    mask = (kj[:, None, :] <= qi[..., None]) \
        & (kj[:, None, :] < kv_len[:, None, None])        # (B, C, Sk)
    s = jnp.where(mask[:, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      vv.astype(jnp.float32)).astype(q.dtype)


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, window: int | None = None,
                      scale: float | None = None, block_q: int = 1024,
                      block_k: int = 1024, remat: bool = True) -> jax.Array:
    """Memory-efficient attention in pure jnp (flash algorithm via nested
    ``lax.scan`` over q/kv chunks, fp32 online softmax).

    This is the *shardable* long-sequence path: every op is a plain einsum,
    so GSPMD can partition batch/heads/seq across the mesh — which a
    ``pallas_call`` cannot do under pjit.  Peak live intermediate is
    O(bq * bk) per (batch, head) instead of O(S^2); the kv-step is wrapped
    in ``jax.checkpoint`` so the backward pass recomputes rather than
    stores per-chunk probabilities.
    """
    b, h, s, d = q.shape
    _, hkv, sk, _ = k.shape
    g = h // hkv
    scale = float(scale if scale is not None else d ** -0.5)
    bq = min(block_q, s)
    bk = min(block_k, sk)
    pq, pk = (-s) % bq, (-sk) % bk
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0))) if pq else q
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0))) if pk else k
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0))) if pk else v
    nq, nk = qp.shape[2] // bq, kp.shape[2] // bk
    # (nq, B, H, bq, D) / (nk, B, Hkv, bk, D)
    qs = jnp.moveaxis(qp.reshape(b, h, nq, bq, d), 2, 0)
    ks = jnp.moveaxis(kp.reshape(b, hkv, nk, bk, d), 2, 0)
    vs = jnp.moveaxis(vp.reshape(b, hkv, nk, bk, d), 2, 0)

    def kv_step(carry, inp):
        m_prev, l_prev, acc, qc, iq = carry
        kc, vc, ik = inp
        kc = jnp.repeat(kc, g, axis=1) if g > 1 else kc
        vc = jnp.repeat(vc, g, axis=1) if g > 1 else vc
        sco = jnp.einsum("bhqd,bhkd->bhqk", qc.astype(jnp.float32),
                         kc.astype(jnp.float32)) * scale
        qi = iq * bq + jnp.arange(bq)[:, None]
        kj = ik * bk + jnp.arange(bk)[None, :]
        mask = kj < sk                      # padded keys
        if causal:
            mask &= qi >= kj
        if window is not None:
            mask &= (qi - kj) < window
        sco = jnp.where(mask[None, None], sco, -1e30)
        m_cur = jnp.maximum(m_prev, sco.max(-1))
        p = jnp.exp(sco - m_cur[..., None])
        alpha = jnp.exp(m_prev - m_cur)
        l_cur = l_prev * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p, vc.astype(jnp.float32))
        return (m_cur, l_cur, acc, qc, iq), None

    if remat:
        kv_step = jax.checkpoint(kv_step)

    def q_step(_, inp):
        qc, iq = inp
        m0 = jnp.full((b, h, bq), -1e30, jnp.float32)
        l0 = jnp.zeros((b, h, bq), jnp.float32)
        a0 = jnp.zeros((b, h, bq, d), jnp.float32)
        (m, l, acc, _, _), _ = jax.lax.scan(
            kv_step, (m0, l0, a0, qc, iq), (ks, vs, jnp.arange(nk)))
        l = jnp.where(l == 0.0, 1.0, l)
        return None, (acc / l[..., None]).astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, (qs, jnp.arange(nq)))
    out = jnp.moveaxis(outs, 0, 2).reshape(b, h, nq * bq, d)
    return out[:, :, :s]


def selective_scan_ref(x, dt, a, b, c, d, return_final_state=False):
    """Mamba-1 recurrence via lax.scan.  Shapes as kernels.ssm_scan."""
    bsz, l, di = x.shape
    n = a.shape[1]

    def step(h, inp):
        xt, dtt, bt, ct = inp     # (B,Di) (B,Di) (B,N) (B,N)
        da = jnp.exp(dtt[..., None] * a[None])           # (B, Di, N)
        h = da * h + (dtt * xt)[..., None] * bt[:, None, :]
        y = (h * ct[:, None, :]).sum(-1) + d[None] * xt
        return h, y

    h0 = jnp.zeros((bsz, di, n), jnp.float32)
    xs = (jnp.moveaxis(x, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
          jnp.moveaxis(b, 1, 0).astype(jnp.float32),
          jnp.moveaxis(c, 1, 0).astype(jnp.float32))
    hf, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)
    if return_final_state:
        return y, hf
    return y


def fdm_stress_ref(arrays: dict, state: dict, dt: float) -> dict:
    """Vectorised Sample-8 stress update with edge-clamped (i+1/j+1/k+1)
    neighbour reads (the kernel's convention)."""
    lam, rig, q = arrays["lam"], arrays["rig"], arrays["q"]
    absx, absy, absz = arrays["absx"], arrays["absy"], arrays["absz"]
    pad = jnp.pad(rig, ((0, 1), (0, 1), (0, 1)), mode="edge")
    r_ip1 = pad[1:, :-1, :-1]
    r_jp1 = pad[:-1, 1:, :-1]
    r_kp1 = pad[:-1, :-1, 1:]
    r_ip1jp1 = pad[1:, 1:, :-1]
    r_ip1kp1 = pad[1:, :-1, 1:]
    r_jp1kp1 = pad[:-1, 1:, 1:]
    qg = absx[:, None, None] * absy[None, :, None] * absz[None, None, :] * q
    rm2 = rig + rig
    rltheta = (arrays["dxvx"] + arrays["dyvy"] + arrays["dzvz"]) * lam
    out = {}
    out["sxx"] = (state["sxx"] + (rltheta + rm2 * arrays["dxvx"]) * dt) * qg
    out["syy"] = (state["syy"] + (rltheta + rm2 * arrays["dyvy"]) * dt) * qg
    out["szz"] = (state["szz"] + (rltheta + rm2 * arrays["dzvz"]) * dt) * qg
    stmp1 = 1.0 / rig
    stmp2 = 1.0 / r_ip1
    stmp4 = 1.0 / r_kp1
    stmp3 = stmp1 + stmp2
    rmaxy = 4.0 / (stmp3 + 1.0 / r_jp1 + 1.0 / r_ip1jp1)
    rmaxz = 4.0 / (stmp3 + stmp4 + 1.0 / r_ip1kp1)
    rmayz = 4.0 / (stmp3 + stmp4 + 1.0 / r_jp1kp1)
    out["sxy"] = (state["sxy"]
                  + (rmaxy * (arrays["dxvy"] + arrays["dyvx"])) * dt) * qg
    out["sxz"] = (state["sxz"]
                  + (rmaxz * (arrays["dxvz"] + arrays["dzvx"])) * dt) * qg
    out["syz"] = (state["syz"]
                  + (rmayz * (arrays["dyvz"] + arrays["dzvy"])) * dt) * qg
    return out
