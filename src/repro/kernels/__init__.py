"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel module pairs with a pure-jnp oracle in ref.py; ops.py holds the
public jit'd wrappers with interpret/TPU dispatch.  Kernel block shapes are
install-time AT performance parameters (see tuning/install.py).
"""
from . import ops, ref
from .fdm_stress import fdm_stress
from .flash_attention import flash_attention, flash_decode
from .matmul import matmul
from .ssm_scan import selective_scan

__all__ = ["ops", "ref", "matmul", "flash_attention", "flash_decode",
           "selective_scan", "fdm_stress"]
