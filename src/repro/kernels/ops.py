"""Public jit'd kernel wrappers with interpret/TPU dispatch + AT hookup.

The model stack calls these, never ``pl.pallas_call`` directly.  On CPU
(this container) kernels run in ``interpret=True`` mode; on TPU they
compile for real.  Block-shape performance parameters default to
MXU-aligned values and are overridden by install-time AT results published
through :func:`repro.at.tuned` (see tuning/install.py).

``set_tuned`` is a deprecation shim over :func:`repro.at.publish`; new
code publishes via ``autotune(..., publish=(kernel, mapping))`` and reads
via ``at.tuned(kernel)``.
"""
from __future__ import annotations

import jax

from ..at.session import publish as _publish
from ..at.session import tuned as _tuned
from . import ref
from .flash_attention import (flash_attention, flash_decode,
                              flash_paged_decode, flash_paged_decode_quant,
                              flash_paged_prefill, flash_paged_prefill_quant)
from .matmul import matmul
from .ssm_scan import selective_scan


def set_tuned(name: str, **pps) -> None:
    """Deprecated: use ``repro.at.publish`` (kept as a thin shim)."""
    _publish(name, **pps)


def tuned(name: str, **bps) -> dict:
    return _tuned(name, **bps)


def on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def mm(x, y, bias=None, *, epilogue="none", use_kernel: bool | None = None,
       **pps):
    """GEMM entry point.  Falls back to the jnp reference on CPU unless the
    caller forces the kernel (tests do, with interpret=True)."""
    if use_kernel is None:
        use_kernel = not on_cpu()
    if not use_kernel:
        return ref.matmul_ref(x, y, bias, epilogue)
    kw = tuned("matmul")
    kw.update(pps)
    return matmul(x, y, bias, epilogue=epilogue, interpret=on_cpu(), **kw)


CHUNKED_THRESHOLD = 2048     # above this seq, the jnp path goes flash-style


def attention(q, k, v, *, causal=True, window=None,
              use_kernel: bool | None = None, **pps):
    if use_kernel is None:
        use_kernel = not on_cpu()
    if not use_kernel:
        if q.shape[2] > CHUNKED_THRESHOLD:
            kw = tuned("chunked_attention")
            kw.update(pps)
            return ref.chunked_attention(q, k, v, causal=causal,
                                         window=window, **kw)
        return ref.attention_ref(q, k, v, causal=causal, window=window)
    kw = tuned("flash_attention")
    kw.update(pps)
    return flash_attention(q, k, v, causal=causal, window=window,
                           interpret=on_cpu(), **kw)


def decode_attention(q, k, v, kv_len=None, *, use_kernel: bool | None = None,
                     **pps):
    if use_kernel is None:
        use_kernel = not on_cpu()
    if not use_kernel:
        return ref.decode_ref(q, k, v, kv_len)
    kw = tuned("flash_decode")
    kw.update(pps)
    return flash_decode(q, k, v, kv_len, interpret=on_cpu(), **kw)


def paged_decode_attention(q, k_pool, v_pool, page_table, kv_len, *,
                           k_scale=None, v_scale=None,
                           use_kernel: bool | None = None, **pps):
    """Decode attention over a paged KV cache (serving hot path).

    Dispatch mirrors :func:`decode_attention`: the Pallas PagedAttention
    kernel on TPU, the gather+oracle reference on CPU.  Tuned PPs
    published under ``flash_paged_decode`` (the serving
    ``DecodeAutoTuner`` publishes the per-bucket ``block_k`` sub-page
    tile) flow into the kernel call; the page size itself is structural —
    it is fixed when the pool is built, not a per-call knob.
    ``k_scale``/``v_scale`` (P, Hkv, psz fp32 per-row scales) switch both
    backends to the int8 in-kernel-dequant variant.
    """
    if use_kernel is None:
        use_kernel = not on_cpu()
    if not use_kernel:
        return ref.paged_decode_ref(q, k_pool, v_pool, page_table, kv_len,
                                    k_scale=k_scale, v_scale=v_scale)
    kw = tuned("flash_paged_decode")
    kw.update(pps)
    kw = {k: v for k, v in kw.items() if k in ("block_k", "scale")}
    if k_scale is not None:
        return flash_paged_decode_quant(q, k_pool, v_pool, k_scale, v_scale,
                                        page_table, kv_len,
                                        interpret=on_cpu(), **kw)
    return flash_paged_decode(q, k_pool, v_pool, page_table, kv_len,
                              interpret=on_cpu(), **kw)


def paged_prefill_attention(q, k_pool, v_pool, page_table, start, kv_len, *,
                            k_scale=None, v_scale=None,
                            use_kernel: bool | None = None, **pps):
    """Chunked-prefill attention over a paged KV cache (serving hot path).

    One prompt chunk (q: (B, H, C, D), first token at absolute position
    ``start``) attends causally to the committed prefix *plus* its own
    lower triangle, reading keys straight from the physical pages.  The
    chunk's KV must already be scattered into the pages (write before
    read).  Tuned PPs published under ``flash_paged_prefill`` — the
    serving prefill region tunes the (block_q x block_k) tile per prompt
    bucket x chunk size — flow into the kernel call; on CPU the gather
    oracle runs instead.  ``k_scale``/``v_scale`` switch both backends to
    the int8 in-kernel-dequant variant.
    """
    if use_kernel is None:
        use_kernel = not on_cpu()
    if not use_kernel:
        return ref.paged_prefill_ref(q, k_pool, v_pool, page_table,
                                     start, kv_len,
                                     k_scale=k_scale, v_scale=v_scale)
    kw = tuned("flash_paged_prefill")
    kw.update(pps)
    kw = {k: v for k, v in kw.items() if k in ("block_q", "block_k", "scale")}
    if k_scale is not None:
        return flash_paged_prefill_quant(q, k_pool, v_pool, k_scale, v_scale,
                                         page_table, start, kv_len,
                                         interpret=on_cpu(), **kw)
    return flash_paged_prefill(q, k_pool, v_pool, page_table, start, kv_len,
                               interpret=on_cpu(), **kw)


def paged_verify_attention(q, k_pool, v_pool, page_table, start, kv_len, *,
                           k_scale=None, v_scale=None,
                           use_kernel: bool | None = None, **pps):
    """Speculative-decode verify attention over a paged KV cache.

    The chunk is ``[last committed token, draft_1 .. draft_k]`` (q:
    (B, H, k+1, D), first token at absolute position ``start``): no new
    kernel math — it is exactly the chunked-prefill computation (causal
    over the committed prefix plus the chunk's own triangle), reusing the
    Pallas ``flash_paged_prefill`` kernel on TPU and the gather oracle on
    CPU.  What differs is the tuning surface: verify chunks are k+1
    tokens wide, so tuned PPs are read from ``flash_paged_verify`` (the
    serving ``SpecBucket`` regions tune k and the (block_q x block_k)
    tile per length bucket) instead of the prefill entry.
    """
    if use_kernel is None:
        use_kernel = not on_cpu()
    if not use_kernel:
        return ref.paged_prefill_ref(q, k_pool, v_pool, page_table,
                                     start, kv_len,
                                     k_scale=k_scale, v_scale=v_scale)
    kw = tuned("flash_paged_verify")
    kw.update(pps)
    kw = {k: v for k, v in kw.items() if k in ("block_q", "block_k", "scale")}
    if k_scale is not None:
        return flash_paged_prefill_quant(q, k_pool, v_pool, k_scale, v_scale,
                                         page_table, start, kv_len,
                                         interpret=on_cpu(), **kw)
    return flash_paged_prefill(q, k_pool, v_pool, page_table, start, kv_len,
                               interpret=on_cpu(), **kw)


def ssm_scan(x, dt, a, b, c, d, *, use_kernel: bool | None = None,
             return_final_state: bool = False, **pps):
    if use_kernel is None:
        use_kernel = not on_cpu()
    if not use_kernel or return_final_state:
        return ref.selective_scan_ref(x, dt, a, b, c, d,
                                      return_final_state)
    kw = tuned("ssm_scan")
    kw.update(pps)
    return selective_scan(x, dt, a, b, c, d, interpret=on_cpu(), **kw)
