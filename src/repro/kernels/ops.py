"""Public jit'd kernel wrappers with interpret/TPU dispatch + AT hookup.

The model stack calls these, never ``pl.pallas_call`` directly.  On CPU
(this container) kernels run in ``interpret=True`` mode; on TPU they
compile for real.  Block-shape performance parameters default to
MXU-aligned values and are overridden by install-time AT results published
through :func:`repro.at.tuned` (see tuning/install.py).

Paged attention goes through one typed entry point per op
(:func:`paged_decode` / :func:`paged_prefill` / :func:`paged_verify`),
each taking a :class:`PagedPools` bundle — pools, optional int8 scales
and the page geometry travel together instead of being sniffed from
``k_scale=None`` keywords.  The same entry points are where
tensor-parallel dispatch lives: given a ``mesh`` with a multi-device
``"model"`` axis they wrap the kernel in ``shard_map`` with the
(GQA-grouped) head axes partitioned and page tables replicated.  The old
``paged_*_attention`` keyword-sniffing entries remain as thin
deprecation shims.

``set_tuned`` is a deprecation shim over :func:`repro.at.publish`; new
code publishes via ``autotune(..., publish=(kernel, mapping))`` and reads
via ``at.tuned(kernel)``.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..at.session import publish as _publish
from ..at.session import tuned as _tuned
from . import ref
from .flash_attention import (flash_attention, flash_decode,
                              flash_paged_decode, flash_paged_decode_quant,
                              flash_paged_prefill, flash_paged_prefill_quant)
from .matmul import matmul
from .ssm_scan import selective_scan

if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:                                       # jax < 0.6 export location
    from jax.experimental.shard_map import shard_map as _shard_map


def set_tuned(name: str, **pps) -> None:
    """Deprecated: use ``repro.at.publish`` (kept as a thin shim)."""
    _publish(name, **pps)


def tuned(name: str, **bps) -> dict:
    return _tuned(name, **bps)


def on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def mm(x, y, bias=None, *, epilogue="none", use_kernel: bool | None = None,
       **pps):
    """GEMM entry point.  Falls back to the jnp reference on CPU unless the
    caller forces the kernel (tests do, with interpret=True)."""
    if use_kernel is None:
        use_kernel = not on_cpu()
    if not use_kernel:
        return ref.matmul_ref(x, y, bias, epilogue)
    kw = tuned("matmul")
    kw.update(pps)
    return matmul(x, y, bias, epilogue=epilogue, interpret=on_cpu(), **kw)


CHUNKED_THRESHOLD = 2048     # above this seq, the jnp path goes flash-style


def attention(q, k, v, *, causal=True, window=None, mesh=None,
              use_kernel: bool | None = None, **pps):
    """Full (prefill) attention.  On a mesh with a multi-device ``model``
    axis, long causal sequences take the ring sequence-parallel tail
    (:func:`repro.distributed.ring_attention.make_ring_attention`) —
    each device holds one sequence shard and passes KV blocks around the
    ring instead of all-gathering the whole sequence.  Short sequences,
    windowed attention and indivisible lengths fall through to the
    single-device paths unchanged.
    """
    seq = q.shape[2]
    m = mesh_model_axis(mesh)
    if (m > 1 and causal and window is None and seq == k.shape[2]
            and seq % m == 0 and seq > CHUNKED_THRESHOLD):
        from ..distributed.ring_attention import make_ring_attention
        if k.shape[1] != q.shape[1]:    # GQA: ring keeps heads replicated
            g = q.shape[1] // k.shape[1]
            k = jnp.repeat(k, g, axis=1)
            v = jnp.repeat(v, g, axis=1)
        return make_ring_attention(mesh, causal=True)(q, k, v)
    if use_kernel is None:
        use_kernel = not on_cpu()
    if not use_kernel:
        if q.shape[2] > CHUNKED_THRESHOLD:
            kw = tuned("chunked_attention")
            kw.update(pps)
            return ref.chunked_attention(q, k, v, causal=causal,
                                         window=window, **kw)
        return ref.attention_ref(q, k, v, causal=causal, window=window)
    kw = tuned("flash_attention")
    kw.update(pps)
    return flash_attention(q, k, v, causal=causal, window=window,
                           interpret=on_cpu(), **kw)


def decode_attention(q, k, v, kv_len=None, *, use_kernel: bool | None = None,
                     **pps):
    if use_kernel is None:
        use_kernel = not on_cpu()
    if not use_kernel:
        return ref.decode_ref(q, k, v, kv_len)
    kw = tuned("flash_decode")
    kw.update(pps)
    return flash_decode(q, k, v, kv_len, interpret=on_cpu(), **kw)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class PagedPools:
    """One layer's paged KV state as a typed bundle.

    ``k``/``v`` are the physical page pools, shape ``(P, Hkv, page_size,
    D)``; ``k_scale``/``v_scale`` are the per-row fp32 dequant scales
    ``(P, Hkv, page_size)`` carried only by int8 pools — their presence
    *is* the precision flag, replacing the old ``k_scale=None`` keyword
    sniffing.  Registered as a pytree so a bundle flows through ``jit`` /
    ``scan`` / ``shard_map`` like its bare arrays did (``None`` scales
    are empty subtrees, so fp and int8 bundles stay distinct treedefs).
    """

    k: jax.Array
    v: jax.Array
    k_scale: jax.Array | None = None
    v_scale: jax.Array | None = None

    def tree_flatten(self):
        return (self.k, self.v, self.k_scale, self.v_scale), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)

    @property
    def quantized(self) -> bool:
        return self.k_scale is not None

    @property
    def page_size(self) -> int:
        return self.k.shape[2]

    @property
    def n_kv_heads(self) -> int:
        return self.k.shape[1]

    def head_specs(self, axis: str = "model") -> "PagedPools":
        """shard_map PartitionSpecs partitioning the KV-head axis (pool
        axis 1) over ``axis``; the page axis stays replicated so page
        tables need no translation."""
        pool = P(None, axis, None, None)
        scale = None if self.k_scale is None else P(None, axis, None)
        return PagedPools(pool, pool, scale, scale)


def mesh_model_axis(mesh) -> int:
    """Size of the tensor-parallel ``"model"`` axis of ``mesh`` (1 == no
    sharding: no mesh, no model axis, or a 1-device axis — those all run
    the unsharded code path and stay bit-identical to it)."""
    if mesh is None or "model" not in mesh.axis_names:
        return 1
    return int(mesh.shape["model"])


def _check_pools(pools: PagedPools) -> None:
    if (pools.k_scale is None) != (pools.v_scale is None):
        raise ValueError(
            "PagedPools carries k_scale without v_scale (or vice versa): "
            "int8 pools quantize both sides, fp pools neither")


def _check_head_sharding(q, pools: PagedPools, m: int) -> None:
    h, hkv = q.shape[1], pools.n_kv_heads
    if hkv % m or h % m:
        raise ValueError(
            f"tensor-parallel paged attention shards the KV-head axis: "
            f"kv_heads={hkv} (q heads {h}) is not divisible by the mesh's "
            f"'model' axis of size {m} — pick a mesh whose model axis "
            f"divides the head counts, or run unsharded")


def _head_sharded(mesh, fn, q, pools: PagedPools, *rest):
    """Run ``fn(q, pools, *rest)`` under ``shard_map`` with the head axes
    partitioned over the mesh's ``"model"`` axis.

    Contiguous sharding of both the q-head and kv-head axes keeps every q
    head on the same shard as its GQA kv group (group size H/Hkv divides
    evenly once both axes divide the mesh), so each device runs exactly
    the unsharded math on its head slice; page tables and lane metadata
    (``*rest``) are replicated.  The output is constrained back to
    replicated — an exact all-gather — so the caller's output projection
    computes bitwise identically to the unsharded engine.
    """
    qspec = P(None, "model", None, None)
    shardfn = _shard_map(fn, mesh=mesh,
                         in_specs=(qspec, pools.head_specs(),
                                   *(P() for _ in rest)),
                         out_specs=qspec, check_rep=False)
    out = shardfn(q, pools, *rest)
    return jax.lax.with_sharding_constraint(
        out, NamedSharding(mesh, P(None, None, None, None)))


def _paged_decode_local(q, pools: PagedPools, page_table, kv_len, *,
                        use_kernel: bool | None = None, **pps):
    if use_kernel is None:
        use_kernel = not on_cpu()
    if not use_kernel:
        return ref.paged_decode_ref(q, pools.k, pools.v, page_table, kv_len,
                                    k_scale=pools.k_scale,
                                    v_scale=pools.v_scale)
    kw = tuned("flash_paged_decode")
    kw.update(pps)
    kw = {k: v for k, v in kw.items()
          if k in ("block_k", "num_splits", "scale")}
    if pools.quantized:
        return flash_paged_decode_quant(q, pools.k, pools.v, pools.k_scale,
                                        pools.v_scale, page_table, kv_len,
                                        interpret=on_cpu(), **kw)
    return flash_paged_decode(q, pools.k, pools.v, page_table, kv_len,
                              interpret=on_cpu(), **kw)


def _paged_chunk_local(q, pools: PagedPools, page_table, start, kv_len, *,
                       tuned_key: str, use_kernel: bool | None = None, **pps):
    """Shared local body for prefill and verify (same math, different
    tuning surface — ``tuned_key`` selects which published PPs apply)."""
    if use_kernel is None:
        use_kernel = not on_cpu()
    if not use_kernel:
        return ref.paged_prefill_ref(q, pools.k, pools.v, page_table,
                                     start, kv_len,
                                     k_scale=pools.k_scale,
                                     v_scale=pools.v_scale)
    kw = tuned(tuned_key)
    kw.update(pps)
    kw = {k: v for k, v in kw.items()
          if k in ("block_q", "block_k", "num_splits", "scale")}
    if pools.quantized:
        return flash_paged_prefill_quant(q, pools.k, pools.v, pools.k_scale,
                                         pools.v_scale, page_table, start,
                                         kv_len, interpret=on_cpu(), **kw)
    return flash_paged_prefill(q, pools.k, pools.v, page_table, start, kv_len,
                               interpret=on_cpu(), **kw)


def paged_decode(q, pools: PagedPools, page_table, kv_len, *, mesh=None,
                 use_kernel: bool | None = None, **pps):
    """Decode attention over a paged KV cache (serving hot path).

    Dispatch mirrors :func:`decode_attention`: the Pallas PagedAttention
    kernel on TPU, the gather+oracle reference on CPU.  Tuned PPs
    published under ``flash_paged_decode`` (the serving
    ``DecodeAutoTuner`` publishes the per-bucket ``block_k`` sub-page
    tile and the split-KV ``num_splits`` parallelism degree) flow into
    the kernel call; the page size itself is structural — it is fixed
    when the pool is built, not a per-call knob.  An int8 ``pools``
    bundle (scales present) switches both backends to the
    in-kernel-dequant variant.  A ``mesh`` with a multi-device ``model``
    axis runs the op under ``shard_map`` with heads partitioned
    (:func:`_head_sharded`); a 1-device mesh takes the unsharded path
    unchanged — tuned PPs are read inside the per-shard body, so
    ``num_splits`` splits each device's *local* head slice's KV walk.
    """
    _check_pools(pools)
    m = mesh_model_axis(mesh)
    if m > 1:
        _check_head_sharding(q, pools, m)
        fn = functools.partial(_paged_decode_local, use_kernel=use_kernel,
                               **pps)
        return _head_sharded(mesh, fn, q, pools, page_table, kv_len)
    return _paged_decode_local(q, pools, page_table, kv_len,
                               use_kernel=use_kernel, **pps)


def paged_prefill(q, pools: PagedPools, page_table, start, kv_len, *,
                  mesh=None, use_kernel: bool | None = None, **pps):
    """Chunked-prefill attention over a paged KV cache (serving hot path).

    One prompt chunk (q: (B, H, C, D), first token at absolute position
    ``start``) attends causally to the committed prefix *plus* its own
    lower triangle, reading keys straight from the physical pages.  The
    chunk's KV must already be scattered into the pages (write before
    read).  Tuned PPs published under ``flash_paged_prefill`` — the
    serving prefill region tunes the (block_q x block_k) tile per prompt
    bucket x chunk size — flow into the kernel call; on CPU the gather
    oracle runs instead.  int8 bundles and ``mesh`` dispatch exactly as
    in :func:`paged_decode`.
    """
    _check_pools(pools)
    m = mesh_model_axis(mesh)
    fn = functools.partial(_paged_chunk_local,
                           tuned_key="flash_paged_prefill",
                           use_kernel=use_kernel, **pps)
    if m > 1:
        _check_head_sharding(q, pools, m)
        return _head_sharded(mesh, fn, q, pools, page_table, start, kv_len)
    return fn(q, pools, page_table, start, kv_len)


def paged_verify(q, pools: PagedPools, page_table, start, kv_len, *,
                 mesh=None, use_kernel: bool | None = None, **pps):
    """Speculative-decode verify attention over a paged KV cache.

    The chunk is ``[last committed token, draft_1 .. draft_k]`` (q:
    (B, H, k+1, D), first token at absolute position ``start``): no new
    kernel math — it is exactly the chunked-prefill computation (causal
    over the committed prefix plus the chunk's own triangle), reusing the
    Pallas ``flash_paged_prefill`` kernel on TPU and the gather oracle on
    CPU.  What differs is the tuning surface: verify chunks are k+1
    tokens wide, so tuned PPs are read from ``flash_paged_verify`` (the
    serving ``SpecBucket`` regions tune k and the (block_q x block_k)
    tile per length bucket) instead of the prefill entry.
    """
    _check_pools(pools)
    m = mesh_model_axis(mesh)
    fn = functools.partial(_paged_chunk_local,
                           tuned_key="flash_paged_verify",
                           use_kernel=use_kernel, **pps)
    if m > 1:
        _check_head_sharding(q, pools, m)
        return _head_sharded(mesh, fn, q, pools, page_table, start, kv_len)
    return fn(q, pools, page_table, start, kv_len)


# -- deprecated keyword-sniffing entries ------------------------------------
# Thin shims over the typed entry points so pre-PagedPools callers keep
# working while they migrate; new code passes a PagedPools bundle.

def paged_decode_attention(q, k_pool, v_pool, page_table, kv_len, *,
                           k_scale=None, v_scale=None,
                           use_kernel: bool | None = None, **pps):
    """Deprecated: use :func:`paged_decode` with a :class:`PagedPools`."""
    return paged_decode(q, PagedPools(k_pool, v_pool, k_scale, v_scale),
                        page_table, kv_len, use_kernel=use_kernel, **pps)


def paged_prefill_attention(q, k_pool, v_pool, page_table, start, kv_len, *,
                            k_scale=None, v_scale=None,
                            use_kernel: bool | None = None, **pps):
    """Deprecated: use :func:`paged_prefill` with a :class:`PagedPools`."""
    return paged_prefill(q, PagedPools(k_pool, v_pool, k_scale, v_scale),
                         page_table, start, kv_len,
                         use_kernel=use_kernel, **pps)


def paged_verify_attention(q, k_pool, v_pool, page_table, start, kv_len, *,
                           k_scale=None, v_scale=None,
                           use_kernel: bool | None = None, **pps):
    """Deprecated: use :func:`paged_verify` with a :class:`PagedPools`."""
    return paged_verify(q, PagedPools(k_pool, v_pool, k_scale, v_scale),
                        page_table, start, kv_len,
                        use_kernel=use_kernel, **pps)


def ssm_scan(x, dt, a, b, c, d, *, use_kernel: bool | None = None,
             return_final_state: bool = False, **pps):
    if use_kernel is None:
        use_kernel = not on_cpu()
    if not use_kernel or return_final_state:
        return ref.selective_scan_ref(x, dt, a, b, c, d,
                                      return_final_state)
    kw = tuned("ssm_scan")
    kw.update(pps)
    return selective_scan(x, dt, a, b, c, d, interpret=on_cpu(), **kw)
