"""Seismic FDM stress-update kernel — the paper's §5.2 Sample 8 hot-spot
(ppOpen-APPL/FDM), as a Pallas TPU kernel.

Hardware adaptation (recorded in DESIGN.md): the Fortran loop nest walks a
3-D stencil with (i+1, j+1, k+1) neighbour reads.  On TPU we do not gather —
the shifted operands (``rig_ip1`` etc.) are materialised as shifted views by
the wrapper, so every kernel body is a pure elementwise VPU pass over
blocks.  The paper's loop split then becomes kernel **fission** (two
``pallas_call``s; the flow-dependent scalar plane ``QG`` is *recomputed* in
the second kernel — exactly the ``SplitPointCopyDef`` semantics, i.e.
rematerialisation), and loop fusion becomes the single fused kernel.
The AT region for this kernel selects:

* ``variant`` — fused (1 pass, larger VMEM set) vs split (2 passes, QG
  recomputed) — the paper's Sample 8 trade-off;
* ``bx, by, bz`` — VMEM block shape (the collapse analogue: a (bx*by, bz)
  tile *is* the collapsed iteration space).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ARGS9 = ("lam", "rig", "qg_abs", "dxvx", "dyvy", "dzvz",
         "dxvy", "dyvx", "dxvz", "dzvx", "dyvz", "dzvy")
SHIFTED = ("rig_ip1", "rig_jp1", "rig_kp1", "rig_ip1jp1", "rig_ip1kp1",
           "rig_jp1kp1")
STATE = ("sxx", "syy", "szz", "sxy", "sxz", "syz")


def _normal_part(refs, dt):
    rl = refs["lam"][...]
    rm = refs["rig"][...]
    rm2 = rm + rm
    rltheta = (refs["dxvx"][...] + refs["dyvy"][...]
               + refs["dzvz"][...]) * rl
    qg = refs["qg_abs"][...]
    sxx = (refs["sxx"][...] + (rltheta + rm2 * refs["dxvx"][...]) * dt) * qg
    syy = (refs["syy"][...] + (rltheta + rm2 * refs["dyvy"][...]) * dt) * qg
    szz = (refs["szz"][...] + (rltheta + rm2 * refs["dzvz"][...]) * dt) * qg
    return sxx, syy, szz


def _shear_part(refs, dt):
    stmp1 = 1.0 / refs["rig"][...]
    stmp2 = 1.0 / refs["rig_ip1"][...]
    stmp4 = 1.0 / refs["rig_kp1"][...]
    stmp3 = stmp1 + stmp2
    rmaxy = 4.0 / (stmp3 + 1.0 / refs["rig_jp1"][...]
                   + 1.0 / refs["rig_ip1jp1"][...])
    rmaxz = 4.0 / (stmp3 + stmp4 + 1.0 / refs["rig_ip1kp1"][...])
    rmayz = 4.0 / (stmp3 + stmp4 + 1.0 / refs["rig_jp1kp1"][...])
    qg = refs["qg_abs"][...]     # recomputed read — SplitPointCopyDef
    sxy = (refs["sxy"][...]
           + (rmaxy * (refs["dxvy"][...] + refs["dyvx"][...])) * dt) * qg
    sxz = (refs["sxz"][...]
           + (rmaxz * (refs["dxvz"][...] + refs["dzvx"][...])) * dt) * qg
    syz = (refs["syz"][...]
           + (rmayz * (refs["dyvz"][...] + refs["dzvy"][...])) * dt) * qg
    return sxy, sxz, syz


def _fused_kernel(*refs_list, names, dt):
    refs = dict(zip(names, refs_list[:len(names)]))
    outs = refs_list[len(names):]
    sxx, syy, szz = _normal_part(refs, dt)
    sxy, sxz, syz = _shear_part(refs, dt)
    for o, v in zip(outs, (sxx, syy, szz, sxy, sxz, syz)):
        o[...] = v


def _normal_kernel(*refs_list, names, dt):
    refs = dict(zip(names, refs_list[:len(names)]))
    outs = refs_list[len(names):]
    for o, v in zip(outs, _normal_part(refs, dt)):
        o[...] = v


def _shear_kernel(*refs_list, names, dt):
    refs = dict(zip(names, refs_list[:len(names)]))
    outs = refs_list[len(names):]
    for o, v in zip(outs, _shear_part(refs, dt)):
        o[...] = v


def _prepare(arrays: dict[str, jax.Array]) -> dict[str, jax.Array]:
    """Build shifted operand views + the QG plane from raw FDM fields."""
    rig = arrays["rig"]
    pad = jnp.pad(rig, ((0, 1), (0, 1), (0, 1)), mode="edge")
    nx, ny, nz = rig.shape
    out = dict(arrays)
    out["rig_ip1"] = pad[1:, :-1, :-1]
    out["rig_jp1"] = pad[:-1, 1:, :-1]
    out["rig_kp1"] = pad[:-1, :-1, 1:]
    out["rig_ip1jp1"] = pad[1:, 1:, :-1]
    out["rig_ip1kp1"] = pad[1:, :-1, 1:]
    out["rig_jp1kp1"] = pad[:-1, 1:, 1:]
    out["qg_abs"] = (arrays["absx"][:, None, None]
                     * arrays["absy"][None, :, None]
                     * arrays["absz"][None, None, :] * arrays["q"])
    for k in ("absx", "absy", "absz", "q"):
        out.pop(k)
    return out


def _call(kernel, names, ins, state_names, state, shape, dt, blocks,
          interpret):
    bx, by, bz = blocks
    nx, ny, nz = shape
    bx, by, bz = min(bx, nx), min(by, ny), min(bz, nz)

    def padto(a):
        p = [(0, (-s) % b) for s, b in zip(a.shape, (bx, by, bz))]
        return jnp.pad(a, p) if any(x for _, x in p) else a

    ins_p = [padto(ins[n]) for n in names]
    st_p = [padto(state[n]) for n in state_names]
    px, py, pz = ins_p[0].shape
    grid = (px // bx, py // by, pz // bz)
    spec = pl.BlockSpec((bx, by, bz), lambda i, j, k: (i, j, k))
    n_out = len(state_names)
    out = pl.pallas_call(
        functools.partial(kernel, names=list(names) + list(state_names),
                          dt=dt),
        grid=grid,
        in_specs=[spec] * (len(names) + n_out),
        out_specs=[spec] * n_out,
        out_shape=[jax.ShapeDtypeStruct((px, py, pz), st_p[0].dtype)] * n_out,
        interpret=interpret,
    )(*ins_p, *st_p)
    return [o[:nx, :ny, :nz] for o in out]


@functools.partial(jax.jit, static_argnames=("dt", "variant", "bx", "by",
                                             "bz", "interpret"))
def fdm_stress(arrays: dict[str, jax.Array], state: dict[str, jax.Array],
               dt: float, *, variant: str = "fused", bx: int = 8,
               by: int = 8, bz: int = 128,
               interpret: bool = False) -> dict[str, jax.Array]:
    """One stress update step.

    ``arrays``: lam, rig, q, absx, absy, absz, dxvx..dzvy (nx, ny, nz) /
    (n,); ``state``: sxx..syz.  ``variant``: 'fused' | 'split'.
    """
    ins = _prepare(arrays)
    shape = ins["rig"].shape
    blocks = (bx, by, bz)
    if variant == "fused":
        names = ARGS9 + SHIFTED
        names = tuple(n for n in names if n in ins)
        outs = _call(_fused_kernel, names, ins, STATE, state, shape, dt,
                     blocks, interpret)
        return dict(zip(STATE, outs))
    if variant == "split":
        n_names = ("lam", "rig", "qg_abs", "dxvx", "dyvy", "dzvz")
        o1 = _call(_normal_kernel, n_names, ins, STATE[:3], state, shape,
                   dt, blocks, interpret)
        s_names = ("rig", "rig_ip1", "rig_jp1", "rig_kp1", "rig_ip1jp1",
                   "rig_ip1kp1", "rig_jp1kp1", "qg_abs", "dxvy", "dyvx",
                   "dxvz", "dzvx", "dyvz", "dzvy")
        o2 = _call(_shear_kernel, s_names, ins, STATE[3:], state, shape,
                   dt, blocks, interpret)
        return dict(zip(STATE, o1 + o2))
    raise ValueError(f"unknown variant {variant!r}")
