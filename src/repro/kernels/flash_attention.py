"""Flash attention Pallas kernel (causal / GQA / sliding-window) + decode.

Online-softmax attention with explicit VMEM tiling, IO-aware in the
FlashAttention sense but re-blocked for the TPU memory hierarchy: the MXU
consumes (block_q x d_head) x (d_head x block_k) tiles; running max /
denominator live in VMEM scratch.

Performance parameters (install-time AT): ``block_q``, ``block_k``.
Layout parameters (before-execute-time AT): which attention path (this
kernel vs the jnp reference vs ring-SP) is selected per (arch x shape x
mesh) — see tuning/static.py.

The kernels:

* :func:`flash_attention` — self-attention over (B, H, S, D) with causal
  and/or sliding-window masking and GQA head mapping (kv_head = h // G).
* :func:`flash_decode` — one-token decode against a (B, Hkv, S, D) KV
  cache, blocked over S (FlashDecoding-style), fp32 LSE merge.
* :func:`flash_paged_decode` — one-token decode against a paged KV cache
  (scalar-prefetched page table, vLLM-style).
* :func:`flash_paged_prefill` — one prompt *chunk* against a paged KV
  cache: causal at absolute positions over the committed prefix plus the
  chunk's own triangle (chunked-prefill serving path).
"""
from __future__ import annotations

import functools
import warnings

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams around 0.5; accept both
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 scale: float, causal: bool, window: int | None,
                 block_q: int, block_k: int, n_k: int, k_valid: int):
    iq, ik = pl.program_id(2), pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * block_q
    k_start = ik * block_k
    padded = k_valid != n_k * block_k

    def body():
        q = q_ref[0, 0].astype(jnp.float32)       # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)       # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal or window is not None or padded:
            qi = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
            kj = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
            mask = jnp.ones((block_q, block_k), jnp.bool_)
            if causal:
                mask &= qi >= kj
            if window is not None:
                mask &= (qi - kj) < window
            if padded:
                mask &= kj < k_valid
            s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]                         # (bq, 1)
        m_cur = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_cur)
        alpha = jnp.exp(m_prev - m_cur)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        m_ref[...] = m_cur
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v_ref[0, 0].astype(jnp.float32),
            preferred_element_type=jnp.float32)

    if causal or window is not None:
        # skip fully-masked tiles (upper triangle / outside the window)
        live = True
        if causal:
            live = q_start + block_q - 1 >= k_start
        if window is not None:
            live = jnp.logical_and(
                live, k_start + block_k - 1 > q_start - window)
        pl.when(live)(body)
    else:
        body()

    @pl.when(ik == n_k - 1)
    def _done():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "scale", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    block_q: int = 128, block_k: int = 128,
                    scale: float | None = None,
                    interpret: bool = False) -> jax.Array:
    """Self-attention.  q: (B, H, S, D); k, v: (B, Hkv, S, D), H % Hkv == 0.
    """
    b, h, s, d = q.shape
    _, hkv, sk, _ = k.shape
    assert s == sk, "flash_attention is self-attention (use flash_decode)"
    group = h // hkv
    scale = float(scale if scale is not None else d ** -0.5)
    bq, bk = min(block_q, s), min(block_k, s)

    def pad_seq(a, blk):
        p = (-a.shape[2]) % blk
        if p:
            return jnp.pad(a, ((0, 0), (0, 0), (0, p), (0, 0)))
        return a

    qp = pad_seq(q, bq)
    kp, vp = pad_seq(k, bk), pad_seq(v, bk)
    sq, skk = qp.shape[2], kp.shape[2]
    grid = (b, h, sq // bq, skk // bk)

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        block_q=bq, block_k=bk, n_k=grid[3], k_valid=s)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bb, hh, iq, ik: (bb, hh, iq, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bb, hh, iq, ik, g=group: (bb, hh // g, ik, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bb, hh, iq, ik, g=group: (bb, hh // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda bb, hh, iq, ik: (bb, hh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(qp.shape, q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, d), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :, :s, :]


# --------------------------------------------------------------------------
# decode: one query token against a long KV cache
# --------------------------------------------------------------------------


def _decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, m_ref, l_ref,
                   acc_ref, *, scale: float, block_k: int, n_k: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_len = len_ref[0]
    k_start = ik * block_k

    @pl.when(k_start < kv_len)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)        # (1, d) -> use (G, d)
        k = k_ref[0, 0].astype(jnp.float32)        # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kj = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kj < kv_len, s, NEG_INF)
        m_prev = m_ref[...]
        m_cur = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_cur)
        alpha = jnp.exp(m_prev - m_cur)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        m_ref[...] = m_cur
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v_ref[0, 0].astype(jnp.float32),
            preferred_element_type=jnp.float32)

    @pl.when(ik == n_k - 1)
    def _done():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "scale", "interpret"))
def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                 kv_len: jax.Array | None = None, *, block_k: int = 512,
                 scale: float | None = None,
                 interpret: bool = False) -> jax.Array:
    """Decode attention: q (B, H, 1, D) against caches (B, Hkv, S, D).

    The query's G = H/Hkv grouped heads are folded into the MXU sublane dim
    so a GQA decode step still feeds (G x d) @ (d x bk) tiles — the TPU
    adaptation of FlashDecoding's split-K (no warp shuffles here; the lane
    reduction is the VPU's job).
    ``kv_len`` (B,) masks the valid prefix of the cache.
    """
    b, h, one, d = q.shape
    _, hkv, s, _ = k.shape
    assert one == 1
    g = h // hkv
    scale = float(scale if scale is not None else d ** -0.5)
    bk = min(block_k, s)
    p = (-s) % bk
    if p:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, p), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, p), (0, 0)))
    sp = k.shape[2]
    if kv_len is None:
        kv_len = jnp.full((b,), s, jnp.int32)
    # fold grouped heads: (B, Hkv, G, D)
    qg = q.reshape(b, hkv, g, d)
    grid = (b, hkv, sp // bk)
    kernel = functools.partial(_decode_kernel, scale=scale, block_k=bk,
                               n_k=grid[2])
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda bb, hh, ik: (bb, hh, 0, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bb, hh, ik: (bb, hh, ik, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bb, hh, ik: (bb, hh, ik, 0)),
            pl.BlockSpec((1,), lambda bb, hh, ik: (bb,)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda bb, hh, ik: (bb, hh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((g, 1), jnp.float32),
                        pltpu.VMEM((g, 1), jnp.float32),
                        pltpu.VMEM((g, d), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qg, k, v, kv_len)
    return out.reshape(b, h, 1, d)


# --------------------------------------------------------------------------
# split-KV support (Flash-Decoding two-phase): shared helpers
# --------------------------------------------------------------------------


def _resolve_block_k(block_k: int | None, page_size: int,
                     kernel_name: str) -> tuple[int, int]:
    """Resolve the split-K tile within a page: (block_k, sub_blocks).

    The tile must divide ``page_size`` exactly; a non-divisor request is
    coerced to the whole page *with a warning* (it used to be discarded
    silently, which hid tuned values the AT layer thought it had
    committed).  Candidate grids should be pre-filtered with
    :func:`repro.tuning.dynamic.divisor_block_ks` so this never fires in
    a tuned run.
    """
    bk = min(block_k, page_size) if block_k else page_size
    if page_size % bk:
        warnings.warn(
            f"{kernel_name}: requested block_k={block_k} does not divide "
            f"page_size={page_size}; falling back to block_k={page_size} "
            "(whole page) — filter candidates to divisors of page_size",
            RuntimeWarning,
            stacklevel=3,
        )
        bk = page_size
    return bk, page_size // bk


def _split_combine_kernel(m_ref, l_ref, acc_ref, o_ref):
    """Phase 2 of split-KV attention: merge per-split partial softmax
    states with the standard max-shift rescale, then normalize.

    Blocks: m, l (1, 1, ns, rows); acc (1, 1, ns, rows, d); out
    (1, 1, rows, d).  An empty split carries (m=NEG_INF, l=0, acc=0):
    its rescale weight exp(NEG_INF - m*) underflows to exactly 0.0, so
    it contributes nothing; if *every* split is empty the l* == 0 guard
    reproduces the sequential kernel's zero output.
    """
    m = m_ref[0, 0]                                  # (ns, rows)
    l = l_ref[0, 0]                                  # (ns, rows)
    acc = acc_ref[0, 0]                              # (ns, rows, d)
    m_star = m.max(axis=0, keepdims=True)            # (1, rows)
    alpha = jnp.exp(m - m_star)                      # (ns, rows)
    l_star = (l * alpha).sum(axis=0, keepdims=True)  # (1, rows)
    acc_star = (acc * alpha[..., None]).sum(axis=0)  # (rows, d)
    l_star = jnp.where(l_star == 0.0, 1.0, l_star)
    o_ref[0, 0] = (acc_star / l_star[0][:, None]).astype(o_ref.dtype)


def _combine_splits(m: jax.Array, l: jax.Array, acc: jax.Array,
                    out_dtype, interpret: bool) -> jax.Array:
    """Run the combine kernel over canonical partial-state arrays.

    m, l: (B, R, ns, rows) fp32; acc: (B, R, ns, rows, d) fp32, where R
    is whatever the phase-1 grid parallelised over besides batch (kv
    heads for decode, head x q-tile for prefill).  Returns
    (B, R, rows, d) in ``out_dtype``.
    """
    bb, rr, ns, rows = m.shape
    d = acc.shape[-1]
    return pl.pallas_call(
        _split_combine_kernel,
        grid=(bb, rr),
        in_specs=[
            pl.BlockSpec((1, 1, ns, rows), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, ns, rows), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, ns, rows, d),
                         lambda i, j: (i, j, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, rows, d), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bb, rr, rows, d), out_dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(m, l, acc)


def _num_splits(num_splits: int | None, n_steps: int) -> tuple[int, int]:
    """Clamp the requested parallelism degree to the actual KV walk and
    return (n_splits, steps_per_split).  1 selects the single-phase
    (sequential) kernel — the legacy spelling."""
    ns = max(1, min(int(num_splits or 1), n_steps))
    return ns, -(-n_steps // ns)


# --------------------------------------------------------------------------
# paged decode: one query token against a paged (block) KV cache
# --------------------------------------------------------------------------


def _paged_decode_kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                         m_ref, l_ref, acc_ref, *, scale: float,
                         block_k: int, n_blk: int):
    b, ik = pl.program_id(0), pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_len = len_ref[b]
    k_start = ik * block_k

    @pl.when(k_start < kv_len)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)        # (G, d)
        k = k_ref[0, 0].astype(jnp.float32)        # (psz, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kj = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kj < kv_len, s, NEG_INF)
        m_prev = m_ref[...]
        m_cur = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_cur)
        alpha = jnp.exp(m_prev - m_cur)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        m_ref[...] = m_cur
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v_ref[0, 0].astype(jnp.float32),
            preferred_element_type=jnp.float32)

    @pl.when(ik == n_blk - 1)
    def _done():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def _paged_decode_split_kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref,
                               m_out, l_out, acc_out, m_ref, l_ref,
                               acc_ref, *, scale: float, block_k: int,
                               n_steps: int, steps_per_split: int):
    """Phase 1 of split-KV decode: each split walks its own contiguous
    segment of the page-table and emits partial (m, l, acc) state.  The
    split axis is a *parallel* grid dimension — this is what breaks the
    long serial KV walk that dominates 1-lane long-context ITL."""
    b = pl.program_id(0)
    isp, j = pl.program_id(2), pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_len = len_ref[b]
    ik = isp * steps_per_split + j
    k_start = ik * block_k

    # ik >= n_steps happens only in the ragged last split (ceil-divided
    # segments); its tiles load a redundant clamped page and are masked
    @pl.when(jnp.logical_and(ik < n_steps, k_start < kv_len))
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)        # (G, d)
        k = k_ref[0, 0].astype(jnp.float32)        # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kj = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kj < kv_len, s, NEG_INF)
        m_prev = m_ref[...]
        m_cur = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_cur)
        alpha = jnp.exp(m_prev - m_cur)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        m_ref[...] = m_cur
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v_ref[0, 0].astype(jnp.float32),
            preferred_element_type=jnp.float32)

    @pl.when(j == steps_per_split - 1)
    def _done():
        m_out[0, 0, 0] = m_ref[...][:, 0]
        l_out[0, 0, 0] = l_ref[...][:, 0]
        acc_out[0, 0, 0] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("block_k", "num_splits",
                                             "scale", "interpret"))
def flash_paged_decode(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                       page_table: jax.Array, kv_len: jax.Array, *,
                       block_k: int | None = None,
                       num_splits: int | None = None,
                       scale: float | None = None,
                       interpret: bool = False) -> jax.Array:
    """Decode attention over a paged KV cache (vLLM-style PagedAttention).

    q: (B, H, 1, D); pools (P, Hkv, psz, D); ``page_table`` (B, nblk) int32
    maps each sequence's logical KV block to a physical page.  The table is
    scalar-prefetched so each grid step DMAs straight from the owning page
    — the KV working set never materialises densely, which is the whole
    point: HBM traffic is O(live tokens), not O(B * max_len).  ``kv_len``
    (B,) masks the valid prefix; table entries past it may point anywhere
    (page 0 by convention).

    ``block_k`` is the split-K tile *within* a page (the run-time-AT
    performance parameter of this kernel): it must divide ``page_size``
    and defaults to the whole page; smaller tiles trade more grid steps
    for less VMEM per step.  ``num_splits`` partitions the KV walk into
    that many *parallel* segments (Flash-Decoding two-phase); 1 (the
    default) is the single-phase sequential kernel.
    """
    b, h, one, d = q.shape
    n_pages, hkv, psz, _ = k_pool.shape
    assert one == 1
    g = h // hkv
    nblk = page_table.shape[1]
    scale = float(scale if scale is not None else d ** -0.5)
    bk, sub = _resolve_block_k(block_k, psz, "flash_paged_decode")
    qg = q.reshape(b, hkv, g, d)
    n_steps = nblk * sub
    ns, sps = _num_splits(num_splits, n_steps)
    if ns == 1:
        grid = (b, hkv, n_steps)
        kernel = functools.partial(_paged_decode_kernel, scale=scale,
                                   block_k=bk, n_blk=grid[2])
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, g, d),
                             lambda bb, hh, ik, tbl, ln: (bb, hh, 0, 0)),
                pl.BlockSpec((1, 1, bk, d),
                             lambda bb, hh, ik, tbl, ln, s=sub:
                             (tbl[bb, ik // s], hh, ik % s, 0)),
                pl.BlockSpec((1, 1, bk, d),
                             lambda bb, hh, ik, tbl, ln, s=sub:
                             (tbl[bb, ik // s], hh, ik % s, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, g, d),
                                   lambda bb, hh, ik, tbl, ln:
                                   (bb, hh, 0, 0)),
            scratch_shapes=[pltpu.VMEM((g, 1), jnp.float32),
                            pltpu.VMEM((g, 1), jnp.float32),
                            pltpu.VMEM((g, d), jnp.float32)],
        )
        out = pl.pallas_call(
            kernel,
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
            compiler_params=_CompilerParams(
                dimension_semantics=("parallel", "parallel", "arbitrary")),
            interpret=interpret,
        )(page_table.astype(jnp.int32), kv_len.astype(jnp.int32),
          qg, k_pool, v_pool)
        return out.reshape(b, h, 1, d)

    grid = (b, hkv, ns, sps)
    kernel = functools.partial(_paged_decode_split_kernel, scale=scale,
                               block_k=bk, n_steps=n_steps,
                               steps_per_split=sps)

    def kv_idx(bb, hh, isp, j, tbl, ln, s=sub, sp=sps, n=n_steps):
        ik = jnp.minimum(isp * sp + j, n - 1)
        return (tbl[bb, ik // s], hh, ik % s, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, d),
                         lambda bb, hh, isp, j, tbl, ln: (bb, hh, 0, 0)),
            pl.BlockSpec((1, 1, bk, d), kv_idx),
            pl.BlockSpec((1, 1, bk, d), kv_idx),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, g),
                         lambda bb, hh, isp, j, tbl, ln:
                         (bb, hh, isp, 0)),
            pl.BlockSpec((1, 1, 1, g),
                         lambda bb, hh, isp, j, tbl, ln:
                         (bb, hh, isp, 0)),
            pl.BlockSpec((1, 1, 1, g, d),
                         lambda bb, hh, isp, j, tbl, ln:
                         (bb, hh, isp, 0, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((g, 1), jnp.float32),
                        pltpu.VMEM((g, 1), jnp.float32),
                        pltpu.VMEM((g, d), jnp.float32)],
    )
    pm, pll, pacc = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, hkv, ns, g), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, ns, g), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, ns, g, d), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(page_table.astype(jnp.int32), kv_len.astype(jnp.int32),
      qg, k_pool, v_pool)
    out = _combine_splits(pm, pll, pacc, q.dtype, interpret)
    return out.reshape(b, h, 1, d)


# --------------------------------------------------------------------------
# paged prefill: one prompt chunk against a paged (block) KV cache
# --------------------------------------------------------------------------


def _paged_prefill_kernel(tbl_ref, start_ref, len_ref, q_ref, k_ref, v_ref,
                          o_ref, m_ref, l_ref, acc_ref, *, scale: float,
                          block_q: int, block_k: int, n_k: int):
    b, iq, ik = pl.program_id(0), pl.program_id(2), pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_len = len_ref[b]
    q_start = start_ref[b] + iq * block_q     # absolute pos of q row 0
    k_start = ik * block_k

    # live iff some key in the tile is (a) committed and (b) causally
    # visible to the *last* query row of the q tile
    live = jnp.logical_and(k_start < kv_len,
                           k_start <= q_start + block_q - 1)

    @pl.when(live)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)       # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)       # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qi = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                (block_q, block_k), 0)
        kj = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                (block_q, block_k), 1)
        mask = (kj <= qi) & (kj < kv_len)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_cur = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_cur)
        alpha = jnp.exp(m_prev - m_cur)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        m_ref[...] = m_cur
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v_ref[0, 0].astype(jnp.float32),
            preferred_element_type=jnp.float32)

    @pl.when(ik == n_k - 1)
    def _done():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def _paged_prefill_split_kernel(tbl_ref, start_ref, len_ref, q_ref, k_ref,
                                v_ref, m_out, l_out, acc_out, m_ref, l_ref,
                                acc_ref, *, scale: float, block_q: int,
                                block_k: int, n_steps: int,
                                steps_per_split: int):
    """Phase 1 of split-KV prefill/verify: the KV walk for each q tile is
    partitioned into parallel segments emitting partial (m, l, acc).
    Partials for (q-tile iq, split isp) land at folded row iq*ns+isp so
    the outputs stay <= 5-D."""
    b = pl.program_id(0)
    iq = pl.program_id(2)
    isp, j = pl.program_id(3), pl.program_id(4)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_len = len_ref[b]
    q_start = start_ref[b] + iq * block_q     # absolute pos of q row 0
    ik = isp * steps_per_split + j
    k_start = ik * block_k

    live = jnp.logical_and(
        ik < n_steps,
        jnp.logical_and(k_start < kv_len,
                        k_start <= q_start + block_q - 1))

    @pl.when(live)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)       # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)       # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qi = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                (block_q, block_k), 0)
        kj = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                (block_q, block_k), 1)
        mask = (kj <= qi) & (kj < kv_len)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_cur = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_cur)
        alpha = jnp.exp(m_prev - m_cur)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        m_ref[...] = m_cur
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v_ref[0, 0].astype(jnp.float32),
            preferred_element_type=jnp.float32)

    @pl.when(j == steps_per_split - 1)
    def _done():
        m_out[0, 0, 0] = m_ref[...][:, 0]
        l_out[0, 0, 0] = l_ref[...][:, 0]
        acc_out[0, 0, 0] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("block_q", "block_k",
                                             "num_splits", "scale",
                                             "interpret"))
def flash_paged_prefill(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                        page_table: jax.Array, start: jax.Array,
                        kv_len: jax.Array, *, block_q: int = 128,
                        block_k: int | None = None,
                        num_splits: int | None = None,
                        scale: float | None = None,
                        interpret: bool = False) -> jax.Array:
    """Chunked-prefill attention over a paged KV cache.

    q: (B, H, C, D) — one prompt chunk per sequence, first token at
    absolute position ``start[b]``; pools (P, Hkv, psz, D); ``page_table``
    (B, nblk) int32.  The chunk's KV must already be scattered into its
    pages (write-before-read, same contract as the oracle); ``kv_len``
    (B,) = ``start + chunk_len`` masks the valid key prefix.  Query rows
    attend causally at *absolute* positions, so a chunk sees the whole
    committed prefix plus its own lower triangle.

    The page table and both scalar vectors are scalar-prefetched: each
    grid step DMAs its (block_k, D) key tile straight from the owning
    physical page — the committed prefix never materialises densely.

    Performance parameters (the prefill region's run-time AT space):
    ``block_q`` tiles the chunk, ``block_k`` the split-K tile *within* a
    page (must divide ``page_size``; defaults to the whole page),
    ``num_splits`` the parallel split-KV degree (1 = sequential walk).
    """
    b, h, c, d = q.shape
    n_pages, hkv, psz, _ = k_pool.shape
    g = h // hkv
    nblk = page_table.shape[1]
    scale = float(scale if scale is not None else d ** -0.5)
    bq = min(block_q, c)
    pq = (-c) % bq
    if pq:                       # pad the chunk to a whole q tile; padded
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))   # rows discard
    cp = q.shape[2]
    bk, sub = _resolve_block_k(block_k, psz, "flash_paged_prefill")
    n_steps = nblk * sub
    nq = cp // bq
    ns, sps = _num_splits(num_splits, n_steps)
    if ns == 1:
        grid = (b, h, nq, n_steps)
        kernel = functools.partial(_paged_prefill_kernel, scale=scale,
                                   block_q=bq, block_k=bk, n_k=grid[3])
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, bq, d),
                             lambda bb, hh, iq, ik, tbl, st, ln:
                             (bb, hh, iq, 0)),
                pl.BlockSpec((1, 1, bk, d),
                             lambda bb, hh, iq, ik, tbl, st, ln, g=g, s=sub:
                             (tbl[bb, ik // s], hh // g, ik % s, 0)),
                pl.BlockSpec((1, 1, bk, d),
                             lambda bb, hh, iq, ik, tbl, st, ln, g=g, s=sub:
                             (tbl[bb, ik // s], hh // g, ik % s, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, bq, d),
                                   lambda bb, hh, iq, ik, tbl, st, ln:
                                   (bb, hh, iq, 0)),
            scratch_shapes=[pltpu.VMEM((bq, 1), jnp.float32),
                            pltpu.VMEM((bq, 1), jnp.float32),
                            pltpu.VMEM((bq, d), jnp.float32)],
        )
        out = pl.pallas_call(
            kernel,
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((b, h, cp, d), q.dtype),
            compiler_params=_CompilerParams(
                dimension_semantics=("parallel", "parallel", "parallel",
                                     "arbitrary")),
            interpret=interpret,
        )(page_table.astype(jnp.int32), start.astype(jnp.int32),
          kv_len.astype(jnp.int32), q, k_pool, v_pool)
        return out[:, :, :c, :]

    grid = (b, h, nq, ns, sps)
    kernel = functools.partial(_paged_prefill_split_kernel, scale=scale,
                               block_q=bq, block_k=bk, n_steps=n_steps,
                               steps_per_split=sps)

    def kv_idx(bb, hh, iq, isp, j, tbl, st, ln, g=g, s=sub, sp=sps,
               n=n_steps):
        ik = jnp.minimum(isp * sp + j, n - 1)
        return (tbl[bb, ik // s], hh // g, ik % s, 0)

    def row_idx(bb, hh, iq, isp, j, tbl, st, ln, ns=ns):
        return (bb, hh, iq * ns + isp, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d),
                         lambda bb, hh, iq, isp, j, tbl, st, ln:
                         (bb, hh, iq, 0)),
            pl.BlockSpec((1, 1, bk, d), kv_idx),
            pl.BlockSpec((1, 1, bk, d), kv_idx),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, bq), row_idx),
            pl.BlockSpec((1, 1, 1, bq), row_idx),
            pl.BlockSpec((1, 1, 1, bq, d),
                         lambda bb, hh, iq, isp, j, tbl, st, ln, ns=ns:
                         (bb, hh, iq * ns + isp, 0, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, d), jnp.float32)],
    )
    pm, pll, pacc = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, h, nq * ns, bq), jnp.float32),
            jax.ShapeDtypeStruct((b, h, nq * ns, bq), jnp.float32),
            jax.ShapeDtypeStruct((b, h, nq * ns, bq, d), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "parallel", "arbitrary")),
        interpret=interpret,
    )(page_table.astype(jnp.int32), start.astype(jnp.int32),
      kv_len.astype(jnp.int32), q, k_pool, v_pool)
    # fold (h, nq) into the combine's R axis; the nq*ns rows are laid out
    # q-tile-major so a plain reshape separates them
    out = _combine_splits(pm.reshape(b, h * nq, ns, bq),
                          pll.reshape(b, h * nq, ns, bq),
                          pacc.reshape(b, h * nq, ns, bq, d),
                          q.dtype, interpret)
    return out.reshape(b, h, cp, d)[:, :, :c, :]


# --------------------------------------------------------------------------
# quantized paged kernels: int8 pages + per-row fp32 scales, dequantized
# in-kernel into the same fp32 online-softmax accumulator path
# --------------------------------------------------------------------------


def _paged_decode_quant_kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref,
                               ks_ref, vs_ref, o_ref, m_ref, l_ref,
                               acc_ref, *, scale: float, block_k: int,
                               n_blk: int):
    b, ik = pl.program_id(0), pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_len = len_ref[b]
    k_start = ik * block_k

    @pl.when(k_start < kv_len)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)        # (G, d)
        # int8 tile * per-row scale -> fp32 keys; rest identical to the
        # fp kernel (the accumulator path never sees int8)
        k = k_ref[0, 0].astype(jnp.float32) * ks_ref[0, 0][:, None]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kj = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kj < kv_len, s, NEG_INF)
        m_prev = m_ref[...]
        m_cur = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_cur)
        alpha = jnp.exp(m_prev - m_cur)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        m_ref[...] = m_cur
        v = v_ref[0, 0].astype(jnp.float32) * vs_ref[0, 0][:, None]
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)

    @pl.when(ik == n_blk - 1)
    def _done():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def _paged_decode_split_quant_kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref,
                                     ks_ref, vs_ref, m_out, l_out, acc_out,
                                     m_ref, l_ref, acc_ref, *, scale: float,
                                     block_k: int, n_steps: int,
                                     steps_per_split: int):
    """Split-KV phase 1 over int8 pools — dequant stays in-kernel right
    next to the tile load, exactly as in the sequential quant kernel."""
    b = pl.program_id(0)
    isp, j = pl.program_id(2), pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_len = len_ref[b]
    ik = isp * steps_per_split + j
    k_start = ik * block_k

    @pl.when(jnp.logical_and(ik < n_steps, k_start < kv_len))
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)        # (G, d)
        k = k_ref[0, 0].astype(jnp.float32) * ks_ref[0, 0][:, None]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kj = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kj < kv_len, s, NEG_INF)
        m_prev = m_ref[...]
        m_cur = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_cur)
        alpha = jnp.exp(m_prev - m_cur)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        m_ref[...] = m_cur
        v = v_ref[0, 0].astype(jnp.float32) * vs_ref[0, 0][:, None]
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)

    @pl.when(j == steps_per_split - 1)
    def _done():
        m_out[0, 0, 0] = m_ref[...][:, 0]
        l_out[0, 0, 0] = l_ref[...][:, 0]
        acc_out[0, 0, 0] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("block_k", "num_splits",
                                             "scale", "interpret"))
def flash_paged_decode_quant(q: jax.Array, k_pool: jax.Array,
                             v_pool: jax.Array, k_scale: jax.Array,
                             v_scale: jax.Array, page_table: jax.Array,
                             kv_len: jax.Array, *,
                             block_k: int | None = None,
                             num_splits: int | None = None,
                             scale: float | None = None,
                             interpret: bool = False) -> jax.Array:
    """:func:`flash_paged_decode` over int8 pools.

    Pools (P, Hkv, psz, D) int8; ``k_scale``/``v_scale`` (P, Hkv, psz)
    fp32, one scale per (page, head, slot) row.  Scale tiles ride the
    same page-table-indexed BlockSpecs as their pools and the kernel
    dequantizes in VMEM right before the fp32 dot — the softmax
    accumulator path is byte-for-byte the fp kernel's.
    """
    b, h, one, d = q.shape
    n_pages, hkv, psz, _ = k_pool.shape
    assert one == 1
    g = h // hkv
    nblk = page_table.shape[1]
    scale = float(scale if scale is not None else d ** -0.5)
    bk, sub = _resolve_block_k(block_k, psz, "flash_paged_decode_quant")
    qg = q.reshape(b, hkv, g, d)
    n_steps = nblk * sub
    ns, sps = _num_splits(num_splits, n_steps)
    if ns == 1:
        grid = (b, hkv, n_steps)
        kernel = functools.partial(_paged_decode_quant_kernel, scale=scale,
                                   block_k=bk, n_blk=grid[2])
        pool_spec = pl.BlockSpec((1, 1, bk, d),
                                 lambda bb, hh, ik, tbl, ln, s=sub:
                                 (tbl[bb, ik // s], hh, ik % s, 0))
        scale_spec = pl.BlockSpec((1, 1, bk),
                                  lambda bb, hh, ik, tbl, ln, s=sub:
                                  (tbl[bb, ik // s], hh, ik % s))
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, g, d),
                             lambda bb, hh, ik, tbl, ln: (bb, hh, 0, 0)),
                pool_spec,
                pool_spec,
                scale_spec,
                scale_spec,
            ],
            out_specs=pl.BlockSpec((1, 1, g, d),
                                   lambda bb, hh, ik, tbl, ln:
                                   (bb, hh, 0, 0)),
            scratch_shapes=[pltpu.VMEM((g, 1), jnp.float32),
                            pltpu.VMEM((g, 1), jnp.float32),
                            pltpu.VMEM((g, d), jnp.float32)],
        )
        out = pl.pallas_call(
            kernel,
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
            compiler_params=_CompilerParams(
                dimension_semantics=("parallel", "parallel", "arbitrary")),
            interpret=interpret,
        )(page_table.astype(jnp.int32), kv_len.astype(jnp.int32),
          qg, k_pool, v_pool, k_scale, v_scale)
        return out.reshape(b, h, 1, d)

    grid = (b, hkv, ns, sps)
    kernel = functools.partial(_paged_decode_split_quant_kernel,
                               scale=scale, block_k=bk, n_steps=n_steps,
                               steps_per_split=sps)

    def kv_idx(bb, hh, isp, j, tbl, ln, s=sub, sp=sps, n=n_steps):
        ik = jnp.minimum(isp * sp + j, n - 1)
        return (tbl[bb, ik // s], hh, ik % s, 0)

    def sc_idx(bb, hh, isp, j, tbl, ln, s=sub, sp=sps, n=n_steps):
        ik = jnp.minimum(isp * sp + j, n - 1)
        return (tbl[bb, ik // s], hh, ik % s)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, d),
                         lambda bb, hh, isp, j, tbl, ln: (bb, hh, 0, 0)),
            pl.BlockSpec((1, 1, bk, d), kv_idx),
            pl.BlockSpec((1, 1, bk, d), kv_idx),
            pl.BlockSpec((1, 1, bk), sc_idx),
            pl.BlockSpec((1, 1, bk), sc_idx),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, g),
                         lambda bb, hh, isp, j, tbl, ln:
                         (bb, hh, isp, 0)),
            pl.BlockSpec((1, 1, 1, g),
                         lambda bb, hh, isp, j, tbl, ln:
                         (bb, hh, isp, 0)),
            pl.BlockSpec((1, 1, 1, g, d),
                         lambda bb, hh, isp, j, tbl, ln:
                         (bb, hh, isp, 0, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((g, 1), jnp.float32),
                        pltpu.VMEM((g, 1), jnp.float32),
                        pltpu.VMEM((g, d), jnp.float32)],
    )
    pm, pll, pacc = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, hkv, ns, g), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, ns, g), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, ns, g, d), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(page_table.astype(jnp.int32), kv_len.astype(jnp.int32),
      qg, k_pool, v_pool, k_scale, v_scale)
    out = _combine_splits(pm, pll, pacc, q.dtype, interpret)
    return out.reshape(b, h, 1, d)


def _paged_prefill_quant_kernel(tbl_ref, start_ref, len_ref, q_ref, k_ref,
                                v_ref, ks_ref, vs_ref, o_ref, m_ref, l_ref,
                                acc_ref, *, scale: float, block_q: int,
                                block_k: int, n_k: int):
    b, iq, ik = pl.program_id(0), pl.program_id(2), pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_len = len_ref[b]
    q_start = start_ref[b] + iq * block_q     # absolute pos of q row 0
    k_start = ik * block_k

    live = jnp.logical_and(k_start < kv_len,
                           k_start <= q_start + block_q - 1)

    @pl.when(live)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)       # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32) * ks_ref[0, 0][:, None]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qi = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                (block_q, block_k), 0)
        kj = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                (block_q, block_k), 1)
        mask = (kj <= qi) & (kj < kv_len)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_cur = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_cur)
        alpha = jnp.exp(m_prev - m_cur)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        m_ref[...] = m_cur
        v = v_ref[0, 0].astype(jnp.float32) * vs_ref[0, 0][:, None]
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)

    @pl.when(ik == n_k - 1)
    def _done():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def _paged_prefill_split_quant_kernel(tbl_ref, start_ref, len_ref, q_ref,
                                      k_ref, v_ref, ks_ref, vs_ref, m_out,
                                      l_out, acc_out, m_ref, l_ref,
                                      acc_ref, *, scale: float,
                                      block_q: int, block_k: int,
                                      n_steps: int, steps_per_split: int):
    """Split-KV phase 1 for prefill/verify over int8 pools."""
    b = pl.program_id(0)
    iq = pl.program_id(2)
    isp, j = pl.program_id(3), pl.program_id(4)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_len = len_ref[b]
    q_start = start_ref[b] + iq * block_q     # absolute pos of q row 0
    ik = isp * steps_per_split + j
    k_start = ik * block_k

    live = jnp.logical_and(
        ik < n_steps,
        jnp.logical_and(k_start < kv_len,
                        k_start <= q_start + block_q - 1))

    @pl.when(live)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)       # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32) * ks_ref[0, 0][:, None]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qi = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                (block_q, block_k), 0)
        kj = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                (block_q, block_k), 1)
        mask = (kj <= qi) & (kj < kv_len)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_cur = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_cur)
        alpha = jnp.exp(m_prev - m_cur)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        m_ref[...] = m_cur
        v = v_ref[0, 0].astype(jnp.float32) * vs_ref[0, 0][:, None]
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)

    @pl.when(j == steps_per_split - 1)
    def _done():
        m_out[0, 0, 0] = m_ref[...][:, 0]
        l_out[0, 0, 0] = l_ref[...][:, 0]
        acc_out[0, 0, 0] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("block_q", "block_k",
                                             "num_splits", "scale",
                                             "interpret"))
def flash_paged_prefill_quant(q: jax.Array, k_pool: jax.Array,
                              v_pool: jax.Array, k_scale: jax.Array,
                              v_scale: jax.Array, page_table: jax.Array,
                              start: jax.Array, kv_len: jax.Array, *,
                              block_q: int = 128,
                              block_k: int | None = None,
                              num_splits: int | None = None,
                              scale: float | None = None,
                              interpret: bool = False) -> jax.Array:
    """:func:`flash_paged_prefill` over int8 pools (verify rides this too).

    Same contract as the fp kernel plus ``k_scale``/``v_scale``
    (P, Hkv, psz) per-row fp32 scales, dequantized in VMEM ahead of the
    fp32 score/accumulate dots.
    """
    b, h, c, d = q.shape
    n_pages, hkv, psz, _ = k_pool.shape
    g = h // hkv
    nblk = page_table.shape[1]
    scale = float(scale if scale is not None else d ** -0.5)
    bq = min(block_q, c)
    pq = (-c) % bq
    if pq:                       # pad the chunk to a whole q tile; padded
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))   # rows discard
    cp = q.shape[2]
    bk, sub = _resolve_block_k(block_k, psz, "flash_paged_prefill_quant")
    n_steps = nblk * sub
    nq = cp // bq
    ns, sps = _num_splits(num_splits, n_steps)
    if ns == 1:
        grid = (b, h, nq, n_steps)
        kernel = functools.partial(_paged_prefill_quant_kernel, scale=scale,
                                   block_q=bq, block_k=bk, n_k=grid[3])
        pool_spec = pl.BlockSpec(
            (1, 1, bk, d),
            lambda bb, hh, iq, ik, tbl, st, ln, g=g, s=sub:
            (tbl[bb, ik // s], hh // g, ik % s, 0))
        scale_spec = pl.BlockSpec(
            (1, 1, bk),
            lambda bb, hh, iq, ik, tbl, st, ln, g=g, s=sub:
            (tbl[bb, ik // s], hh // g, ik % s))
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, bq, d),
                             lambda bb, hh, iq, ik, tbl, st, ln:
                             (bb, hh, iq, 0)),
                pool_spec,
                pool_spec,
                scale_spec,
                scale_spec,
            ],
            out_specs=pl.BlockSpec((1, 1, bq, d),
                                   lambda bb, hh, iq, ik, tbl, st, ln:
                                   (bb, hh, iq, 0)),
            scratch_shapes=[pltpu.VMEM((bq, 1), jnp.float32),
                            pltpu.VMEM((bq, 1), jnp.float32),
                            pltpu.VMEM((bq, d), jnp.float32)],
        )
        out = pl.pallas_call(
            kernel,
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((b, h, cp, d), q.dtype),
            compiler_params=_CompilerParams(
                dimension_semantics=("parallel", "parallel", "parallel",
                                     "arbitrary")),
            interpret=interpret,
        )(page_table.astype(jnp.int32), start.astype(jnp.int32),
          kv_len.astype(jnp.int32), q, k_pool, v_pool, k_scale, v_scale)
        return out[:, :, :c, :]

    grid = (b, h, nq, ns, sps)
    kernel = functools.partial(_paged_prefill_split_quant_kernel,
                               scale=scale, block_q=bq, block_k=bk,
                               n_steps=n_steps, steps_per_split=sps)

    def kv_idx(bb, hh, iq, isp, j, tbl, st, ln, g=g, s=sub, sp=sps,
               n=n_steps):
        ik = jnp.minimum(isp * sp + j, n - 1)
        return (tbl[bb, ik // s], hh // g, ik % s, 0)

    def sc_idx(bb, hh, iq, isp, j, tbl, st, ln, g=g, s=sub, sp=sps,
               n=n_steps):
        ik = jnp.minimum(isp * sp + j, n - 1)
        return (tbl[bb, ik // s], hh // g, ik % s)

    def row_idx(bb, hh, iq, isp, j, tbl, st, ln, ns=ns):
        return (bb, hh, iq * ns + isp, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d),
                         lambda bb, hh, iq, isp, j, tbl, st, ln:
                         (bb, hh, iq, 0)),
            pl.BlockSpec((1, 1, bk, d), kv_idx),
            pl.BlockSpec((1, 1, bk, d), kv_idx),
            pl.BlockSpec((1, 1, bk), sc_idx),
            pl.BlockSpec((1, 1, bk), sc_idx),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, bq), row_idx),
            pl.BlockSpec((1, 1, 1, bq), row_idx),
            pl.BlockSpec((1, 1, 1, bq, d),
                         lambda bb, hh, iq, isp, j, tbl, st, ln, ns=ns:
                         (bb, hh, iq * ns + isp, 0, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, d), jnp.float32)],
    )
    pm, pll, pacc = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, h, nq * ns, bq), jnp.float32),
            jax.ShapeDtypeStruct((b, h, nq * ns, bq), jnp.float32),
            jax.ShapeDtypeStruct((b, h, nq * ns, bq, d), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "parallel", "arbitrary")),
        interpret=interpret,
    )(page_table.astype(jnp.int32), start.astype(jnp.int32),
      kv_len.astype(jnp.int32), q, k_pool, v_pool, k_scale, v_scale)
    out = _combine_splits(pm.reshape(b, h * nq, ns, bq),
                          pll.reshape(b, h * nq, ns, bq),
                          pacc.reshape(b, h * nq, ns, bq, d),
                          q.dtype, interpret)
    return out.reshape(b, h, cp, d)[:, :, :c, :]


def attention_vmem_bytes(block_q: int, block_k: int, d: int,
                         bytes_per_el: int = 2) -> int:
    """Analytic VMEM footprint per grid step (CPU-side AT cost model)."""
    return (block_q * d + 2 * block_k * d) * bytes_per_el \
        + block_q * block_k * 4 + block_q * (d + 2) * 4
