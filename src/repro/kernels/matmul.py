"""Tiled MXU matmul Pallas kernel.

The framework's GEMM hot-spot.  Performance parameters (install-time AT):

* ``block_m``, ``block_n``, ``block_k`` — VMEM tile shape.  The MXU wants
  the contracting/lane dims in multiples of 128 and the sublane dim in
  multiples of 8, so the AT ``varied`` ranges are generated in
  hardware-aligned steps (see tuning/install.py), not 1..16 as in the
  paper's Fortran loops — this is the documented hardware adaptation of the
  paper's ``unroll`` PP.

Accumulation is fp32 in a VMEM scratch tile across the k grid dimension
(innermost), with an optional fused epilogue (bias add / gelu / silu /
residual) so XLA does not round-trip the tile through HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams around 0.5; accept both
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _epilogue(x: jax.Array, kind: str) -> jax.Array:
    if kind == "none":
        return x
    if kind == "gelu":
        return jax.nn.gelu(x)
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "relu":
        return jnp.maximum(x, 0)
    raise ValueError(f"unknown epilogue {kind!r}")


def _mm_kernel(x_ref, y_ref, o_ref, acc_ref, *, epilogue: str,
               n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], y_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _done():
        o_ref[...] = _epilogue(acc_ref[...], epilogue).astype(o_ref.dtype)


def _mm_bias_kernel(x_ref, y_ref, b_ref, o_ref, acc_ref, *, epilogue: str,
                    n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], y_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _done():
        o_ref[...] = _epilogue(acc_ref[...] + b_ref[...].astype(jnp.float32),
                               epilogue).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "block_m", "block_n", "block_k", "epilogue", "interpret", "out_dtype"))
def matmul(x: jax.Array, y: jax.Array, bias: jax.Array | None = None, *,
           block_m: int = 128, block_n: int = 128, block_k: int = 128,
           epilogue: str = "none", interpret: bool = False,
           out_dtype=None) -> jax.Array:
    """``x @ y (+ bias)`` with explicit VMEM tiling.

    Shapes: x (M, K), y (K, N), bias (N,) optional.  M/N/K need not divide
    the block sizes — blocks are clamped and the operands zero-padded.
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, (x.shape, y.shape)
    out_dtype = out_dtype or x.dtype
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)

    def pad(a, mults):
        pads = [(0, (-s) % mult) for s, mult in zip(a.shape, mults)]
        if any(p for _, p in pads):
            return jnp.pad(a, pads)
        return a

    xp, yp = pad(x, (bm, bk)), pad(y, (bk, bn))
    mp, kp = xp.shape
    np_ = yp.shape[1]
    grid = (mp // bm, np_ // bn, kp // bk)

    in_specs = [pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
                pl.BlockSpec((bk, bn), lambda i, j, l: (l, j))]
    args = [xp, yp]
    if bias is not None:
        in_specs.append(pl.BlockSpec((bn,), lambda i, j, l: (j,)))
        args.append(pad(bias, (bn,)))
        kernel = functools.partial(_mm_bias_kernel, epilogue=epilogue,
                                   n_k=grid[2])
    else:
        kernel = functools.partial(_mm_kernel, epilogue=epilogue,
                                   n_k=grid[2])

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*args)
    return out[:m, :n]


def matmul_vmem_bytes(block_m: int, block_n: int, block_k: int,
                      bytes_per_el: int = 2) -> int:
    """Analytic VMEM footprint of one grid step (the install-time AT cost
    model used on CPU where wall-clock is meaningless)."""
    return (block_m * block_k + block_k * block_n) * bytes_per_el \
        + block_m * block_n * 4 + block_m * block_n * bytes_per_el
