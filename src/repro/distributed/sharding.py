"""Parameter/activation sharding rules — the layout plans selected by
before-execute-time AT.

Plans (per arch x shape x mesh; the ``select according estimated`` targets):

* ``tp``     — tensor parallel: attention heads / FFN width / experts /
               vocab over the ``model`` axis, FSDP over ``data``.
* ``fsdp``   — fully-sharded only: every weight sharded over both axes'
               *first* dim where possible, activations replicated over
               ``model``.  The fallback when head counts do not divide the
               model axis (phi4 24H, llama4 40H).
* ``decode_seq`` — decode-time variant of ``tp`` that shards the KV-cache
               *sequence* over ``model`` (flash-decoding LSE merge happens
               inside XLA's partitioned softmax) — used when kv_heads do
               not divide the model axis (yi-6b kv=4) or the cache
               dominates memory.

The ``pod`` axis (multi-pod mesh) is pure data parallelism: batch sharded,
params replicated across pods, gradient all-reduce crossing the inter-pod
links (optionally int8-compressed, distributed/compression.py).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..configs.base import ArchConfig
from ..models.sharding_ctx import LayoutPlan

DATA_AXES_SINGLE = ("data",)
DATA_AXES_MULTI = ("pod", "data")


def batch_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def model_axis_size(mesh: Mesh) -> int:
    return mesh.shape["model"] if "model" in mesh.axis_names else 1


def make_serving_mesh(spec: str | None) -> Mesh | None:
    """Build the serving device mesh from an ``"RxC"`` flag value:
    R devices on the ``data`` axis x C on the tensor-parallel ``model``
    axis (``"1x4"`` = 4-way tensor parallelism).  ``None``/empty means no
    mesh — the unsharded engine.  A 1x1 mesh is accepted and behaves
    identically to no mesh (every dispatch site treats a 1-device model
    axis as the unsharded path), which is what keeps 1-device-mesh runs
    bit-identical and lets them reuse unsharded tuning winners."""
    if not spec:
        return None
    parts = [p for p in str(spec).lower().split("x") if p]
    try:
        dims = tuple(int(p) for p in parts)
    except ValueError:
        dims = ()
    if len(dims) != 2 or any(d < 1 for d in dims):
        raise ValueError(
            f"bad --mesh {spec!r}: expected 'RxC' (data x model), "
            f"e.g. '1x4'")
    r, c = dims
    devices = jax.devices()
    if r * c > len(devices):
        raise ValueError(
            f"--mesh {spec} needs {r * c} devices but only "
            f"{len(devices)} are available (set XLA_FLAGS="
            f"--xla_force_host_platform_device_count=N to emulate)")
    return Mesh(np.array(devices[:r * c]).reshape(r, c), ("data", "model"))


def _divisible(n: int, mesh: Mesh, axis: str = "model") -> bool:
    return axis in mesh.axis_names and n % mesh.shape[axis] == 0


def choose_plan_name(cfg: ArchConfig, kind: str, mesh: Mesh) -> str:
    """Heuristic default; the static-AT driver *searches* over plans and
    this is only the fallback when no tuning record exists."""
    m = model_axis_size(mesh)
    if kind == "decode" and cfg.ssm_version == 0:
        if not _divisible(cfg.n_kv_heads, mesh):
            return "decode_seq"
    if cfg.n_heads % m and cfg.d_ff and cfg.d_ff % m == 0:
        return "fsdp" if cfg.family in ("dense", "vlm") else "tp"
    return "tp"


def make_plan(cfg: ArchConfig, kind: str, mesh: Mesh,
              name: str | None = None, *, remat: str = "none",
              num_microbatches: int = 1) -> LayoutPlan:
    name = name or choose_plan_name(cfg, kind, mesh)
    dp = batch_axes(mesh)
    dpa = dp if len(dp) > 1 else dp[0]
    m = model_axis_size(mesh)
    specs: dict[str, P] = {"tokens": P(dpa, None)}
    if name == "tp":
        specs["hidden"] = P(dpa, None, None)
        if cfg.n_heads % m == 0:
            specs["heads"] = P(dpa, "model", None, None)
        if cfg.n_kv_heads % m == 0:
            specs["kv_heads"] = P(dpa, "model", None, None)
        specs["logits_hidden"] = P(dpa, None)
        specs["moe_experts"] = P("model", dpa, None, None)
    elif name == "fsdp":
        specs["hidden"] = P(dpa, None, None)
        specs["logits_hidden"] = P(dpa, None)
        specs["moe_experts"] = P("model", dpa, None, None)
    elif name == "decode_seq":
        specs["hidden"] = P(dpa, None, None)
        specs["logits_hidden"] = P(dpa, None)
        specs["moe_experts"] = P("model", dpa, None, None)
    elif name == "decode_resident":
        # weights live sharded over the model axis only (never re-gathered
        # per token); batch over data; cache seq over model when kv heads
        # do not divide
        specs["hidden"] = P(dpa, None, None)
        specs["logits_hidden"] = P(dpa, None)
        specs["moe_experts"] = P("model", dpa, None, None)
    return LayoutPlan(name=name, specs=specs, remat=remat,
                      num_microbatches=num_microbatches)


# --------------------------------------------------------------------------
# parameter shardings
# --------------------------------------------------------------------------


def _spec_for_param(path: str, shape: tuple, cfg: ArchConfig, plan: str,
                    mesh: Mesh) -> P:
    """PartitionSpec for one parameter, by name-path + shape."""
    m = model_axis_size(mesh)

    def ok(dim):
        return dim % m == 0

    stacked = path.startswith("layers/") or path.startswith("enc_layers/") \
        or path.startswith("dec_layers/")
    lead = (None,) if stacked else ()
    core = shape[1:] if stacked else shape

    def ps(*axes):
        return P(*(lead + axes))

    last = path.split("/")[-1]
    # embeddings / head: vocab over model, d over data
    if last in ("embed", "lm_head", "pos_embed"):
        v, d = shape
        if plan == "decode_resident":
            return P("model" if ok(v) else None, None)
        return P("model" if ok(v) else None, "data" if d % _data(mesh) == 0
                 else None)
    if plan == "decode_resident":
        # model-axis-only residency: shard ONE dim over model, never data
        if len(core) == 3 and "moe" in path:
            e = core[0]
            return ps("model" if ok(e) else None, None, None)
        if len(core) == 2:
            a, b = core
            if last in ("wo", "w_down", "out_proj") and ok(a):
                return ps("model", None)
            if ok(b):
                return ps(None, "model")
            if ok(a):
                return ps("model", None)
            return ps(None, None)
        return ps(*([None] * len(core)))
    if len(core) == 0:
        return ps()
    # MoE experts (E, d, f) / (E, f, d): experts over model, next over data
    if "moe" in path and last in ("w_gate", "w_up", "w_down") \
            and len(core) == 3:
        e, a, b = core
        return ps("model" if ok(e) else None,
                  "data" if a % _data(mesh) == 0 else None, None)
    if len(core) == 1:
        return ps(None)
    if len(core) == 2:
        a, b = core
        if plan == "fsdp":
            # shard the larger dim over the flattened (data, model) axes
            if a % (m * _data(mesh)) == 0:
                return ps(("data", "model"), None)
            if b % (m * _data(mesh)) == 0:
                return ps(None, ("data", "model"))
            return ps("data" if a % _data(mesh) == 0 else None, None)
        # tp / decode_seq: column-parallel then row-parallel by name
        if last in ("wq", "wk", "wv", "w_up", "w_gate", "x_proj", "in_proj",
                    "dt_proj", "router"):
            return ps("data" if a % _data(mesh) == 0 else None,
                      "model" if ok(b) else None)
        if last in ("wo", "w_down", "out_proj"):
            return ps("model" if ok(a) else None,
                      "data" if b % _data(mesh) == 0 else None)
        return ps("data" if a % _data(mesh) == 0 else None,
                  "model" if ok(b) else None)
    # conv weights (K, C) etc.
    if len(core) >= 2:
        axes = [None] * len(core)
        return ps(*axes)
    return ps()


def _data(mesh: Mesh) -> int:
    return mesh.shape["data"] if "data" in mesh.axis_names else 1


def _tree_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
        out.append(("/".join(parts), leaf))
    return out, treedef


def param_shardings(abstract_params, cfg: ArchConfig, plan: LayoutPlan,
                    mesh: Mesh):
    """NamedSharding pytree matching the params pytree."""
    flat, treedef = _tree_paths(abstract_params)
    shardings = []
    for path, leaf in flat:
        spec = _spec_for_param(path, leaf.shape, cfg, plan.name, mesh)
        # validate divisibility; drop axes that do not divide
        spec = _sanitize(spec, leaf.shape, mesh)
        shardings.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, shardings)


def layer_param_specs(abstract_params, cfg: ArchConfig, plan: LayoutPlan,
                      mesh: Mesh):
    """Per-layer (stack axis dropped) NamedShardings for the scan body."""
    if "layers" not in abstract_params:
        return None
    stacked = abstract_params["layers"]
    flat, treedef = _tree_paths(stacked)
    out = []
    for path, leaf in flat:
        spec = _spec_for_param("layers/" + path, leaf.shape, cfg, plan.name,
                               mesh)
        spec = _sanitize(spec, leaf.shape, mesh)
        out.append(NamedSharding(mesh, P(*spec[1:])))   # drop stack axis
    return jax.tree_util.tree_unflatten(treedef, out)


def _sanitize(spec: P, shape: tuple, mesh: Mesh) -> P:
    out = []
    for i, ax in enumerate(spec):
        if ax is None:
            out.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        size = int(np.prod([mesh.shape[a] for a in axes]))
        if i < len(shape) and shape[i] % size == 0:
            out.append(ax)
        else:
            out.append(None)
    while len(out) < len(shape):
        out.append(None)
    return P(*out[:len(shape)])


def cache_shardings(abstract_caches, cfg: ArchConfig, plan: LayoutPlan,
                    mesh: Mesh):
    """Shardings for decode caches (stacked (L, B, H, S, D) KV / SSM)."""
    dp = batch_axes(mesh)
    dpa = dp if len(dp) > 1 else dp[0]
    m = model_axis_size(mesh)
    flat, treedef = _tree_paths(abstract_caches)
    out = []
    for path, leaf in flat:
        shp = leaf.shape
        if len(shp) == 5:        # (L, B, Hkv, S, D) KV cache
            if plan.name == "decode_seq":
                spec = P(None, dpa, None, "model", None)
            elif plan.name == "decode_resident":
                spec = P(None, dpa,
                         "model" if shp[2] % m == 0 else None,
                         None if shp[2] % m == 0 else "model", None)
            else:
                spec = P(None, dpa,
                         "model" if shp[2] % m == 0 else None, None, None)
        elif len(shp) == 4:      # (L, B, H, N) / (L, B, d_inner, n) ssm h
            spec = P(None, dpa, "model" if shp[2] % m == 0 else None, None)
        elif len(shp) == 3:      # (L, B, conv...) etc.
            spec = P(None, dpa, None)
        elif len(shp) == 5 + 1:
            spec = P(*([None] * len(shp)))
        else:
            spec = P(*([None] * len(shp)))
        out.append(NamedSharding(mesh, _sanitize(spec, shp, mesh)))
    return jax.tree_util.tree_unflatten(treedef, out)


def batch_shardings(specs: dict, mesh: Mesh):
    """Shardings for the input batch dict (tokens/labels/frontend/...)."""
    dp = batch_axes(mesh)
    dpa = dp if len(dp) > 1 else dp[0]

    def one(leaf):
        spec = P(*((dpa,) + (None,) * (len(leaf.shape) - 1))) \
            if leaf.shape else P()
        return NamedSharding(mesh, _sanitize(spec, leaf.shape, mesh))

    return jax.tree.map(one, specs)
