from .compression import (compress_roundtrip_error, compressed_psum,
                          dequantize_int8, quantize_int8)
from .fault_tolerance import (HeartbeatMonitor, RemeshPlan,
                              StragglerWatchdog, plan_remesh)
from .ring_attention import make_ring_attention, ring_collective_bytes
from .sharding import (batch_shardings, cache_shardings, choose_plan_name,
                       layer_param_specs, make_plan, param_shardings)
__all__ = ["quantize_int8", "dequantize_int8", "compressed_psum",
           "compress_roundtrip_error", "HeartbeatMonitor", "RemeshPlan",
           "StragglerWatchdog", "plan_remesh", "make_plan",
           "param_shardings", "batch_shardings", "cache_shardings",
           "choose_plan_name", "layer_param_specs", "make_ring_attention",
           "ring_collective_bytes"]
