"""Fault tolerance: heartbeat failure detection, elastic re-mesh planning,
straggler mitigation.

This container has one real device, so the multi-host runtime is modelled
as an explicit, fully-tested state machine (the same objects a real
launcher would drive; the only stub is "who calls tick()"):

* :class:`HeartbeatMonitor` — hosts report heartbeats; a host silent for
  ``timeout_s`` is declared failed.
* :func:`plan_remesh` — given the surviving chip count, choose the largest
  spare-free production mesh (keeping the model axis intact, shrinking the
  data/pod axes), and emit the resharding plan: restore from the latest
  checkpoint with new shardings + rescale ``global_batch`` or grad-accum.
* :class:`StragglerWatchdog` — per-step wall-time EMA + z-score detector;
  persistent stragglers trigger the same remesh path (eject the slow
  host).  On real TPU fleets this reads per-host step barriers; here the
  observable is step_time(host) fed by the launcher.

Recovery sequence (train.py drives it):
  detect -> checkpoint-wait -> plan_remesh -> rebuild mesh ->
  restore(ckpt, new shardings) -> resume data at (step, new shard map).
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field


@dataclass
class HeartbeatMonitor:
    n_hosts: int
    timeout_s: float = 30.0
    last_seen: dict[int, float] = field(default_factory=dict)
    failed: set[int] = field(default_factory=set)

    def beat(self, host: int, now: float | None = None) -> None:
        self.last_seen[host] = time.monotonic() if now is None else now

    def tick(self, now: float | None = None) -> set[int]:
        """Returns newly-failed hosts."""
        now = time.monotonic() if now is None else now
        new = set()
        for h in range(self.n_hosts):
            if h in self.failed:
                continue
            seen = self.last_seen.get(h)
            if seen is None or now - seen > self.timeout_s:
                self.failed.add(h)
                new.add(h)
        return new

    @property
    def alive(self) -> list[int]:
        return [h for h in range(self.n_hosts) if h not in self.failed]


@dataclass(frozen=True)
class RemeshPlan:
    old_shape: tuple
    new_shape: tuple
    axis_names: tuple
    batch_scale: float       # new_global_batch / old
    resume_step: int
    note: str


def plan_remesh(old_shape: tuple, axis_names: tuple, surviving_chips: int,
                resume_step: int, model_axis: str = "model") -> RemeshPlan:
    """Largest spare-free mesh that keeps the model axis intact.

    The model axis carries sharded weights (resharding it is a full
    re-layout); the data/pod axes are pure DP and shrink freely.  The
    surviving chip count is rounded down to a multiple of the model axis,
    then the data axis takes the quotient (pod axis folds into data when a
    whole pod is lost).
    """
    sizes = dict(zip(axis_names, old_shape))
    m = sizes.get(model_axis, 1)
    usable = (surviving_chips // m) * m
    if usable < m:
        raise RuntimeError(
            f"cannot keep model axis of {m} with {surviving_chips} chips")
    data_total = usable // m
    old_data_total = math.prod(s for a, s in sizes.items()
                               if a != model_axis)
    if "pod" in sizes and data_total % sizes["pod"] == 0 \
            and data_total >= sizes["pod"]:
        new_shape = (sizes["pod"], data_total // sizes["pod"], m)
        names = ("pod", "data", model_axis)
        note = "kept pod axis"
    else:
        new_shape = (data_total, m)
        names = ("data", model_axis)
        note = "folded pod axis into data"
    return RemeshPlan(old_shape=tuple(old_shape), new_shape=new_shape,
                      axis_names=names,
                      batch_scale=data_total / old_data_total,
                      resume_step=resume_step, note=note)


@dataclass
class StragglerWatchdog:
    """Per-host step-time EMA + z-score detection."""

    n_hosts: int
    alpha: float = 0.1
    z_threshold: float = 3.0
    strikes_to_eject: int = 3
    ema: dict[int, float] = field(default_factory=dict)
    var: dict[int, float] = field(default_factory=dict)
    strikes: dict[int, int] = field(default_factory=dict)

    def observe(self, host: int, step_time: float) -> bool:
        """Record one step time; returns True if host should be ejected."""
        mu = self.ema.get(host, step_time)
        var = self.var.get(host, 0.0)
        fleet = [self.ema[h] for h in self.ema if h != host]
        fleet_mu = sum(fleet) / len(fleet) if fleet else mu
        fleet_sd = (sum((x - fleet_mu) ** 2 for x in fleet)
                    / len(fleet)) ** 0.5 if len(fleet) > 1 else 0.0
        is_straggling = fleet_sd > 0 and \
            (step_time - fleet_mu) / fleet_sd > self.z_threshold
        self.ema[host] = (1 - self.alpha) * mu + self.alpha * step_time
        self.var[host] = (1 - self.alpha) * var \
            + self.alpha * (step_time - mu) ** 2
        if is_straggling:
            self.strikes[host] = self.strikes.get(host, 0) + 1
        else:
            self.strikes[host] = 0
        return self.strikes.get(host, 0) >= self.strikes_to_eject
