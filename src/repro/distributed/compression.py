"""Gradient compression for slow inter-pod links.

int8 quantization with a per-tensor scale and deterministic stochastic
rounding, applied to the pod-axis gradient all-reduce (the 2-pod mesh's
cross-DCN hop; ~10x less ICI-equivalent traffic than fp32, 4x less than
bf16).  Error feedback (residual carry) keeps the scheme unbiased over
steps — the standard large-scale distributed-optimization trick.

Inside jit the quantize/dequantize pair wraps ``jax.lax.psum`` under
``shard_map`` over the ``pod`` axis; on a 1-device CPU run it reduces to a
local no-op quantize round-trip, which tests assert is within int8 error.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array, key: jax.Array | None = None):
    """Returns (q int8, scale f32).  Stochastic rounding when key given."""
    amax = jnp.max(jnp.abs(x)).astype(jnp.float32)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    y = x.astype(jnp.float32) / scale
    if key is not None:
        noise = jax.random.uniform(key, x.shape, jnp.float32, -0.5, 0.5)
        y = y + noise
    q = jnp.clip(jnp.round(y), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def quantize_int8_rows(x: jax.Array):
    """Per-row int8 quantization: one fp32 scale per last-axis row.

    Returns ``(q int8, scale f32)`` with ``scale.shape == x.shape[:-1]``.
    Row granularity is what the paged KV pool wants — each (page, head,
    slot) row quantizes independently, so a decode-step append or a COW
    page copy never forces a whole-page requantization.
    """
    amax = jnp.max(jnp.abs(x), axis=-1).astype(jnp.float32)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    y = x.astype(jnp.float32) / scale[..., None]
    q = jnp.clip(jnp.round(y), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8_rows(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale[..., None]


def compressed_psum(x: jax.Array, axis_name: str,
                    key: jax.Array | None = None) -> jax.Array:
    """int8 all-gather + local sum over ``axis_name``.

    An int8 all-reduce cannot psum in int8 (overflow); instead each member
    contributes its quantized tensor via all-gather and sums dequantized —
    for a pod axis of size 2-4 this is the right trade (wire bytes /4 vs
    bf16, accumulate in fp32).
    """
    q, scale = quantize_int8(x, key)
    qs = jax.lax.all_gather(q, axis_name)           # (pods, ...)
    ss = jax.lax.all_gather(scale, axis_name)
    return jnp.tensordot(ss.astype(jnp.float32),
                         qs.astype(jnp.float32), axes=((0,), (0,)))


def compress_roundtrip_error(x: jax.Array) -> jax.Array:
    """Quantization round-trip error (tests / telemetry)."""
    q, s = quantize_int8(x)
    return jnp.max(jnp.abs(dequantize_int8(q, s) - x.astype(jnp.float32)))


def compress_roundtrip_error_rows(x: jax.Array) -> jax.Array:
    """Per-row quantization round-trip error (tests / telemetry)."""
    q, s = quantize_int8_rows(x)
    return jnp.max(
        jnp.abs(dequantize_int8_rows(q, s) - x.astype(jnp.float32)))
