"""Ring sequence-parallel attention (shard_map + ppermute).

The `ring_sp` layout plan: Q/K/V are sharded over the *sequence* on the
``model`` axis; each device computes flash-style partial attention against
the KV block it currently holds, then passes the block around the ring —
overlapping the permute with the next chunk's compute on real hardware
(here: correctness + collective-volume accounting; the dry-run shows
``collective-permute`` ops of exactly one KV block per step instead of an
all-gather of the whole sequence).

This is the long-context-prefill alternative to ``tp`` when heads do not
divide the model axis or when S >> heads·d and KV residency dominates:
wire bytes per device = (n-1)/n × local KV vs a full KV all-gather, and
peak memory never exceeds one extra KV block.

Causal masking uses global positions derived from the ring step, so the
result is exactly ``attention_ref`` on the gathered sequence.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:                                       # jax < 0.6 export location
    from jax.experimental.shard_map import shard_map as _shard_map


def make_ring_attention(mesh: Mesh, axis_name: str = "model",
                        causal: bool = True, scale: float | None = None,
                        batch_axis: str | None = None):
    """Build the shard_map'd ring attention for a given mesh axis.

    ``batch_axis``: also shard the batch dim (e.g. "data") — without it the
    manual region replicates the batch across that axis.
    """
    n = mesh.shape[axis_name]
    perm = [(j, (j + 1) % n) for j in range(n)]
    if batch_axis is None and "data" in mesh.axis_names:
        batch_axis = "data"

    def body(q, k, v):
        b, h, s_loc, d = q.shape
        sc = float(scale if scale is not None else d ** -0.5)
        idx = jax.lax.axis_index(axis_name)
        q32 = q.astype(jnp.float32)
        # mark the carries device-varying (the scan produces per-shard
        # values; jax's vma type system requires matching carry types)
        axes = (axis_name,) if batch_axis is None \
            else (axis_name, batch_axis)
        if hasattr(jax.lax, "pcast"):
            mk = lambda x: jax.lax.pcast(x, axes, to="varying")
        else:       # jax < 0.6: no varying-manual-axes type system
            mk = lambda x: x
        m0 = mk(jnp.full((b, h, s_loc), -1e30, jnp.float32))
        l0 = mk(jnp.zeros((b, h, s_loc), jnp.float32))
        a0 = mk(jnp.zeros((b, h, s_loc, d), jnp.float32))

        def step(carry, i):
            m_prev, l_prev, acc, kc, vc = carry
            src = (idx - i) % n
            sco = jnp.einsum("bhqd,bhkd->bhqk", q32,
                             kc.astype(jnp.float32)) * sc
            if causal:
                qi = idx * s_loc + jnp.arange(s_loc)[:, None]
                kj = src * s_loc + jnp.arange(s_loc)[None, :]
                sco = jnp.where((qi >= kj)[None, None], sco, -1e30)
            m_cur = jnp.maximum(m_prev, sco.max(-1))
            p = jnp.exp(sco - m_cur[..., None])
            alpha = jnp.exp(m_prev - m_cur)
            l_cur = l_prev * alpha + p.sum(-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p, vc.astype(jnp.float32))
            kc = jax.lax.ppermute(kc, axis_name, perm)
            vc = jax.lax.ppermute(vc, axis_name, perm)
            return (m_cur, l_cur, acc, kc, vc), None

        (m, l, acc, _, _), _ = jax.lax.scan(
            step, (m0, l0, a0, k, v), jnp.arange(n))
        l = jnp.where(l == 0.0, 1.0, l)
        return (acc / l[..., None]).astype(q.dtype)

    spec = P(batch_axis, None, axis_name, None)
    return _shard_map(body, mesh=mesh, in_specs=(spec, spec, spec),
                         out_specs=spec)


def ring_collective_bytes(b: int, h: int, s: int, d: int, n: int,
                          bytes_per_el: int = 2) -> int:
    """Analytic wire bytes per device: (n-1) permutes of one local KV."""
    return 2 * b * h * (s // n) * d * bytes_per_el * (n - 1)
