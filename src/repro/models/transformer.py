"""Decoder-family LM assembly: dense / MoE / SSM / hybrid / VLM.

Layers are stacked along a leading axis and driven by ``lax.scan`` so HLO
size is O(1) in depth (the 1000+-node posture: an 81-layer zamba2 lowers to
the same program size as a 2-layer smoke model).  Remat policy and
activation shardings come from the active
:class:`~repro.models.sharding_ctx.LayoutPlan`.

Modes: ``forward`` (train/prefill), ``prefill`` (forward + KV/SSM state
collection), ``decode_step`` (one token, scanned over per-layer caches).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import attention as attn
from . import moe as moe_mod
from . import ssm as ssm_mod
from .layers import (chunked_cross_entropy, dense_init, embed_init,
                     layer_norm, rms_norm)
from .sharding_ctx import (constrain, constrain_layer_params,
                           current_plan)

# --------------------------------------------------------------------------
# config adapters
# --------------------------------------------------------------------------


def attn_config(cfg: ArchConfig, window: bool = True) -> attn.AttnConfig:
    return attn.AttnConfig(
        d_model=cfg.d_model, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        d_head=cfg.head_dim, rope_theta=cfg.rope_theta,
        window=cfg.window if window else None, causal=True,
        use_rope=cfg.use_rope, qkv_bias=cfg.qkv_bias)


def moe_config(cfg: ArchConfig) -> moe_mod.MoEConfig:
    return moe_mod.MoEConfig(
        d_model=cfg.d_model, d_ff=cfg.d_ff, n_experts=cfg.n_experts,
        top_k=cfg.top_k, n_shared_experts=cfg.n_shared_experts,
        capacity_factor=cfg.capacity_factor, group_size=cfg.moe_group_size)


def mamba1_config(cfg: ArchConfig) -> ssm_mod.Mamba1Config:
    return ssm_mod.Mamba1Config(cfg.d_model, cfg.ssm_d_inner, cfg.ssm_state)


def mamba2_config(cfg: ArchConfig) -> ssm_mod.Mamba2Config:
    return ssm_mod.Mamba2Config(
        cfg.d_model, cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_n_heads,
        cfg.ssm_head_dim, chunk=cfg.ssm_chunk)


def _norm(cfg: ArchConfig, p: dict, x: jax.Array, name: str) -> jax.Array:
    if cfg.norm == "ln":
        return layer_norm(x, p[f"{name}_w"], p[f"{name}_b"])
    return rms_norm(x, p[f"{name}_w"])


def _init_norm(cfg: ArchConfig, name: str, dtype) -> dict:
    p = {f"{name}_w": jnp.ones((cfg.d_model,), dtype)}
    if cfg.norm == "ln":
        p[f"{name}_b"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def _mlp_init(key, cfg: ArchConfig, dtype) -> dict:
    from .layers import init_mlp
    return init_mlp(key, cfg.d_model, cfg.d_ff, gated=True, dtype=dtype)


def _mlp_apply(p: dict, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    from .layers import apply_mlp
    return apply_mlp(p, x, cfg.act)


# --------------------------------------------------------------------------
# per-layer init / apply
# --------------------------------------------------------------------------


def init_layer(key, cfg: ArchConfig, kind: str, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 4)
    p: dict = {}
    if kind in ("attn_mlp", "attn_moe"):
        p.update(_init_norm(cfg, "norm1", dtype))
        p.update(_init_norm(cfg, "norm2", dtype))
        p["attn"] = attn.init_attention(ks[0], attn_config(cfg), dtype)
        if kind == "attn_mlp":
            p["mlp"] = _mlp_init(ks[1], cfg, dtype)
        else:
            p["moe"] = moe_mod.init_moe(ks[1], moe_config(cfg), dtype)
    elif kind == "mamba1":
        p.update(_init_norm(cfg, "norm1", dtype))
        p["ssm"] = ssm_mod.init_mamba1(ks[0], mamba1_config(cfg), dtype)
    elif kind == "mamba2":
        p.update(_init_norm(cfg, "norm1", dtype))
        p["ssm"] = ssm_mod.init_mamba2(ks[0], mamba2_config(cfg), dtype)
    else:
        raise ValueError(kind)
    return p


def apply_layer(p: dict, x: jax.Array, cfg: ArchConfig, kind: str):
    """Full-sequence layer application.  Returns (x, aux_loss)."""
    aux = jnp.float32(0.0)
    if kind in ("attn_mlp", "attn_moe"):
        h = attn.full(p["attn"], _norm(cfg, p, x, "norm1"), attn_config(cfg))
        x = x + h
        h2 = _norm(cfg, p, x, "norm2")
        if kind == "attn_mlp":
            x = x + _mlp_apply(p["mlp"], h2, cfg)
        else:
            out, aux = moe_mod.apply_moe(p["moe"], h2, moe_config(cfg))
            x = x + out
    elif kind == "mamba1":
        x = x + ssm_mod.apply_mamba1(p["ssm"], _norm(cfg, p, x, "norm1"),
                                     mamba1_config(cfg))
    elif kind == "mamba2":
        x = x + ssm_mod.apply_mamba2(p["ssm"], _norm(cfg, p, x, "norm1"),
                                     mamba2_config(cfg))
    else:
        raise ValueError(kind)
    return constrain(x, "hidden"), aux


# --------------------------------------------------------------------------
# model init
# --------------------------------------------------------------------------


def init_params(key, cfg: ArchConfig) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    kinds = cfg.layer_kinds()
    kind = kinds[0]            # decoder stacks are homogeneous per family
    ks = jax.random.split(key, 8)
    layer_keys = jax.random.split(ks[0], cfg.n_layers)
    layers = jax.vmap(lambda k: init_layer(k, cfg, kind, dtype))(layer_keys)
    p: dict = {
        "embed": embed_init(ks[1], cfg.padded_vocab, cfg.d_model, dtype),
        "layers": layers,
    }
    p.update(_init_norm(cfg, "final_norm", dtype))
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[2], cfg.d_model, cfg.padded_vocab,
                                  dtype)
    if cfg.attn_period > 0:    # zamba2 shared attention block
        p["shared_attn"] = {
            "attn": attn.init_attention(ks[3], attn_config(cfg), dtype),
            "mlp": _mlp_init(ks[4], cfg, dtype),
            **_init_norm(cfg, "norm1", dtype),
            **_init_norm(cfg, "norm2", dtype),
        }
    if cfg.frontend != "none":
        p["frontend_proj"] = dense_init(ks[5], cfg.d_model, cfg.d_model,
                                        dtype)
    return p


def _compute(x, cfg: ArchConfig):
    return x.astype(jnp.dtype(cfg.compute_dtype))


def _cast_tree(p, cfg: ArchConfig):
    dt = jnp.dtype(cfg.compute_dtype)
    return jax.tree.map(
        lambda a: a.astype(dt) if jnp.issubdtype(a.dtype, jnp.floating)
        else a, p)


def _shared_attn_block(p: dict, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    h = attn.full(p["attn"], _norm(cfg, p, x, "norm1"), attn_config(cfg))
    x = x + h
    x = x + _mlp_apply(p["mlp"], _norm(cfg, p, x, "norm2"), cfg)
    return x


def _remat_wrap(fn):
    plan = current_plan()
    policy = plan.remat if plan is not None else "none"
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)   # full


# --------------------------------------------------------------------------
# forward (train / prefill)
# --------------------------------------------------------------------------


def embed_tokens(params: dict, tokens: jax.Array, cfg: ArchConfig,
                 frontend_embeds: jax.Array | None = None) -> jax.Array:
    x = jnp.take(params["embed"], tokens, axis=0)
    x = _compute(x, cfg)
    if frontend_embeds is not None:
        fe = _compute(frontend_embeds, cfg) @ _compute(
            params["frontend_proj"], cfg)
        x = jnp.concatenate([fe, x], axis=1)
    return constrain(x, "hidden")


def forward(params: dict, tokens: jax.Array, cfg: ArchConfig,
            frontend_embeds: jax.Array | None = None):
    """tokens (B, S) -> (hidden (B, S', d), aux_loss)."""
    x = embed_tokens(params, tokens, cfg, frontend_embeds)
    kind = cfg.layer_kinds()[0]
    shared = _cast_tree(params.get("shared_attn"), cfg) \
        if cfg.attn_period > 0 else None
    # cast the stacked weights ONCE, before the scan: the FSDP all-gather
    # then moves bf16 (2 bytes) instead of fp32 — half the wire bytes
    layers_c = _cast_tree(params["layers"], cfg)

    def body(carry, scanned):
        x, aux, idx = carry
        lp = constrain_layer_params(scanned)
        x, a = apply_layer(lp, x, cfg, kind)
        if shared is not None:
            x = jax.lax.cond(
                (idx + 1) % cfg.attn_period == 0,
                lambda v: _shared_attn_block(shared, v, cfg),
                lambda v: v, x)
        return (x, aux + a, idx + 1), None

    body = _remat_wrap(body)
    (x, aux, _), _ = jax.lax.scan(
        body, (x, jnp.float32(0.0), jnp.int32(0)), layers_c)
    x = _norm(cfg, _cast_tree(
        {k: params[k] for k in params if k.startswith("final_norm")}, cfg),
        x, "final_norm")
    return x, aux


def lm_head_weight(params: dict, cfg: ArchConfig) -> jax.Array:
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return w


def train_loss(params: dict, batch: dict, cfg: ArchConfig,
               n_loss_chunks: int | None = None) -> jax.Array:
    """batch: tokens (B, S), labels (B, S), optional loss_mask,
    frontend_embeds."""
    if n_loss_chunks is None:
        plan = current_plan()
        n_loss_chunks = plan.loss_chunks if plan is not None else 8
    hidden, aux = forward(params, batch["tokens"], cfg,
                          batch.get("frontend_embeds"))
    fs = hidden.shape[1] - batch["labels"].shape[1]
    if fs > 0:                   # vlm/audio prefix carries no loss
        hidden = hidden[:, fs:]
    b, s, d = hidden.shape
    hidden = constrain(hidden.reshape(b * s, d), "logits_hidden")
    labels = batch["labels"].reshape(-1)
    mask = batch.get("loss_mask")
    mask = mask.reshape(-1).astype(jnp.float32) if mask is not None else None
    w = _compute(lm_head_weight(params, cfg), cfg)
    loss = chunked_cross_entropy(hidden, w, labels, mask,
                                 n_chunks=n_loss_chunks)
    return loss + aux


# --------------------------------------------------------------------------
# decode: caches + one-token step
# --------------------------------------------------------------------------


def init_caches(cfg: ArchConfig, batch: int, max_len: int,
                dtype=None) -> Any:
    """Stacked per-layer decode state (KV in cfg.cache_dtype)."""
    dtype = jnp.dtype(cfg.cache_dtype) if dtype is None else dtype
    kind = cfg.layer_kinds()[0]
    l = cfg.n_layers

    def stack(tree):
        return jax.tree.map(lambda a: jnp.broadcast_to(
            a, (l,) + a.shape).copy(), tree)

    caches: dict = {}
    if kind in ("attn_mlp", "attn_moe"):
        k, v = attn.init_cache(batch, attn_config(cfg), max_len, dtype)
        caches["kv"] = (stack(k), stack(v))
    elif kind == "mamba1":
        caches["ssm"] = stack(ssm_mod.init_mamba1_state(
            batch, mamba1_config(cfg)))
    elif kind == "mamba2":
        caches["ssm"] = stack(ssm_mod.init_mamba2_state(
            batch, mamba2_config(cfg)))
    if cfg.attn_period > 0:
        napp = cfg.n_shared_attn_applications()
        k, v = attn.init_cache(batch, attn_config(cfg), max_len, dtype)
        caches["shared_kv"] = (
            jax.tree.map(lambda a: jnp.broadcast_to(
                a, (napp,) + a.shape).copy(), k),
            jax.tree.map(lambda a: jnp.broadcast_to(
                a, (napp,) + a.shape).copy(), v))
    return caches


def supports_paged_cache(cfg: ArchConfig) -> bool:
    """Paged KV applies to plain attention stacks: SSM state is O(1) and
    SWA ring buffers are already O(window), so neither benefits from
    paging; the zamba2 shared-attn block would need a second page space."""
    return (cfg.layer_kinds()[0] in ("attn_mlp", "attn_moe")
            and cfg.attn_period == 0 and cfg.window is None)


def init_paged_caches(cfg: ArchConfig, n_pages: int, page_size: int,
                      dtype=None, quantized: bool = False) -> Any:
    """Layer-stacked physical page pools: ``kv`` = (L, P, Hkv, psz, Dh) x2.

    Unlike :func:`init_caches` this allocates O(n_pages * page_size)
    tokens of KV *total*, not O(batch * max_len) — lanes borrow pages from
    the shared pool via their page tables.

    ``quantized=True`` stores the pools as int8 and adds a ``kv_scale``
    entry — (L, P, Hkv, psz) fp32 per-row scales for k and v.  The scale
    arrays keep the page axis at position 1 (after the layer stack), so
    every page-indexed treemap over the caches (COW copies, swap
    gather/scatter) applies to scales unchanged.
    """
    if not supports_paged_cache(cfg):
        raise ValueError(
            f"arch {cfg.name!r} does not support the paged KV cache "
            "(needs a plain attention stack: no SSM/SWA/shared-attn)")
    l = cfg.n_layers
    stack = lambda a: jnp.broadcast_to(a, (l,) + a.shape).copy()
    if quantized:
        k, v = attn.init_paged_pool(n_pages, attn_config(cfg), page_size,
                                    jnp.int8)
        ks, vs = attn.init_paged_scales(n_pages, attn_config(cfg), page_size)
        return {"kv": (stack(k), stack(v)),
                "kv_scale": (stack(ks), stack(vs))}
    dtype = jnp.dtype(cfg.cache_dtype) if dtype is None else dtype
    k, v = attn.init_paged_pool(n_pages, attn_config(cfg), page_size, dtype)
    return {"kv": (stack(k), stack(v))}


def _paged_out_caches(new_states: dict) -> dict:
    """Scan outputs -> cache dict (kv, plus kv_scale for int8 pools)."""
    out = {"kv": new_states["kv"]}
    if "kv_scale" in new_states:
        out["kv_scale"] = new_states["kv_scale"]
    return out


def paged_decode_step(params: dict, caches: Any, page_table: jax.Array,
                      token: jax.Array, pos: jax.Array, cfg: ArchConfig,
                      mesh=None):
    """One decode step over paged caches.

    token (B, 1) int32, pos (B,) int32, page_table (B, nblk) int32 shared
    by every layer (one logical->physical mapping per sequence; each layer
    has its own physical pool).  Returns (logits (B, V), caches).
    """
    x = jnp.take(params["embed"], token, axis=0)
    x = _compute(x, cfg)
    kind = cfg.layer_kinds()[0]
    acfg = attn_config(cfg)

    def body(carry, scanned):
        x, = carry
        lp = scanned["params"]
        kp, vp = scanned["kv"]
        scales = scanned.get("kv_scale")
        h, kp, vp, scales = attn.paged_decode(
            lp["attn"], _norm(cfg, lp, x, "norm1"),
            kp, vp, page_table, pos, acfg, scales, mesh=mesh)
        x = x + h
        h2 = _norm(cfg, lp, x, "norm2")
        if kind == "attn_mlp":
            x = x + _mlp_apply(lp["mlp"], h2, cfg)
        else:
            out, _ = moe_mod.apply_moe(lp["moe"], h2, moe_config(cfg))
            x = x + out
        states = {"kv": (kp, vp)}
        if scales is not None:
            states["kv_scale"] = scales
        return (x,), states

    scanned_in = {"params": _cast_tree(params["layers"], cfg),
                  "kv": caches["kv"]}
    if "kv_scale" in caches:        # int8 pools: thread the scales too
        scanned_in["kv_scale"] = caches["kv_scale"]
    (x,), new_states = jax.lax.scan(body, (x,), scanned_in)
    x = _norm(cfg, _cast_tree(
        {k: params[k] for k in params if k.startswith("final_norm")}, cfg),
        x, "final_norm")
    w = _compute(lm_head_weight(params, cfg), cfg)
    logits = (x[:, 0] @ w).astype(jnp.float32)
    return logits, _paged_out_caches(new_states)


def paged_prefill_step(params: dict, caches: Any, page_table: jax.Array,
                       tokens: jax.Array, start: jax.Array,
                       kv_len: jax.Array, logit_idx: jax.Array,
                       cfg: ArchConfig, mesh=None):
    """One prompt *chunk* of prefill over paged caches.

    tokens (B, C) int32 — a fixed-size chunk (pad the ragged tail; padded
    positions are masked by ``kv_len`` and their KV lands in the null
    page), start (B,) int32 — absolute position of the chunk's first
    token, kv_len (B,) = start + valid chunk length, page_table (B, nblk)
    shared by every layer.  ``logit_idx`` (B,) selects the chunk row whose
    logits are returned — the last valid prompt token on the final chunk
    (what seeds decode); earlier chunks' logits are discarded by the
    caller.  Returns (logits (B, V), caches).
    """
    x = jnp.take(params["embed"], tokens, axis=0)
    x = _compute(x, cfg)
    kind = cfg.layer_kinds()[0]
    acfg = attn_config(cfg)

    def body(carry, scanned):
        x, = carry
        lp = scanned["params"]
        kp, vp = scanned["kv"]
        scales = scanned.get("kv_scale")
        h, kp, vp, scales = attn.paged_prefill(
            lp["attn"], _norm(cfg, lp, x, "norm1"),
            kp, vp, page_table, start, kv_len, acfg, scales, mesh=mesh)
        x = x + h
        h2 = _norm(cfg, lp, x, "norm2")
        if kind == "attn_mlp":
            x = x + _mlp_apply(lp["mlp"], h2, cfg)
        else:
            out, _ = moe_mod.apply_moe(lp["moe"], h2, moe_config(cfg))
            x = x + out
        states = {"kv": (kp, vp)}
        if scales is not None:
            states["kv_scale"] = scales
        return (x,), states

    scanned_in = {"params": _cast_tree(params["layers"], cfg),
                  "kv": caches["kv"]}
    if "kv_scale" in caches:
        scanned_in["kv_scale"] = caches["kv_scale"]
    (x,), new_states = jax.lax.scan(body, (x,), scanned_in)
    x = _norm(cfg, _cast_tree(
        {k: params[k] for k in params if k.startswith("final_norm")}, cfg),
        x, "final_norm")
    x_last = jnp.take_along_axis(
        x, logit_idx[:, None, None].astype(jnp.int32), axis=1)[:, 0]
    w = _compute(lm_head_weight(params, cfg), cfg)
    logits = (x_last @ w).astype(jnp.float32)
    return logits, _paged_out_caches(new_states)


def speculative_step(params: dict, caches: Any, page_table: jax.Array,
                     tokens: jax.Array, start: jax.Array,
                     kv_len: jax.Array, cfg: ArchConfig, mesh=None):
    """Speculative *verify* step: score every chunk position in one call.

    tokens (B, C) int32 — ``[last committed token, draft_1 .. draft_k]``
    at absolute positions ``start .. start + C - 1`` (rows at positions
    ``>= kv_len`` are padding; their KV routes to the null page), start /
    kv_len (B,) int32, page_table (B, nblk) shared by every layer.  The
    attention math is exactly chunked prefill (committed prefix + the
    chunk's causal triangle at absolute positions) dispatched through the
    ``verify``-tuned kernel entry; unlike :func:`paged_prefill_step` the
    *full* (B, C, V) logits come back — the accept/reject rule needs the
    target distribution at every drafted position, not just the last one.
    Returns (logits (B, C, V) float32, caches).
    """
    x = jnp.take(params["embed"], tokens, axis=0)
    x = _compute(x, cfg)
    kind = cfg.layer_kinds()[0]
    acfg = attn_config(cfg)

    def body(carry, scanned):
        x, = carry
        lp = scanned["params"]
        kp, vp = scanned["kv"]
        scales = scanned.get("kv_scale")
        h, kp, vp, scales = attn.paged_verify(
            lp["attn"], _norm(cfg, lp, x, "norm1"),
            kp, vp, page_table, start, kv_len, acfg, scales, mesh=mesh)
        x = x + h
        h2 = _norm(cfg, lp, x, "norm2")
        if kind == "attn_mlp":
            x = x + _mlp_apply(lp["mlp"], h2, cfg)
        else:
            out, _ = moe_mod.apply_moe(lp["moe"], h2, moe_config(cfg))
            x = x + out
        states = {"kv": (kp, vp)}
        if scales is not None:
            states["kv_scale"] = scales
        return (x,), states

    scanned_in = {"params": _cast_tree(params["layers"], cfg),
                  "kv": caches["kv"]}
    if "kv_scale" in caches:
        scanned_in["kv_scale"] = caches["kv_scale"]
    (x,), new_states = jax.lax.scan(body, (x,), scanned_in)
    x = _norm(cfg, _cast_tree(
        {k: params[k] for k in params if k.startswith("final_norm")}, cfg),
        x, "final_norm")
    w = _compute(lm_head_weight(params, cfg), cfg)
    logits = (x @ w).astype(jnp.float32)
    return logits, _paged_out_caches(new_states)


def slice_draft_params(params: dict, cfg: ArchConfig,
                       draft_cfg: ArchConfig) -> dict:
    """Self-speculative draft parameters: the target's leading layers.

    Slices the layer-stacked leaves down to ``draft_cfg.n_layers`` and
    shares the embedding / final norm / head, so the draft is the target
    with its tail layers skipped (Draft&Verify-style self-speculation).
    Requires an identical width (``draft_config(width_frac=1.0)``) —
    a narrower draft has its own embedding geometry and must be trained
    (initialised) independently instead.
    """
    if (draft_cfg.d_model, draft_cfg.n_heads, draft_cfg.head_dim) != \
            (cfg.d_model, cfg.n_heads, cfg.head_dim):
        raise ValueError(
            "slice_draft_params needs a same-width draft config; "
            "width-reduced drafts take independently initialised params")
    if draft_cfg.n_layers > cfg.n_layers:
        raise ValueError("draft is deeper than the target")
    out = dict(params)
    out["layers"] = jax.tree.map(lambda a: a[:draft_cfg.n_layers],
                                 params["layers"])
    return out


def decode_step(params: dict, caches: Any, token: jax.Array,
                pos: jax.Array, cfg: ArchConfig):
    """token (B, 1) int32, pos (B,) int32 -> (logits (B, V), caches)."""
    x = jnp.take(params["embed"], token, axis=0)
    x = _compute(x, cfg)
    kind = cfg.layer_kinds()[0]
    shared = _cast_tree(params.get("shared_attn"), cfg) \
        if cfg.attn_period > 0 else None
    acfg = attn_config(cfg)

    shared_kv = caches.get("shared_kv")

    def body(carry, scanned):
        x, idx, skv = carry
        lp = scanned["params"]
        if kind in ("attn_mlp", "attn_moe"):
            ck, cv = scanned["kv"]
            h, ck, cv = attn.decode(lp["attn"], _norm(cfg, lp, x, "norm1"),
                                    ck, cv, pos, acfg)
            x = x + h
            h2 = _norm(cfg, lp, x, "norm2")
            if kind == "attn_mlp":
                x = x + _mlp_apply(lp["mlp"], h2, cfg)
            else:
                out, _ = moe_mod.apply_moe(lp["moe"], h2, moe_config(cfg))
                x = x + out
            new_state = {"kv": (ck, cv)}
        elif kind == "mamba1":
            h, st = ssm_mod.step_mamba1(lp["ssm"],
                                        _norm(cfg, lp, x, "norm1"),
                                        scanned["ssm"], mamba1_config(cfg))
            x = x + h
            new_state = {"ssm": st}
        else:
            h, st = ssm_mod.step_mamba2(lp["ssm"],
                                        _norm(cfg, lp, x, "norm1"),
                                        scanned["ssm"], mamba2_config(cfg))
            x = x + h
            new_state = {"ssm": st}
        if shared is not None:
            app_idx = (idx + 1) // cfg.attn_period - 1

            def apply_shared(operand):
                x, skv = operand
                k_all, v_all = skv
                ck = jax.tree.map(lambda a: a[app_idx], k_all)
                cv = jax.tree.map(lambda a: a[app_idx], v_all)
                h, ck, cv = attn.decode(shared["attn"],
                                        _norm(cfg, shared, x, "norm1"),
                                        ck, cv, pos, acfg)
                x = x + h
                x = x + _mlp_apply(shared["mlp"],
                                   _norm(cfg, shared, x, "norm2"), cfg)
                k_all = jax.tree.map(
                    lambda a, b: jax.lax.dynamic_update_index_in_dim(
                        a, b.astype(a.dtype), app_idx, 0), k_all, ck)
                v_all = jax.tree.map(
                    lambda a, b: jax.lax.dynamic_update_index_in_dim(
                        a, b.astype(a.dtype), app_idx, 0), v_all, cv)
                return x, (k_all, v_all)

            x, skv = jax.lax.cond(
                (idx + 1) % cfg.attn_period == 0, apply_shared,
                lambda op: op, (x, skv))
        return (x, idx + 1, skv), new_state

    scanned_in = {"params": _cast_tree(params["layers"], cfg)}
    for key in ("kv", "ssm"):
        if key in caches:
            scanned_in[key] = caches[key]
    (x, _, shared_kv), new_states = jax.lax.scan(
        body, (x, jnp.int32(0), shared_kv), scanned_in)
    x = _norm(cfg, _cast_tree(
        {k: params[k] for k in params if k.startswith("final_norm")}, cfg),
        x, "final_norm")
    w = _compute(lm_head_weight(params, cfg), cfg)
    logits = (x[:, 0] @ w).astype(jnp.float32)
    new_caches = dict(new_states)
    if shared_kv is not None:
        new_caches["shared_kv"] = shared_kv
    return logits, new_caches


def prefill(params: dict, tokens: jax.Array, cfg: ArchConfig,
            frontend_embeds: jax.Array | None = None,
            max_len: int | None = None, mesh=None):
    """Forward over the prompt; returns (last-token logits, caches).

    Attention layers collect KV for the whole prompt; SSM layers collect the
    final recurrent state.  Sliding-window archs keep only the last W keys
    (ring-buffer layout, slot = pos % W).  ``max_len`` sizes the returned
    KV caches (>= prompt length) so decode steps have room to append —
    without it the cache is exactly prompt-sized and the *next* token's KV
    would be dropped.  ``mesh`` routes long causal prompts through the
    ring sequence-parallel attention tail (see
    :func:`repro.kernels.ops.attention`).
    """
    x = embed_tokens(params, tokens, cfg, frontend_embeds)
    b, s, _ = x.shape
    kind = cfg.layer_kinds()[0]
    acfg = attn_config(cfg)
    shared = _cast_tree(params.get("shared_attn"), cfg) \
        if cfg.attn_period > 0 else None
    w = acfg.window
    max_len = max(max_len or s, s)
    cache_len = min(max_len, w) if w is not None else max_len
    cdt = jnp.dtype(cfg.cache_dtype)

    def kv_out(k, v):
        if s > cache_len:
            # ring-buffer layout: slot = pos % W must match decode's indexing
            start = s - cache_len
            k, v = k[:, :, -cache_len:], v[:, :, -cache_len:]
            shift = start % cache_len
            k = jnp.roll(k, shift, axis=2)
            v = jnp.roll(v, shift, axis=2)
        elif s < cache_len:   # room for decode appends (slot = pos [% W])
            pad = ((0, 0), (0, 0), (0, cache_len - s), (0, 0))
            k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        return k.astype(cdt), v.astype(cdt)

    def body(carry, scanned):
        x, aux, idx = carry
        lp = scanned
        ys = {}
        if kind in ("attn_mlp", "attn_moe"):
            h, (k, v) = attn.full(lp["attn"], _norm(cfg, lp, x, "norm1"),
                                  acfg, return_cache=True, mesh=mesh)
            ys["kv"] = kv_out(k, v)
            x = x + h
            h2 = _norm(cfg, lp, x, "norm2")
            if kind == "attn_mlp":
                x = x + _mlp_apply(lp["mlp"], h2, cfg)
            else:
                out, a = moe_mod.apply_moe(lp["moe"], h2, moe_config(cfg))
                x = x + out
                aux = aux + a
        elif kind in ("mamba1", "mamba2"):
            mcfg = mamba1_config(cfg) if kind == "mamba1" \
                else mamba2_config(cfg)
            appf = ssm_mod.apply_mamba1 if kind == "mamba1" \
                else ssm_mod.apply_mamba2
            xin = _norm(cfg, lp, x, "norm1")
            # the chunked scan hands back the exact final recurrent state
            # (§Perf: this replaced an O(S)-sequential replay that cost
            # 32768 tiny psums per layer at prefill_32k)
            h, st = appf(lp["ssm"], xin, mcfg, return_state=True)
            x = x + h
            ys["ssm"] = st
        if shared is not None:
            def app(v):
                xin = _norm(cfg, shared, v, "norm1")
                h, (k, vv) = attn.full(shared["attn"], xin, acfg,
                                       return_cache=True)
                v = v + h
                v = v + _mlp_apply(shared["mlp"],
                                   _norm(cfg, shared, v, "norm2"), cfg)
                return v, kv_out(k, vv)

            def noapp(v):
                zk = jnp.zeros((b, acfg.n_kv_heads, cache_len,
                                acfg.d_head), cdt)
                return v, (zk, zk)

            is_app = (idx + 1) % cfg.attn_period == 0
            x, skv = jax.lax.cond(is_app, app, noapp, x)
            ys["shared_kv_all"] = skv
            ys["is_app"] = is_app.astype(jnp.float32)
        return (x, aux, idx + 1), ys

    (x, aux, _), states = jax.lax.scan(
        body, (x, jnp.float32(0.0), jnp.int32(0)),
        _cast_tree(params["layers"], cfg))

    caches = dict(states) if states else {}
    if shared is not None:
        # compact (L, ...) zero-padded shared KV down to (n_apps, ...)
        is_app = caches.pop("is_app")
        kv_all = caches.pop("shared_kv_all")
        napp = cfg.n_shared_attn_applications()
        idxs = jnp.cumsum(is_app.astype(jnp.int32)) - 1
        sel = jnp.zeros((napp, cfg.n_layers), jnp.float32)
        sel = sel.at[idxs, jnp.arange(cfg.n_layers)].set(is_app)
        caches["shared_kv"] = (
            jnp.einsum("al,l...->a...", sel,
                       kv_all[0].astype(jnp.float32)).astype(cdt),
            jnp.einsum("al,l...->a...", sel,
                       kv_all[1].astype(jnp.float32)).astype(cdt))
    x = _norm(cfg, _cast_tree(
        {k: params[k] for k in params if k.startswith("final_norm")}, cfg),
        x, "final_norm")
    wv = _compute(lm_head_weight(params, cfg), cfg)
    logits = (x[:, -1] @ wv).astype(jnp.float32)
    return logits, caches



