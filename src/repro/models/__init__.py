"""Model substrate: pure-JAX LM families (dense / MoE / SSM / hybrid /
encoder-decoder / VLM-backbone) with scan-over-layers assembly."""
from .model import Model, build_model
from .sharding_ctx import LayoutPlan, constrain, current_plan, use_plan

__all__ = ["Model", "build_model", "LayoutPlan", "constrain",
           "current_plan", "use_plan"]
