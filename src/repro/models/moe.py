"""Mixture-of-Experts layer: grouped top-k capacity routing (GShard-style),
expert-parallel over the mesh's ``model`` axis.

Dispatch is the einsum formulation (dense one-hot dispatch/combine tensors
over small token *groups*), which is the TPU-native adaptation of GPU
scatter/gather MoE kernels: every step is a dense (MXU-friendly) einsum and
the group->expert resharding lowers to an all-to-all under GSPMD.  Group
size bounds the dispatch tensor to (G, group, E, C) — O(tokens * E * C /
group) elements — instead of (tokens, E, C).

Performance parameters: ``capacity_factor`` and ``group_size`` are
before-execute-time AT knobs (tokens dropped vs dispatch memory); the
router jitter and aux-loss weight follow Switch/GShard defaults.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .layers import dense_init
from .sharding_ctx import constrain


class MoEConfig(NamedTuple):
    d_model: int
    d_ff: int                  # per-expert FFN width
    n_experts: int
    top_k: int
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    group_size: int = 512
    aux_loss_weight: float = 0.01


def init_moe(key, cfg: MoEConfig, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 5)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    scale = d ** -0.5
    p = {
        "router": dense_init(ks[0], d, e, dtype),
        "w_gate": jax.random.normal(ks[1], (e, d, f), dtype) * scale,
        "w_up": jax.random.normal(ks[2], (e, d, f), dtype) * scale,
        "w_down": jax.random.normal(ks[3], (e, f, d), dtype) * (f ** -0.5),
    }
    if cfg.n_shared_experts:
        sf = f * cfg.n_shared_experts
        kss = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": dense_init(kss[0], d, sf, dtype),
            "w_up": dense_init(kss[1], d, sf, dtype),
            "w_down": dense_init(kss[2], sf, d, dtype),
        }
    return p


def capacity(cfg: MoEConfig, group: int) -> int:
    c = int(group * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(c, 4)


def apply_moe(p: dict, x: jax.Array, cfg: MoEConfig):
    """x: (B, S, d) -> (out, aux_loss)."""
    b, s, d = x.shape
    tokens = x.reshape(-1, d)
    t = tokens.shape[0]
    g = max(min(cfg.group_size, t), 1)
    n_groups = -(-t // g)              # ceil: ragged tail is padded,
    pad = n_groups * g - t             # never dropped
    if pad:
        tokens = jnp.concatenate(
            [tokens, jnp.zeros((pad, d), tokens.dtype)])
    valid = (jnp.arange(n_groups * g) < t).reshape(
        n_groups, g).astype(jnp.float32)
    tokens = tokens.reshape(n_groups, g, d)
    e, k = cfg.n_experts, cfg.top_k
    c = capacity(cfg, g)

    logits = (tokens @ p["router"]).astype(jnp.float32)     # (G, g, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)                  # (G, g, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    top_p = top_p * valid[..., None]   # padded rows route nowhere

    # load-balancing aux loss (Switch): mean prob * mean assignment share
    me = probs.mean(axis=1)                                  # (G, E)
    onehot_any = jax.nn.one_hot(top_i, e, dtype=jnp.float32).sum(2)  # (G,g,E)
    ce = onehot_any.mean(axis=1)                             # (G, E)
    aux = (me * ce).sum(-1).mean() * e * cfg.aux_loss_weight

    # position of each (token, choice) within its expert queue
    disp = jnp.zeros((tokens.shape[0], g, e, c), jnp.float32)
    comb = jnp.zeros_like(disp)
    running = jnp.zeros((tokens.shape[0], e), jnp.int32)
    for choice in range(k):
        idx = top_i[:, :, choice]                            # (G, g)
        oh = jax.nn.one_hot(idx, e, dtype=jnp.int32) \
            * valid[..., None].astype(jnp.int32)             # (G, g, E)
        pos_in_e = jnp.cumsum(oh, axis=1) - oh + running[:, None, :]
        pos = jnp.take_along_axis(pos_in_e, idx[..., None],
                                  axis=-1)[..., 0]           # (G, g)
        keep = pos < c
        poh = jax.nn.one_hot(pos, c, dtype=jnp.float32) * \
            keep[..., None].astype(jnp.float32)              # (G, g, C)
        sel = oh.astype(jnp.float32)[..., None] * poh[:, :, None, :]
        disp = disp + sel
        comb = comb + sel * top_p[:, :, choice][..., None, None]
        running = running + oh.sum(axis=1)

    disp = disp.astype(x.dtype)
    expert_in = jnp.einsum("gsec,gsd->egcd", disp, tokens)   # (E, G, C, d)
    expert_in = constrain(expert_in, "moe_experts")
    gate = jnp.einsum("egcd,edf->egcf", expert_in, p["w_gate"])
    up = jnp.einsum("egcd,edf->egcf", expert_in, p["w_up"])
    act = jax.nn.silu(gate) * up
    expert_out = jnp.einsum("egcf,efd->egcd", act, p["w_down"])
    expert_out = constrain(expert_out, "moe_experts")
    out = jnp.einsum("gsec,egcd->gsd", comb.astype(x.dtype), expert_out)

    out = out.reshape(-1, d)[:t]
    if cfg.n_shared_experts:
        sh = p["shared"]
        flat = x.reshape(-1, d)
        h = jax.nn.silu(flat @ sh["w_gate"]) * (flat @ sh["w_up"])
        out = out + h @ sh["w_down"]
    return out.reshape(b, s, d), aux
