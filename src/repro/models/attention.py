"""GQA attention: train/prefill path, decode path with KV cache, cross-attn.

Modes:
* ``full(params, x, cfg)`` — training / prefill over a whole sequence
  (flash kernel on TPU, jnp oracle on CPU), causal with optional sliding
  window; returns attention output and (optionally) the KV cache.
* ``decode(params, x, cache, pos, cfg)`` — one new token against the cache
  (flash-decode kernel on TPU).  Sliding-window archs use a ring-buffer
  cache of O(window) memory, which is what makes ``long_500k`` runnable.
* ``cross_full`` / ``cross_decode`` — encoder-decoder cross attention
  (whisper): KV computed once from encoder states.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..distributed.compression import quantize_int8_rows
from ..kernels import ops
from .layers import apply_rope, dense_init
from .sharding_ctx import constrain


class AttnConfig(NamedTuple):
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    rope_theta: float = 10000.0
    window: int | None = None
    causal: bool = True
    use_rope: bool = True
    qkv_bias: bool = False


def init_attention(key, cfg: AttnConfig, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], cfg.d_model, cfg.n_heads * cfg.d_head, dtype),
        "wk": dense_init(ks[1], cfg.d_model,
                         cfg.n_kv_heads * cfg.d_head, dtype),
        "wv": dense_init(ks[2], cfg.d_model,
                         cfg.n_kv_heads * cfg.d_head, dtype),
        "wo": dense_init(ks[3], cfg.n_heads * cfg.d_head, cfg.d_model, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * cfg.d_head,), dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * cfg.d_head,), dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * cfg.d_head,), dtype)
    return p


def _project_qkv(p: dict, x: jax.Array, cfg: AttnConfig, positions):
    b, s, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, cfg.n_kv_heads, cfg.d_head).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.d_head).transpose(0, 2, 1, 3)
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def full(p: dict, x: jax.Array, cfg: AttnConfig,
         positions: jax.Array | None = None, return_cache: bool = False,
         mesh=None):
    """Whole-sequence attention.  x: (B, S, d).  ``mesh`` routes long
    causal sequences through the ring sequence-parallel tail (see
    :func:`repro.kernels.ops.attention`)."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)
    q, k, v = _project_qkv(p, x, cfg, positions)
    q = constrain(q, "heads")
    k = constrain(k, "kv_heads")
    v = constrain(v, "kv_heads")
    out = ops.attention(q, k, v, causal=cfg.causal, window=cfg.window,
                        mesh=mesh)
    out = constrain(out, "heads")
    out = out.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * cfg.d_head)
    out = out @ p["wo"]
    if return_cache:
        return out, (k, v)
    return out


def decode(p: dict, x: jax.Array, cache_k: jax.Array, cache_v: jax.Array,
           pos: jax.Array, cfg: AttnConfig):
    """One-token decode.  x: (B, 1, d); caches (B, Hkv, W, Dh); ``pos`` (B,)
    is the absolute position of the new token.  Returns (out, new_k, new_v).

    With a sliding window the cache is a ring buffer indexed ``pos % W`` —
    RoPE is applied at absolute positions before caching, so softmax over an
    unordered window is exact.
    """
    b, one, _ = x.shape
    w = cache_k.shape[2]
    q, k, v = _project_qkv(p, x, cfg, pos[:, None])
    slot = pos % w if cfg.window is not None else pos
    idx = slot[:, None, None, None]
    bidx = jnp.arange(b)[:, None, None, None]
    hidx = jnp.arange(cfg.n_kv_heads)[None, :, None, None]
    didx = jnp.arange(cfg.d_head)[None, None, None, :]
    cache_k = cache_k.at[bidx, hidx, idx, didx].set(
        k.transpose(0, 1, 2, 3)[:, :, :1, :].astype(cache_k.dtype))
    cache_v = cache_v.at[bidx, hidx, idx, didx].set(
        v[:, :, :1, :].astype(cache_v.dtype))
    kv_len = jnp.minimum(pos + 1, w).astype(jnp.int32)
    out = ops.decode_attention(q, cache_k, cache_v, kv_len)
    out = out.transpose(0, 2, 1, 3).reshape(b, one, cfg.n_heads * cfg.d_head)
    return out @ p["wo"], cache_k, cache_v


def paged_decode(p: dict, x: jax.Array, k_pool: jax.Array,
                 v_pool: jax.Array, page_table: jax.Array,
                 pos: jax.Array, cfg: AttnConfig, scales=None, mesh=None):
    """One-token decode against a paged KV cache.

    x: (B, 1, d); pools (P, Hkv, psz, Dh) are shared by every sequence,
    ``page_table`` (B, nblk) maps logical KV blocks to physical pages (the
    allocator guarantees pages are lane-exclusive, so the scatter below
    cannot race between lanes).  The new token's KV lands in page
    ``table[b, pos // psz]`` at slot ``pos % psz``.  Sliding-window archs
    are not supported on this path (their ring buffer is already O(W)).

    ``scales``: for int8 pools, ``(k_scale, v_scale)`` fp32 arrays of
    shape (P, Hkv, psz) — one scale per (page, head, slot) row.  The new
    token's KV is quantized on the way in and attention dequantizes
    in-kernel.  Returns ``(out, k_pool, v_pool, scales)``; ``scales`` is
    None on the fp path.

    ``mesh``: a mesh with a multi-device ``model`` axis runs the
    attention op head-sharded (the op's output is gathered back to
    replicated before the output projection, so results stay bit
    identical to the unsharded path — see
    :func:`repro.kernels.ops.paged_decode`).
    """
    assert cfg.window is None, "paged decode does not support SWA archs"
    b, one, _ = x.shape
    psz = k_pool.shape[2]
    q, k, v = _project_qkv(p, x, cfg, pos[:, None])
    phys = jnp.take_along_axis(page_table, (pos // psz)[:, None],
                               axis=1)[:, 0]                      # (B,)
    slot = pos % psz
    pidx = phys[:, None, None, None]
    hidx = jnp.arange(cfg.n_kv_heads)[None, :, None, None]
    sidx = slot[:, None, None, None]
    didx = jnp.arange(cfg.d_head)[None, None, None, :]
    k_scale = v_scale = None
    if scales is not None:
        k_scale, v_scale = scales
        kq, ks = quantize_int8_rows(k[:, :, :1, :])       # ks: (B, Hkv, 1)
        vq, vs = quantize_int8_rows(v[:, :, :1, :])
        k_pool = k_pool.at[pidx, hidx, sidx, didx].set(kq)
        v_pool = v_pool.at[pidx, hidx, sidx, didx].set(vq)
        sp = phys[:, None, None]
        sh = jnp.arange(cfg.n_kv_heads)[None, :, None]
        ss = slot[:, None, None]
        k_scale = k_scale.at[sp, sh, ss].set(ks)
        v_scale = v_scale.at[sp, sh, ss].set(vs)
        scales = (k_scale, v_scale)
    else:
        k_pool = k_pool.at[pidx, hidx, sidx, didx].set(
            k[:, :, :1, :].astype(k_pool.dtype))
        v_pool = v_pool.at[pidx, hidx, sidx, didx].set(
            v[:, :, :1, :].astype(v_pool.dtype))
    kv_len = (pos + 1).astype(jnp.int32)
    pools = ops.PagedPools(k_pool, v_pool, k_scale, v_scale)
    out = ops.paged_decode(q, pools, page_table, kv_len, mesh=mesh)
    out = out.transpose(0, 2, 1, 3).reshape(b, one, cfg.n_heads * cfg.d_head)
    return out @ p["wo"], k_pool, v_pool, scales


def paged_prefill(p: dict, x: jax.Array, k_pool: jax.Array,
                  v_pool: jax.Array, page_table: jax.Array,
                  start: jax.Array, kv_len: jax.Array, cfg: AttnConfig,
                  scales=None, mesh=None):
    """One prompt *chunk* against a paged KV cache.

    x: (B, C, d) — chunk tokens whose first token sits at absolute
    position ``start[b]``; pools (P, Hkv, psz, Dh); ``page_table``
    (B, nblk); ``kv_len`` (B,) = ``start + valid_chunk_len``.  RoPE runs
    at absolute positions, the chunk's KV is scattered into its pages
    (padded tail positions — ``pos >= kv_len`` — are redirected to the
    null page 0 so ragged chunks can never corrupt live pages), then
    attention runs over the committed prefix plus the chunk's causal
    triangle.  Returns (out, k_pool, v_pool, scales) — ``scales`` is the
    updated ``(k_scale, v_scale)`` pair for int8 pools, None for fp.
    """
    q, k_pool, v_pool, scales = _paged_chunk_scatter(
        p, x, k_pool, v_pool, page_table, start, kv_len, cfg, scales)
    k_scale, v_scale = scales if scales is not None else (None, None)
    pools = ops.PagedPools(k_pool, v_pool, k_scale, v_scale)
    out = ops.paged_prefill(q, pools, page_table, start, kv_len, mesh=mesh)
    b, c, _ = x.shape
    out = out.transpose(0, 2, 1, 3).reshape(b, c, cfg.n_heads * cfg.d_head)
    return out @ p["wo"], k_pool, v_pool, scales


def paged_verify(p: dict, x: jax.Array, k_pool: jax.Array,
                 v_pool: jax.Array, page_table: jax.Array,
                 start: jax.Array, kv_len: jax.Array, cfg: AttnConfig,
                 scales=None, mesh=None):
    """Speculative-verify attention: one *candidate* chunk against a paged
    KV cache.

    Identical math to :func:`paged_prefill` — the chunk here is
    ``[last committed token, draft_1 .. draft_k]`` rather than prompt
    tokens, causal at absolute positions over the committed prefix plus
    the chunk's own triangle — but dispatched through
    :func:`~repro.kernels.ops.paged_verify_attention`, whose tile space is
    tuned separately (verify chunks are k+1 tokens wide, not a prefill
    chunk).  Rejected drafts' KV lands in the pages and is rolled back by
    the cache layer (``truncate_to``); padded rows (``pos >= kv_len``)
    route to the null page as in prefill.  Returns
    (out, k_pool, v_pool, scales).
    """
    q, k_pool, v_pool, scales = _paged_chunk_scatter(
        p, x, k_pool, v_pool, page_table, start, kv_len, cfg, scales)
    k_scale, v_scale = scales if scales is not None else (None, None)
    pools = ops.PagedPools(k_pool, v_pool, k_scale, v_scale)
    out = ops.paged_verify(q, pools, page_table, start, kv_len, mesh=mesh)
    b, c, _ = x.shape
    out = out.transpose(0, 2, 1, 3).reshape(b, c, cfg.n_heads * cfg.d_head)
    return out @ p["wo"], k_pool, v_pool, scales


def _paged_chunk_scatter(p: dict, x: jax.Array, k_pool: jax.Array,
                         v_pool: jax.Array, page_table: jax.Array,
                         start: jax.Array, kv_len: jax.Array,
                         cfg: AttnConfig, scales=None):
    """Project a chunk's QKV at absolute positions and scatter its KV into
    the pages (write-before-read contract shared by prefill and verify).
    Padded tail positions — ``pos >= kv_len`` — are redirected to the
    null page 0 so ragged chunks can never corrupt live pages.  For int8
    pools (``scales`` given) the chunk's KV is quantized per row on the
    way in and the matching scale rows are scattered alongside."""
    assert cfg.window is None, "paged chunk attention does not support SWA"
    b, c, _ = x.shape
    psz = k_pool.shape[2]
    positions = start[:, None] + jnp.arange(c)[None, :]       # (B, C)
    q, k, v = _project_qkv(p, x, cfg, positions)
    phys = jnp.take_along_axis(page_table, positions // psz, axis=1)
    phys = jnp.where(positions < kv_len[:, None], phys, 0)    # null-page sink
    slot = positions % psz
    pidx = phys[:, None, :, None]                             # (B, 1, C, 1)
    hidx = jnp.arange(cfg.n_kv_heads)[None, :, None, None]
    sidx = slot[:, None, :, None]
    didx = jnp.arange(cfg.d_head)[None, None, None, :]
    if scales is not None:
        k_scale, v_scale = scales
        kq, ks = quantize_int8_rows(k)                # ks: (B, Hkv, C)
        vq, vs = quantize_int8_rows(v)
        k_pool = k_pool.at[pidx, hidx, sidx, didx].set(kq)
        v_pool = v_pool.at[pidx, hidx, sidx, didx].set(vq)
        sp = phys[:, None, :]                                 # (B, 1, C)
        sh = jnp.arange(cfg.n_kv_heads)[None, :, None]
        ss = slot[:, None, :]
        k_scale = k_scale.at[sp, sh, ss].set(ks)
        v_scale = v_scale.at[sp, sh, ss].set(vs)
        scales = (k_scale, v_scale)
    else:
        k_pool = k_pool.at[pidx, hidx, sidx, didx].set(k.astype(k_pool.dtype))
        v_pool = v_pool.at[pidx, hidx, sidx, didx].set(v.astype(v_pool.dtype))
    return q, k_pool, v_pool, scales


def init_paged_pool(n_pages: int, cfg: AttnConfig, page_size: int,
                    dtype=jnp.bfloat16):
    """Physical page pool for one layer: (P, Hkv, psz, Dh) k and v."""
    shape = (n_pages, cfg.n_kv_heads, page_size, cfg.d_head)
    return jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)


def init_paged_scales(n_pages: int, cfg: AttnConfig, page_size: int):
    """Per-row fp32 scales for an int8 page pool: (P, Hkv, psz) k and v.
    Zero scales dequantize untouched rows to exactly 0.0, matching the
    zero-initialized fp pool."""
    shape = (n_pages, cfg.n_kv_heads, page_size)
    return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)


def init_cross_attention(key, cfg: AttnConfig, dtype=jnp.float32) -> dict:
    return init_attention(key, cfg, dtype)


def cross_kv(p: dict, enc: jax.Array, cfg: AttnConfig):
    """Precompute cross-attention KV from encoder states (B, Se, d)."""
    b, se, _ = enc.shape
    k = (enc @ p["wk"]).reshape(b, se, cfg.n_kv_heads,
                                cfg.d_head).transpose(0, 2, 1, 3)
    v = (enc @ p["wv"]).reshape(b, se, cfg.n_kv_heads,
                                cfg.d_head).transpose(0, 2, 1, 3)
    return k, v


def cross_full(p: dict, x: jax.Array, k: jax.Array, v: jax.Array,
               cfg: AttnConfig):
    """Cross attention (no RoPE, not causal).  x: (B, Sd, d)."""
    b, s, _ = x.shape
    q = (x @ p["wq"]).reshape(b, s, cfg.n_heads,
                              cfg.d_head).transpose(0, 2, 1, 3)
    out = ops.attention(q, k, v, causal=False)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * cfg.d_head)
    return out @ p["wo"]


def cross_decode(p: dict, x: jax.Array, k: jax.Array, v: jax.Array,
                 cfg: AttnConfig):
    b, one, _ = x.shape
    q = (x @ p["wq"]).reshape(b, one, cfg.n_heads,
                              cfg.d_head).transpose(0, 2, 1, 3)
    out = ops.decode_attention(q, k, v)
    out = out.transpose(0, 2, 1, 3).reshape(b, one, cfg.n_heads * cfg.d_head)
    return out @ p["wo"]


def init_cache(batch: int, cfg: AttnConfig, max_len: int,
               dtype=jnp.bfloat16):
    """KV cache for one layer; O(window) when sliding-window."""
    w = min(max_len, cfg.window) if cfg.window is not None else max_len
    shape = (batch, cfg.n_kv_heads, w, cfg.d_head)
    return jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)
