"""Model facade: one object per architecture, dispatching to the decoder
family (transformer.py) or encoder-decoder (encdec.py) implementations.

Every entry point is a pure function of (params, inputs); ``input_specs``
returns ShapeDtypeStruct stand-ins for the dry-run (weak-type-correct,
shardable, no allocation) and ``abstract_params`` runs init under
``jax.eval_shape``.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeConfig
from . import encdec, transformer


@dataclass(frozen=True)
class Model:
    cfg: ArchConfig

    # -- init ------------------------------------------------------------
    def init(self, key) -> dict:
        if self.cfg.is_encoder_decoder:
            return encdec.init_params(key, self.cfg)
        return transformer.init_params(key, self.cfg)

    def abstract_params(self):
        return jax.eval_shape(
            lambda: self.init(jax.random.PRNGKey(0)))

    # -- steps -----------------------------------------------------------
    def train_loss(self, params, batch):
        if self.cfg.is_encoder_decoder:
            return encdec.train_loss(params, batch, self.cfg)
        return transformer.train_loss(params, batch, self.cfg)

    def forward(self, params, tokens, frontend_embeds=None):
        return transformer.forward(params, tokens, self.cfg,
                                   frontend_embeds)

    def prefill(self, params, tokens, frontend_embeds=None,
                max_len=None, mesh=None):
        if self.cfg.is_encoder_decoder:
            return encdec.prefill(params, tokens, self.cfg, frontend_embeds,
                                  max_len)
        return transformer.prefill(params, tokens, self.cfg,
                                   frontend_embeds, max_len, mesh=mesh)

    def decode_step(self, params, caches, token, pos):
        if self.cfg.is_encoder_decoder:
            return encdec.decode_step(params, caches, token, pos, self.cfg)
        return transformer.decode_step(params, caches, token, pos, self.cfg)

    def init_caches(self, batch: int, max_len: int, dtype=None):
        if self.cfg.is_encoder_decoder:
            return encdec.init_caches(self.cfg, batch, max_len, dtype)
        return transformer.init_caches(self.cfg, batch, max_len, dtype)

    def abstract_caches(self, batch: int, max_len: int):
        return jax.eval_shape(
            functools.partial(self.init_caches, batch, max_len))

    # -- paged KV (serving) ------------------------------------------------
    @property
    def supports_paged_cache(self) -> bool:
        return (not self.cfg.is_encoder_decoder
                and transformer.supports_paged_cache(self.cfg))

    def init_paged_caches(self, n_pages: int, page_size: int, dtype=None,
                          quantized: bool = False):
        if self.cfg.is_encoder_decoder:
            raise ValueError("paged KV cache is decoder-only")
        return transformer.init_paged_caches(self.cfg, n_pages, page_size,
                                             dtype, quantized)

    def paged_decode_step(self, params, caches, page_table, token, pos,
                          mesh=None):
        return transformer.paged_decode_step(params, caches, page_table,
                                             token, pos, self.cfg, mesh=mesh)

    def paged_prefill_step(self, params, caches, page_table, tokens,
                           start, kv_len, logit_idx, mesh=None):
        return transformer.paged_prefill_step(params, caches, page_table,
                                              tokens, start, kv_len,
                                              logit_idx, self.cfg, mesh=mesh)

    # -- speculative decoding (serving) ------------------------------------
    def speculative_step(self, params, caches, page_table, tokens,
                         start, kv_len, mesh=None):
        """Verify one candidate chunk per lane; full (B, C, V) logits."""
        return transformer.speculative_step(params, caches, page_table,
                                            tokens, start, kv_len, self.cfg,
                                            mesh=mesh)

    def draft_model(self, depth_frac: float = 0.5,
                    width_frac: float = 1.0) -> "Model":
        """The reduced-depth/width draft of this architecture."""
        return Model(self.cfg.draft_config(depth_frac, width_frac))

    def slice_draft_params(self, params, draft_model: "Model") -> dict:
        """Self-speculative draft params (target's leading layers)."""
        return transformer.slice_draft_params(params, self.cfg,
                                              draft_model.cfg)

    # -- dry-run input stand-ins ------------------------------------------
    def input_specs(self, shape: ShapeConfig) -> dict:
        """ShapeDtypeStruct inputs for the given shape's step function."""
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        f32 = jnp.float32

        def sds(shp, dt):
            return jax.ShapeDtypeStruct(shp, dt)

        fe_seq = cfg.frontend_seq if cfg.frontend != "none" else 0
        if shape.kind == "train":
            specs = {"tokens": sds((b, s - fe_seq), i32),
                     "labels": sds((b, s - fe_seq), i32)}
            if cfg.is_encoder_decoder:
                specs = {"tokens": sds((b, s), i32),
                         "labels": sds((b, s), i32),
                         "frontend_embeds": sds((b, fe_seq or s // 2,
                                                 cfg.d_model), f32)}
            elif fe_seq:
                specs["frontend_embeds"] = sds((b, fe_seq, cfg.d_model), f32)
            return specs
        if shape.kind == "prefill":
            specs = {"tokens": sds((b, s - fe_seq), i32)}
            if cfg.is_encoder_decoder:
                specs = {"tokens": sds((b, s), i32),
                         "frontend_embeds": sds((b, fe_seq or s // 2,
                                                 cfg.d_model), f32)}
            elif fe_seq:
                specs["frontend_embeds"] = sds((b, fe_seq, cfg.d_model), f32)
            return specs
        # decode: one new token with a KV cache of seq_len
        return {"caches": self.abstract_caches(b, s),
                "token": sds((b, 1), i32),
                "pos": sds((b,), i32)}


def build_model(cfg: ArchConfig) -> Model:
    return Model(cfg)
