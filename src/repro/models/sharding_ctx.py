"""Activation-sharding context — the model's only coupling to the mesh.

The launcher installs a :class:`LayoutPlan` (chosen by before-execute-time
AT per arch x shape x mesh); model code calls ``constrain(x, role)`` at
block boundaries.  With no plan installed (CPU tests) it is a no-op, so
model code never imports mesh machinery.
"""
from __future__ import annotations

import contextlib
from dataclasses import dataclass, field

import jax
from jax.sharding import PartitionSpec as P


@dataclass
class LayoutPlan:
    """Named activation PartitionSpecs (the static-AT-selected layout).

    Roles: ``tokens`` (B, S), ``hidden`` (B, S, d), ``heads`` (B, H, S, Dh),
    ``kv_cache`` (L, B, Hkv, S, Dh), ``logits_hidden`` (T, d),
    ``moe_experts`` (E, G, C, d), ``ssm_inner`` (B, L, d_inner).
    """

    name: str = "replicated"
    specs: dict[str, P] = field(default_factory=dict)
    # per-layer parameter PartitionSpecs (stack axis dropped); applied
    # INSIDE the layer scan so dW reductions lower to reduce-scatter onto
    # the shard (GSPMD does not propagate through scan bodies)
    layer_specs: object = None
    # PP knobs carried with the plan (static AT results)
    remat: str = "none"            # none | dots | full
    num_microbatches: int = 1
    loss_chunks: int = 8           # CE vocab-chunk count (PP: wire bytes
    #                                of the head-grad psum scale with it)
    grad_compress: bool = False    # int8 pod-axis gradient all-reduce

    def spec(self, role: str) -> P | None:
        return self.specs.get(role)


_ACTIVE: list[LayoutPlan | None] = [None]


def current_plan() -> LayoutPlan | None:
    return _ACTIVE[-1]


@contextlib.contextmanager
def use_plan(plan: LayoutPlan | None):
    _ACTIVE.append(plan)
    try:
        yield plan
    finally:
        _ACTIVE.pop()


def constrain(x: jax.Array, role: str) -> jax.Array:
    plan = current_plan()
    if plan is None:
        return x
    spec = plan.spec(role)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def constrain_layer_params(lp):
    """Pin one layer's weight slices (and therefore their cotangents) to
    the plan's layout, inside the scan body."""
    plan = current_plan()
    if plan is None or plan.layer_specs is None:
        return lp
    return jax.tree.map(
        lambda x, s: x if s is None
        else jax.lax.with_sharding_constraint(x, s), lp, plan.layer_specs,
        is_leaf=lambda n: n is None)
