"""State-space blocks: Mamba-1 (falcon-mamba) and Mamba-2 / SSD (zamba2).

Train/prefill paths use the chunked formulations (the Pallas selective-scan
kernel for Mamba-1 on TPU; a dense chunked SSD in jnp whose intra-chunk
matmuls are MXU-shaped).  Decode is the O(1)-per-token recurrence — the
reason ``long_500k`` runs for the SSM/hybrid archs.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..kernels import ops
from .layers import causal_conv1d, dense_init, rms_norm


# --------------------------------------------------------------------------
# Mamba-1 (falcon-mamba-7b)
# --------------------------------------------------------------------------


class Mamba1Config(NamedTuple):
    d_model: int
    d_inner: int           # 2 * d_model
    d_state: int           # 16
    d_conv: int = 4
    dt_rank: int = 0       # d_model // 16 default


def m1_dt_rank(cfg: Mamba1Config) -> int:
    return cfg.dt_rank or max(cfg.d_model // 16, 1)


def init_mamba1(key, cfg: Mamba1Config, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 7)
    di, n, r = cfg.d_inner, cfg.d_state, m1_dt_rank(cfg)
    return {
        "in_proj": dense_init(ks[0], cfg.d_model, 2 * di, dtype),
        "conv_w": jax.random.normal(ks[1], (cfg.d_conv, di), dtype) * 0.2,
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[2], di, r + 2 * n, dtype),
        "dt_proj": dense_init(ks[3], r, di, dtype),
        "dt_bias": jnp.zeros((di,), dtype),
        "a_log": jnp.log(jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32),
                                  (di, 1))).astype(dtype),
        "d_skip": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[4], di, cfg.d_model, dtype),
    }


def apply_mamba1(p: dict, x: jax.Array, cfg: Mamba1Config,
                 return_state: bool = False):
    """x: (B, L, d) -> (B, L, d) [, final decode state]."""
    di, n, r = cfg.d_inner, cfg.d_state, m1_dt_rank(cfg)
    xz = x @ p["in_proj"]
    xin_raw, z = jnp.split(xz, 2, axis=-1)
    xin = jax.nn.silu(causal_conv1d(xin_raw, p["conv_w"], p["conv_b"]))
    dbc = xin @ p["x_proj"]
    dt, bmat, cmat = jnp.split(dbc, [r, r + n], axis=-1)
    dt = jax.nn.softplus(dt @ p["dt_proj"] + p["dt_bias"])
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    if return_state:
        y, hf = ops.ssm_scan(xin, dt, a, bmat, cmat, p["d_skip"],
                             return_final_state=True)
        state = {"conv": _conv_tail(xin_raw, cfg.d_conv), "h": hf}
    else:
        y = ops.ssm_scan(xin, dt, a, bmat, cmat, p["d_skip"])
    y = y * jax.nn.silu(z)
    out = y @ p["out_proj"]
    if return_state:
        return out, state
    return out


def _conv_tail(raw: jax.Array, d_conv: int) -> jax.Array:
    """Last d_conv-1 pre-conv inputs (front-padded), the decode conv
    state."""
    b, l, c = raw.shape
    k = d_conv - 1
    if l >= k:
        return raw[:, l - k:]
    return jnp.concatenate(
        [jnp.zeros((b, k - l, c), raw.dtype), raw], axis=1)


def init_mamba1_state(batch: int, cfg: Mamba1Config, dtype=jnp.float32):
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, cfg.d_inner), dtype),
        "h": jnp.zeros((batch, cfg.d_inner, cfg.d_state), jnp.float32),
    }


def step_mamba1(p: dict, x: jax.Array, state: dict, cfg: Mamba1Config):
    """One decode step.  x: (B, 1, d) -> (y (B, 1, d), new state)."""
    di, n, r = cfg.d_inner, cfg.d_state, m1_dt_rank(cfg)
    xz = x[:, 0] @ p["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)                 # (B, di)
    conv_buf = jnp.concatenate([state["conv"], xin[:, None]], axis=1)
    w = p["conv_w"]                                    # (K, di)
    xc = jax.nn.silu((conv_buf * w[None]).sum(axis=1) + p["conv_b"])
    dbc = xc @ p["x_proj"]
    dt, bmat, cmat = jnp.split(dbc, [r, r + n], axis=-1)
    dt = jax.nn.softplus(dt @ p["dt_proj"] + p["dt_bias"])  # (B, di)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))            # (di, n)
    da = jnp.exp(dt[..., None].astype(jnp.float32) * a[None])  # (B, di, n)
    h = da * state["h"] + (dt * xc).astype(jnp.float32)[..., None] \
        * bmat.astype(jnp.float32)[:, None, :]
    y = (h * cmat.astype(jnp.float32)[:, None, :]).sum(-1) \
        + p["d_skip"].astype(jnp.float32) * xc.astype(jnp.float32)
    y = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["out_proj"]
    return y[:, None], {"conv": conv_buf[:, 1:], "h": h}


# --------------------------------------------------------------------------
# Mamba-2 / SSD (zamba2-7b)
# --------------------------------------------------------------------------


class Mamba2Config(NamedTuple):
    d_model: int
    d_inner: int          # n_heads * head_dim
    d_state: int          # 64
    n_heads: int
    head_dim: int
    d_conv: int = 4
    chunk: int = 64


def init_mamba2(key, cfg: Mamba2Config, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 6)
    di, n, h = cfg.d_inner, cfg.d_state, cfg.n_heads
    conv_dim = di + 2 * n
    return {
        "in_proj": dense_init(ks[0], cfg.d_model,
                              2 * di + 2 * n + h, dtype),
        "conv_w": jax.random.normal(ks[1], (cfg.d_conv, conv_dim),
                                    dtype) * 0.2,
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32)
                         ).astype(dtype),
        "dt_bias": jnp.zeros((h,), dtype),
        "d_skip": jnp.ones((h,), dtype),
        "norm_w": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[2], di, cfg.d_model, dtype),
    }


def _segsum(a: jax.Array) -> jax.Array:
    """L[i, j] = sum_{j < t <= i} a_t for i >= j else -inf.  a: (..., Q)."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]          # (..., Q, Q)
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, a_head, b, c, d_skip, chunk: int,
                return_final_state: bool = False):
    """SSD (Mamba-2) chunked algorithm.

    x: (B, L, H, P); dt: (B, L, H) (positive); a_head: (H,) negative;
    b, c: (B, L, N); d_skip: (H,) -> y (B, L, H, P).
    """
    bs, l, h, pdim = x.shape
    n = b.shape[-1]
    q = min(chunk, l)
    pad = (-l) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // q
    xc = x.reshape(bs, nc, q, h, pdim)
    dtc = dt.reshape(bs, nc, q, h).astype(jnp.float32)
    bc = b.reshape(bs, nc, q, n).astype(jnp.float32)
    cc = c.reshape(bs, nc, q, n).astype(jnp.float32)
    a = dtc * a_head[None, None, None, :]               # (B, nc, Q, H)

    # intra-chunk (dense, MXU-shaped)
    lmat = jnp.exp(_segsum(a.transpose(0, 1, 3, 2)))    # (B, nc, H, Q, Q)
    scores = jnp.einsum("bcin,bcjn->bcij", cc, bc)      # (B, nc, Q, Q)
    w = scores[:, :, None] * lmat                       # (B, nc, H, Q, Q)
    xdt = xc.astype(jnp.float32) * dtc[..., None]       # (B, nc, Q, H, P)
    y_intra = jnp.einsum("bchij,bcjhp->bcihp", w, xdt)

    # chunk states + inter-chunk recurrence
    a_cum = jnp.cumsum(a, axis=2)                       # (B, nc, Q, H)
    a_tot = a_cum[:, :, -1]                             # (B, nc, H)
    decay_out = jnp.exp(a_tot[:, :, None] - a_cum)      # (B, nc, Q, H)
    s_c = jnp.einsum("bcjn,bcjhp,bcjh->bchnp", bc, xdt, decay_out)

    def scan_fn(hprev, inp):
        s, atot = inp
        hnew = jnp.exp(atot)[..., None, None] * hprev + s
        return hnew, hprev

    h0 = jnp.zeros((bs, h, n, pdim), jnp.float32)
    h_final, h_in = jax.lax.scan(
        scan_fn, h0, (s_c.transpose(1, 0, 2, 3, 4),
                      a_tot.transpose(1, 0, 2)))
    h_in = h_in.transpose(1, 0, 2, 3, 4)                # (B, nc, H, N, P)

    decay_in = jnp.exp(a_cum)                           # (B, nc, Q, H)
    y_inter = jnp.einsum("bcin,bchnp,bcih->bcihp", cc, h_in, decay_in)
    y = y_intra + y_inter + xc.astype(jnp.float32) * d_skip[None, None,
                                                            None, :, None]
    y = y.reshape(bs, nc * q, h, pdim)[:, :l]
    if return_final_state:
        # note: with a padded tail the padded steps have dt=0 -> a=0,
        # exp(0)=1 and zero input, so h_final is exact
        return y.astype(x.dtype), h_final
    return y.astype(x.dtype)


def apply_mamba2(p: dict, x: jax.Array, cfg: Mamba2Config,
                 return_state: bool = False):
    """x: (B, L, d) -> (B, L, d) [, final decode state]."""
    di, n, h, pd = cfg.d_inner, cfg.d_state, cfg.n_heads, cfg.head_dim
    proj = x @ p["in_proj"]
    z, xbc_raw, dt = jnp.split(proj, [di, 2 * di + 2 * n], axis=-1)
    xbc = jax.nn.silu(causal_conv1d(xbc_raw, p["conv_w"], p["conv_b"]))
    xin, bmat, cmat = jnp.split(xbc, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt + p["dt_bias"])              # (B, L, H)
    a_head = -jnp.exp(p["a_log"].astype(jnp.float32))    # (H,)
    bsz, l, _ = x.shape
    out = ssd_chunked(xin.reshape(bsz, l, h, pd), dt, a_head, bmat, cmat,
                      p["d_skip"], cfg.chunk,
                      return_final_state=return_state)
    if return_state:
        y, hf = out
        # ssd state layout (B, H, N, P) -> decode layout (B, H, N, P)
        state = {"conv": _conv_tail(xbc_raw, cfg.d_conv), "h": hf}
    else:
        y = out
    y = y.reshape(bsz, l, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"])
    out_p = y @ p["out_proj"]
    if return_state:
        return out_p, state
    return out_p


def init_mamba2_state(batch: int, cfg: Mamba2Config, dtype=jnp.float32):
    conv_dim = cfg.d_inner + 2 * cfg.d_state
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, conv_dim), dtype),
        "h": jnp.zeros((batch, cfg.n_heads, cfg.d_state, cfg.head_dim),
                       jnp.float32),
    }


def step_mamba2(p: dict, x: jax.Array, state: dict, cfg: Mamba2Config):
    """One decode step.  x: (B, 1, d)."""
    di, n, h, pd = cfg.d_inner, cfg.d_state, cfg.n_heads, cfg.head_dim
    proj = x[:, 0] @ p["in_proj"]
    z, xbc, dt = jnp.split(proj, [di, 2 * di + 2 * n], axis=-1)
    conv_buf = jnp.concatenate([state["conv"], xbc[:, None]], axis=1)
    xbc = jax.nn.silu((conv_buf * p["conv_w"][None]).sum(axis=1)
                      + p["conv_b"])
    xin, bmat, cmat = jnp.split(xbc, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt + p["dt_bias"]).astype(jnp.float32)  # (B, H)
    a_head = -jnp.exp(p["a_log"].astype(jnp.float32))
    da = jnp.exp(dt * a_head[None])                      # (B, H)
    xh = xin.reshape(-1, h, pd).astype(jnp.float32)
    hst = da[..., None, None] * state["h"] + jnp.einsum(
        "bn,bhp,bh->bhnp", bmat.astype(jnp.float32), xh, dt)
    y = jnp.einsum("bn,bhnp->bhp", cmat.astype(jnp.float32), hst) \
        + p["d_skip"][None, :, None] * xh
    y = y.reshape(-1, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"])
    return (y @ p["out_proj"])[:, None], {"conv": conv_buf[:, 1:], "h": hst}
