"""Shared model layers: norms, RoPE, MLPs, embeddings, chunked CE loss.

Pure-JAX (no flax): parameters are nested dicts of arrays; every layer is a
pair of ``init_*(key, ...) -> params`` and a pure apply function.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------


def dense_init(key, in_dim: int, out_dim: int, dtype=jnp.float32):
    scale = 1.0 / math.sqrt(in_dim)
    return jax.random.uniform(key, (in_dim, out_dim), dtype, -scale, scale)


def embed_init(key, vocab: int, dim: int, dtype=jnp.float32):
    return jax.random.normal(key, (vocab, dim), dtype) * 0.02


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * weight.astype(jnp.float32)
            ).astype(dtype)


def layer_norm(x: jax.Array, weight: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = x.mean(axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(dtype)


# --------------------------------------------------------------------------
# rotary position embedding
# --------------------------------------------------------------------------


def rope_freqs(d_head: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2,
                                       dtype=jnp.float32) / d_head))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 10000.0) -> jax.Array:
    """x: (B, H, S, D) with positions (S,) or (B, S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    if angles.ndim == 2:          # (S, D/2) -> (1, 1, S, D/2)
        angles = angles[None, None]
    else:                         # (B, S, D/2) -> (B, 1, S, D/2)
        angles = angles[:, None]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                          axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, dim: int) -> jax.Array:
    """Whisper-style fixed sinusoidal embedding (S, D)."""
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    idx = jnp.arange(dim // 2, dtype=jnp.float32)[None, :]
    angles = pos / (10000.0 ** (2 * idx / dim))
    return jnp.concatenate([jnp.sin(angles), jnp.cos(angles)], axis=-1)


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------


def init_mlp(key, d_model: int, d_ff: int, gated: bool = True,
             dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], d_model, d_ff, dtype),
         "w_down": dense_init(ks[1], d_ff, d_model, dtype)}
    if gated:
        p["w_gate"] = dense_init(ks[2], d_model, d_ff, dtype)
    return p


def apply_mlp(p: dict, x: jax.Array, act: str = "silu") -> jax.Array:
    up = x @ p["w_up"]
    if "w_gate" in p:
        gate = x @ p["w_gate"]
        h = jax.nn.silu(gate) * up if act == "silu" else jax.nn.gelu(gate) * up
    else:
        h = jax.nn.gelu(up) if act == "gelu" else jax.nn.silu(up)
    return h @ p["w_down"]


# --------------------------------------------------------------------------
# chunked (vocab-safe) cross-entropy
# --------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("n_chunks", "z_weight"))
def chunked_cross_entropy(hidden: jax.Array, w_vocab: jax.Array,
                          labels: jax.Array, mask: jax.Array | None = None,
                          n_chunks: int = 8, z_weight: float = 0.0
                          ) -> jax.Array:
    """Mean CE of ``hidden @ w_vocab`` vs labels, scanning over token chunks
    so the full (tokens, vocab) logits tensor is never resident — the
    1000+-node posture for 200k vocabularies.  hidden: (T, d); labels: (T,).
    """
    t, d = hidden.shape
    pad = (-t) % n_chunks
    if pad:
        hidden = jnp.pad(hidden, ((0, pad), (0, 0)))
        labels = jnp.pad(labels, (0, pad))
        mask = jnp.pad(mask, (0, pad)) if mask is not None else \
            jnp.pad(jnp.ones((t,), jnp.float32), (0, pad))
    elif mask is None:
        mask = jnp.ones((t,), jnp.float32)
    tc = hidden.shape[0] // n_chunks
    hs = hidden.reshape(n_chunks, tc, d)
    ls = labels.reshape(n_chunks, tc)
    ms = mask.reshape(n_chunks, tc)

    def chunk_loss(carry, inp):
        h, lbl, m = inp
        logits = (h @ w_vocab).astype(jnp.float32)          # (tc, V)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lbl[:, None], axis=-1)[:, 0]
        nll = (lse - gold) * m
        z = (lse ** 2) * m * z_weight
        return carry + nll.sum() + z.sum(), None

    total, _ = jax.lax.scan(chunk_loss, jnp.float32(0.0), (hs, ls, ms))
    return total / jnp.maximum(mask.sum(), 1.0)


def causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array | None = None
                  ) -> jax.Array:
    """Depthwise causal conv over seq.  x: (B, L, C); w: (K, C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp, w[:, None, :],                    # (K, 1, C) kernel
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NHC", "HIO", "NHC"),
        feature_group_count=x.shape[-1])
    if b is not None:
        out = out + b
    return out
