"""Encoder–decoder model (whisper-tiny backbone).

The audio conv frontend is a STUB per spec: ``input_specs()`` provides
precomputed frame embeddings (B, S_audio, d); a learned projection stands in
for the conv stack.  Encoder = bidirectional attention + GELU MLP with
sinusoidal positions; decoder = causal self-attention + cross-attention +
MLP with learned positions.  LayerNorm throughout (whisper convention).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import attention as attn
from .layers import (apply_mlp, dense_init, embed_init, init_mlp, layer_norm,
                     sinusoidal_positions)
from .sharding_ctx import constrain
from .transformer import _cast_tree, _compute


def _acfg(cfg: ArchConfig, causal: bool) -> attn.AttnConfig:
    return attn.AttnConfig(
        d_model=cfg.d_model, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        d_head=cfg.head_dim, causal=causal, use_rope=False,
        qkv_bias=cfg.qkv_bias)


def _ln_init(d: int, dtype) -> dict:
    return {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def _ln(p: dict, x):
    return layer_norm(x, p["w"], p["b"])


def _enc_layer_init(key, cfg: ArchConfig, dtype) -> dict:
    ks = jax.random.split(key, 2)
    return {"ln1": _ln_init(cfg.d_model, dtype),
            "attn": attn.init_attention(ks[0], _acfg(cfg, False), dtype),
            "ln2": _ln_init(cfg.d_model, dtype),
            "mlp": init_mlp(ks[1], cfg.d_model, cfg.d_ff, gated=False,
                            dtype=dtype)}


def _dec_layer_init(key, cfg: ArchConfig, dtype) -> dict:
    ks = jax.random.split(key, 3)
    return {"ln1": _ln_init(cfg.d_model, dtype),
            "self_attn": attn.init_attention(ks[0], _acfg(cfg, True), dtype),
            "ln_x": _ln_init(cfg.d_model, dtype),
            "cross_attn": attn.init_cross_attention(ks[1], _acfg(cfg, False),
                                                    dtype),
            "ln2": _ln_init(cfg.d_model, dtype),
            "mlp": init_mlp(ks[2], cfg.d_model, cfg.d_ff, gated=False,
                            dtype=dtype)}


def init_params(key, cfg: ArchConfig) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    enc_keys = jax.random.split(ks[0], cfg.n_encoder_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    return {
        "frontend_proj": dense_init(ks[2], cfg.d_model, cfg.d_model, dtype),
        "enc_layers": jax.vmap(
            lambda k: _enc_layer_init(k, cfg, dtype))(enc_keys),
        "enc_ln": _ln_init(cfg.d_model, dtype),
        "embed": embed_init(ks[3], cfg.padded_vocab, cfg.d_model, dtype),
        "pos_embed": embed_init(ks[4], 8192, cfg.d_model, dtype),
        "dec_layers": jax.vmap(
            lambda k: _dec_layer_init(k, cfg, dtype))(dec_keys),
        "dec_ln": _ln_init(cfg.d_model, dtype),
    }


def encode(params: dict, frames: jax.Array, cfg: ArchConfig) -> jax.Array:
    """frames: (B, Se, d) stub embeddings -> encoder states (B, Se, d)."""
    x = _compute(frames, cfg) @ _compute(params["frontend_proj"], cfg)
    x = x + sinusoidal_positions(x.shape[1], cfg.d_model).astype(x.dtype)
    x = constrain(x, "hidden")
    acfg = _acfg(cfg, False)

    def body(x, scanned):
        lp = scanned
        x = x + attn.full(lp["attn"], _ln(lp["ln1"], x), acfg)
        x = x + apply_mlp(lp["mlp"], _ln(lp["ln2"], x), act="gelu")
        return constrain(x, "hidden"), None

    x, _ = jax.lax.scan(body, x, _cast_tree(params["enc_layers"], cfg))
    return _ln(_cast_tree(params["enc_ln"], cfg), x)


def decode_train(params: dict, tokens: jax.Array, enc: jax.Array,
                 cfg: ArchConfig) -> jax.Array:
    """Teacher-forced decoder pass -> hidden (B, Sd, d)."""
    x = jnp.take(params["embed"], tokens, axis=0)
    x = _compute(x, cfg)
    pos = jnp.arange(tokens.shape[1]) % params["pos_embed"].shape[0]
    x = x + _compute(jnp.take(params["pos_embed"], pos, axis=0), cfg)
    x = constrain(x, "hidden")
    sa, ca = _acfg(cfg, True), _acfg(cfg, False)

    def body(x, scanned):
        lp = scanned
        x = x + attn.full(lp["self_attn"], _ln(lp["ln1"], x), sa)
        k, v = attn.cross_kv(lp["cross_attn"], enc, ca)
        x = x + attn.cross_full(lp["cross_attn"], _ln(lp["ln_x"], x), k, v,
                                ca)
        x = x + apply_mlp(lp["mlp"], _ln(lp["ln2"], x), act="gelu")
        return constrain(x, "hidden"), None

    x, _ = jax.lax.scan(body, x, _cast_tree(params["dec_layers"], cfg))
    return _ln(_cast_tree(params["dec_ln"], cfg), x)


def train_loss(params: dict, batch: dict, cfg: ArchConfig,
               n_loss_chunks: int = 8) -> jax.Array:
    from .layers import chunked_cross_entropy
    enc = encode(params, batch["frontend_embeds"], cfg)
    hidden = decode_train(params, batch["tokens"], enc, cfg)
    b, s, d = hidden.shape
    w = _compute(params["embed"].T, cfg)        # tied head (whisper)
    mask = batch.get("loss_mask")
    mask = mask.reshape(-1).astype(jnp.float32) if mask is not None else None
    return chunked_cross_entropy(
        constrain(hidden.reshape(b * s, d), "logits_hidden"), w,
        batch["labels"].reshape(-1), mask, n_chunks=n_loss_chunks)


# --------------------------------------------------------------------------
# serving: prefill + one-token decode with self-KV + cross-KV caches
# --------------------------------------------------------------------------


def init_caches(cfg: ArchConfig, batch: int, max_len: int,
                dtype=None):
    dtype = jnp.dtype(cfg.cache_dtype) if dtype is None else dtype
    k, v = attn.init_cache(batch, _acfg(cfg, True), max_len, dtype)
    l = cfg.n_layers
    stack = lambda a: jnp.broadcast_to(a, (l,) + a.shape).copy()
    se = cfg.frontend_seq or 128
    cross = jnp.zeros((l, batch, cfg.n_kv_heads, se, cfg.head_dim), dtype)
    return {"kv": (stack(k), stack(v)), "cross_kv": (cross, cross)}


def prefill(params: dict, tokens: jax.Array, cfg: ArchConfig,
            frontend_embeds: jax.Array | None = None,
            max_len: int | None = None):
    max_len = max(max_len or tokens.shape[1], tokens.shape[1])
    enc = encode(params, frontend_embeds, cfg)
    x = jnp.take(params["embed"], tokens, axis=0)
    x = _compute(x, cfg)
    pos = jnp.arange(tokens.shape[1]) % params["pos_embed"].shape[0]
    x = x + _compute(jnp.take(params["pos_embed"], pos, axis=0), cfg)
    sa, ca = _acfg(cfg, True), _acfg(cfg, False)

    def body(x, scanned):
        lp = scanned
        h, (k, v) = attn.full(lp["self_attn"], _ln(lp["ln1"], x), sa,
                              return_cache=True)
        x = x + h
        ck, cv = attn.cross_kv(lp["cross_attn"], enc, ca)
        x = x + attn.cross_full(lp["cross_attn"], _ln(lp["ln_x"], x), ck, cv,
                                ca)
        x = x + apply_mlp(lp["mlp"], _ln(lp["ln2"], x), act="gelu")
        cdt = jnp.dtype(cfg.cache_dtype)
        pad = ((0, 0), (0, 0), (0, max_len - k.shape[2]), (0, 0))
        k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        return x, {"kv": (k.astype(cdt), v.astype(cdt)),
                   "cross_kv": (ck.astype(cdt), cv.astype(cdt))}

    x, caches = jax.lax.scan(body, x,
                             _cast_tree(params["dec_layers"], cfg))
    x = _ln(_cast_tree(params["dec_ln"], cfg), x)
    w = _compute(params["embed"].T, cfg)
    logits = (x[:, -1] @ w).astype(jnp.float32)
    return logits, dict(caches)


def decode_step(params: dict, caches: dict, token: jax.Array,
                pos: jax.Array, cfg: ArchConfig):
    x = jnp.take(params["embed"], token, axis=0)
    x = _compute(x, cfg)
    pe = params["pos_embed"]
    x = x + _compute(jnp.take(pe, pos[:, None] % pe.shape[0], axis=0), cfg)
    sa, ca = _acfg(cfg, True), _acfg(cfg, False)

    def body(carry, scanned):
        x, = carry
        lp = scanned["params"]
        ck, cv = scanned["kv"]
        h, ck, cv = attn.decode(lp["self_attn"], _ln(lp["ln1"], x), ck, cv,
                                pos, sa)
        x = x + h
        xk, xv = scanned["cross_kv"]
        x = x + attn.cross_decode(lp["cross_attn"], _ln(lp["ln_x"], x),
                                  xk, xv, ca)
        x = x + apply_mlp(lp["mlp"], _ln(lp["ln2"], x), act="gelu")
        return (x,), {"kv": (ck, cv), "cross_kv": (xk, xv)}

    (x,), new_caches = jax.lax.scan(
        body, (x,), {"params": _cast_tree(params["dec_layers"], cfg),
                     "kv": caches["kv"],
                     "cross_kv": caches["cross_kv"]})
    x = _ln(_cast_tree(params["dec_ln"], cfg), x)
    w = _compute(params["embed"].T, cfg)
    logits = (x[:, 0] @ w).astype(jnp.float32)
    return logits, dict(new_caches)
