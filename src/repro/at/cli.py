"""``python -m repro.at`` — the tuning-fleet CLI.

Operate on tuning DBs from the shell: inspect what a workdir has tuned,
find what a machine still has to tune, and move fingerprint-keyed winners
between deployments (the MITuna shape: tune anywhere, promote winners to
a golden DB, warm-load everywhere).

=======  ==============================================================
command  semantics
=======  ==============================================================
list     enumerate records grouped per phase and mesh suffix (region
         names parsed through ``tuning.dynamic``; foreign machines
         included with ``--machine all``)
stale    (phase, region) pairs some machine has tuned but the target
         fingerprint has not — the tuning jobs to dispatch
export   dump records to a golden DB file (format by extension:
         ``.sqlite``/``.db`` → sqlite, else JSONL)
merge    import a golden DB into a workdir's store (``--backend`` picks
         jsonl/sqlite; collisions resolve per ``--prefer``)
promote  merge a workdir's winners *into* an existing golden DB,
         keeping the better-cost record per key
=======  ==============================================================

Examples::

    python -m repro.at list --workdir /srv/at --machine all
    python -m repro.at export --workdir /srv/at --out golden.jsonl
    python -m repro.at merge --workdir /tmp/fresh --db golden.jsonl \\
        --backend sqlite
    python -m repro.at promote --workdir /srv/at --db /fleet/golden.sqlite
    python -m repro.at stale --workdir /srv/at --fail-on-stale
"""
from __future__ import annotations

import argparse
import os
from typing import Iterable

from .records import (machine_fingerprint, open_record_store, prefer_incoming,
                      read_records_file, write_records_file)


def _describe(name: str) -> dict | None:
    # lazy: tuning.dynamic pulls the serving stack (jax) in; the pure
    # record operations (export/merge) must not pay for that
    try:
        from ..tuning.dynamic import describe_region
    except Exception:
        return None
    return describe_region(name)


def _open_store(args: argparse.Namespace):
    machine = getattr(args, "machine", None)
    return open_record_store(args.workdir, backend=args.backend,
                             machine=None if machine == "all" else machine)


def _records_of(args: argparse.Namespace) -> Iterable:
    if getattr(args, "db", None) and not os.path.isdir(args.db):
        return read_records_file(args.db)
    return _open_store(args).records()


# --------------------------------------------------------------------------
# commands
# --------------------------------------------------------------------------

def cmd_list(args: argparse.Namespace) -> int:
    recs = list(_records_of(args))
    machine = args.machine or machine_fingerprint()
    if machine != "all":
        recs = [r for r in recs if r.machine == machine]
    if args.phase:
        recs = [r for r in recs if r.phase == args.phase]
    if not recs:
        print("no records")
        return 0

    def group(rec) -> tuple:
        d = _describe(rec.region)
        return (rec.machine, rec.phase, d["mesh"] if d else "")

    by_group: dict[tuple, list] = {}
    for r in recs:
        by_group.setdefault(group(r), []).append(r)
    for (m, phase, mesh), rows in sorted(by_group.items()):
        suffix = f" · mesh {mesh}" if mesh else ""
        print(f"[{m} · {phase}{suffix}] {len(rows)} record(s)")
        for r in sorted(rows, key=lambda r: (r.region, str(r.bp))):
            d = _describe(r.region)
            kind = f" kind={d['kind']}" if d else ""
            bp = f" bp={r.bp}" if r.bp else ""
            cost = f" cost={r.cost:.6g}" if r.cost is not None else ""
            print(f"  {r.region}{kind}{bp} pp={r.pp}{cost}")
    print(f"{len(recs)} record(s) total")
    return 0


def cmd_stale(args: argparse.Namespace) -> int:
    recs = list(_records_of(args))
    machine = args.machine or machine_fingerprint()
    known = {(r.phase, r.region) for r in recs}
    have = {(r.phase, r.region) for r in recs if r.machine == machine}
    stale = sorted(known - have)
    if args.phase:
        stale = [(p, r) for p, r in stale if p == args.phase]
    for phase, region in stale:
        d = _describe(region)
        mesh = f" mesh={d['mesh']}" if d and d["mesh"] else ""
        print(f"stale: {phase} {region}{mesh}")
    print(f"{len(stale)} stale region(s) for {machine} "
          f"({len(have)} tuned, {len(known)} known fleet-wide)")
    return 1 if stale and args.fail_on_stale else 0


def cmd_export(args: argparse.Namespace) -> int:
    store = _open_store(args)
    n = store.export(args.out, machine=args.machine or "all",
                     phase=args.phase)
    print(f"exported {n} record(s) -> {args.out}")
    return 0


def cmd_merge(args: argparse.Namespace) -> int:
    store = _open_store(args)
    stats = store.merge_records(read_records_file(args.db),
                                prefer=args.prefer)
    print(f"merged {args.db} -> {store.workdir} [{store.backend_name}]: "
          f"{stats['added']} added, {stats['updated']} updated, "
          f"{stats['kept']} kept")
    return 0


def cmd_promote(args: argparse.Namespace) -> int:
    recs = list(_open_store(args).records())
    if args.phase:
        recs = [r for r in recs if r.phase == args.phase]
    existing = read_records_file(args.db) if os.path.exists(args.db) else []
    index = {r.key: r for r in existing}
    added = updated = kept = 0
    for rec in recs:
        cur = index.get(rec.key)
        if cur is None:
            index[rec.key] = rec
            added += 1
        elif prefer_incoming(cur, rec, args.prefer):
            index[rec.key] = rec
            updated += 1
        else:
            kept += 1
    write_records_file(args.db, list(index.values()))
    print(f"promoted {store_desc(args)} -> {args.db}: {added} added, "
          f"{updated} updated, {kept} kept ({len(index)} golden)")
    return 0


def store_desc(args: argparse.Namespace) -> str:
    return f"{args.workdir} [{args.backend}]"


# --------------------------------------------------------------------------
# entry point
# --------------------------------------------------------------------------

def _add_common(p: argparse.ArgumentParser, *, machine_default_all=False):
    p.add_argument("--workdir", default=".",
                   help="tuning-DB workdir (default: cwd)")
    p.add_argument("--backend", default="jsonl",
                   help="record backend for --workdir (jsonl | sqlite)")
    p.add_argument("--machine", default="all" if machine_default_all
                   else None,
                   help="machine fingerprint to scope to ('all' = every "
                        "machine; default: %(default)s, None = live "
                        "fingerprint)")
    p.add_argument("--phase", default=None,
                   help="restrict to one phase (install | static | "
                        "dynamic)")


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.at",
        description="tuning-DB fleet operations (list / stale / export / "
                    "merge / promote)")
    sub = ap.add_subparsers(dest="command", required=True)

    p = sub.add_parser("list", help="enumerate records per phase and mesh")
    _add_common(p, machine_default_all=True)
    p.add_argument("--db", default=None,
                   help="read a golden DB file instead of --workdir")
    p.set_defaults(fn=cmd_list)

    p = sub.add_parser("stale", help="regions tuned elsewhere but not "
                                     "for this fingerprint")
    _add_common(p)
    p.add_argument("--db", default=None,
                   help="read a golden DB file instead of --workdir")
    p.add_argument("--fail-on-stale", action="store_true",
                   help="exit 1 when stale regions exist (CI gating)")
    p.set_defaults(fn=cmd_stale)

    p = sub.add_parser("export", help="dump records to a golden DB file")
    _add_common(p, machine_default_all=True)
    p.add_argument("--out", required=True,
                   help="golden DB path (.sqlite/.db → sqlite, else "
                        "JSONL)")
    p.set_defaults(fn=cmd_export)

    p = sub.add_parser("merge", help="import a golden DB into a workdir")
    _add_common(p)
    p.add_argument("--db", required=True, help="golden DB file to import")
    p.add_argument("--prefer", default="better-cost",
                   choices=("better-cost", "incoming", "existing"),
                   help="key-collision policy (default: %(default)s)")
    p.set_defaults(fn=cmd_merge)

    p = sub.add_parser("promote", help="merge a workdir's winners into a "
                                       "golden DB (better cost wins)")
    _add_common(p, machine_default_all=True)
    p.add_argument("--db", required=True,
                   help="golden DB file to promote into (created if "
                        "missing)")
    p.add_argument("--prefer", default="better-cost",
                   choices=("better-cost", "incoming", "existing"),
                   help="key-collision policy (default: %(default)s)")
    p.set_defaults(fn=cmd_promote)
    return ap


def main(argv: list[str] | None = None) -> int:
    args = build_parser().parse_args(argv)
    return args.fn(args)
